"""Convergence comparison — paper Figures 1-2 / Tables 1-2 (metric columns).

Trains (a) the rank-4 CNN on synthetic prototype images and (b) a small
Transformer LM on the structured synthetic stream, with all five
optimizers, and reports the final losses. The paper's claim: SMMF is
competitive with Adam/Adafactor/SM3/CAME at a fraction of the memory.

The LM table additionally runs quantized-state SMMF (``quant=int8``/
``fp8``, the qstate codec) and ASSERTS final-loss parity with f32 SMMF
within 5% — the convergence half of the quantized-state acceptance
(the memory half lives in ``benchmarks/memory_table.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticImageStream, SyntheticLMStream
from repro.launch.steps import make_train_step
from repro.models import cnn_loss, init_cnn, init_lm
from repro.models.config import ModelConfig
from repro.optim import OptimizerSpec, Partition, build_optimizer
from repro.optim.base import apply_updates
from repro.utils.tree import tree_bytes

# final-loss parity tolerance for every assertion below (quantized-vs-f32
# and zoo-family-vs-dense-reference alike)
PARITY_TOL = 0.05


def _opts(lr, family, quant=False, zoo=False):
    gamma = -0.5 if family == "cnn" else -0.8
    out = {
        "adam": build_optimizer(OptimizerSpec(family="adam", hyperparams={"lr": lr})),
        "adafactor": build_optimizer(OptimizerSpec(family="adafactor", hyperparams={"lr": lr})),
        "sm3": build_optimizer(OptimizerSpec(family="sm3", hyperparams={"lr": lr})),
        "came": build_optimizer(OptimizerSpec(family="came", hyperparams={"lr": lr})),
        "smmf": build_optimizer(OptimizerSpec(family="smmf",
                                              hyperparams={"lr": lr, "decay_rate": gamma})),
    }
    if quant:
        for mode in ("int8", "fp8"):
            out[f"smmf({mode})"] = build_optimizer(OptimizerSpec(
                family="smmf",
                hyperparams={"lr": lr, "decay_rate": gamma, "quant": mode}))
    if zoo:
        # the optimizer zoo's parity rows: each new family vs its dense
        # reference (asserted in main) — adapprox vs adam (rank-k second
        # moment), hfac vs adafactor (factorized stats), and the
        # AdaPM-style partial-momentum recipe vs full-momentum smmf
        out["adapprox(r2)"] = build_optimizer(OptimizerSpec(
            family="adapprox",
            hyperparams={"lr": lr, "decay_rate": gamma, "rank": 2}))
        out["hfac"] = build_optimizer(OptimizerSpec(
            family="hfac", hyperparams={"lr": lr}))
        out["adapm"] = build_optimizer(OptimizerSpec(
            family="smmf", hyperparams={"lr": lr, "decay_rate": gamma},
            partitions=(Partition(name="nomom", match=r"attn/|ffn/",
                                  hyperparams={"beta1": None}),)))
    return out


def bench_cnn(steps=60, lr=3e-3) -> dict:
    stream = SyntheticImageStream(num_classes=10, global_batch=32)
    out = {}
    for name, opt in _opts(lr, "cnn").items():
        params = init_cnn(jax.random.PRNGKey(0), 10, width=8, depth=2)
        state = opt.init(params)

        @jax.jit
        def step(p, s, batch):
            (l, m), g = jax.value_and_grad(cnn_loss, has_aux=True)(p, batch)
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s, m

        hist = []
        for t in range(steps):
            b = stream.batch(t)
            b = {"images": jnp.asarray(b["images"]), "labels": jnp.asarray(b["labels"])}
            params, state, m = step(params, state, b)
            hist.append(float(m["loss"]))
        out[name] = {
            "final_loss": float(np.mean(hist[-10:])),
            "opt_bytes": tree_bytes(state),
        }
    return out


def bench_lm(steps=60, lr=1e-3) -> dict:
    cfg = ModelConfig("bench-lm", "dense", 2, 64, 4, 128, 512, n_kv_heads=2, dtype="float32")
    stream = SyntheticLMStream(cfg, 8, 64, seed=0)
    out = {}
    for name, opt in _opts(lr, "transformer", quant=True, zoo=True).items():
        params = init_lm(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt))
        hist = []
        for t in range(steps):
            b = jax.tree.map(jnp.asarray, stream.batch(t))
            params, state, m = step(params, state, b)
            hist.append(float(m["loss"]))
        out[name] = {
            "final_loss": float(np.mean(hist[-10:])),
            "opt_bytes": tree_bytes(state),
        }
    return out


def main() -> None:
    print("== CNN (rank-4 momenta, gamma=-0.5) ==")
    res = bench_cnn()
    base = res["adam"]["final_loss"]
    for k, v in res.items():
        print(f"{k:10s} loss {v['final_loss']:7.4f} (adam {base:.4f})  opt-state {v['opt_bytes']/1024:8.1f}KiB")
    print("\n== Transformer LM (gamma=-0.8, + quantized-state parity) ==")
    res = bench_lm()
    base = res["adam"]["final_loss"]
    for k, v in res.items():
        print(f"{k:10s} loss {v['final_loss']:7.4f} (adam {base:.4f})  opt-state {v['opt_bytes']/1024:8.1f}KiB")
    f32 = res["smmf"]["final_loss"]
    for mode in ("int8", "fp8"):
        q = res[f"smmf({mode})"]["final_loss"]
        assert abs(q - f32) <= PARITY_TOL * abs(f32), (
            f"quantized-vs-f32 parity broken: smmf({mode}) {q:.4f} vs "
            f"smmf {f32:.4f}")
    print("quantized parity OK: smmf(int8/fp8) final losses within 5% of f32 smmf")
    # optimizer-zoo parity: each new family vs its dense reference
    for name, ref in (("adapprox(r2)", "adam"), ("hfac", "adafactor"),
                      ("adapm", "smmf")):
        z, r = res[name]["final_loss"], res[ref]["final_loss"]
        assert abs(z - r) <= PARITY_TOL * abs(r), (
            f"zoo parity broken: {name} {z:.4f} vs {ref} {r:.4f}")
    print("zoo parity OK: adapprox/hfac/adapm final losses within 5% of "
          "their dense references (adam/adafactor/smmf)")


if __name__ == "__main__":
    main()
