"""Optimizer-memory table — paper Tables 1-4 (memory columns).

For each model (CNN high-rank case, Transformer-base/big, and the assigned
archs' smoke variants + analytic full variants), reports persistent
optimizer state bytes for Adam / Adafactor / SM3 / CAME / SMMF and the
reduction ratios the paper claims (up to ~96% vs the memory-efficient
family, tens-of-x vs Adam).

Full-size configs are measured ANALYTICALLY via jax.eval_shape over
abstract params (no allocation), exactly matching what the optimizer would
hold in memory.
"""

from __future__ import annotations

import jax

from repro.configs import ARCH_IDS, PAPER_IDS, get_config, smoke_config
from repro.launch import specs as S
from repro.models import init_cnn
from repro.optim import (
    OptimizerSpec,
    Partition,
    build_optimizer,
    state_bytes_by_group,
)
from repro.utils.tree import tree_bytes

OPTS = {
    name: (lambda n=name: build_optimizer(OptimizerSpec(family=n,
                                                        hyperparams={"lr": 1e-3})))
    for name in ("adam", "adafactor", "sm3", "came", "smmf")
}

# mixed partition-aware spec tracked in the perf trajectory: SMMF on the
# matrices, plain Adam on norms/biases/scales (the per-group column shows
# where the state bytes live)
MIXED_SPEC = OptimizerSpec(
    family="smmf", hyperparams={"lr": 1e-3},
    partitions=(Partition(name="norms", match=r"norm|scale$|bias$|lam$",
                          family="adam"),),
)


def _measure(params_sds) -> dict[str, int]:
    return {name: tree_bytes(jax.eval_shape(mk().init, params_sds)) for name, mk in OPTS.items()}


def rows():
    out = []
    # CNN (the paper's rank-4 momentum case)
    cnn = jax.eval_shape(lambda: init_cnn(jax.random.PRNGKey(0), 100, width=32, depth=3))
    out.append(("cnn_small(rank-4)", tree_bytes(cnn), _measure(cnn)))
    for arch in PAPER_IDS + ARCH_IDS:
        cfg = get_config(arch)
        sds = S.params_specs(cfg)
        out.append((arch, tree_bytes(sds), _measure(sds)))
    return out


def group_rows():
    """Per-group state bytes: the mixed smmf+adam spec on every arch, plus a
    LoRA-style frozen-base row (base frozen, rank-8 adapters on SMMF) —
    the frozen group's 0 bytes IS the LoRA memory win."""
    out = []
    for arch in PAPER_IDS + ARCH_IDS:
        sds = S.params_specs(get_config(arch))
        opt = build_optimizer(MIXED_SPEC)
        out.append((f"{arch} (mixed)", state_bytes_by_group(opt, sds)))
    # LoRA row: frozen base + adapters, one spec-built optimizer
    from repro.models import init_lm
    from repro.train.lora import lora_init

    cfg = smoke_config("transformer_base")
    base = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    adapters = jax.eval_shape(lambda: lora_init(jax.random.PRNGKey(1),
                                                base, rank=8))
    spec = OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3},
        partitions=(Partition(name="frozen_base", match=r"^base(/|$)",
                              freeze=True),),
    )
    tree = {"base": base, "lora": adapters}
    out.append(("transformer_base lora", state_bytes_by_group(build_optimizer(spec), tree)))
    return out


def main() -> None:
    print(f"{'model':22s} {'params':>10s} | " + " ".join(f"{n:>12s}" for n in OPTS)
          + " |  smmf/adam  smmf/best-eff")
    for name, pbytes, sizes in rows():
        best_eff = min(sizes["adafactor"], sizes["sm3"], sizes["came"])
        print(
            f"{name:22s} {pbytes/2**20:9.1f}M | "
            + " ".join(f"{sizes[n]/2**20:11.2f}M" for n in OPTS)
            + f" | {sizes['smmf']/sizes['adam']:9.4f} {sizes['smmf']/best_eff:12.4f}"
        )
    print("\n(ratios: lower is better; paper claims up to 0.04 = 96% reduction "
          "vs the memory-efficient family on high-rank/transformer models)")

    print(f"\n{'spec (per-group state bytes)':28s}  groups")
    for name, by_group in group_rows():
        cells = "  ".join(f"{g}={b/2**20:.3f}M" for g, b in sorted(by_group.items()))
        print(f"{name:28s}  {cells}")
    print("\n(frozen groups hold exactly 0 bytes — the LoRA frozen-base win; "
          "per-group numbers are what rules.opt_state_shardings shards)")


if __name__ == "__main__":
    main()
