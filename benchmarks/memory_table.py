"""Optimizer-memory table — paper Tables 1-4 (memory columns).

For each model (CNN high-rank case, Transformer-base/big, and the assigned
archs' smoke variants + analytic full variants), reports persistent
optimizer state bytes for Adam / Adafactor / SM3 / CAME / SMMF and the
reduction ratios the paper claims (up to ~96% vs the memory-efficient
family, tens-of-x vs Adam).

A third section prices the **qstate codec** (``repro.optim.qstate``,
``docs/memory.md``): total AND per-device (4-way fsdp) state bytes for
f32 vs int8 vs fp8 SMMF on transformer_base, momentum and momentum-free.
Acceptance (asserted every run): ``smmf(beta1=None), quant=int8`` holds
<= 30% of its f32 twin per device, scales included. (The momentum variant
is honestly reported too — its packed sign matrix is already 1
bit/element and dominates, so quantization only trims the factor
vectors.)

A fourth section prices the **host-offload tier** (``--offload cold``,
``repro.optim.offload``): per-device device-resident state bytes with the
quantized buckets parked on pinned host vs the device-resident qstate
baseline. Acceptance (asserted every run, gated by
``tools/bench_compare.py``): offload-on device bytes strictly below the
baseline.

Full-size configs are measured ANALYTICALLY via jax.eval_shape over
abstract params (no allocation), exactly matching what the optimizer would
hold in memory. ``main(json_path=...)`` additionally emits the whole table
as a machine-readable record (``benchmarks/run.py`` writes
``BENCH_opt_memory.json`` for the CI perf-trajectory artifact).
"""

from __future__ import annotations

import json
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, PAPER_IDS, get_config, smoke_config
from repro.launch import specs as S
from repro.models import init_cnn
from repro.optim import (
    OptimizerSpec,
    Partition,
    build_optimizer,
    state_bytes_by_group,
)
from repro.utils.tree import tree_bytes

# acceptance bound for the quantized momentum-free row (scales included)
QUANT_ACCEPT_FRACTION = 0.30
# acceptance bound for fully-quantized Adafactor/CAME (momentum slot now
# rides blockwise sub-row scales, so every full-size f32 slot is covered;
# payloads are 1/4 of f32, scales add ~1/128 per momentum block)
MOMENTUM_QUANT_ACCEPT_FRACTION = 0.30

OPTS = {
    name: (lambda n=name: build_optimizer(OptimizerSpec(family=n,
                                                        hyperparams={"lr": 1e-3})))
    for name in ("adam", "adafactor", "sm3", "came", "smmf",
                 "adapprox", "hfac")
}

# mixed partition-aware spec tracked in the perf trajectory: SMMF on the
# matrices, plain Adam on norms/biases/scales (the per-group column shows
# where the state bytes live)
MIXED_SPEC = OptimizerSpec(
    family="smmf", hyperparams={"lr": 1e-3},
    partitions=(Partition(name="norms", match=r"norm|scale$|bias$|lam$",
                          family="adam"),),
)


def _measure(params_sds) -> dict[str, int]:
    return {name: tree_bytes(jax.eval_shape(mk().init, params_sds)) for name, mk in OPTS.items()}


def rows():
    out = []
    # CNN (the paper's rank-4 momentum case)
    cnn = jax.eval_shape(lambda: init_cnn(jax.random.PRNGKey(0), 100, width=32, depth=3))
    out.append(("cnn_small(rank-4)", tree_bytes(cnn), _measure(cnn)))
    for arch in PAPER_IDS + ARCH_IDS:
        cfg = get_config(arch)
        sds = S.params_specs(cfg)
        out.append((arch, tree_bytes(sds), _measure(sds)))
    return out


def group_rows():
    """Per-group state bytes: the mixed smmf+adam spec on every arch, plus a
    LoRA-style frozen-base row (base frozen, rank-8 adapters on SMMF) —
    the frozen group's 0 bytes IS the LoRA memory win."""
    out = []
    for arch in PAPER_IDS + ARCH_IDS:
        sds = S.params_specs(get_config(arch))
        opt = build_optimizer(MIXED_SPEC)
        out.append((f"{arch} (mixed)", state_bytes_by_group(opt, sds)))
    # LoRA row: frozen base + adapters, one spec-built optimizer
    from repro.models import init_lm
    from repro.train.lora import lora_init

    cfg = smoke_config("transformer_base")
    base = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    adapters = jax.eval_shape(lambda: lora_init(jax.random.PRNGKey(1),
                                                base, rank=8))
    spec = OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3},
        partitions=(Partition(name="frozen_base", match=r"^base(/|$)",
                              freeze=True),),
    )
    tree = {"base": base, "lora": adapters}
    out.append(("transformer_base lora", state_bytes_by_group(build_optimizer(spec), tree)))
    return out


def quant_rows(arch: str = "transformer_base"):
    """The qstate pricing grid for one arch: (variant, quant) -> total and
    per-device (4-way fsdp) state bytes. Spec math only (AbstractMesh)."""
    from jax.sharding import AbstractMesh

    from repro.distributed import rules

    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    mesh = AbstractMesh((("data", 4),))
    out = []
    for label, beta1 in (("smmf", 0.9), ("smmf(beta1=None)", None)):
        for quant in (None, "int8", "fp8"):
            hp = {"lr": 1e-3, "decay_rate": -0.8, "beta1": beta1}
            if quant:
                hp["quant"] = quant
            opt = build_optimizer(OptimizerSpec(family="smmf", hyperparams=hp))
            state_shape = jax.eval_shape(opt.init, psds)
            sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
            out.append({
                "variant": label, "quant": quant or "f32",
                "total": tree_bytes(state_shape),
                "per_device": rules.sharded_state_bytes(sh, state_shape),
            })
    # Adafactor/CAME under full quantization: the momentum slot (the one
    # remaining full-size f32 slot pre-blockwise-scales) now quantizes with
    # sub-row block scales, so int8 covers the whole state tuple
    for fam in ("adafactor", "came"):
        for quant in (None, "int8"):
            hp = {"lr": 1e-3}
            if quant:
                hp["quant"] = quant
            opt = build_optimizer(OptimizerSpec(family=fam, hyperparams=hp))
            state_shape = jax.eval_shape(opt.init, psds)
            sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
            out.append({
                "variant": fam, "quant": quant or "f32",
                "total": tree_bytes(state_shape),
                "per_device": rules.sharded_state_bytes(sh, state_shape),
            })
    return out


def offload_rows(arch: str = "transformer_base"):
    """The host-offload tier's device-HBM claim on one arch (4-way fsdp):
    per-device **device-resident** optimizer-state bytes with
    ``offload="cold"`` vs the device-resident qstate baseline, for the
    momentum and momentum-free quantized SMMF variants. Analytic spec math
    (``repro.optim.offload.state_bytes_split`` with per-leaf shard shapes),
    so the numbers hold on any backend."""
    from jax.sharding import AbstractMesh

    from repro.distributed import rules
    from repro.optim import offload

    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    mesh = AbstractMesh((("data", 4),))
    out = []
    for label, beta1 in (("smmf", 0.9), ("smmf(beta1=None)", None)):
        hp = {"lr": 1e-3, "decay_rate": -0.8, "beta1": beta1, "quant": "int8"}
        opt = build_optimizer(OptimizerSpec(family="smmf", hyperparams=hp))
        engine = opt.plan(psds)
        state_shape = jax.eval_shape(opt.init, psds)
        sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
        for mode in (None, "cold"):
            split = offload.state_bytes_split(engine, state_shape, mode,
                                              shardings=sh)
            out.append({"variant": label, "quant": "int8",
                        "offload": mode or "none",
                        "per_device_device_bytes": split["device"],
                        "per_device_host_bytes": split["host"]})
    return out


def main(json_path: str | Path | None = None) -> dict:
    """Print all four memory tables, assert the qstate and offload
    acceptance bounds, and return (optionally write) the machine-readable
    record."""
    rec: dict = {"archs": {}, "groups": {}, "qstate": [], "offload": []}
    print(f"{'model':22s} {'params':>10s} | " + " ".join(f"{n:>12s}" for n in OPTS)
          + " |  smmf/adam  smmf/best-eff")
    for name, pbytes, sizes in rows():
        best_eff = min(sizes["adafactor"], sizes["sm3"], sizes["came"])
        rec["archs"][name] = {"param_bytes": pbytes, **sizes}
        print(
            f"{name:22s} {pbytes/2**20:9.1f}M | "
            + " ".join(f"{sizes[n]/2**20:11.2f}M" for n in OPTS)
            + f" | {sizes['smmf']/sizes['adam']:9.4f} {sizes['smmf']/best_eff:12.4f}"
        )
    print("\n(ratios: lower is better; paper claims up to 0.04 = 96% reduction "
          "vs the memory-efficient family on high-rank/transformer models)")

    print(f"\n{'spec (per-group state bytes)':28s}  groups")
    for name, by_group in group_rows():
        rec["groups"][name] = dict(by_group)
        cells = "  ".join(f"{g}={b/2**20:.3f}M" for g, b in sorted(by_group.items()))
        print(f"{name:28s}  {cells}")
    print("\n(frozen groups hold exactly 0 bytes — the LoRA frozen-base win; "
          "per-group numbers are what rules.opt_state_shardings shards)")

    print(f"\nquantized state (qstate codec), transformer_base, 4-way fsdp:")
    print(f"{'variant':20s} {'quant':>5s} {'total MB':>9s} {'per-dev MB':>11s} "
          f"{'vs f32':>7s}")
    base = {}
    frac_accept = None
    mom_frac: dict = {}
    for row in quant_rows():
        rec["qstate"].append(row)
        key = row["variant"]
        if row["quant"] == "f32":
            base[key] = row["per_device"]
        frac = row["per_device"] / base[key]
        if key == "smmf(beta1=None)" and row["quant"] == "int8":
            frac_accept = frac
        if key in ("adafactor", "came") and row["quant"] == "int8":
            mom_frac[key] = frac
        print(f"{key:20s} {row['quant']:>5s} {row['total']/2**20:9.3f} "
              f"{row['per_device']/2**20:11.3f} {frac:6.1%}")
    assert frac_accept is not None and frac_accept <= QUANT_ACCEPT_FRACTION, (
        f"qstate acceptance: smmf(beta1=None),quant=int8 per-device bytes "
        f"are {frac_accept:.1%} of f32 (bound {QUANT_ACCEPT_FRACTION:.0%})")
    print(f"\nqstate acceptance OK: smmf(beta1=None),quant=int8 = "
          f"{frac_accept:.1%} of f32 (<= {QUANT_ACCEPT_FRACTION:.0%}, scales "
          f"included; the momentum variant is sign-bound — docs/memory.md)")
    # full-size momentum on blockwise sub-row scales: with the last f32
    # slot quantized, Adafactor/CAME int8 must land near the 1-byte payload
    # ratio (scales included) — the carried-forward ROADMAP follow-up
    for fam in ("adafactor", "came"):
        assert fam in mom_frac and \
            mom_frac[fam] <= MOMENTUM_QUANT_ACCEPT_FRACTION, (
                f"momentum-quant acceptance: {fam},quant=int8 per-device "
                f"bytes are {mom_frac.get(fam, 1.0):.1%} of f32 "
                f"(bound {MOMENTUM_QUANT_ACCEPT_FRACTION:.0%})")
    print(f"momentum-quant acceptance OK: adafactor/came int8 = "
          + "/".join(f"{mom_frac[f]:.1%}" for f in ("adafactor", "came"))
          + f" of f32 (<= {MOMENTUM_QUANT_ACCEPT_FRACTION:.0%}; the "
          f"momentum slot rides blockwise sub-row scales)")

    print(f"\nhost-offload tier (--offload cold), transformer_base int8, "
          f"4-way fsdp, per device:")
    print(f"{'variant':20s} {'offload':>7s} {'dev MB':>8s} {'host MB':>8s}")
    dev_base: dict = {}
    for row in offload_rows():
        rec["offload"].append(row)
        key = row["variant"]
        if row["offload"] == "none":
            dev_base[key] = row["per_device_device_bytes"]
        else:
            # the offload acceptance claim, asserted every run (and gated
            # in CI by tools/bench_compare.py): cold offload strictly
            # reduces per-device device-resident state below the
            # device-resident qstate baseline
            assert row["per_device_device_bytes"] < dev_base[key], (
                f"offload acceptance: {key} device bytes "
                f"{row['per_device_device_bytes']} not below baseline "
                f"{dev_base[key]}")
        print(f"{key:20s} {row['offload']:>7s} "
              f"{row['per_device_device_bytes']/2**20:8.3f} "
              f"{row['per_device_host_bytes']/2**20:8.3f}")
    print("(cold = quantized buckets park on pinned host; device bytes are "
          "the HBM the optimizer still holds — repro.optim.offload)")

    if json_path is not None:
        Path(json_path).parent.mkdir(parents=True, exist_ok=True)
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"[memory_table] wrote {json_path}")
    return rec


if __name__ == "__main__":
    main()
