"""Optimizer-memory table — paper Tables 1-4 (memory columns).

For each model (CNN high-rank case, Transformer-base/big, and the assigned
archs' smoke variants + analytic full variants), reports persistent
optimizer state bytes for Adam / Adafactor / SM3 / CAME / SMMF and the
reduction ratios the paper claims (up to ~96% vs the memory-efficient
family, tens-of-x vs Adam).

Full-size configs are measured ANALYTICALLY via jax.eval_shape over
abstract params (no allocation), exactly matching what the optimizer would
hold in memory.
"""

from __future__ import annotations

import jax

from repro.configs import ARCH_IDS, PAPER_IDS, get_config, smoke_config
from repro.core.smmf import smmf
from repro.launch import specs as S
from repro.models import init_cnn
from repro.optim import adafactor, adam, came, sm3
from repro.utils.tree import tree_bytes

OPTS = {
    "adam": lambda: adam(1e-3),
    "adafactor": lambda: adafactor(1e-3),
    "sm3": lambda: sm3(1e-3),
    "came": lambda: came(1e-3),
    "smmf": lambda: smmf(1e-3),
}


def _measure(params_sds) -> dict[str, int]:
    return {name: tree_bytes(jax.eval_shape(mk().init, params_sds)) for name, mk in OPTS.items()}


def rows():
    out = []
    # CNN (the paper's rank-4 momentum case)
    cnn = jax.eval_shape(lambda: init_cnn(jax.random.PRNGKey(0), 100, width=32, depth=3))
    out.append(("cnn_small(rank-4)", tree_bytes(cnn), _measure(cnn)))
    for arch in PAPER_IDS + ARCH_IDS:
        cfg = get_config(arch)
        sds = S.params_specs(cfg)
        out.append((arch, tree_bytes(sds), _measure(sds)))
    return out


def main() -> None:
    print(f"{'model':22s} {'params':>10s} | " + " ".join(f"{n:>12s}" for n in OPTS)
          + " |  smmf/adam  smmf/best-eff")
    for name, pbytes, sizes in rows():
        best_eff = min(sizes["adafactor"], sizes["sm3"], sizes["came"])
        print(
            f"{name:22s} {pbytes/2**20:9.1f}M | "
            + " ".join(f"{sizes[n]/2**20:11.2f}M" for n in OPTS)
            + f" | {sizes['smmf']/sizes['adam']:9.4f} {sizes['smmf']/best_eff:12.4f}"
        )
    print("\n(ratios: lower is better; paper claims up to 0.04 = 96% reduction "
          "vs the memory-efficient family on high-rank/transformer models)")


if __name__ == "__main__":
    main()
