"""Per-device optimizer-state bytes under multi-axis bucket-stack sharding.

The SMMF paper's headline is optimizer-*memory*: up to 96% less state than
the Adafactor/CAME/SM3 family. That claim only survives multi-device
deployment if the state is actually partitioned — a replicated factor stack
costs every chip the full O(sqrt(N)) bytes. This benchmark reports the
per-device optimizer-state bytes produced by
``repro.distributed.rules.opt_state_shardings`` over a **pod × fsdp grid**
(the multi-axis stack policy splits each bucket's stacked leading axis
across ``("pod", "data")`` whenever divisible), split **per partition
group** when the spec is mixed — including groups with a ``state_sharding``
override riding the "model" axis.

Everything is spec math over AbstractMesh + ShapeDtypeStructs — no arrays
are allocated, so the 94M-param transformer_base default runs in
milliseconds on any host.

    PYTHONPATH=src python benchmarks/opt_memory_sharded.py
    PYTHONPATH=src python benchmarks/opt_memory_sharded.py --arch yi_6b \
        --opt adafactor --model-ways 2
    PYTHONPATH=src python benchmarks/opt_memory_sharded.py \
        --optim-rule 'norm|scale$|bias$=adam,lr=3e-4'

Acceptance (PR 2 baseline, re-asserted every run on the defaults): on the
4-way fsdp mesh, smmf/transformer_base per-device bytes must not regress
above 25.4% of replicated (the stack axis of every multi-leaf bucket
carries the fsdp axis; single-leaf buckets fall back to row/col sharding
and only their small column factors stay replicated).
"""

from __future__ import annotations

import argparse

import jax
from jax.sharding import AbstractMesh

from repro.configs import get_config
from repro.distributed import rules
from repro.launch import specs as S
from repro.optim import OptimizerSpec, build_optimizer
from repro.utils.tree import tree_bytes

# PR 2 measured 4-way-fsdp baseline for smmf/transformer_base: 25.4% of
# replicated. The multi-axis policy must never regress it.
BASELINE_4WAY_FRACTION = 0.254


def _mk(family, rules_=(), **hp):
    """Spec-built optimizer (benchmarks construct via the OptimizerSpec API)."""
    spec = OptimizerSpec(family=family, hyperparams=hp)
    for r in rules_:
        spec = spec.with_rule(r)
    return build_optimizer(spec)


OPTS = {
    "smmf": lambda gamma, r: _mk("smmf", r, lr=1e-3, decay_rate=gamma),
    "smmf_local": lambda gamma, r: _mk("smmf", r, lr=1e-3, decay_rate=gamma, blocks=4),
    "adafactor": lambda gamma, r: _mk("adafactor", r, lr=1e-3),
    "came": lambda gamma, r: _mk("came", r, lr=1e-3),
    "sm3": lambda gamma, r: _mk("sm3", r, lr=1e-3),
}


def per_device_bytes(arch: str, opt_name: str, data_ways: int,
                     model_ways: int = 1, pod_ways: int = 1,
                     optim_rules=()) -> dict:
    """Per-device vs total optimizer-state bytes for one (arch, opt, mesh).

    Builds the optimizer state abstractly (``jax.eval_shape``), asks the
    sharding rules for its placement on a ``(pod, data, model)``
    AbstractMesh (the ``data`` axis is always present; ``pod``/``model``
    are omitted at way-count 1, matching production mesh construction),
    and sums shard sizes — total (``rules.sharded_state_bytes``) and per
    partition group (``rules.sharded_state_bytes_by_group``).
    """
    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    gamma = -0.5 if cfg.family == "cnn" else -0.8
    opt = OPTS[opt_name](gamma, tuple(optim_rules))
    axes = ()
    if pod_ways > 1:
        axes += (("pod", pod_ways),)
    axes += (("data", data_ways),)
    if model_ways > 1:
        axes += (("model", model_ways),)
    mesh = AbstractMesh(axes)
    shardings = rules.opt_state_shardings(mesh, cfg, psds, opt)
    state_shape = jax.eval_shape(opt.init, psds)
    total = tree_bytes(state_shape)
    per_dev = rules.sharded_state_bytes(shardings, state_shape)
    groups = [p.name for p in opt.spec.partitions]
    by_group = rules.sharded_state_bytes_by_group(shardings, state_shape, groups)
    return {"total": total, "per_device": per_dev, "by_group": by_group,
            "devices": pod_ways * data_ways * max(1, model_ways)}


def main() -> None:
    """Print the pod × fsdp per-device optimizer-memory grid (with per-group
    columns for mixed specs) and assert the 4-way fsdp point has not
    regressed from the PR 2 baseline."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="transformer_base")
    ap.add_argument("--opt", default="smmf", choices=sorted(OPTS))
    ap.add_argument("--model-ways", type=int, default=1,
                    help="extra tensor-parallel axis (column factors)")
    ap.add_argument("--optim-rule", action="append", default=[],
                    metavar="PATTERN=FAMILY[,K=V...]",
                    help="append an OptimizerSpec partition rule (same "
                         "syntax as the train launcher; state_sharding=... "
                         "overrides that group's stack axes)")
    args = ap.parse_args()

    grid = [(1, 1), (1, 2), (1, 4), (1, 8), (2, 2), (2, 4), (2, 8)]
    base = None
    frac_4way = None
    print(f"{args.arch} / {args.opt} (model axis: {args.model_ways}-way)")
    header = (f"{'mesh':>12s} {'state MB':>10s} {'per-dev MB':>11s} "
              f"{'vs replicated':>14s}")
    rows = []
    for pod, ways in grid:
        rec = per_device_bytes(args.arch, args.opt, ways, args.model_ways,
                               pod_ways=pod, optim_rules=args.optim_rule)
        if base is None:
            base = rec["per_device"]
            groups = sorted(rec["by_group"])
            if len(groups) > 1:
                header += "".join(f" {g[:12]:>13s}" for g in groups)
        frac = rec["per_device"] / base
        if (pod, ways) == (1, 4):
            frac_4way = frac
        row = (f"{pod:>8d}x{ways:<2d}x{args.model_ways:<1d} "
               f"{rec['total']/1e6:10.3f} {rec['per_device']/1e6:11.3f} "
               f"{frac:13.1%}")
        if len(rec["by_group"]) > 1:
            row += "".join(f" {rec['by_group'][g]/1e6:11.3f}MB" for g in groups)
        rows.append(row)
    print(header)
    for row in rows:
        print(row)
    print(f"\n(pod×fsdp grid: the stacked bucket axis splits across "
          f"(pod, data) when divisible — see docs/sharding.md)")
    if (args.arch, args.opt, args.model_ways) == ("transformer_base", "smmf", 1) \
            and not args.optim_rule:
        assert frac_4way <= BASELINE_4WAY_FRACTION + 1e-3, (
            f"4-way fsdp per-device state regressed: {frac_4way:.1%} of "
            f"replicated vs the PR 2 baseline {BASELINE_4WAY_FRACTION:.1%}")
        print(f"4-way fsdp acceptance OK: {frac_4way:.1%} <= "
              f"{BASELINE_4WAY_FRACTION:.1%} of replicated")


if __name__ == "__main__":
    main()
