"""Per-device optimizer-state bytes under bucket-stack sharding.

The SMMF paper's headline is optimizer-*memory*: up to 96% less state than
the Adafactor/CAME/SM3 family. That claim only survives multi-device
deployment if the state is actually partitioned — a replicated factor stack
costs every chip the full O(sqrt(N)) bytes. This benchmark reports the
per-device optimizer-state bytes produced by
``repro.distributed.rules.opt_state_shardings`` on 1/2/4/8-way "data"
(fsdp) meshes, against the fully replicated baseline (= the 1-way bytes).

Everything is spec math over AbstractMesh + ShapeDtypeStructs — no arrays
are allocated, so the 94M-param transformer_base default runs in
milliseconds on any host.

    PYTHONPATH=src python benchmarks/opt_memory_sharded.py
    PYTHONPATH=src python benchmarks/opt_memory_sharded.py --arch yi_6b \
        --opt adafactor --model-ways 2

Acceptance (PR 2): on the 4-way mesh, smmf/transformer_base per-device
bytes must be <= 30% of replicated (the stack axis of every multi-leaf
bucket carries the fsdp axis; single-leaf buckets fall back to row/col
sharding and only their small column factors stay replicated).
"""

from __future__ import annotations

import argparse

import jax
from jax.sharding import AbstractMesh

from repro.configs import get_config
from repro.distributed import rules
from repro.launch import specs as S
from repro.optim import OptimizerSpec, build_optimizer
from repro.utils.tree import tree_bytes


def _mk(family, **hp):
    """Spec-built optimizer (benchmarks construct via the OptimizerSpec API)."""
    return build_optimizer(OptimizerSpec(family=family, hyperparams=hp))


OPTS = {
    "smmf": lambda gamma: _mk("smmf", lr=1e-3, decay_rate=gamma),
    "smmf_local": lambda gamma: _mk("smmf", lr=1e-3, decay_rate=gamma, blocks=4),
    "adafactor": lambda gamma: _mk("adafactor", lr=1e-3),
    "came": lambda gamma: _mk("came", lr=1e-3),
    "sm3": lambda gamma: _mk("sm3", lr=1e-3),
}


def per_device_bytes(arch: str, opt_name: str, data_ways: int, model_ways: int = 1) -> dict:
    """Per-device vs total optimizer-state bytes for one (arch, opt, mesh).

    Builds the optimizer state abstractly (``jax.eval_shape``), asks the
    sharding rules for its placement on a ``(data, model)`` AbstractMesh,
    and sums shard sizes (``rules.sharded_state_bytes``).
    """
    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    gamma = -0.5 if cfg.family == "cnn" else -0.8
    opt = OPTS[opt_name](gamma)
    axes = (("data", data_ways),)
    if model_ways > 1:
        axes += (("model", model_ways),)
    mesh = AbstractMesh(axes)
    shardings = rules.opt_state_shardings(mesh, cfg, psds, opt)
    state_shape = jax.eval_shape(opt.init, psds)
    total = tree_bytes(state_shape)
    per_dev = rules.sharded_state_bytes(shardings, state_shape)
    return {"total": total, "per_device": per_dev,
            "devices": data_ways * max(1, model_ways)}


def main() -> None:
    """Print the 1/2/4/8-way per-device optimizer-memory table."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="transformer_base")
    ap.add_argument("--opt", default="smmf", choices=sorted(OPTS))
    ap.add_argument("--model-ways", type=int, default=1,
                    help="extra tensor-parallel axis (column factors)")
    args = ap.parse_args()

    base = None
    print(f"{args.arch} / {args.opt} (model axis: {args.model_ways}-way)")
    print(f"{'mesh':>10s} {'state MB':>10s} {'per-dev MB':>11s} {'vs replicated':>14s}")
    for ways in (1, 2, 4, 8):
        rec = per_device_bytes(args.arch, args.opt, ways, args.model_ways)
        if base is None:
            base = rec["per_device"]
        frac = rec["per_device"] / base
        print(f"{ways:>8d}x{args.model_ways:<1d} {rec['total']/1e6:10.3f} "
              f"{rec['per_device']/1e6:11.3f} {frac:13.1%}")
    print("\n(acceptance: 4-way per-device <= 30% of replicated for "
          "smmf/transformer_base — bucket stacks carry the fsdp axis, see "
          "docs/sharding.md)")


if __name__ == "__main__":
    main()
