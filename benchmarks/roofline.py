"""Roofline analysis from the dry-run artifacts (results/dryrun/*.json).

Per (arch x shape x mesh): the three terms in seconds
  compute    = HLO_FLOPs_per_dev / 197 TFLOP/s (bf16 MXU)
  memory     = HLO_bytes_per_dev / 819 GB/s (HBM)
  collective = wire_bytes_per_dev / 50 GB/s (ICI per link)
plus the dominant term, MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D
(prefill) / 2*N_active*B (decode), and the useful-compute ratio
MODEL_FLOPS / (HLO_FLOPs * devices).

All HLO quantities are loop-trip-corrected per-device numbers from
repro.launch.hloanalysis (see EXPERIMENTS.md §Roofline for caveats about
CPU-pipeline vs TPU-pipeline differences).
"""

from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_IDS, get_config
from repro.models.config import SHAPES, ModelConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def active_params(cfg: ModelConfig) -> float:
    """Parameters touched per token (MoE: top_k routed + shared experts)."""
    total = cfg.param_count()
    if not cfg.n_experts:
        return total

    def _ffn(f):
        return cfg.d_model * f * (3 if cfg.gated_mlp else 2)

    routed_all = cfg.n_layers * cfg.n_experts * _ffn(cfg.expert_ff)
    routed_active = cfg.n_layers * cfg.top_k * _ffn(cfg.expert_ff)
    return total - routed_all + routed_active


def model_flops(cfg: ModelConfig, shape) -> float:
    n = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load(tag_filter: str = "", opt: str = "smmf", variant: str = "") -> list[dict]:
    rows = []
    for f in sorted(RESULTS.glob("*.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "run" or "flops" not in rec:
            continue
        if rec.get("opt") != opt or rec.get("variant", "") != variant:
            continue
        if tag_filter and tag_filter not in f.name:
            continue
        rows.append(rec)
    return rows


def terms(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    comp = rec["flops"] / PEAK_FLOPS
    mem = rec["bytes_accessed"] / HBM_BW
    coll = rec["coll_bytes"] / ICI_BW
    dom = max(("compute", comp), ("memory", mem), ("collective", coll), key=lambda kv: kv[1])
    mf = model_flops(cfg, shape)
    ratio = mf / max(1.0, rec["flops"] * rec["devices"])
    bound = max(comp, mem, coll)
    return {
        "compute_s": comp,
        "memory_s": mem,
        "coll_s": coll,
        "dominant": dom[0],
        "model_flops": mf,
        "useful_ratio": ratio,
        # fraction of roofline-achievable: the compute term over the binding
        # term (1.0 = perfectly compute-bound at peak)
        "roofline_frac": comp / bound if bound > 0 else 0.0,
    }


def main() -> None:
    rows = load()
    if not rows:
        print("no dry-run artifacts found — run `python -m repro.launch.dryrun --all` first")
        return
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':11s} {'comp_s':>9s} {'mem_s':>9s} "
           f"{'coll_s':>9s} {'dominant':>10s} {'mflops/hlo':>10s} {'roofl%':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for rec in rows:
        t = terms(rec)
        print(f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:11s} "
              f"{t['compute_s']:9.4f} {t['memory_s']:9.4f} {t['coll_s']:9.4f} "
              f"{t['dominant']:>10s} {t['useful_ratio']:10.3f} {100*t['roofline_frac']:6.1f}%")


if __name__ == "__main__":
    main()
