"""Benchmark aggregator: one section per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import time


def _section(title: str):
    print(f"\n{'=' * 70}\n== {title}\n{'=' * 70}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the slow convergence runs")
    args = ap.parse_args()

    t0 = time.time()

    _section("Optimizer memory (paper Tables 1-4, memory columns)")
    from benchmarks import memory_table

    memory_table.main()

    _section("Optimizer step time (paper Table 5)")
    from benchmarks import step_time

    step_time.main()

    if not args.fast:
        _section("Convergence, 5 optimizers (paper Figures 1-2)")
        from benchmarks import convergence

        convergence.main()

    _section("Roofline terms from the multi-pod dry-run (EXPERIMENTS.md §Roofline)")
    from benchmarks import roofline

    roofline.main()

    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
