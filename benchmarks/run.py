"""Benchmark aggregator: one section per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--json-dir DIR]

Emits the machine-readable perf trajectory alongside the printed tables:
``BENCH_opt_memory.json`` (per-arch state bytes per family, per-group rows
incl. frozen groups, the qstate quantized grid, and the host-offload
device/host split), ``BENCH_step_time.json`` (per-optimizer
ms/launches/boundary-transport bytes plus the ``--overlap``/``--offload``
on/off grid), ``BENCH_telemetry.json`` (the ``--telemetry`` in-jit
counters' full-train-step overhead ratio + scalars/step, gated at
1.1x), ``BENCH_transport.json`` (gradient-boundary bytes per
transport mode + the compressed-vs-dense convergence parity), and
``BENCH_serve.json`` (paged-serving tokens/s and p50/p99 per-token
latency vs the legacy slot-batcher on an open-loop trace) under
``--json-dir`` (default ``results/bench/``). The CI
``bench`` job gates the fresh records against the committed repo-root
baselines via ``tools/bench_compare.py`` and uploads them as workflow
artifacts, so every commit carries its measured trajectory.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path


def _section(title: str):
    print(f"\n{'=' * 70}\n== {title}\n{'=' * 70}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="skip the slow convergence runs")
    ap.add_argument("--json-dir", default=str(Path(__file__).resolve().parents[1]
                                             / "results" / "bench"),
                    help="directory for the BENCH_*.json trajectory records")
    args = ap.parse_args()

    json_dir = Path(args.json_dir)
    t0 = time.time()

    _section("Optimizer memory (paper Tables 1-4, memory columns + qstate grid)")
    from benchmarks import memory_table

    memory_table.main(json_path=json_dir / "BENCH_opt_memory.json")

    _section("Optimizer step time (paper Table 5 + boundary transport)")
    from benchmarks import step_time

    step_time.main(json_path=json_dir / "BENCH_step_time.json")

    _section("Telemetry overhead: full train step, --telemetry off vs on")
    step_time.main_telemetry(json_path=json_dir / "BENCH_telemetry.json")

    if not args.fast:
        _section("Convergence, 5 optimizers + quantized parity (paper Figures 1-2)")
        from benchmarks import convergence

        convergence.main()

    _section("Gradient transport: boundary pricing + convergence parity")
    from benchmarks import transport_bench

    transport_bench.main(json_path=json_dir / "BENCH_transport.json",
                         fast=args.fast)

    _section("Serving: paged continuous batching vs the seed slot-batcher")
    from benchmarks import serve_bench

    serve_bench.main(json_path=json_dir / "BENCH_serve.json")

    _section("Roofline terms from the multi-pod dry-run (EXPERIMENTS.md §Roofline)")
    from benchmarks import roofline

    roofline.main()

    print(f"\n[benchmarks] total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
