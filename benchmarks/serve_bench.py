"""Serving throughput/latency: paged continuous batching vs the seed engine.

Replays a synthetic **open-loop arrival trace** (deterministic: fixed
prompt lengths, fixed arrival offsets — requests arrive on the clock
whether or not the engine is keeping up, so queueing shows up in the tail
latency) against:

* ``legacy`` — the seed slot-batcher kept verbatim as
  ``repro.serving.legacy.LegacySlotEngine``: one-at-a-time prefill with a
  fresh jit per distinct prompt length, every slot's cache padded to
  ``max_len``, greedy host argmax;
* ``paged`` (+ ``paged_int8``) — the rebuilt ``GenerationEngine``:
  batched budget-capped prefill admission, paged KV (decode attention
  covers the smallest pow2 page bucket holding the longest active row,
  not ``max_len``), pow2-bucketed jit keys.

**Methodology.** Each engine instance owns its jitted steps, so each
variant is warmed by replaying a warmup trace first — then timed on a
replay whose prompt lengths are *different* (shifted within the same page
bucket). That is the production situation the engines are designed for:
unseen lengths arrive constantly. The paged engine's bucketed jit keys
absorb them with zero new compiles; the legacy engine's per-exact-length
prefill retraces on every one — that unbounded compile surface, plus the
``max_len``-padded decode and one-at-a-time admission, is precisely what
the rebuild removes, so it is measured, not warmed away.

Reported per variant: end-to-end ``tokens_per_s`` over the trace and
``p50_ms`` / ``p99_ms`` **per-token latency** (gap between a request's
consecutive token completions; the first token counts from the request's
scheduled arrival, so admission queueing and compile stalls land in the
tail).

``main(json_path=...)`` writes ``BENCH_serve.json``;
``tools/bench_compare.py`` enforces the hard >= 2x tokens/s floor of the
paged engine over legacy (same process, same machine — the ratio is
machine-independent) plus legacy-normalized trajectory vs the committed
baseline. The trace uses a dense arch: the legacy baseline cannot serve
enc-dec at all (that capability itself is new in the paged engine).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

import jax

from repro.models import ModelConfig, init_lm
from repro.serving import (
    GenerationEngine,
    LegacyRequest,
    LegacySlotEngine,
    Request,
)

CFG = ModelConfig("serve-bench", "dense", 2, 128, 4, 256, 256, n_kv_heads=2,
                  dtype="float32")
SLOTS = 4
MAX_LEN = 512
MAX_NEW = 16
N_REQ = 16
PAGE = 16


def _trace(shift: int):
    """(prompt_len, arrival_s) rows. ``shift`` moves every prompt length
    within its page bucket, so warmup (shift=0) and the timed replay
    (shift=1) exercise identical paged jit buckets but zero identical
    exact lengths — every timed prefill is a fresh shape for legacy."""
    return [(5 + 2 * i + shift, 0.01 * i) for i in range(N_REQ)]


def _prompt(i: int, plen: int) -> np.ndarray:
    rng = np.random.default_rng(1000 + i)
    return rng.integers(0, CFG.vocab, size=plen).astype(np.int32)


def _replay(eng, mk_request, trace, record: bool):
    """Drive ``eng`` through ``trace`` open-loop; returns (wall_s,
    latencies_ms) with one latency per generated token (first token
    measured from the request's scheduled arrival)."""
    reqs = [mk_request(i, _prompt(i, plen)) for i, (plen, _) in enumerate(trace)]
    seen = [0] * len(reqs)
    last = [0.0] * len(reqs)
    lat: list[float] = []
    start = time.perf_counter()
    nxt = 0
    while True:
        now = time.perf_counter() - start
        while nxt < len(trace) and trace[nxt][1] <= now:
            last[nxt] = trace[nxt][1]
            eng.submit(reqs[nxt])
            nxt += 1
        progressed = eng.step()
        now = time.perf_counter() - start
        if record:
            for i, r in enumerate(reqs):
                while seen[i] < len(r.out):
                    lat.append((now - last[i]) * 1e3)
                    last[i] = now
                    seen[i] += 1
        if not progressed:
            if nxt >= len(trace):
                break
            time.sleep(max(0.0, trace[nxt][1] - now))
    assert all(r.done for r in reqs)
    assert all(len(r.out) == MAX_NEW for r in reqs)
    return time.perf_counter() - start, lat


def _warm_buckets(eng, mk_request) -> None:
    """Exercise the paged engine's whole jit-bucket grid: admission rows
    bp in {1,2,4} x prefill lengths covering every pow2 page bucket the
    trace can touch (decode npb buckets fill in along the way). The grid
    is finite *by design* — that is the property being measured; the
    legacy engine has no finite equivalent to warm."""
    rid = 10_000
    for plen in (5, 17, 37):
        for bp in (1, 2, 4):
            reqs = [mk_request(rid + j, _prompt(rid + j, plen))
                    for j in range(bp)]
            rid += bp
            for r in reqs:
                eng.submit(r)
            while eng.step():
                pass


def _measure(make_engine, mk_request, warm_grid: bool) -> dict:
    eng = make_engine()
    if warm_grid:
        _warm_buckets(eng, mk_request)
    _replay(eng, mk_request, _trace(0), record=False)   # warm on-trace shapes
    wall, lat = _replay(eng, mk_request, _trace(1), record=True)
    toks = N_REQ * MAX_NEW
    return {
        "tokens": toks,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(toks / wall, 2),
        "p50_ms": round(float(np.percentile(lat, 50)), 3),
        "p99_ms": round(float(np.percentile(lat, 99)), 3),
    }


def main(json_path: str | Path | None = None) -> dict:
    params = init_lm(jax.random.PRNGKey(0), CFG)
    variants = {
        "legacy": (
            lambda: LegacySlotEngine(params, CFG, slots=SLOTS, max_len=MAX_LEN),
            lambda i, p: LegacyRequest(rid=i, prompt=p, max_new=MAX_NEW)),
        "paged": (
            lambda: GenerationEngine(params, CFG, slots=SLOTS, max_len=MAX_LEN,
                                     page=PAGE),
            lambda i, p: Request(rid=i, prompt=p, max_new=MAX_NEW)),
        "paged_int8": (
            lambda: GenerationEngine(params, CFG, slots=SLOTS, max_len=MAX_LEN,
                                     page=PAGE, kv_quant="int8"),
            lambda i, p: Request(rid=i, prompt=p, max_new=MAX_NEW)),
    }
    record: dict = {"arch": CFG.name, "slots": SLOTS, "max_len": MAX_LEN,
                    "max_new": MAX_NEW, "requests": N_REQ}
    print(f"{'variant':<12} {'tok/s':>9} {'p50 ms':>8} {'p99 ms':>9} {'wall s':>8}")
    for name, (mk_eng, mk_req) in variants.items():
        row = _measure(mk_eng, mk_req, warm_grid=name != "legacy")
        record[name] = row
        print(f"{name:<12} {row['tokens_per_s']:>9.1f} {row['p50_ms']:>8.2f} "
              f"{row['p99_ms']:>9.2f} {row['wall_s']:>8.2f}")
    speed = record["paged"]["tokens_per_s"] / record["legacy"]["tokens_per_s"]
    print(f"\npaged vs legacy: {speed:.2f}x tokens/s "
          f"(gate floor 2.0x, tools/bench_compare.py)")
    if json_path is not None:
        json_path = Path(json_path)
        json_path.parent.mkdir(parents=True, exist_ok=True)
        json_path.write_text(json.dumps(record, indent=1))
        print(f"[serve_bench] wrote {json_path}")
    return record


if __name__ == "__main__":
    main(json_path=Path(__file__).resolve().parents[1] / "results" / "bench"
         / "BENCH_serve.json")
