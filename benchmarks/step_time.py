"""Optimizer step-time overhead — paper Table 5 — plus launch accounting.

Measures the pure optimizer update (decompress -> EMA -> compress -> update)
per step for the five optimizers on a transformer-block-sized param set,
reporting the SMMF/Adam ratio (the paper reports 1.2-1.6x end-to-end; the
optimizer-only ratio is the upper bound of that overhead).

The ``launches`` column is the leaf-plan engine's static per-step update
launch count: bucketed variants issue one launch per same-geometry bucket,
the ``nobucket`` baseline one per leaf. The bucketed/per-leaf ratio is the
acceptance metric for the engine refactor (>= 5x fewer launches here).

A second table runs SMMF on a dense-fallback-heavy (CNN-like) tree —
``vector_reshape=False`` leaves every 1-D bias/scale on the plain-Adam
fallback — showing the fused flat dense launch (``fuse_dense``, PR 2):
all fallback leaves of a dtype dispatch as **one** concatenated launch
instead of one per distinct element count, and ``stats()`` counts it as 1.

The ``bnd@4dev`` column prices the ``"opt_update_row"`` replicated
boundary on a hypothetical 4-way fsdp mesh
(``rules.boundary_transport_bytes``): per step, the f32 bytes each
non-stack-sharded bucket transports explicitly through the gather/scatter
(and SMMF sign) pins — including the override-group demo row, whose
``state_sharding=("model",)`` group always takes the replicated boundary.
``main(json_path=...)`` emits the whole table as a machine-readable record
(``benchmarks/run.py`` writes ``BENCH_step_time.json``).

A fourth section runs the ``--overlap``/``--offload`` execution-knob grid
(:data:`OVERLAP_GRID`) on the quantized SMMF variant: step time with the
bucket updates interleaved (``schedule="grad"``) and/or the cold buckets
round-tripping the host tier, next to the analytic device/host state-byte
split and the offload transport per step. ``tools/bench_compare.py`` gates
regressions on these rows (overlap-on must not be slower than overlap-off
beyond tolerance, at equal memory).

:func:`bench_telemetry` prices the in-jit telemetry knob
(``--telemetry``, ``docs/observability.md``): a **full** bench-sized
dense-LM train step (fwd+bwd+update — the denominator the 1.1x budget
is defined against) with the collector off vs on, on the most heavily
instrumented spec (int8 state + rank-1 transport), median over
interleaved repeat rounds so CPU drift hits both variants equally.
``benchmarks/run.py`` writes the record as ``BENCH_telemetry.json``
(overhead ratio + events/step) and ``tools/bench_compare.py`` holds the
ratio under its :data:`~tools.bench_compare.TELEMETRY_OVERHEAD_MAX`
budget as a hard invariant on the candidate alone.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.rules import boundary_transport_bytes
from repro.launch.steps import optimizer_launch_stats
from repro.optim import OptimizerSpec, build_optimizer
from repro.optim.base import apply_updates

# hypothetical mesh for the static boundary-transport column
TRANSPORT_AXES = {"data": 4}


def _mk(family, _rules=(), **hp):
    """Spec-built optimizer (benchmarks construct via the OptimizerSpec API)."""
    spec = OptimizerSpec(family=family, hyperparams=hp)
    for r in _rules:
        spec = spec.with_rule(r)
    return build_optimizer(spec)


OPTS = {
    "adam": lambda: _mk("adam", lr=1e-3),
    "adafactor": lambda: _mk("adafactor", lr=1e-3),
    "sm3": lambda: _mk("sm3", lr=1e-3),
    "came": lambda: _mk("came", lr=1e-3),
    "came_conf": lambda: _mk("came_conf", lr=1e-3),
    "smmf": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.8),
    "smmf(nobucket)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.8, bucket=False),
    "smmf(kernel)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.8, use_kernel=True),
    "smmf(kernel,b=4)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.8, use_kernel=True, blocks=4),
    "smmf(int8)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.8, quant="int8"),
    "smmf(int8,kernel)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.8,
                                     quant="int8", use_kernel=True),
    "smmf(fp8)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.8, quant="fp8"),
    # override-group demo (PR 4 follow-up): the attn leaves ride a "model"
    # state_sharding override, so their buckets take the explicit
    # replicated boundary — the transport column prices it
    "smmf(override)": lambda: _mk(
        "smmf", _rules=('attn=smmf,state_sharding=("model",)',),
        lr=1e-3, decay_rate=-0.8),
}


def _params(d=1024, layers=4):
    rng = np.random.default_rng(0)
    p = {}
    for i in range(layers):
        p[f"attn{i}"] = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
        p[f"ffn{i}"] = jnp.asarray(rng.standard_normal((d, 4 * d)), jnp.float32)
        p[f"out{i}"] = jnp.asarray(rng.standard_normal((4 * d, d)), jnp.float32)
    return p


def _cnn_params(layers=6):
    """Fallback-heavy tree: conv kernels plus many distinct-size 1-D leaves
    (biases / bn stats) that land on the dense path when vector_reshape is
    off — one dense bucket per element count without fusion."""
    rng = np.random.default_rng(1)
    p = {}
    for i in range(layers):
        c = 8 * (i + 1)
        p[f"conv{i}/w"] = jnp.asarray(rng.standard_normal((3, 3, c, 2 * c)), jnp.float32)
        p[f"conv{i}/b"] = jnp.asarray(rng.standard_normal((2 * c,)), jnp.float32)
        p[f"bn{i}/scale"] = jnp.asarray(rng.standard_normal((2 * c,)), jnp.float32)
        p[f"bn{i}/bias"] = jnp.asarray(rng.standard_normal((2 * c,)), jnp.float32)
    return p


# dense-fallback fusion scenarios (second table): vector_reshape=False keeps
# 1-D leaves dense, isolating the fused flat launch from factorization
DENSE_OPTS = {
    "smmf(fused dense)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.5, vector_reshape=False),
    "smmf(per-geom dense)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.5,
                                        vector_reshape=False, fuse_dense=False),
    "smmf(nobucket)": lambda: _mk("smmf", lr=1e-3, decay_rate=-0.5, vector_reshape=False,
                                   bucket=False),
}


def bench(name: str, iters: int = 20, opts=None, params_fn=_params):
    """Compile + time ``iters`` optimizer-only steps; returns
    (ms, launches, boundary-transport bytes on the TRANSPORT_AXES mesh)."""
    opt = (opts or OPTS)[name]()
    params = params_fn()
    state = opt.init(params)
    grads = jax.tree.map(lambda p: p * 0.01, params)
    stats = optimizer_launch_stats(opt, params)
    launches = stats["update_launches"] if stats else None
    transport = boundary_transport_bytes(opt.plan(params), TRANSPORT_AXES)

    @jax.jit
    def step(params, state, grads):
        u, s2 = opt.update(grads, state, params)
        return apply_updates(params, u), s2

    params, state = step(params, state, grads)  # compile
    jax.block_until_ready(params)
    t0 = time.perf_counter()
    for _ in range(iters):
        params, state = step(params, state, grads)
    jax.block_until_ready(params)
    return (time.perf_counter() - t0) / iters * 1e3, launches, transport


def bench_overlap(name: str, iters: int = 20, schedule=None, offload=None):
    """Time the optimizer-only step under the execution knobs of the
    overlapped train step: ``schedule="grad"`` (interleave order +
    optimization-barrier chain) and/or ``offload="cold"`` (host tier
    round-trip; structural on CPU). Returns (ms, analytic device/host
    state-byte split, offload transport bytes/step)."""
    from repro.optim import offload as O

    opt = OPTS[name]()
    params = _params()
    state = opt.init(params)
    grads = jax.tree.map(lambda p: p * 0.01, params)
    engine = opt.plan(params)
    state_sds = jax.eval_shape(opt.init, params)
    split = O.state_bytes_split(engine, state_sds, offload)
    transport = O.transport_bytes(engine, state_sds, offload)
    extras = {}
    if schedule is not None:
        extras["schedule"] = schedule
    if offload is not None:
        extras["offload"] = offload

    @jax.jit
    def step(params, state, grads):
        u, s2 = opt.update(grads, state, params, **extras)
        return apply_updates(params, u), s2

    params, state = step(params, state, grads)  # compile
    jax.block_until_ready(params)
    t0 = time.perf_counter()
    for _ in range(iters):
        params, state = step(params, state, grads)
    jax.block_until_ready(params)
    return (time.perf_counter() - t0) / iters * 1e3, split, transport


def bench_telemetry(iters: int = 3, rounds: int = 6) -> dict:
    """Full-train-step telemetry overhead: off vs on, interleaved rounds.

    Builds a bench-sized dense LM train step (fwd+bwd+update — the
    compute profile the 1.1x budget is defined against; the test-suite
    smoke configs are too small for the collector's fixed per-step
    reduction cost to amortize) on the maximally instrumented spec (smmf
    int8 + rank-1 transport: update-RMS, clip-sat, requant-err, rt-err,
    flush and NaN-guard counters all live) twice — ``telemetry=False``
    and ``True`` — and times ``rounds`` alternating blocks of ``iters``
    steps each, reporting the medians and their ratio plus the number of
    telemetry scalars riding out per step.
    """
    from repro.data import SyntheticLMStream
    from repro.launch.steps import make_train_step
    from repro.models import init_lm
    from repro.models.config import ModelConfig

    cfg = ModelConfig("telemetry-bench", "dense", 4, 256, 8, 1024, 1024,
                      n_kv_heads=8, dtype="float32")
    spec = OptimizerSpec(
        family="smmf",
        hyperparams={"lr": 1e-3, "decay_rate": -0.8, "quant": "int8",
                     "transport": "rank1"})
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(spec, params)
    batch = SyntheticLMStream(cfg, 8, 128, seed=0).batch(0)
    state = opt.init(params)

    steps = {tel: jax.jit(make_train_step(cfg, opt, telemetry=tel))
             for tel in (False, True)}
    events_per_step = 0
    for tel, step in steps.items():  # compile both before any timing
        _, _, metrics = step(params, state, batch)
        jax.block_until_ready(metrics["loss"])
        if tel:
            events_per_step = len(metrics["telemetry"])

    times: dict[bool, list[float]] = {False: [], True: []}
    for _ in range(rounds):
        for tel in (False, True):  # interleave so drift is shared
            step = steps[tel]
            t0 = time.perf_counter()
            for _ in range(iters):
                p2, s2, metrics = step(params, state, batch)
            jax.block_until_ready((p2, s2, metrics))
            times[tel].append((time.perf_counter() - t0) / iters * 1e3)
    off_ms = float(np.median(times[False]))
    on_ms = float(np.median(times[True]))
    return {
        "arch": cfg.name,
        "spec": {"family": "smmf", "quant": "int8", "transport": "rank1"},
        "iters": iters,
        "rounds": rounds,
        "off_ms": off_ms,
        "on_ms": on_ms,
        "overhead_ratio": on_ms / off_ms,
        "events_per_step": events_per_step,
    }


def main_telemetry(json_path: str | Path | None = None) -> dict:
    """Print + optionally write the telemetry-overhead record
    (``BENCH_telemetry.json``; gated by tools/bench_compare.py)."""
    rec = bench_telemetry()
    print(f"full train step ({rec['arch']}, smmf int8 + rank1 transport, "
          f"fwd+bwd+update):")
    print(f"  telemetry off: {rec['off_ms']:8.2f} ms/step")
    print(f"  telemetry on:  {rec['on_ms']:8.2f} ms/step  "
          f"({rec['overhead_ratio']:.3f}x, {rec['events_per_step']} "
          f"scalars/step riding the metrics transfer)")
    print("(budget: <= 1.10x — tools/bench_compare.py TELEMETRY_OVERHEAD_MAX)")
    if json_path is not None:
        Path(json_path).parent.mkdir(parents=True, exist_ok=True)
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"[step_time] wrote {json_path}")
    return rec


# (overlap, offload) grid for the overlapped-step section: the bench gate
# (tools/bench_compare.py) asserts overlap-on <= overlap-off within
# tolerance and offload-on device bytes strictly below device-resident
OVERLAP_GRID = (
    ("base", None, None),
    ("overlap", "grad", None),
    ("offload", None, "cold"),
    ("overlap+offload", "grad", "cold"),
)


def main(json_path: str | Path | None = None) -> dict:
    """Print the step-time, dense-fallback, and overlap/offload tables
    (with the boundary transport column) and return (optionally write) the
    machine-readable record."""
    rec: dict = {"transport_axes": TRANSPORT_AXES, "optimizers": {},
                 "dense": {}, "overlap_offload": {}}
    base = None
    launch = {}
    print(f"{'optimizer':18s} {'ms/step':>9s} {'vs adam':>8s} {'launches':>9s} "
          f"{'bnd@4dev':>9s}")
    for name in OPTS:
        ms, launches, transport = bench(name)
        launch[name] = launches
        if name == "adam":
            base = ms
        rec["optimizers"][name] = {"ms": ms, "launches": launches,
                                   "boundary_bytes": transport["total"],
                                   "boundary_by_group": transport["by_group"]}
        ls = f"{launches:9d}" if launches is not None else f"{'-':>9s}"
        ratio = f"{ms/base:7.2f}x" if base else ""
        print(f"{name:18s} {ms:9.2f} {ratio} {ls} "
              f"{transport['total']/2**20:8.2f}M")
    if launch.get("smmf") and launch.get("smmf(nobucket)"):
        r = launch["smmf(nobucket)"] / launch["smmf"]
        print(f"\nbucketed engine: {launch['smmf']} launches/step vs "
              f"{launch['smmf(nobucket)']} per-leaf ({r:.1f}x fewer)")
    ov = rec["optimizers"]["smmf(override)"]["boundary_by_group"]
    print(f"override-group transport (state_sharding=('model',)): "
          + ", ".join(f"{g}={b/2**20:.2f}M" for g, b in sorted(ov.items()))
          + " per step through the replicated opt_update_row boundary")

    print(f"\ndense-fallback fusion (CNN-like tree, vector_reshape=False):")
    print(f"{'variant':22s} {'ms/step':>9s} {'launches':>9s}")
    for name in DENSE_OPTS:
        ms, launches, transport = bench(name, opts=DENSE_OPTS,
                                        params_fn=_cnn_params)
        rec["dense"][name] = {"ms": ms, "launches": launches,
                              "boundary_bytes": transport["total"]}
        ls = f"{launches:9d}" if launches is not None else f"{'-':>9s}"
        print(f"{name:22s} {ms:9.2f} {ls}")

    print("\noverlapped step / host-offload grid (smmf int8, execution knobs "
          "of --overlap/--offload):")
    print(f"{'variant':18s} {'ms/step':>9s} {'dev MB':>8s} {'host MB':>8s} "
          f"{'offl MB/step':>13s}")
    for label, schedule, off in OVERLAP_GRID:
        ms, split, transport = bench_overlap("smmf(int8)", schedule=schedule,
                                             offload=off)
        rec["overlap_offload"][label] = {
            "ms": ms, "schedule": schedule, "offload": off,
            "device_bytes": split["device"], "host_bytes": split["host"],
            "offload_transport_bytes": transport,
        }
        print(f"{label:18s} {ms:9.2f} {split['device']/2**20:8.3f} "
              f"{split['host']/2**20:8.3f} {transport/2**20:13.3f}")
    print("(equal-memory rows: 'overlap' moves no state; offload rows trade "
          "device HBM for 2x host-link transport per step — analytic split, "
          "backend-independent; timings are CPU + structural transfers)")

    print("\n(paper Table 5: SMMF ~1.2-1.6x Adam end-to-end; optimizer-only "
          "overhead is the bound. CPU timings; TPU uses the fused Pallas kernel.)")

    if json_path is not None:
        Path(json_path).parent.mkdir(parents=True, exist_ok=True)
        Path(json_path).write_text(json.dumps(rec, indent=1))
        print(f"[step_time] wrote {json_path}")
    return rec


if __name__ == "__main__":
    main()
