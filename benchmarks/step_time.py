"""Optimizer step-time overhead — paper Table 5 — plus launch accounting.

Measures the pure optimizer update (decompress -> EMA -> compress -> update)
per step for the five optimizers on a transformer-block-sized param set,
reporting the SMMF/Adam ratio (the paper reports 1.2-1.6x end-to-end; the
optimizer-only ratio is the upper bound of that overhead).

The ``launches`` column is the leaf-plan engine's static per-step update
launch count: bucketed variants issue one launch per same-geometry bucket,
the ``nobucket`` baseline one per leaf. The bucketed/per-leaf ratio is the
acceptance metric for the engine refactor (>= 5x fewer launches here).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.smmf import smmf
from repro.launch.steps import optimizer_launch_stats
from repro.optim import adafactor, adam, came, sm3
from repro.optim.base import apply_updates

OPTS = {
    "adam": lambda: adam(1e-3),
    "adafactor": lambda: adafactor(1e-3),
    "sm3": lambda: sm3(1e-3),
    "came": lambda: came(1e-3),
    "smmf": lambda: smmf(1e-3, decay_rate=-0.8),
    "smmf(nobucket)": lambda: smmf(1e-3, decay_rate=-0.8, bucket=False),
    "smmf(kernel)": lambda: smmf(1e-3, decay_rate=-0.8, use_kernel=True),
    "smmf(kernel,b=4)": lambda: smmf(1e-3, decay_rate=-0.8, use_kernel=True, blocks=4),
}


def _params(d=1024, layers=4):
    rng = np.random.default_rng(0)
    p = {}
    for i in range(layers):
        p[f"attn{i}"] = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
        p[f"ffn{i}"] = jnp.asarray(rng.standard_normal((d, 4 * d)), jnp.float32)
        p[f"out{i}"] = jnp.asarray(rng.standard_normal((4 * d, d)), jnp.float32)
    return p


def bench(name: str, iters: int = 20) -> tuple[float, int | None]:
    opt = OPTS[name]()
    params = _params()
    state = opt.init(params)
    grads = jax.tree.map(lambda p: p * 0.01, params)
    stats = optimizer_launch_stats(opt, params)
    launches = stats["update_launches"] if stats else None

    @jax.jit
    def step(params, state, grads):
        u, s2 = opt.update(grads, state, params)
        return apply_updates(params, u), s2

    params, state = step(params, state, grads)  # compile
    jax.block_until_ready(params)
    t0 = time.perf_counter()
    for _ in range(iters):
        params, state = step(params, state, grads)
    jax.block_until_ready(params)
    return (time.perf_counter() - t0) / iters * 1e3, launches


def main() -> None:
    base = None
    launch = {}
    print(f"{'optimizer':16s} {'ms/step':>9s} {'vs adam':>8s} {'launches':>9s}")
    for name in OPTS:
        ms, launches = bench(name)
        launch[name] = launches
        if name == "adam":
            base = ms
        ls = f"{launches:9d}" if launches is not None else f"{'-':>9s}"
        ratio = f"{ms/base:7.2f}x" if base else ""
        print(f"{name:16s} {ms:9.2f} {ratio} {ls}")
    if launch.get("smmf") and launch.get("smmf(nobucket)"):
        r = launch["smmf(nobucket)"] / launch["smmf"]
        print(f"\nbucketed engine: {launch['smmf']} launches/step vs "
              f"{launch['smmf(nobucket)']} per-leaf ({r:.1f}x fewer)")
    print("\n(paper Table 5: SMMF ~1.2-1.6x Adam end-to-end; optimizer-only "
          "overhead is the bound. CPU timings; TPU uses the fused Pallas kernel.)")


if __name__ == "__main__":
    main()
