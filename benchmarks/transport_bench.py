"""Gradient-transport trajectory — pricing, convergence parity, step time.

Three sections, one committed+gated record (``BENCH_transport.json``):

* **pricing** — analytic gradient-boundary bytes/step on the *full*
  ``transformer_base`` param tree under every transport mode
  (``rules.boundary_transport_bytes``'s ``grad`` column). ASSERTS the
  acceptance ratios: rank1 <= 35% and int8 <= 30% of dense f32.
* **convergence** — the transformer_base smoke config trained from the
  same init/stream under ``transport=none|int8|rank1``; ASSERTS the
  compressed final losses match dense transport within 0.5% (run is
  deterministic: seeded SR, synthetic stream).
* **opt_ms** — optimizer-only step time per mode on the transformer-block
  param set (``benchmarks/step_time._params``), the trajectory rows
  ``tools/bench_compare.py`` tracks ratio-normalized.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLMStream
from repro.distributed.rules import boundary_transport_bytes
from repro.launch.specs import params_specs
from repro.launch.steps import make_train_step
from repro.models import init_encdec, init_lm
from repro.optim import OptimizerSpec, build_optimizer

MODES = ("none", "int8", "rank1")
RANK1_MAX_RATIO = 0.35   # acceptance: rank1 bytes vs dense f32
INT8_MAX_RATIO = 0.30
PARITY_TOL = 0.005       # acceptance: compressed vs dense final loss

TRANSPORT_AXES = {"data": 4}


def _spec(mode: str, lr=1e-3):
    hp = {"lr": lr, "decay_rate": -0.8}
    if mode != "none":
        hp.update(transport=mode, transport_flush_every=8)
    return OptimizerSpec(family="smmf", hyperparams=hp)


def bench_pricing(arch: str = "transformer_base") -> dict:
    """Per-mode gradient-boundary bytes on the full arch param tree."""
    psds = params_specs(get_config(arch))
    opt = build_optimizer(_spec("rank1"))
    grad = boundary_transport_bytes(opt.plan(psds), TRANSPORT_AXES)["grad"]
    dense = grad["by_mode"]["none"]
    out = {"arch": arch,
           "modes": {m: {"bytes": grad["by_mode"][m],
                         "ratio_vs_dense": grad["by_mode"][m] / dense}
                     for m in MODES}}
    assert out["modes"]["rank1"]["ratio_vs_dense"] <= RANK1_MAX_RATIO, out
    assert out["modes"]["int8"]["ratio_vs_dense"] <= INT8_MAX_RATIO, out
    return out


def bench_convergence(steps: int = 120, batch: int = 4, seq: int = 32,
                      window: int = 20) -> dict:
    """transformer_base smoke: same init + stream per mode, final-loss
    parity (mean of the last ``window`` steps). 120 steps / 20-step tail
    because transport SR perturbs the *trajectory* (unbiased, not a drift):
    shorter smokes compare two noisy snapshots and the 0.5% bar is then
    dominated by when you stop, not by the compression."""
    cfg = smoke_config("transformer_base")
    out = {}
    for mode in MODES:
        opt = build_optimizer(_spec(mode))
        init = init_encdec if cfg.family == "encdec" else init_lm
        params = init(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)
        stream = SyntheticLMStream(cfg, batch, seq, seed=0)
        step = jax.jit(make_train_step(cfg, opt))
        hist = []
        for t in range(steps):
            b = jax.tree.map(jnp.asarray, stream.batch(t))
            params, state, m = step(params, state, b)
            hist.append(float(m["loss"]))
        out[mode] = {"final_loss": float(np.mean(hist[-window:])),
                     "first_loss": hist[0]}
    dense = out["none"]["final_loss"]
    for mode in ("int8", "rank1"):
        rel = abs(out[mode]["final_loss"] - dense) / abs(dense)
        out[mode]["rel_vs_dense"] = rel
        assert rel <= PARITY_TOL, (
            f"transport={mode} final loss {out[mode]['final_loss']:.5f} "
            f"vs dense {dense:.5f}: {100 * rel:.3f}% > "
            f"{100 * PARITY_TOL}%")
    return out


def bench_opt_ms(iters: int = 20) -> dict:
    """Optimizer-only step time per mode (transformer-block param set)."""
    from benchmarks.step_time import _params
    from repro.optim.base import apply_updates

    out = {}
    for mode in MODES:
        opt = build_optimizer(_spec(mode))
        params = _params()
        state = opt.init(params)
        grads = jax.tree.map(lambda p: p * 0.01, params)

        @jax.jit
        def step(params, state, grads):
            u, s2 = opt.update(grads, state, params)
            return apply_updates(params, u), s2

        params, state = step(params, state, grads)  # compile
        jax.block_until_ready(params)
        t0 = time.perf_counter()
        for _ in range(iters):
            params, state = step(params, state, grads)
        jax.block_until_ready(params)
        out[mode] = {"ms": (time.perf_counter() - t0) / iters * 1e3}
    return out


def main(json_path: str | Path | None = None, fast: bool = False) -> dict:
    """Print the three transport tables, assert the acceptance ratios, and
    return (optionally write) the machine-readable record. ``fast=True``
    skips the convergence smoke (kept for ``run.py --fast``; the committed
    baseline and the CI bench job always run it)."""
    rec: dict = {"transport_axes": TRANSPORT_AXES,
                 "flush_every": 8, "pricing": {}, "opt_ms": {}}

    print("== gradient-boundary bytes/step (transformer_base, full size) ==")
    rec["pricing"] = bench_pricing()
    for m, row in rec["pricing"]["modes"].items():
        print(f"{m:6s} {row['bytes'] / 1e6:9.2f} MB/step  "
              f"{100 * row['ratio_vs_dense']:6.2f}% of dense")
    print(f"acceptance OK: rank1 <= {100 * RANK1_MAX_RATIO:.0f}%, "
          f"int8 <= {100 * INT8_MAX_RATIO:.0f}% of dense f32")

    print("\n== optimizer-only step time per mode ==")
    rec["opt_ms"] = bench_opt_ms()
    base = rec["opt_ms"]["none"]["ms"]
    for m, row in rec["opt_ms"].items():
        print(f"{m:6s} {row['ms']:7.2f} ms  ({row['ms'] / base:4.2f}x dense)")

    if not fast:
        print("\n== transformer_base smoke convergence parity ==")
        rec["convergence"] = bench_convergence()
        for m, row in rec["convergence"].items():
            extra = f"  ({100 * row['rel_vs_dense']:.3f}% vs dense)" \
                if "rel_vs_dense" in row else ""
            print(f"{m:6s} final {row['final_loss']:8.5f}{extra}")
        print(f"parity OK: int8/rank1 within {100 * PARITY_TOL}% of dense")

    if json_path is not None:
        path = Path(json_path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(rec, indent=2, sort_keys=True) + "\n")
        print(f"\n[transport_bench] wrote {path}")
    return rec


if __name__ == "__main__":
    main()
