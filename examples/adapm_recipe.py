"""AdaPM-style partial momentum as a partitions recipe.

AdaPM's observation is that full momentum pays for itself only on some
parameter groups — the big matmul weights tolerate momentum-free updates,
while embeddings, norms and biases keep theirs. In this codebase that is
not a new optimizer family at all: momentum-free SMMF (``beta1=None``,
second-moment factors only, no sign matrix) already exists, so partial
momentum is exactly one :class:`~repro.optim.spec.Partition` rule mapping
``beta1=None`` onto the chosen groups. The matmul group's state drops from
five slots (r_m, c_m, sign, r_v, c_v) to two (r_v, c_v) — the packed sign
matrix, which dominates the momentum variant's bytes, disappears for the
largest parameters.

The shipped spec below (picked up by ``tools/spec_lint.py``) turns
momentum off for attention/FFN projection matrices and keeps it elsewhere.
``beta1``-presence is layout-relevant, so the recipe has its own
``spec_hash`` — a full-momentum checkpoint will not silently restore into
the partial-momentum layout. Run:

    PYTHONPATH=src python examples/adapm_recipe.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import OptimizerSpec, build_optimizer
from repro.optim.spec import Partition
from repro.optim.base import apply_updates
from repro.utils.tree import tree_bytes

SPEC = OptimizerSpec(
    family="smmf",
    hyperparams={"lr": 1e-3},
    partitions=(
        # momentum-free SMMF on the projection matrices (the AdaPM cut:
        # these are the parameters whose momentum state costs the most and
        # buys the least); everything else keeps full momentum + signs
        Partition(
            name="nomom",
            match=r"(attn|ffn|mlp)/.*w|w[qkvo]$|w[io]$",
            hyperparams={"beta1": None},
        ),
    ),
)


def main():
    """Train a toy two-matrix model with full vs partial momentum and
    report the trajectories + state bytes."""
    rng = np.random.default_rng(0)
    targets = {
        "attn/wq": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32),
        "emb/table": jnp.asarray(rng.standard_normal((96, 32)), jnp.float32),
    }

    def loss_fn(p):
        return sum(jnp.mean((p[k] - targets[k]) ** 2) for k in targets)

    print(f"{'recipe':16s} {'final loss':>11s} {'state KiB':>10s}")
    for name, spec in (
        ("smmf (full m)", OptimizerSpec(family="smmf", hyperparams={"lr": 1e-3})),
        ("adapm recipe", SPEC),
    ):
        opt = build_optimizer(spec)
        params = jax.tree.map(jnp.zeros_like, targets)
        state = opt.init(params)

        @jax.jit
        def step(p, s):
            l, g = jax.value_and_grad(loss_fn)(p)
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s, l

        for _ in range(200):
            params, state, l = step(params, state)
        print(f"{name:16s} {float(l):11.5f} {tree_bytes(state)/1024:10.2f}")
    print("\n(The recipe's 'nomom' group holds only (r_v, c_v) — no momentum "
          "factors, no packed sign matrix — while the embedding keeps full "
          "momentum. Same family, same engine; one partition rule.)")


if __name__ == "__main__":
    main()
