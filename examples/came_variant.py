"""Registry composition demo: the ``came_conf`` variant family.

``came_conf`` (``repro.optim.families``) is CAME with a second per-leaf RMS
clip applied to the confidence-rescaled *output* — registered as a
``dataclasses.replace`` of the base ``came`` entry, so planner, state
layout, capability flags and qstate quant slots are all inherited and only
the update math differs. This is the composition path third-party variants
take: no engine code, no spec code, just a registry entry.

The shipped spec below (picked up by ``tools/spec_lint.py``) pairs the
variant with quantized state storage — confidence statistics are exactly
the kind of state the qstate codec compresses (row/col vectors,
sqrt-companded int8). Run:

    PYTHONPATH=src python examples/came_variant.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import OptimizerSpec, build_optimizer
from repro.optim.base import apply_updates
from repro.utils.tree import tree_bytes

SPEC = OptimizerSpec(
    family="came_conf",
    hyperparams={"lr": 1e-3, "quant": "int8"},
)


def main():
    """Train a toy quadratic bowl with came vs came_conf (quantized) and
    report the trajectories + state bytes."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2)

    print(f"{'family':12s} {'final loss':>11s} {'state KiB':>10s}")
    for name, spec in (
        ("came", OptimizerSpec(family="came", hyperparams={"lr": 1e-3})),
        ("came_conf", SPEC),
    ):
        opt = build_optimizer(spec)
        params = {"w": jnp.zeros((64, 64), jnp.float32)}
        state = opt.init(params)

        @jax.jit
        def step(p, s):
            l, g = jax.value_and_grad(loss_fn)(p)
            u, s = opt.update(g, s, p)
            return apply_updates(p, u), s, l

        for _ in range(200):
            params, state, l = step(params, state)
        print(f"{name:12s} {float(l):11.5f} {tree_bytes(state)/1024:10.2f}")
    print("\n(came_conf = dataclasses.replace(came, update_bucket=...) — see "
          "repro/optim/families.py; its spec ships with quant='int8'. The "
          "slower bowl descent is the variant working as intended: base "
          "CAME's confidence rescale amplifies early steps far beyond lr, "
          "came_conf clips that amplification to the per-leaf RMS bound.)")


if __name__ == "__main__":
    main()
