"""LoRA fine-tuning with SMMF — the paper's LLaMA-7b setup (Table 4) at
demo scale, expressed as ONE partition-aware ``OptimizerSpec``: the frozen
base LM and the trained rank-8 adapters live in the same pytree, a
``freeze`` partition gives the base **zero optimizer state and zero
updates**, and SMMF handles the adapters — one engine, one state dict, one
step counter.

    PYTHONPATH=src python examples/lora_finetune.py
"""

import jax
import jax.numpy as jnp

from repro.data import SyntheticLMStream
from repro.models import init_lm, lm_loss
from repro.models.config import ModelConfig
from repro.optim import (
    OptimizerSpec,
    Partition,
    apply_updates,
    build_optimizer,
    state_bytes_by_group,
)
from repro.train.lora import lora_init, lora_merge
from repro.utils.tree import tree_bytes

# the run's declarative optimizer: SMMF on the adapters, frozen base.
# tools/spec_lint.py round-trips this spec through JSON in CI.
SPEC = OptimizerSpec(
    family="smmf",
    hyperparams={"lr": 5e-3, "decay_rate": -0.8},
    partitions=(Partition(name="frozen_base", match=r"^base(/|$)", freeze=True),),
)


def main():
    """Train rank-8 adapters over a frozen base with one spec-built optimizer."""
    cfg = ModelConfig("lora-demo", "dense", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab=512, dtype="float32")
    base = init_lm(jax.random.PRNGKey(0), cfg)
    adapters = lora_init(jax.random.PRNGKey(1), base, rank=8)
    tree = {"base": base, "lora": adapters}

    opt = build_optimizer(SPEC, tree)
    opt_state = opt.init(tree)
    by_group = state_bytes_by_group(opt, tree)

    print(f"base params      {tree_bytes(base)/2**20:7.2f} MiB (frozen)")
    print(f"lora adapters    {tree_bytes(adapters)/2**20:7.2f} MiB (trained)")
    print(f"SMMF lora state  {by_group['default']/2**20:7.2f} MiB (group 'default')")
    print(f"frozen-base optimizer state bytes = {by_group['frozen_base']}")
    assert by_group["frozen_base"] == 0, "freeze partition must hold zero state"
    from repro.optim import adam

    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        adam_full = tree_bytes(jax.eval_shape(adam(1e-3).init, base))
    print(f"Adam full state  {adam_full/2**20:7.2f} MiB (what full fine-tuning would hold)")

    def train_step(tree, opt_state, batch):
        def compute(tr):
            merged = lora_merge(tr["base"], tr["lora"])
            return lm_loss(merged, cfg, batch)

        (_, metrics), grads = jax.value_and_grad(compute, has_aux=True)(tree)
        updates, opt_state = opt.update(grads, opt_state, tree)
        return apply_updates(tree, updates), opt_state, metrics

    step = jax.jit(train_step)
    stream = SyntheticLMStream(cfg, 8, 64)
    losses = []
    base0 = jax.tree.map(lambda x: x, tree["base"])
    for t in range(60):
        batch = jax.tree.map(jnp.asarray, stream.batch(t))
        tree, opt_state, m = step(tree, opt_state, batch)
        losses.append(float(m["loss"]))
    # the freeze partition really froze the base: bitwise-identical weights
    import numpy as np

    for a, b in zip(jax.tree.leaves(base0), jax.tree.leaves(tree["base"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print(f"loss {losses[0]:.3f} -> {sum(losses[-5:])/5:.3f} "
          f"(adapters only; base frozen, verified bitwise)")


if __name__ == "__main__":
    main()
