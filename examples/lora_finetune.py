"""LoRA fine-tuning with SMMF — the paper's LLaMA-7b setup (Table 4) at
demo scale: freeze the base LM, train rank-8 adapters with SMMF, and show
the optimizer-state bill vs full-model Adam.

    PYTHONPATH=src python examples/lora_finetune.py
"""

import jax

from repro.core.smmf import smmf
from repro.data import SyntheticLMStream
from repro.models import init_lm, lm_loss
from repro.models.config import ModelConfig
from repro.optim import adam
from repro.train.lora import lora_init, make_lora_train_step
from repro.utils.tree import tree_bytes


def main():
    cfg = ModelConfig("lora-demo", "dense", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab=512, dtype="float32")
    base = init_lm(jax.random.PRNGKey(0), cfg)
    adapters = lora_init(jax.random.PRNGKey(1), base, rank=8)
    opt = smmf(5e-3, decay_rate=-0.8)
    opt_state = opt.init(adapters)

    print(f"base params      {tree_bytes(base)/2**20:7.2f} MiB (frozen)")
    print(f"lora adapters    {tree_bytes(adapters)/2**20:7.2f} MiB (trained)")
    print(f"SMMF lora state  {tree_bytes(opt_state)/2**20:7.2f} MiB")
    print(f"Adam full state  {tree_bytes(jax.eval_shape(adam(1e-3).init, base))/2**20:7.2f} MiB (what full fine-tuning would hold)")

    stream = SyntheticLMStream(cfg, 8, 64)
    step = jax.jit(make_lora_train_step(cfg, opt, lm_loss))
    losses = []
    for t in range(60):
        batch = jax.tree.map(jax.numpy.asarray, stream.batch(t))
        adapters, opt_state, m = step(base, adapters, opt_state, batch)
        losses.append(float(m["loss"]))
    print(f"loss {losses[0]:.3f} -> {sum(losses[-5:])/5:.3f} (adapters only; base frozen)")


if __name__ == "__main__":
    main()
