"""The paper's memory claim, measured end-to-end on a real model:

optimizer state bytes + checkpoint-on-disk bytes for Adam vs Adafactor vs
SMMF, on an instantiated transformer. Run:

    PYTHONPATH=src python examples/memory_compare.py
"""

import os
import tempfile

import jax

from repro.checkpoint import save
from repro.models import init_lm
from repro.models.config import ModelConfig
from repro.optim import OptimizerSpec, build_optimizer
from repro.utils.tree import tree_bytes

SPECS = {
    "adam": OptimizerSpec(family="adam", hyperparams={"lr": 1e-3}),
    "adafactor": OptimizerSpec(family="adafactor", hyperparams={"lr": 1e-3}),
    "smmf": OptimizerSpec(family="smmf",
                          hyperparams={"lr": 1e-3, "decay_rate": -0.8}),
}


def _dir_bytes(d):
    return sum(os.path.getsize(os.path.join(r, f)) for r, _, fs in os.walk(d) for f in fs)


def main():
    cfg = ModelConfig("mem-demo", "dense", n_layers=4, d_model=512, n_heads=8,
                      n_kv_heads=4, d_ff=2048, vocab=8192, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    print(f"model {cfg.param_count()/1e6:.1f}M params ({tree_bytes(params)/2**20:.1f} MiB)\n")

    print(f"{'optimizer':12s} {'state MiB':>10s} {'ckpt MiB':>10s} {'vs adam':>8s}")
    base = None
    for name, spec in SPECS.items():
        state = build_optimizer(spec).init(params)
        sbytes = tree_bytes(state)
        with tempfile.TemporaryDirectory() as td:
            save(td, 0, {"opt": state})
            ck = _dir_bytes(td)
        if base is None:
            base = sbytes
        print(f"{name:12s} {sbytes/2**20:10.2f} {ck/2**20:10.2f} {sbytes/base:7.3f}x")

    print("\nSMMF checkpoints (state) are ~60x smaller than Adam's — elastic "
          "re-sharding of optimizer state on resume is effectively free.")


if __name__ == "__main__":
    main()
