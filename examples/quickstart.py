"""Quickstart: train a small LM with SMMF and compare optimizer memory.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.data import SyntheticLMStream
from repro.launch.steps import make_train_step
from repro.models import init_lm
from repro.models.config import ModelConfig
from repro.optim import OptimizerSpec, build_optimizer
from repro.utils.tree import tree_bytes

# one declarative spec per optimizer (see docs/optimizer_api.md)
SPECS = {
    "adam": OptimizerSpec(family="adam", hyperparams={"lr": 1e-3}),
    "smmf": OptimizerSpec(family="smmf",
                          hyperparams={"lr": 1e-3, "decay_rate": -0.8}),
}


def main():
    cfg = ModelConfig("quickstart", "dense", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab=512, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    stream = SyntheticLMStream(cfg, global_batch=8, seq_len=64)

    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.1f}M params "
          f"({tree_bytes(params)/2**20:.1f} MiB)")

    for name, spec in SPECS.items():
        opt = build_optimizer(spec)
        p = jax.tree.map(jnp.array, params)  # fresh copy
        state = opt.init(p)
        step = jax.jit(make_train_step(cfg, opt))
        losses = []
        for t in range(60):
            p, state, m = step(p, state, jax.tree.map(jnp.asarray, stream.batch(t)))
            losses.append(float(m["loss"]))
        print(f"{name:5s}: optimizer state {tree_bytes(state)/2**20:6.2f} MiB | "
              f"loss {losses[0]:.3f} -> {sum(losses[-5:])/5:.3f}")

    print("\nSMMF trains to the same loss with a fraction of the optimizer memory.")


if __name__ == "__main__":
    main()
