"""Serving example: continuous batching on the paged, quantized KV cache.

Eight requests, four decode slots, int8 KV pages, the paged Pallas decode
kernel, and mixed sampling: half the requests decode greedy, half sample
with per-request seeds (a request's stream is identical solo or batched —
see docs/serving.md). Finished sequences retire mid-flight, return their
pages to the pool, and queued requests batch-prefill into the free slots.

    PYTHONPATH=src python examples/serve.py
"""

import time

import jax
import numpy as np

from repro.models import init_lm
from repro.models.config import ModelConfig
from repro.serving import GenerationEngine
from repro.serving.engine import Request


def main():
    cfg = ModelConfig("serve-demo", "dense", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab=512, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, slots=4, max_len=128, page=16,
                           kv_quant="int8", use_kernel=True)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 512, size=8 + i).astype(np.int32),
                    max_new=16,
                    # even rids: greedy; odd rids: seeded nucleus sampling
                    temperature=0.0 if i % 2 == 0 else 0.8,
                    top_p=1.0 if i % 2 == 0 else 0.95, seed=i)
            for i in range(8)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in done)
    st = eng.stats
    print(f"served {len(done)} requests / {tokens} tokens in "
          f"{st['decode_steps']} decode steps, {st['prefill_batches']} prefill "
          f"batches ({dt:.2f}s, {tokens/dt:.1f} tok/s on CPU, int8 KV pages)")
    for r in reqs[:3]:
        mode = "greedy" if r.temperature == 0.0 else f"sampled(seed={r.seed})"
        print(f"  req {r.rid} [{mode}]: prompt {r.prompt[:6].tolist()}... "
              f"-> {r.out}")


if __name__ == "__main__":
    main()
