"""Serving example: batched generation with the slot-based engine.

Eight requests, four decode slots — finished sequences free their slot and
queued requests prefill into it (continuous batching at decode-step
granularity).

    PYTHONPATH=src python examples/serve.py
"""

import time

import jax
import numpy as np

from repro.models import init_lm
from repro.models.config import ModelConfig
from repro.serving import GenerationEngine
from repro.serving.engine import Request


def main():
    cfg = ModelConfig("serve-demo", "dense", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=2, d_ff=256, vocab=512, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, slots=4, max_len=128)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 512, size=8 + i).astype(np.int32),
                    max_new=16) for i in range(8)]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    steps = 0
    while eng.step():
        steps += 1
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in reqs)
    print(f"served {len(reqs)} requests / {tokens} tokens in {steps} decode steps "
          f"({dt:.2f}s, {tokens/dt:.1f} tok/s on CPU)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt[:6].tolist()}... -> {r.out}")


if __name__ == "__main__":
    main()
