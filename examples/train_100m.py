"""End-to-end driver: train a ~100M-parameter LM with SMMF, checkpointed.

Default arguments are sized to finish on the CPU container (a ~10M model,
300 steps); pass --full for the true ~100M configuration (same code path,
longer wall-clock; on a TPU pod this is the config you would launch via
repro.launch.train with the production mesh).

    PYTHONPATH=src python examples/train_100m.py [--full] [--steps N]
"""

import argparse

import jax

from repro.data import SyntheticLMStream
from repro.launch.steps import make_train_step
from repro.models import init_lm
from repro.models.config import ModelConfig
from repro.optim import OptimizerSpec, build_optimizer
from repro.train import TrainLoop, TrainLoopConfig
from repro.utils.tree import tree_bytes

SPEC = OptimizerSpec(family="smmf", hyperparams={"lr": 3e-4, "decay_rate": -0.8})

SMALL = ModelConfig("lm-10m", "dense", n_layers=4, d_model=256, n_heads=8,
                    n_kv_heads=4, d_ff=1024, vocab=8192, dtype="float32")
FULL = ModelConfig("lm-100m", "dense", n_layers=12, d_model=768, n_heads=12,
                   n_kv_heads=4, d_ff=2048, vocab=32768, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="~100M params (slow on CPU)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    cfg = FULL if args.full else SMALL
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(SPEC, params)
    opt_state = opt.init(params)
    print(f"[{cfg.name}] {cfg.param_count()/1e6:.1f}M params, "
          f"opt state {tree_bytes(opt_state)/2**20:.2f} MiB "
          f"(params {tree_bytes(params)/2**20:.1f} MiB)")

    stream = SyntheticLMStream(cfg, args.batch, args.seq)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    loop = TrainLoop(step_fn, params, opt_state, stream,
                     TrainLoopConfig(total_steps=args.steps, ckpt_every=100,
                                     ckpt_dir=args.ckpt_dir, log_every=20,
                                     spec_hash=SPEC.spec_hash()))
    out = loop.run()
    h = out["history"]
    print(f"done: loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over {out['final_step']} steps "
          f"({out['stragglers']} stragglers, {out['nan_skips']} nan-skips)")


if __name__ == "__main__":
    main()
