"""repro: production-grade JAX reproduction of SMMF (AAAI 2025).

Public API re-exports are lazy (PEP 562) so that `python -m
repro.launch.dryrun` can set XLA_FLAGS before anything imports jax.
"""

__version__ = "1.0.0"

_EXPORTS = {
    "smmf": "repro.core.smmf",
    "smmf_local": "repro.core.smmf",
    "adam": "repro.optim",
    "adamw": "repro.optim",
    "adafactor": "repro.optim",
    "came": "repro.optim",
    "sgd": "repro.optim",
    "sm3": "repro.optim",
    "GradientTransformation": "repro.optim.base",
    "apply_updates": "repro.optim.base",
    "OptimizerSpec": "repro.optim.spec",
    "Partition": "repro.optim.spec",
    "build_optimizer": "repro.optim.spec",
    "state_bytes_by_group": "repro.optim.spec",
}

__all__ = list(_EXPORTS) + ["__version__"]


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        mod = importlib.import_module(_EXPORTS[name])
        return getattr(mod, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
