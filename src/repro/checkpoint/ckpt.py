"""Preemption-safe, mesh-agnostic checkpointing.

* **Atomic**: writes into ``<dir>/tmp.<step>/`` then ``os.rename`` to
  ``step_<n>/`` — a killed process never leaves a half-checkpoint that
  restore would pick up.
* **Mesh-agnostic / elastic**: leaves are saved as host numpy arrays keyed
  by pytree path; restore re-shards onto *any* mesh via ``jax.device_put``
  with freshly computed shardings, so a job checkpointed on 256 chips can
  resume on 512 (or 1 CPU in tests).
* **Manifest**: step, wall-time, config name, leaf index with shapes/dtypes
  — restart never needs the writer's mesh.

SMMF's payoff at this layer: the optimizer state is O(sqrt(N)) per tensor,
so checkpoint size ~= params + signs (1/16 of an Adam checkpoint's state),
and elastic re-sharding of optimizer state is effectively free.

Quantized optimizer state (the qstate codec, ``repro.optim.qstate``) flows
through the same path-keyed mechanism: int8 payloads and f32 scales are
ordinary leaves, and fp8 payloads are **bit-preserved** — saved as uint8
views (``np.savez`` cannot round-trip ml_dtypes float8) with the true
dtype recorded in the manifest, and viewed back on restore. Elastic
restore re-shards payload and scale leaves like any other state.

Host-offloaded state (``repro.optim.offload``) is checkpoint-transparent:
cold buckets parked on pinned-host memory save as the same host numpy
leaves, and the manifest records their non-default memory kinds for
observability only — restore always materializes on default device memory
(the training loop's ``place_state`` hook re-parks cold buckets), so the
checkpoint stays portable across backends with different memory tiers.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

PyTree = Any

_SEP = "|"


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}

    def _name(p) -> str:
        parts = []
        for e in p:
            if hasattr(e, "key"):
                parts.append(str(e.key))
            elif hasattr(e, "idx"):
                parts.append(str(e.idx))
            else:
                parts.append(str(e))
        return _SEP.join(parts)

    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_name(path)] = np.asarray(leaf)
    return flat


def _memory_kinds(tree: PyTree) -> dict[str, str]:
    """Non-default memory kinds by leaf name (host-offloaded optimizer
    state, ``repro.optim.offload``). Recorded in the manifest purely for
    observability — restore placement is driven by the caller's shardings
    (plus ``TrainLoop.place_state``), never by the writer's memory tiering,
    so a checkpoint written with ``--offload cold`` restores cleanly on a
    host with no host memory kind at all."""
    from repro.optim.offload import default_memory_kind

    default = default_memory_kind()
    kinds: dict[str, str] = {}
    for name, leaf in zip(_flatten(tree), jax.tree_util.tree_leaves(tree)):
        kind = getattr(getattr(leaf, "sharding", None), "memory_kind", None)
        if kind is not None and kind != default:
            kinds[name] = kind
    return kinds


def save(ckpt_dir: str | Path, step: int, state: PyTree, extra: dict | None = None,
         spec_hash: str | None = None) -> Path:
    """Atomically write checkpoint for `step`. Returns the final directory.

    ``spec_hash`` (``OptimizerSpec.spec_hash()``) records which optimizer
    spec produced the state's layout; :func:`restore` verifies it so a
    resume under a different spec (different families/partitions → different
    state keys) fails loudly instead of silently mis-restoring.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    tmp = ckpt_dir / f"tmp.{step}.{os.getpid()}"
    final = ckpt_dir / f"step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(state)
    # fp8 payloads (qstate): store the raw bytes as uint8 — np.savez drops
    # ml_dtypes dtypes to void on reload; the manifest keeps the true dtype
    # and restore() views the bits back
    store = {k: (v.view(np.uint8) if str(v.dtype).startswith("float8") else v)
             for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **store)
    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in flat.items()},
        "extra": extra or {},
    }
    kinds = _memory_kinds(state)
    if kinds:
        manifest["memory_kinds"] = kinds
    if spec_hash is not None:
        manifest["spec_hash"] = spec_hash
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    # prune stale tmp dirs from preempted writers
    for stale in ckpt_dir.glob("tmp.*"):
        shutil.rmtree(stale, ignore_errors=True)
    return final


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*") if (p / "manifest.json").exists()
    )
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, like: PyTree, step: int | None = None,
            shardings: PyTree | None = None,
            spec_hash: str | None = None) -> tuple[PyTree, dict]:
    """Restore into the structure of `like` (shapes validated), re-sharding
    onto `shardings` if given (elastic resume on a different mesh).

    When both the caller and the manifest carry a ``spec_hash``, they must
    agree — a mismatch means the optimizer spec changed since the
    checkpoint was written and the state layout cannot be trusted.
    Checkpoints without a recorded hash restore freely (pre-spec format).
    """
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = ckpt_dir / f"step_{step:010d}"
    manifest = json.loads((d / "manifest.json").read_text())
    saved_hash = manifest.get("spec_hash")
    if spec_hash is not None and saved_hash is not None and spec_hash != saved_hash:
        raise ValueError(
            f"optimizer spec hash mismatch: checkpoint step {step} was written "
            f"under spec {saved_hash} but the current spec is {spec_hash}; "
            "refusing to restore optimizer state with a different layout")
    data = np.load(d / "arrays.npz")
    flat_like = _flatten(like)
    missing = set(flat_like) - set(data.files)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    names = list(_flatten(like).keys())
    out = []
    flat_sh = jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(names)
    for name, ref, sh in zip(names, leaves_like, flat_sh):
        arr = data[name]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {name}: ckpt {arr.shape} vs model {ref.shape}")
        if str(ref.dtype).startswith("float8") and arr.dtype == np.uint8:
            arr = arr.view(np.dtype(ref.dtype))  # bit-exact fp8 payload
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    return treedef.unflatten(out), manifest
