"""Architecture registry: the 10 assigned archs + the paper's own models.

``get_config(arch_id)`` returns the full ModelConfig; ``smoke_config`` a
reduced same-family config for CPU smoke tests; ``CELLS`` the full
(arch x shape) evaluation matrix with skip reasons.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "grok_1_314b",
    "deepseek_moe_16b",
    "yi_6b",
    "deepseek_7b",
    "qwen1_5_4b",
    "nemotron_4_15b",
    "recurrentgemma_2b",
    "whisper_base",
    "llava_next_34b",
    "mamba2_370m",
]

PAPER_IDS = ["transformer_base", "transformer_big"]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE


def recommended_decay_rate(model_family: str) -> float:
    """The paper's recommended SMMF beta2 decay rate (Algo 8 gamma) per
    model family: -0.5 for CNN-like models, -0.8 otherwise (Transformers).
    Single source for the launchers and the arch default specs."""
    return -0.5 if model_family == "cnn" else -0.8


def default_optimizer_spec(arch_id: str, lr: float = 1e-3):
    """The arch's default training ``OptimizerSpec``: SMMF with
    :func:`recommended_decay_rate` for the arch's model family.
    Round-tripped by ``tools/spec_lint.py`` in CI."""
    from repro.optim.spec import OptimizerSpec

    cfg = get_config(arch_id)
    return OptimizerSpec(
        family="smmf",
        hyperparams={"lr": lr, "decay_rate": recommended_decay_rate(cfg.family)})


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or a skip reason for one (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "skip: full quadratic attention cannot hold a 500k dense KV state"
    return "run"


def all_cells() -> list[tuple[str, str, str]]:
    """[(arch_id, shape_name, status)] for the 10x4 matrix."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            out.append((a, s.name, cell_status(cfg, s)))
    return out


__all__ = ["ARCH_IDS", "PAPER_IDS", "get_config", "smoke_config", "all_cells",
           "cell_status", "default_optimizer_spec", "recommended_decay_rate",
           "SHAPES"]
