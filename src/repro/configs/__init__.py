"""Architecture registry: the 10 assigned archs + the paper's own models.

``get_config(arch_id)`` returns the full ModelConfig; ``smoke_config`` a
reduced same-family config for CPU smoke tests; ``CELLS`` the full
(arch x shape) evaluation matrix with skip reasons.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

ARCH_IDS = [
    "grok_1_314b",
    "deepseek_moe_16b",
    "yi_6b",
    "deepseek_7b",
    "qwen1_5_4b",
    "nemotron_4_15b",
    "recurrentgemma_2b",
    "whisper_base",
    "llava_next_34b",
    "mamba2_370m",
]

PAPER_IDS = ["transformer_base", "transformer_big"]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE


def cell_status(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """'run' or a skip reason for one (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return "skip: full quadratic attention cannot hold a 500k dense KV state"
    return "run"


def all_cells() -> list[tuple[str, str, str]]:
    """[(arch_id, shape_name, status)] for the 10x4 matrix."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            out.append((a, s.name, cell_status(cfg, s)))
    return out


__all__ = ["ARCH_IDS", "PAPER_IDS", "get_config", "smoke_config", "all_cells", "cell_status", "SHAPES"]
