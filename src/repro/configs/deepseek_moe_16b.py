"""deepseek-moe-16b [moe]: 28L d2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 2 shared + 64 routed top-6, fine-grained experts. [arXiv:2401.06066; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab=102400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
)

SMOKE = ModelConfig(
    name="deepseek-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    moe_d_ff=48,
    vocab=256,
    n_experts=8,
    top_k=3,
    n_shared_experts=2,
    dtype="float32",
)
