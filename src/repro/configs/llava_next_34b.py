"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
anyres-tiling vision frontend STUB (input_specs provides precomputed patch
embeddings, 576-patch prefix). [hf:llava-hf/llava-v1.6-mistral-7b-hf;
unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    n_patches=576,
)

SMOKE = ModelConfig(
    name="llava-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    n_patches=8,
    dtype="float32",
)
