"""mamba2-370m [ssm]: 48L d1024 (attention-free) vocab=50280, SSD
(state-space duality) with ssm_state=128, headdim 64, expand 2.
[arXiv:2405.21060; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    d_ff=0,
    vocab=256,
    ssm_state=16,
    ssm_headdim=16,
    ssm_expand=2,
    ssm_chunk=16,
    tie_embeddings=True,
    dtype="float32",
)
