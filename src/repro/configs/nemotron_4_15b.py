"""nemotron-4-15b [dense]: 32L d6144 48H (GQA kv=8) d_ff=24576 vocab=256000,
GQA + squared-ReLU MLP (non-gated). [arXiv:2402.16819; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    activation="sq_relu",
    gated_mlp=False,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="nemotron-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab=256,
    activation="sq_relu",
    gated_mlp=False,
    norm="layernorm",
    dtype="float32",
)
