"""recurrentgemma-2b [hybrid]: 26L d2560 10H (GQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention (window 2048), 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    attn_window=2048,
    rglru_ratio=2,
    lru_width=2560,
    activation="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=256,
    attn_window=16,
    rglru_ratio=2,
    lru_width=64,
    activation="gelu",
    dtype="float32",
)
