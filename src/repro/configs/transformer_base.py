"""Transformer-base (Vaswani et al. 2017) — the paper's own full-training
model (Table 2, WMT32k): 6+6 enc-dec, d512 8H d_ff=2048, vocab 32k."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="transformer-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=32000,
    encoder_layers=6,
    encoder_seq=256,
    norm="layernorm",
    gated_mlp=False,
    activation="relu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="transformer-base-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    encoder_layers=2,
    encoder_seq=24,
    norm="layernorm",
    gated_mlp=False,
    activation="relu",
    tie_embeddings=True,
    dtype="float32",
)
