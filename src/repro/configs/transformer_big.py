"""Transformer-big (Vaswani et al. 2017) — the paper's Table 2 big model:
6+6 enc-dec, d1024 16H d_ff=4096, vocab 32k."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="transformer-big",
    family="encdec",
    n_layers=6,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=32000,
    encoder_layers=6,
    encoder_seq=256,
    norm="layernorm",
    gated_mlp=False,
    activation="relu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="transformer-big-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    encoder_layers=2,
    encoder_seq=24,
    norm="layernorm",
    gated_mlp=False,
    activation="relu",
    tie_embeddings=True,
    dtype="float32",
)
