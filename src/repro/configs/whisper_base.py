"""whisper-base [audio]: 6L d512 8H (kv=8) d_ff=2048 vocab=51865, enc-dec
with conv frontend STUB (input_specs provides precomputed frame embeddings,
1500 frames). [arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    encoder_layers=6,
    encoder_seq=1500,
    norm="layernorm",
    gated_mlp=False,
    activation="gelu",
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    encoder_layers=2,
    encoder_seq=24,
    norm="layernorm",
    gated_mlp=False,
    activation="gelu",
    tie_embeddings=True,
    dtype="float32",
)
