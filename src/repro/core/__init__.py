"""Core SMMF building blocks (the paper's contribution)."""

from repro.core.matricize import effective_shape, square_matricize, unmatricize
from repro.core.nnmf import nnmf_compress, nnmf_decompress
from repro.core.plan import Bucket, LeafPlan, build_buckets, smmf_planner
from repro.core.schedules import beta1_schedule, beta2_schedule
from repro.core.signpack import pack_signs, unpack_signs
from repro.core.smmf import SMMFState, smmf, smmf_local

__all__ = [
    "effective_shape",
    "square_matricize",
    "unmatricize",
    "nnmf_compress",
    "nnmf_decompress",
    "beta1_schedule",
    "beta2_schedule",
    "pack_signs",
    "unpack_signs",
    "smmf",
    "smmf_local",
    "SMMFState",
    "LeafPlan",
    "Bucket",
    "build_buckets",
    "smmf_planner",
]
