"""Square-matricization (paper Algorithm 2).

Given a rank-d tensor with N elements, find (n_hat, m_hat) with
n_hat * m_hat = N minimizing |n_hat - m_hat| (equivalently n_hat + m_hat,
Theorem 3.2), and reshape to that matrix. The factor search is plain Python
over static shapes — it runs once at optimizer init (O(sqrt(N)), Algo 2) and
never appears in the traced graph; the traced op is a single reshape.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def effective_shape(numel: int) -> tuple[int, int]:
    """Paper Algorithm 2 / reference code `_get_effective_shape`.

    Returns (n_hat, m_hat) with n_hat >= m_hat, n_hat * m_hat = numel and
    m_hat the largest divisor <= sqrt(numel).
    """
    if numel <= 0:
        raise ValueError(f"numel must be positive, got {numel}")
    s = math.isqrt(numel)
    if s * s == numel:
        return (s, s)
    for i in range(s, 0, -1):
        if numel % i == 0:
            return (numel // i, i)
    return (numel, 1)  # unreachable (i=1 always divides)


def square_matricize(x: jnp.ndarray) -> jnp.ndarray:
    """Reshape any-rank tensor to its nearest-square matrix."""
    n, m = effective_shape(int(x.size))
    return x.reshape(n, m)


def unmatricize(x: jnp.ndarray, shape: tuple[int, ...]) -> jnp.ndarray:
    """Inverse of square_matricize."""
    return x.reshape(shape)
