"""One-shot factorizers for non-negative momentum matrices.

Rank-1 (paper Algorithm 4/5, after Shazeer & Stern 2018):

compress:  r = M @ 1, c = 1^T @ M, then normalize the *smaller* vector
           (paper Algo 4: normalize r if n_hat <= m_hat else c) so the outer
           product has the right scale with one division.
decompress: M_hat = r (outer) c.

Rank-k (Adapprox-style, Zhao et al. 2024): the positive rank-1
Algorithm-4 baseline plus a one-shot randomized range-finder sketch of
the *residual* — project ``M - r1 c1^T`` onto a fixed Gaussian test
matrix, take an orthonormal range basis Q, and append ``(Q, resid^T Q)``
as the remaining k-1 factor columns. ``R @ C^T`` is then
``r1 c1^T + Q Q^T resid``: every row/column with mass keeps a strictly
positive baseline (the property denominator-side consumers rely on — a
pure signed sketch can reconstruct a low-traffic row as ~0 and turn
``m / (sqrt(v) + eps)`` into a 1/eps blow-up), while the signed
correction refines the dominant structure. Consumers still clamp the
reconstruction at 0. The ``rank=1`` path delegates to
:func:`nnmf_compress` and is bitwise-identical to it.

All in f32. The rank-1 factorization is exact for rank-1 non-negative
matrices and is the I-divergence-optimal rank-1 approximation otherwise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def nnmf_compress(mat: jnp.ndarray, eps: float = 0.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Factorize a non-negative (n, m) matrix into (r: (n,), c: (m,))."""
    n, m = mat.shape
    r = jnp.sum(mat, axis=1)
    c = jnp.sum(mat, axis=0)
    # Guard the denominator: an all-zero moment (step-1 state, frozen
    # groups) would otherwise evaluate 0/0 in the discarded where-branch
    # and trip jax_debug_nans.
    if n <= m:
        total = jnp.sum(r)
        r = r / jnp.where(total > 0, total, 1.0)
    else:
        total = jnp.sum(c)
        c = c / jnp.where(total > 0, total, 1.0)
    return r, c


def nnmf_decompress(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Outer product reconstruction (paper Algorithm 3)."""
    return jnp.outer(r, c)


def _sketch_matrix(m: int, rank: int) -> jnp.ndarray:
    """Fixed Gaussian test matrix (m, rank), deterministic in the shape.

    The seed depends only on the static geometry so recompression at every
    step reuses one projection — no per-step randomness, no state.
    """
    key = jax.random.PRNGKey(m * 1000003 + rank)
    return jax.random.normal(key, (m, rank), dtype=jnp.float32)


def nnmf_compress_k(
    mat: jnp.ndarray, rank: int, eps: float = 0.0
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Rank-k factorization of a batched (B, n, m) stack.

    Returns ``(R: (B, n, k), C: (B, m, k))`` with ``R @ C^T`` the rank-k
    range-finder approximation. ``rank=1`` delegates to the batched
    Algorithm-4 path so it stays bitwise-identical to the paper layout.
    """
    if mat.ndim != 3:
        raise ValueError(f"nnmf_compress_k wants a (B, n, m) stack, got {mat.shape}")
    _, n, m = mat.shape
    r1, c1 = jax.vmap(nnmf_compress)(mat)
    if rank <= 1:
        return r1[:, :, None], c1[:, :, None]
    resid = mat - r1[:, :, None] * c1[:, None, :]
    omega = _sketch_matrix(m, rank - 1)
    y = resid @ omega                    # (B, n, k-1)
    q, _ = jnp.linalg.qr(y)              # (B, n, k-1) orthonormal range basis
    coeff = jnp.einsum("bnm,bnk->bmk", resid, q)
    r = jnp.concatenate([r1[:, :, None], q], axis=2)
    c = jnp.concatenate([c1[:, :, None], coeff], axis=2)
    return r, c


def nnmf_decompress_k(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Batched rank-k reconstruction ``R @ C^T`` → (B, n, m).

    The range-finder factors are signed, so denominator-side consumers
    clamp (``jnp.maximum(..., 0)``) before taking square roots.
    """
    return jnp.einsum("bnk,bmk->bnm", r, c)
