"""One-shot rank-1 NNMF (paper Algorithm 4/5, after Shazeer & Stern 2018).

compress:  r = M @ 1, c = 1^T @ M, then normalize the *smaller* vector
           (paper Algo 4: normalize r if n_hat <= m_hat else c) so the outer
           product has the right scale with one division.
decompress: M_hat = r (outer) c.

All in f32. The factorization is exact for rank-1 non-negative matrices and
is the I-divergence-optimal rank-1 approximation otherwise.
"""

from __future__ import annotations

import jax.numpy as jnp


def nnmf_compress(mat: jnp.ndarray, eps: float = 0.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Factorize a non-negative (n, m) matrix into (r: (n,), c: (m,))."""
    n, m = mat.shape
    r = jnp.sum(mat, axis=1)
    c = jnp.sum(mat, axis=0)
    if n <= m:
        total = jnp.sum(r)
        r = jnp.where(total > 0, r / total, r)
    else:
        total = jnp.sum(c)
        c = jnp.where(total > 0, c / total, c)
    return r, c


def nnmf_decompress(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Outer product reconstruction (paper Algorithm 3)."""
    return jnp.outer(r, c)
