"""Static per-leaf update plans and geometry bucketing.

The SMMF paper's factorization applies uniformly to any tensor rank, but a
naive implementation dispatches every pytree leaf through a Python loop and
launches one (tiny) fused op per leaf. This module computes, once at
optimizer ``init``, a static :class:`LeafPlan` per parameter — factorized
vs. dense-fallback, ``(blocks, rows, cols)`` working geometry, fused-kernel
eligibility and pad geometry, sharding-constraint kind — and groups
same-geometry leaves into :class:`Bucket` s. The update engine
(``repro.optim.engine``) then stacks each bucket's leaves along a leading
axis and runs **one** vectorized (or fused Pallas) launch per bucket instead
of one per leaf: a Transformer step's hundreds of per-leaf ops collapse into
a handful of large ones.

Everything here is plain Python over static shapes: it runs at trace time
only and never appears in the compiled graph.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.core.matricize import effective_shape
from repro.core.signpack import packed_width

# Default Pallas tile of the fused SMMF update kernel. This module is the
# single source: kernels/smmf_update/kernel.py, repro.optim.families,
# repro.optim.engine and repro.core.smmf all import it from here (plan.py
# sits below every one of them in the import graph, so no cycle), and
# tests/test_kernel_block_sync.py asserts all surfaces agree.
DEFAULT_KERNEL_BLOCK = (256, 512)


def block_shape(numel: int, blocks: int) -> tuple[int, int, int]:
    """(B, rows_per_block, cols) for the blockwise SMMF factorization.

    ``blocks=1`` is the paper-faithful global variant. For ``blocks=K`` the
    square matrix is split into K row-blocks factorized independently; if the
    row axis is indivisible each of the K equal element-chunks is
    re-matricized to its own square, and if the element count itself is
    indivisible the plan degrades gracefully to global.
    """
    n, m = effective_shape(numel)
    if blocks <= 1:
        return 1, n, m
    if n % blocks == 0:
        return blocks, n // blocks, m
    if numel % blocks == 0:
        n2, m2 = effective_shape(numel // blocks)
        return blocks, n2, m2
    return 1, n, m  # indivisible: degrade gracefully to global


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Static update recipe for one parameter leaf.

    ``geometry`` is the per-leaf working shape the update math runs in:
    ``(blocks, rows, cols)`` for square-matricized SMMF leaves, the native
    shape for last-two-axes (Adafactor/CAME) and axis-cover (SM3) leaves,
    and ``(numel,)`` for dense fallback leaves. Leaves sharing
    ``(group, factorized, geometry)`` are bucketable into one stacked
    launch; buckets never span partition groups.

    Group-aware fields (set by ``repro.optim.spec`` when lowering an
    ``OptimizerSpec``; the default values reproduce the single-family
    layout, whose bucket keys carry no group prefix):

    * ``group`` — partition-group label ("" = the spec's default group);
    * ``freeze`` — the leaf holds **no** optimizer state and always gets a
      zero update (it is excluded from every bucket);
    * ``solo`` — per-leaf baseline for this leaf (its bucket key is
      suffixed ``@index`` so it is never grouped);
    * ``fuse`` — a dense-fallback leaf that may be concatenated into its
      group's flat ``dense:flat:<dtype>`` bucket.
    """

    index: int                      # position in the flattened params
    shape: tuple[int, ...]          # original leaf shape
    factorized: bool                # factorized vs dense-fallback
    geometry: tuple[int, ...]       # per-leaf working geometry (see above)
    blocks: int = 1                 # SMMF blockwise count (B)
    kernel_ok: bool = False         # fused Pallas kernel eligible
    constraint: str | None = None   # ctx.constrain kind for the working matrix
    dtype: str = "float32"          # parameter dtype (fused-dense grouping)
    group: str = ""                 # partition-group label ("" = default)
    freeze: bool = False            # no state, zero update
    solo: bool = False              # per-leaf baseline for this leaf
    fuse: bool = False              # dense leaf eligible for flat fusion
    state_axes: tuple[str, ...] | None = None  # per-group stack-axis override
    quant: str | None = None        # qstate storage mode (int8/fp8/None)
    transport: str | None = None    # gradient transport (int8/rank1/None)
    transport_flush_every: int = 8  # rank1 dense-residual-flush period
    momentum: bool = True           # SMMF: first-moment factors + signs exist
    rank: int = 1                   # factor rank k (1 = the paper's vectors)

    @property
    def numel(self) -> int:
        """Total element count of the original leaf."""
        return int(math.prod(self.shape)) if self.shape else 1

    @property
    def group_prefix(self) -> str:
        """State-key prefix of the leaf's partition group (empty for the
        default group, so single-family state keys stay stable)."""
        return f"{self.group}/" if self.group else ""

    @property
    def bucket_key(self) -> str:
        """Deterministic state-dict key prefix:
        ``[<group>/]fac:GEOM`` / ``[<group>/]dense:GEOM``. Rank-k factored
        plans (``rank > 1``) suffix the geometry with ``xr<k>`` — state
        shapes carry an extra trailing factor axis, so the key must differ;
        rank-1 keys are byte-identical to the pre-rank layout."""
        kind = "fac" if self.factorized else "dense"
        key = f"{self.group_prefix}{kind}:" + "x".join(map(str, self.geometry))
        if self.factorized and self.rank > 1:
            key += f"xr{self.rank}"
        return key


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A group of leaves updated by one stacked (or concatenated) launch.

    Regular buckets hold same-geometry leaves stacked along a new leading
    axis of length ``size``. **Fused dense** buckets (``fused=True``, key
    ``dense:flat:<dtype>``) instead concatenate *all* dense-fallback leaves
    of one dtype into a single flat ``(1, total_numel)`` row — dense math is
    elementwise, so fallback-heavy trees dispatch one launch per dtype
    instead of one per distinct element count.
    """

    key: str
    factorized: bool
    geometry: tuple[int, ...]
    plans: tuple[LeafPlan, ...]
    fused: bool = False

    @property
    def size(self) -> int:
        """Number of parameter leaves in this bucket."""
        return len(self.plans)

    @property
    def stack(self) -> int:
        """Leading stack-axis length of the bucket's state arrays (1 when
        the bucket is a fused flat concatenation)."""
        return 1 if self.fused else len(self.plans)

    @property
    def indices(self) -> tuple[int, ...]:
        """Flat-param indices of the bucket's leaves, in stack order."""
        return tuple(p.index for p in self.plans)

    @property
    def offsets(self) -> tuple[int, ...]:
        """Per-leaf start offsets into the fused flat row (fused buckets)."""
        out, off = [], 0
        for p in self.plans:
            out.append(off)
            off += p.numel
        return tuple(out)

    def segment_ids(self):
        """Static contained-leaf segment ids of the fused flat row (int32
        numpy, one entry per element) — the single source for every
        per-leaf reduction over a fused bucket (the Adafactor/CAME
        segment-aware RMS clip and the qstate per-leaf quantization
        scales must agree on it)."""
        import numpy as np

        return np.repeat(np.arange(self.size, dtype=np.int32),
                         [p.numel for p in self.plans])

    @property
    def kernel_ok(self) -> bool:
        """True iff every leaf in the bucket planned onto the fused kernel."""
        return self.factorized and all(p.kernel_ok for p in self.plans)

    @property
    def state_axes(self) -> tuple[str, ...] | None:
        """The partition group's stack-axis override (buckets never span
        groups, so every plan agrees; None = the default (pod, data)
        preference chain of :func:`stack_axes`)."""
        return self.plans[0].state_axes

    @property
    def quant(self) -> str | None:
        """The partition group's qstate storage mode (buckets never span
        groups, so every plan agrees; None = full-precision f32 state)."""
        return self.plans[0].quant

    @property
    def transport(self) -> str | None:
        """The partition group's gradient-transport mode (buckets never
        span groups, so every plan agrees; None = dense f32 traffic)."""
        return self.plans[0].transport

    @property
    def transport_flush_every(self) -> int:
        """rank1 transport's dense-residual-flush period (steps)."""
        return self.plans[0].transport_flush_every

    @property
    def rank(self) -> int:
        """Factor rank k of the bucket's factored state (rank is part of
        the bucket key, so every plan agrees; 1 = the rank-1 vector pair)."""
        return self.plans[0].rank


def build_buckets(
    plans: Sequence[LeafPlan], bucket: bool = True, fuse_dense: bool = False,
) -> tuple[Bucket, ...]:
    """Group plans by (group, factorized, geometry), preserving first-seen
    order. Buckets never span partition groups (each plan's ``group`` label
    is baked into its bucket key).

    ``bucket=False`` (or a plan's ``solo`` flag) gives the per-leaf
    baseline: one single-leaf bucket per parameter (key suffixed with the
    leaf index so state names stay unique). ``fuse_dense=True`` (or a dense
    plan's ``fuse`` flag) merges dense-fallback leaves of a (group, dtype)
    into one concatenated flat bucket (``[<group>/]dense:flat:<dtype>``,
    geometry ``(total_numel,)``) so dense leaves cost one launch per group
    and dtype. Only valid for optimizers whose dense math is purely
    elementwise or segment-aware (a registry capability —
    ``repro.optim.families``); ignored in per-leaf mode. ``freeze`` plans
    hold no state and join no bucket.
    """
    groups: dict[str, list[LeafPlan]] = {}
    for p in plans:
        if p.freeze:
            continue
        key = p.bucket_key if bucket and not p.solo else f"{p.bucket_key}@{p.index}"
        groups.setdefault(key, []).append(p)
    out: list[Bucket] = []
    dense_flat: dict[tuple[str, str], list[LeafPlan]] = {}
    for key, ps in groups.items():
        p0 = ps[0]
        fusable = bucket and not p0.solo and not p0.factorized \
            and (fuse_dense or p0.fuse)
        if fusable:
            for p in ps:
                dense_flat.setdefault((p.group_prefix, p.dtype), []).append(p)
            continue
        out.append(Bucket(key=key, factorized=p0.factorized,
                          geometry=p0.geometry, plans=tuple(ps)))
    for (prefix, dt), ps in dense_flat.items():
        total = sum(p.numel for p in ps)
        out.append(Bucket(key=f"{prefix}dense:flat:{dt}", factorized=False,
                          geometry=(total,), plans=tuple(ps), fused=True))
    return tuple(out)


# ---------------------------------------------------------------------------
# bucket schedules (dispatch order of the per-bucket update launches)
# ---------------------------------------------------------------------------

def grad_ready_rank(bucket: Bucket) -> int:
    """Reverse-mode readiness key of a bucket: the *minimum* flat-leaf
    index among its plans.

    A bucket's stacked update can only start once every one of its leaves
    has a gradient. Reverse-mode AD emits gradients roughly in reverse
    forward (flatten) order, so the leaf that gates a bucket is its
    lowest-index one — the earliest in the forward pass, whose gradient
    arrives **last** in the backward. Buckets with a *high* minimum index
    are therefore fully ready while the backward is still working through
    the earlier layers.
    """
    return min(p.index for p in bucket.plans)


def bucket_schedule(buckets: Sequence[Bucket],
                    order: str | None = "plan") -> tuple[int, ...]:
    """Dispatch order (a permutation of bucket positions) for the engine's
    per-bucket update launches.

    * ``"plan"`` / ``None`` — construction order (the barrier baseline:
      whatever order :func:`build_buckets` emitted);
    * ``"grad"`` — reverse-mode gradient-availability order: descending
      :func:`grad_ready_rank`, ties broken by construction position. Under
      this order the update chain walks the buckets in the same order the
      backward finishes their gradients, so a scheduler that interleaves
      the chained updates with the remaining backward compute
      (``repro.optim.spec`` emits ``lax.optimization_barrier`` links)
      always has a ready bucket to overlap — bucket *i*'s scatter
      transport hides behind bucket *i+1*'s (and the backward's) compute.

    Pure static plan math: same buckets + same order string → the same
    permutation, so a scheduled update is a deterministic re-emission (and
    bitwise-identical — see ``tests/test_overlap_offload.py``) of the
    barrier-order program.
    """
    if order in (None, "plan"):
        return tuple(range(len(buckets)))
    if order == "grad":
        return tuple(sorted(range(len(buckets)),
                            key=lambda i: (-grad_ready_rank(buckets[i]), i)))
    raise ValueError(f"unknown bucket schedule {order!r} "
                     "(want 'plan', 'grad', or None)")


# ---------------------------------------------------------------------------
# per-bucket partition wants (mesh placement of the stacked state)
# ---------------------------------------------------------------------------

# Default preference chain for the stacked leading axis: split over the pod
# axis times the fsdp axis on multi-pod meshes, plain fsdp otherwise.
DEFAULT_STACK_AXES = ("pod", "data")


def bucket_stack_wants(leading: int, data_size: int) -> bool:
    """True when a bucket's stacked leading axis (``K*B`` for SMMF, ``K``
    for the other engine optimizers) should carry the "data"/fsdp mesh axis:
    the axis must exist (size > 1) and divide the stack.

    Single-axis special case of :func:`stack_axes`, kept as the cheap gate
    for callers that only care about the flat fsdp axis.
    """
    return data_size > 1 and leading % data_size == 0


def stack_axes(
    leading: int,
    axis_sizes: dict[str, int],
    prefer: tuple[str, ...] = DEFAULT_STACK_AXES,
) -> tuple[str, ...] | None:
    """Multi-axis assignment for a bucket's stacked leading axis.

    Returns the ordered subset of ``prefer`` (axis order preserved) with the
    **largest total way-count** such that every chosen axis exists in the
    mesh with size > 1 and the product of the chosen sizes divides
    ``leading`` — e.g. a 32-leaf stack on a ``(pod=2, data=16)`` mesh gets
    ``("pod", "data")`` (32-way), a 16-leaf stack gets ``("data",)``, and a
    6-leaf stack gets ``("pod",)``. ``None`` means no subset fits (the
    caller falls back to the working-matrix rules).

    ``prefer`` is the per-group ``state_sharding`` override hook: expert
    groups pass e.g. ``("model",)`` so their stacks ride the expert-parallel
    axis instead of fsdp. At most the first 8 preferred axes are considered
    (subset enumeration); real meshes have 2-3.
    """
    present = [a for a in prefer[:8] if axis_sizes.get(a, 0) > 1]
    best: tuple[str, ...] | None = None
    best_ways = 1
    for mask in range(1, 1 << len(present)):
        combo = tuple(a for i, a in enumerate(present) if mask >> i & 1)
        ways = math.prod(axis_sizes[a] for a in combo)
        if ways > best_ways and leading % ways == 0:
            best, best_ways = combo, ways
    return best


def _stack_want(st: tuple[str, ...] | None):
    """Collapse a 1-axis assignment to the bare name so single-axis meshes
    produce specs identical to the pre-multi-axis (PR 2/3) layout."""
    if st is None:
        return None
    return st[0] if len(st) == 1 else st


def bucket_partition_wants(
    kind: str,
    shape: tuple[int, ...],
    axis_sizes: dict[str, int],
    stack_over: tuple[str, ...] | None = None,
) -> tuple:
    """Axis-name *wants* for one stacked SMMF state tensor of a bucket.

    ``kind`` is one of ``"matrix"`` (the (K·B, n, m) working matrix),
    ``"rows"`` (r_m / r_v, (K·B, n)), ``"cols"`` (c_m / c_v, (K·B, m)),
    ``"sign"`` (the (K·B·n, ceil(m/8)) packed-sign matrix) or ``"dense"``
    (a (K, numel) / (1, total) dense-fallback moment). Rank-k factors carry
    one extra trailing axis — ``"rows"``/``"cols"`` on a 3-D
    ``(K·B, dim, k)`` shape (and per-column quant scales on
    ``(K·B, 1, k)``) get the 2-D wants padded with ``None`` for every
    trailing axis, so the k axis is never sharded. ``axis_sizes`` maps
    mesh axis name → size (missing = absent); ``stack_over`` replaces the
    default ``("pod", "data")`` stack preference chain (the per-group
    ``state_sharding`` override of ``repro.optim.spec.Partition``).
    Preference order:

    * stack axis → the best :func:`stack_axes` subset of the preference
      chain — every per-device state slice then shrinks ~linearly with the
      assigned way-count and the per-stack-entry factorization needs zero
      cross-shard collectives;
    * otherwise fall back to the working-matrix rules (rows → "data",
      cols → "model"), which is the pre-sharded (PR 1) placement.

    An axis is never assigned twice: when the stack carries "model" (an
    expert-group override) the column/sign minor dims drop their "model"
    want. Divisibility of the *non-stack* dims is checked downstream by
    ``rules.fit_spec`` (indivisible axes degrade to replication).
    """
    prefer = tuple(stack_over) if stack_over else DEFAULT_STACK_AXES
    if kind == "dense":
        elem = stack_axes(shape[1], axis_sizes, prefer)
        return (None, _stack_want(elem) or "data")
    st = stack_axes(shape[0], axis_sizes, prefer)
    minor_model = "model" if "model" not in (st or ()) else None
    if kind == "sign":
        return (_stack_want(st) or "data", minor_model)
    if kind == "matrix":
        return ((_stack_want(st), None, minor_model) if st
                else (None, "data", "model"))
    if kind == "rows":
        want = (_stack_want(st), None) if st else (None, "data")
    elif kind == "cols":
        want = (_stack_want(st), minor_model) if st else (None, "model")
    else:
        raise ValueError(f"unknown bucket state kind: {kind!r}")
    # rank-k factors: pad the trailing factor axis (never sharded)
    return want + (None,) * (len(shape) - 2)


# ---------------------------------------------------------------------------
# per-optimizer planners
# ---------------------------------------------------------------------------

def smmf_planner(
    blocks: int = 1,
    vector_reshape: bool = True,
    use_kernel: bool = False,
    momentum: bool = True,
    rank: int = 1,
) -> Callable[[int, tuple[int, ...]], LeafPlan]:
    """Planner for square-matricized SMMF leaves.

    Mirrors the reference code's policy: rank-1 tensors bypass factorization
    unless ``vector_reshape`` (default True); scalars never factorize. The
    fused kernel is eligible for every factorized geometry (padding to the
    clamped tile, :func:`clamp_kernel_block`, handles lane alignment).
    ``momentum=False`` marks the beta1=None variant (no momentum factors,
    no sign matrix — state and boundary accounting differ). ``rank > 1``
    plans rank-k factor matrices instead of the paper's vectors (the
    Adapprox generalization; the fused kernel is rank-1 only, so rank-k
    plans never take it) — rank-1 plans are byte-identical to the
    pre-rank layout.
    """

    def plan(index: int, shape: tuple[int, ...]) -> LeafPlan:
        numel = int(math.prod(shape)) if shape else 1
        squeezed = [s for s in shape if s != 1]
        factorized = numel > 1 and not (len(squeezed) <= 1 and not vector_reshape)
        if not factorized:
            return LeafPlan(index, shape, False, (numel,), momentum=momentum,
                            rank=rank)
        b, n, m = block_shape(numel, blocks)
        return LeafPlan(
            index, shape, True, (b, n, m), blocks=b,
            kernel_ok=use_kernel and rank == 1, constraint="smmf_matrix",
            momentum=momentum, rank=rank,
        )

    return plan


def lasttwo_planner() -> Callable[[int, tuple[int, ...]], LeafPlan]:
    """Planner for Adafactor/CAME: factor rank>=2 leaves over the last two
    axes (leading axes sliced), keep rank<=1 leaves dense."""

    def plan(index: int, shape: tuple[int, ...]) -> LeafPlan:
        numel = int(math.prod(shape)) if shape else 1
        if len(shape) >= 2:
            return LeafPlan(index, shape, True, shape)
        return LeafPlan(index, shape, False, (numel,))

    return plan


def axiscover_planner() -> Callable[[int, tuple[int, ...]], LeafPlan]:
    """Planner for SM3: one accumulator vector per axis (cover sets), so the
    working geometry is just the native shape (scalars lift to (1,))."""

    def plan(index: int, shape: tuple[int, ...]) -> LeafPlan:
        geom = shape if shape else (1,)
        return LeafPlan(index, shape, True, geom)

    return plan


# ---------------------------------------------------------------------------
# kernel geometry + state accounting helpers
# ---------------------------------------------------------------------------

def clamp_kernel_block(n: int, m: int, block: tuple[int, int]) -> tuple[int, int]:
    """Clamp kernel tiles to the lane-padded problem so tiny layers don't
    blow up into a full default tile (the single source of this policy —
    kernels/smmf_update/ops.py calls it at dispatch).

    Both tile dims must be positive multiples of 8 (the packed-sign tile is
    bm/8 bytes wide); the clamp preserves that property.
    """
    bn, bm = block
    if bn <= 0 or bm <= 0 or bn % 8 or bm % 8:
        raise ValueError(f"kernel block dims must be positive multiples of 8, got {block}")
    bn = min(bn, max(8, -(-n // 8) * 8))
    bm = min(bm, max(128, -(-m // 128) * 128))
    return bn, bm


def smmf_plan_bytes(p: LeafPlan, quant: str | None = None,
                    momentum: bool = True) -> int:
    """Predicted persistent optimizer-state bytes for one SMMF leaf plan
    (the paper's 'optimizer memory'): factor vectors + packed signs, or the
    dense fallback's full M and V. Only meaningful for plans produced by
    :func:`smmf_planner` (geometry (blocks, rows, cols)).

    ``quant`` prices the qstate storage codec (``repro.optim.qstate``):
    factor vectors (and dense buffers) drop to 1 byte/element plus one f32
    scale per stacked row; the packed sign matrix is already 1 bit/element
    and does not shrink. ``momentum=False`` prices the beta1=None variant
    (no momentum factors, no sign matrix) — the configuration where
    quantization cuts the *whole* state ~4x.
    """
    elem = 1 if quant else 4
    if not p.factorized:
        n_buf = 2 if momentum else 1
        return n_buf * (elem * p.numel + (4 if quant else 0))
    b, n, m = p.geometry
    vecs = 2 if momentum else 1           # (r_m, c_m) and/or (r_v, c_v)
    out = elem * vecs * (b * n + b * m)   # factor vector payloads
    if quant:
        out += 4 * 2 * vecs * b           # one f32 scale per stacked row
    if momentum:
        out += b * n * packed_width(m)    # packed sign bits (never shrink)
    return out


def smmf_state_bytes(plans: Sequence[LeafPlan], quant: str | None = None,
                     momentum: bool = True) -> int:
    """Predicted persistent SMMF optimizer-state bytes for a whole plan set
    (see :func:`smmf_plan_bytes`; SMMF planner geometries only)."""
    return sum(smmf_plan_bytes(p, quant=quant, momentum=momentum)
               for p in plans)
