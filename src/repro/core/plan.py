"""Static per-leaf update plans and geometry bucketing.

The SMMF paper's factorization applies uniformly to any tensor rank, but a
naive implementation dispatches every pytree leaf through a Python loop and
launches one (tiny) fused op per leaf. This module computes, once at
optimizer ``init``, a static :class:`LeafPlan` per parameter — factorized
vs. dense-fallback, ``(blocks, rows, cols)`` working geometry, fused-kernel
eligibility and pad geometry, sharding-constraint kind — and groups
same-geometry leaves into :class:`Bucket` s. The update engine
(``repro.optim.engine``) then stacks each bucket's leaves along a leading
axis and runs **one** vectorized (or fused Pallas) launch per bucket instead
of one per leaf: a Transformer step's hundreds of per-leaf ops collapse into
a handful of large ones.

Everything here is plain Python over static shapes: it runs at trace time
only and never appears in the compiled graph.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

from repro.core.matricize import effective_shape
from repro.core.signpack import packed_width


def block_shape(numel: int, blocks: int) -> tuple[int, int, int]:
    """(B, rows_per_block, cols) for the blockwise SMMF factorization.

    ``blocks=1`` is the paper-faithful global variant. For ``blocks=K`` the
    square matrix is split into K row-blocks factorized independently; if the
    row axis is indivisible each of the K equal element-chunks is
    re-matricized to its own square, and if the element count itself is
    indivisible the plan degrades gracefully to global.
    """
    n, m = effective_shape(numel)
    if blocks <= 1:
        return 1, n, m
    if n % blocks == 0:
        return blocks, n // blocks, m
    if numel % blocks == 0:
        n2, m2 = effective_shape(numel // blocks)
        return blocks, n2, m2
    return 1, n, m  # indivisible: degrade gracefully to global


@dataclasses.dataclass(frozen=True)
class LeafPlan:
    """Static update recipe for one parameter leaf.

    ``geometry`` is the per-leaf working shape the update math runs in:
    ``(blocks, rows, cols)`` for square-matricized SMMF leaves, the native
    shape for last-two-axes (Adafactor/CAME) and axis-cover (SM3) leaves,
    and ``(numel,)`` for dense fallback leaves. Leaves sharing
    ``(factorized, geometry)`` are bucketable into one stacked launch.
    """

    index: int                      # position in the flattened params
    shape: tuple[int, ...]          # original leaf shape
    factorized: bool                # factorized vs dense-fallback
    geometry: tuple[int, ...]       # per-leaf working geometry (see above)
    blocks: int = 1                 # SMMF blockwise count (B)
    kernel_ok: bool = False         # fused Pallas kernel eligible
    constraint: str | None = None   # ctx.constrain kind for the working matrix

    @property
    def numel(self) -> int:
        return int(math.prod(self.shape)) if self.shape else 1

    @property
    def bucket_key(self) -> str:
        kind = "fac" if self.factorized else "dense"
        return f"{kind}:" + "x".join(map(str, self.geometry))


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A group of same-geometry leaves updated by one stacked launch."""

    key: str
    factorized: bool
    geometry: tuple[int, ...]
    plans: tuple[LeafPlan, ...]

    @property
    def size(self) -> int:
        return len(self.plans)

    @property
    def indices(self) -> tuple[int, ...]:
        return tuple(p.index for p in self.plans)

    @property
    def kernel_ok(self) -> bool:
        return self.factorized and all(p.kernel_ok for p in self.plans)


def build_buckets(plans: Sequence[LeafPlan], bucket: bool = True) -> tuple[Bucket, ...]:
    """Group plans by (factorized, geometry), preserving first-seen order.

    ``bucket=False`` gives the per-leaf baseline: one single-leaf bucket per
    parameter (key suffixed with the leaf index so state names stay unique).
    """
    groups: dict[str, list[LeafPlan]] = {}
    for p in plans:
        key = p.bucket_key if bucket else f"{p.bucket_key}@{p.index}"
        groups.setdefault(key, []).append(p)
    return tuple(
        Bucket(key=key, factorized=ps[0].factorized, geometry=ps[0].geometry, plans=tuple(ps))
        for key, ps in groups.items()
    )


# ---------------------------------------------------------------------------
# per-optimizer planners
# ---------------------------------------------------------------------------

def smmf_planner(
    blocks: int = 1,
    vector_reshape: bool = True,
    use_kernel: bool = False,
) -> Callable[[int, tuple[int, ...]], LeafPlan]:
    """Planner for square-matricized SMMF leaves.

    Mirrors the reference code's policy: rank-1 tensors bypass factorization
    unless ``vector_reshape`` (default True); scalars never factorize. The
    fused kernel is eligible for every factorized geometry (padding to the
    clamped tile, :func:`clamp_kernel_block`, handles lane alignment).
    """

    def plan(index: int, shape: tuple[int, ...]) -> LeafPlan:
        numel = int(math.prod(shape)) if shape else 1
        squeezed = [s for s in shape if s != 1]
        factorized = numel > 1 and not (len(squeezed) <= 1 and not vector_reshape)
        if not factorized:
            return LeafPlan(index, shape, False, (numel,))
        b, n, m = block_shape(numel, blocks)
        return LeafPlan(
            index, shape, True, (b, n, m), blocks=b,
            kernel_ok=use_kernel, constraint="smmf_matrix",
        )

    return plan


def lasttwo_planner() -> Callable[[int, tuple[int, ...]], LeafPlan]:
    """Planner for Adafactor/CAME: factor rank>=2 leaves over the last two
    axes (leading axes sliced), keep rank<=1 leaves dense."""

    def plan(index: int, shape: tuple[int, ...]) -> LeafPlan:
        numel = int(math.prod(shape)) if shape else 1
        if len(shape) >= 2:
            return LeafPlan(index, shape, True, shape)
        return LeafPlan(index, shape, False, (numel,))

    return plan


def axiscover_planner() -> Callable[[int, tuple[int, ...]], LeafPlan]:
    """Planner for SM3: one accumulator vector per axis (cover sets), so the
    working geometry is just the native shape (scalars lift to (1,))."""

    def plan(index: int, shape: tuple[int, ...]) -> LeafPlan:
        geom = shape if shape else (1,)
        return LeafPlan(index, shape, True, geom)

    return plan


# ---------------------------------------------------------------------------
# kernel geometry + state accounting helpers
# ---------------------------------------------------------------------------

def clamp_kernel_block(n: int, m: int, block: tuple[int, int]) -> tuple[int, int]:
    """Clamp kernel tiles to the lane-padded problem so tiny layers don't
    blow up into a full default tile (the single source of this policy —
    kernels/smmf_update/ops.py calls it at dispatch).

    Both tile dims must be positive multiples of 8 (the packed-sign tile is
    bm/8 bytes wide); the clamp preserves that property.
    """
    bn, bm = block
    if bn <= 0 or bm <= 0 or bn % 8 or bm % 8:
        raise ValueError(f"kernel block dims must be positive multiples of 8, got {block}")
    bn = min(bn, max(8, -(-n // 8) * 8))
    bm = min(bm, max(128, -(-m // 128) * 128))
    return bn, bm


def smmf_plan_bytes(p: LeafPlan) -> int:
    """Predicted persistent optimizer-state bytes for one SMMF leaf plan
    (the paper's 'optimizer memory'): factor vectors + packed signs, or the
    dense fallback's full M and V. Only meaningful for plans produced by
    :func:`smmf_planner` (geometry (blocks, rows, cols))."""
    if not p.factorized:
        return 2 * 4 * p.numel
    b, n, m = p.geometry
    # (r_m, r_v) 2*b*n + (c_m, c_v) 2*b*m f32 vectors + packed sign bits
    return 4 * 2 * (b * n + b * m) + b * n * packed_width(m)


def smmf_state_bytes(plans: Sequence[LeafPlan]) -> int:
    """Predicted persistent SMMF optimizer-state bytes for a whole plan set
    (see :func:`smmf_plan_bytes`; SMMF planner geometries only)."""
    return sum(smmf_plan_bytes(p) for p in plans)
