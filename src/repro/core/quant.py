"""Quantization numerics for stored optimizer state (the qstate subsystem).

SMMF's value proposition is optimizer-state *memory*; this module supplies
the number formats that compound the factorization win by another ~4x:
persistent state tensors are stored in

* ``"int8"`` — symmetric absmax int8: ``q = clip(round(x / s), -127, 127)``
  with one f32 scale ``s = absmax / 127`` per **leading-stack row** (the
  bucket engine's stacked leaf axis), or per contained-leaf *segment* for
  fused flat dense rows; or
* ``"fp8"`` — an e4m3 emulation: payloads live in ``jnp.float8_e4m3fn``
  (1 byte, 4-bit exponent / 3-bit mantissa, max normal 448) with the same
  per-row scale mapping the row's absmax onto the format's range.

Both formats support **stochastic rounding** (pass a PRNG ``key``): int8
rounds ``floor(y + u)``, ``u ~ U[0, 1)``, which is exactly unbiased; fp8
adds uniform noise to the low ``23 - 3`` f32 mantissa bits and truncates,
which is unbiased for values in e4m3's normal range (the sub-normal tail
falls back to round-to-nearest granularity). Stochastic rounding is what
lets the optimizer *re-quantize its own state every step* without the
quantization bias accumulating — no error-feedback buffer needed, and the
same property is what lets ``repro.distributed.transport`` compress
gradient traffic EF-free.

Scale granularities: per leading-stack row (:func:`row_scale`), per
contained-leaf segment of a fused flat row (:func:`segment_scale`), and
per contiguous sub-row *block* along the last axis (:func:`block_scale`) —
the blockwise form keeps quantization tight on very long factor rows
(e.g. the rank-1 transport sketches of a fused ``dense:flat`` bucket,
where one absmax across tens of thousands of elements from different
leaves would swamp the small ones).

Everything here is shape-polymorphic math over arrays; the bucket-aware
codec that decides *which* state tensors quantize (and threads sharding
constraints) is ``repro.optim.qstate``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

QUANT_MODES = ("int8", "fp8")

INT8_QMAX = 127.0
FP8_QMAX = 448.0          # largest e4m3fn normal
_SCALE_FLOOR = 1e-30      # zero rows quantize to zero, never divide by 0
_FP8_DROP_BITS = 20       # f32 mantissa (23) - e4m3 mantissa (3)


def check_mode(mode: str) -> str:
    """Validate a quantization mode string (``"int8"`` / ``"fp8"``)."""
    if mode not in QUANT_MODES:
        raise ValueError(f"unknown quantization mode {mode!r}; "
                         f"supported: {QUANT_MODES}")
    return mode


def payload_dtype(mode: str):
    """Storage dtype of a quantized payload (1 byte/element either way)."""
    check_mode(mode)
    return jnp.int8 if mode == "int8" else jnp.float8_e4m3fn


def qmax(mode: str) -> float:
    """Largest representable scaled magnitude of ``mode`` (127 / 448)."""
    check_mode(mode)
    return INT8_QMAX if mode == "int8" else FP8_QMAX


def row_scale(x: jnp.ndarray, mode: str) -> jnp.ndarray:
    """Per-leading-row absmax scale for ``x``: shape ``x.shape[:1] + (1,)*``
    (keepdims), mapping each row's absmax onto the format's full range."""
    axes = tuple(range(1, x.ndim))
    s = jnp.max(jnp.abs(x), axis=axes, keepdims=True) / qmax(mode)
    return jnp.maximum(s.astype(jnp.float32), _SCALE_FLOOR)


def segment_scale(x: jnp.ndarray, seg: jnp.ndarray, num_segments: int,
                  mode: str) -> jnp.ndarray:
    """Per-segment absmax scale ``(num_segments,)`` for a flat fused row
    (``seg`` = static contained-leaf ids, sorted): each concatenated leaf
    keeps its own quantization range instead of sharing one row absmax."""
    absmax = jax.ops.segment_max(jnp.abs(x.reshape(-1)), seg,
                                 num_segments=num_segments,
                                 indices_are_sorted=True)
    return jnp.maximum(absmax.astype(jnp.float32) / qmax(mode), _SCALE_FLOOR)


def block_count(length: int, block: int) -> int:
    """Number of sub-row blocks covering a ``length``-wide last axis:
    ``ceil(length / block)`` (the tail block may be short)."""
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    return -(-length // block)


def block_scale(x: jnp.ndarray, block: int, mode: str) -> jnp.ndarray:
    """Per-(row, block) absmax scale along the **last** axis.

    ``x`` of shape ``(..., L)`` yields scales of shape
    ``(..., ceil(L / block))``: one f32 scale per contiguous ``block``-wide
    slice (zero-padded tail), so a single huge element only loosens its own
    block instead of the whole row. ``block >= L`` degenerates to one scale
    per row. Use :func:`block_expand` to broadcast back for
    :func:`quantize` / :func:`dequantize`.
    """
    check_mode(mode)
    length = x.shape[-1]
    nb = block_count(length, block)
    pad = nb * block - length
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    absmax = jnp.max(jnp.abs(x.reshape(*x.shape[:-1], nb, block)), axis=-1)
    s = absmax.astype(jnp.float32) / qmax(mode)
    return jnp.maximum(s, _SCALE_FLOOR)


def block_expand(scale: jnp.ndarray, block: int, length: int) -> jnp.ndarray:
    """Broadcast blockwise scales ``(..., nblocks)`` back to ``(..., length)``
    so they align elementwise with the quantized payload."""
    if scale.shape[-1] != block_count(length, block):
        raise ValueError(
            f"scale last axis {scale.shape[-1]} != "
            f"block_count({length}, {block}) = {block_count(length, block)}")
    return jnp.repeat(scale, block, axis=-1)[..., :length]


def _sr_fp8(y: jnp.ndarray, key) -> jnp.ndarray:
    # stochastic rounding by mantissa-noise + truncate: add U[0, 2^20) to
    # the f32 bit pattern, clear the dropped bits, cast (the cast of an
    # exactly-representable value is the identity). |y| <= 448 keeps the
    # noisy pattern inside the same exponent bucket, so no overflow.
    bits = jax.lax.bitcast_convert_type(y, jnp.uint32)
    noise = jax.random.bits(key, y.shape, jnp.uint32) \
        & jnp.uint32((1 << _FP8_DROP_BITS) - 1)
    bits = (bits + noise) & jnp.uint32(0xFFFFFFFF ^ ((1 << _FP8_DROP_BITS) - 1))
    return jax.lax.bitcast_convert_type(bits, jnp.float32).astype(jnp.float8_e4m3fn)


def quantize(x: jnp.ndarray, scale: jnp.ndarray, mode: str,
             key=None) -> jnp.ndarray:
    """Quantize f32 ``x`` against a broadcastable ``scale``.

    ``key=None`` rounds to nearest (used at ``init`` where the state is
    exact zeros); a PRNG key selects stochastic rounding (used at every
    update's re-quantization so the per-step bias is zero in expectation).
    Non-negative inputs stay non-negative under both roundings.
    """
    check_mode(mode)
    y = x.astype(jnp.float32) / scale
    if mode == "int8":
        if key is None:
            q = jnp.round(y)
        else:
            q = jnp.floor(y + jax.random.uniform(key, y.shape))
        return jnp.clip(q, -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    y = jnp.clip(y, -FP8_QMAX, FP8_QMAX)
    if key is None:
        return y.astype(jnp.float8_e4m3fn)
    return _sr_fp8(y, key)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize` up to rounding: ``q * scale`` in f32
    (works for both payload dtypes — fp8 upcasts exactly)."""
    return q.astype(jnp.float32) * scale
