"""SMMF momentum-coefficient schedules (paper Algorithm 8).

beta1_t = beta1 * lambda^(t-1)     (AdamNC-style growth-rate, default 0.999)
beta2_t = 1 - t^gamma              (Adafactor-style decay-rate; gamma=-0.5
                                    recommended for CNNs, -0.8 for
                                    Transformers)
"""

from __future__ import annotations

import jax.numpy as jnp


def beta1_schedule(beta1: float, growth_rate: float):
    """step -> beta1 * lambda^(t-1) (paper Algo 8, first-moment schedule)."""
    def sched(step: jnp.ndarray) -> jnp.ndarray:
        t = step.astype(jnp.float32)
        return beta1 * jnp.power(growth_rate, t - 1.0)

    return sched


def beta2_schedule(decay_rate: float):
    """step -> 1 - t^gamma (paper Algo 8, second-moment schedule)."""
    def sched(step: jnp.ndarray) -> jnp.ndarray:
        t = step.astype(jnp.float32)
        return 1.0 - jnp.power(t, decay_rate)

    return sched
