"""1-bit sign storage for the first momentum (paper Sec. 3 / Sec. 6).

The paper stores S_M as bools (8 bits/elt in practice; their Table 5 even
measures an 8-bit format). We bit-pack to uint8 — a true 32x reduction vs
f32 and 8x denser than bool storage. Packing is along the *last* axis, which
must be a multiple of 8 after padding (we pad and remember the true width).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_BITS = jnp.asarray([1, 2, 4, 8, 16, 32, 64, 128], dtype=jnp.uint8)


def packed_width(m: int) -> int:
    """uint8 columns needed to bit-pack an m-wide sign row: ceil(m/8)."""
    return (m + 7) // 8


def pack_signs(nonneg: jnp.ndarray) -> jnp.ndarray:
    """Pack a bool (n, m) 'is non-negative' matrix to uint8 (n, ceil(m/8))."""
    n, m = nonneg.shape
    pad = (-m) % 8
    if pad:
        nonneg = jnp.pad(nonneg, ((0, 0), (0, pad)))
    b = nonneg.reshape(n, -1, 8).astype(jnp.uint8)
    return jnp.sum(b * _BITS[None, None, :], axis=-1, dtype=jnp.uint8)


def unpack_signs(packed: jnp.ndarray, m: int) -> jnp.ndarray:
    """Unpack uint8 (n, ceil(m/8)) to float (n, m) of +1.0 / -1.0."""
    bits = (packed[:, :, None] & _BITS[None, None, :]) > 0
    signs = jnp.where(bits, 1.0, -1.0).astype(jnp.float32)
    return signs.reshape(packed.shape[0], -1)[:, :m]


def sign_bytes(shape: tuple[int, int]) -> int:
    """Persistent bytes for the packed sign matrix of a (n, m) momentum."""
    n, m = shape
    return n * packed_width(m)


def np_pack_signs(nonneg: np.ndarray) -> np.ndarray:
    """NumPy twin of pack_signs for checkpoint/test tooling."""
    n, m = nonneg.shape
    pad = (-m) % 8
    if pad:
        nonneg = np.pad(nonneg, ((0, 0), (0, pad)))
    return np.packbits(nonneg.astype(bool), axis=-1, bitorder="little").reshape(n, -1)
