"""SMMF (Square-Matricized Momentum Factorization) — paper Algorithm 1.

The optimizer state per weight tensor W (N elements, square-matricized to
(n_hat, m_hat)) is:

  r_m (n_hat,) f32   row factor of |M|
  c_m (m_hat,) f32   col factor of |M|
  sign (n_hat, ceil(m_hat/8)) uint8   bit-packed sign of M
  r_v (n_hat,) f32   row factor of V
  c_v (m_hat,) f32   col factor of V

i.e. O(n_hat + m_hat) floats + N bits, vs Adam's 2N floats — the paper's
up-to-96% optimizer-memory reduction.

Each update step performs the paper's decompression -> compression scheme:

  G_bar  = reshape(G, (n_hat, m_hat))                       [Algo 2, static]
  M_hat  = sign * (r_m (x) c_m);  V_hat = r_v (x) c_v       [Algo 3]
  beta1_t = beta1 * lambda^(t-1);  beta2_t = 1 - t^gamma    [Algo 8]
  M_t = beta1_t M_hat + (1-beta1_t) G_bar
  V_t = beta2_t V_hat + (1-beta2_t) G_bar^2
  compress M_t (with sign), V_t                             [Algo 4]
  U = M_t / (sqrt(V_t) + eps)   (reference code form)
  update = -lr * reshape(U, shape(W))

Two factorization scopes:

* ``blocks=1`` (default) — the paper-faithful *global* variant: one rank-1
  factorization of the whole square-matricized momentum.
* ``blocks=K`` — the beyond-paper *blockwise/local* variant: the matrix is
  split into K row-blocks factorized independently (strictly better
  approximation; when the row axis is sharded K-way the factorization needs
  **zero cross-shard collectives**). State grows to K*(n_hat/K + m_hat)
  which is still O(sqrt(N)) per block.

Execution is driven by the **leaf-plan engine** (repro.optim.engine): at
``init`` every parameter gets a static LeafPlan (factorized vs fallback,
(blocks, n, m) geometry, kernel eligibility) and same-geometry leaves are
bucketed into stacked arrays, so ``update`` runs one vectorized launch per
bucket instead of one per leaf. State is stored per bucket:

  factors["fac:BxNxM"]        = (r_m (K*B, n), c_m (K*B, m),
                                 sign (K*B*n, pw), r_v (K*B, n), c_v (K*B, m))
  factors["dense:flat:DTYPE"] = (m (1, TOTAL), v (1, TOTAL))  # fused fallback

with K the number of leaves sharing the geometry. The dense plain-Adam
fallback is **fused**: all fallback leaves of one dtype are concatenated
into a single flat row, so fallback-heavy (CNN-like) trees dispatch one
dense launch per dtype instead of one per distinct element count
(``fuse_dense=False`` restores per-geometry ``dense:NUM`` buckets of shape
(K, NUM)). ``bucket=False`` recovers the per-leaf baseline (one single-leaf
bucket per parameter, dense fusion off).

On a mesh, the stacked state is sharded rather than replicated: the leading
K*B stack axis carries the "data"/fsdp axis whenever divisible, and the
update emits matching sharding constraints ("smmf_matrix", "smmf_rows",
"smmf_cols", "smmf_sign", "dense_flat") on every stacked moment so per-chip
optimizer bytes shrink ~linearly with the fsdp axis (see docs/sharding.md
and repro.distributed.rules.opt_state_shardings).

When ``use_kernel=True`` the fused Pallas TPU kernel
(repro.kernels.smmf_update) executes decompress + EMA + sign-extract +
row/col partial sums + update in one pass over HBM — one launch per bucket,
composing with ``blocks=K`` (the kernel's leading batch axis carries
buckets x blocks). Requires ``beta1`` (the momentum-free variant takes the
unfused path).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax.numpy as jnp

from repro.core.plan import smmf_planner
from repro.core.signpack import pack_signs, packed_width, unpack_signs
from repro.distributed.ctx import constrain
from repro.optim.base import GradientTransformation, as_schedule
from repro.optim.engine import DEFAULT_KERNEL_BLOCK, LeafPlanEngine

PyTree = Any


class SMMFState(NamedTuple):
    step: jnp.ndarray
    factors: PyTree  # dict: bucket key -> stacked factor tuple (see module doc)


def _compress(mat: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched Algo 4: mat (B, n, m) non-negative -> r (B, n), c (B, m).

    Normalizes the *smaller* vector per matrix (paper Algo 4) so the outer
    product keeps the matrix scale with a single division.
    """
    _, n, m = mat.shape
    r = jnp.sum(mat, axis=2)
    c = jnp.sum(mat, axis=1)
    if n <= m:
        tot = jnp.sum(r, axis=1, keepdims=True)
        r = jnp.where(tot > 0, r / tot, r)
    else:
        tot = jnp.sum(c, axis=1, keepdims=True)
        c = jnp.where(tot > 0, c / tot, c)
    return r, c


def _decompress(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Batched Algo 3: r (B, n), c (B, m) -> (B, n, m)."""
    return r[:, :, None] * c[:, None, :]


def smmf(
    lr=1e-3,
    beta1: float | None = 0.9,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    decay_rate: float = -0.5,
    growth_rate: float = 0.999,
    vector_reshape: bool = True,
    weight_decay_mode: str = "adamw",
    blocks: int = 1,
    use_kernel: bool = False,
    bucket: bool = True,
    fuse_dense: bool = True,
    kernel_block: tuple[int, int] = DEFAULT_KERNEL_BLOCK,
    interpret: bool | None = None,
) -> GradientTransformation:
    """Build the SMMF gradient transformation.

    Args mirror the paper's reference implementation. ``decay_rate`` is the
    gamma of Algo 8 (-0.5 CNN / -0.8 Transformer recommended), ``growth_rate``
    the lambda. ``blocks`` > 1 selects the beyond-paper local variant.

    Engine knobs: ``bucket`` stacks same-geometry leaves into one launch
    (False = per-leaf baseline); ``fuse_dense`` concatenates all dense
    fallback leaves of a dtype into one flat launch (legal because the
    fallback is plain elementwise Adam; see module docstring);
    ``use_kernel`` routes factored buckets through the fused Pallas kernel
    with tile ``kernel_block``; ``interpret=None`` auto-selects interpreter
    mode off-TPU.
    """
    if isinstance(lr, (int, float)) and lr < 0.0:
        raise ValueError(f"lr must be >= 0, got {lr}")
    if beta1 is not None and not 0.0 <= beta1 <= 1.0:
        raise ValueError(f"beta1 must be in [0,1], got {beta1}")
    if not -1.0 <= decay_rate <= 0.0:
        raise ValueError(f"decay_rate must be in [-1,0], got {decay_rate}")
    if not 0.0 <= growth_rate <= 1.0:
        raise ValueError(f"growth_rate must be in [0,1], got {growth_rate}")
    if weight_decay_mode not in ("adam", "adamw"):
        raise ValueError(f"weight_decay_mode must be adam|adamw, got {weight_decay_mode}")
    bn_k, bm_k = kernel_block
    if bn_k <= 0 or bm_k <= 0 or bn_k % 8 or bm_k % 8:
        # the packed-sign tile is bm/8 bytes wide; a non-multiple-of-8 tile
        # mis-tiles the sign array deep inside the kernel
        raise ValueError(f"kernel_block dims must be positive multiples of 8, got {kernel_block}")
    lr_fn = as_schedule(lr)

    plan_fn = smmf_planner(
        blocks=blocks, vector_reshape=vector_reshape,
        # the fused kernel always computes the momentum EMA; the
        # momentum-free variant keeps the unfused path
        use_kernel=use_kernel and beta1 is not None,
    )

    def plan(params) -> LeafPlanEngine:
        """Static leaf-plan engine for ``params`` (see LeafPlanEngine)."""
        return LeafPlanEngine(params, plan_fn, bucket=bucket,
                              fuse_dense=fuse_dense and bucket)

    def init(params):
        engine = plan(params)
        factors = {}
        for bk in engine.buckets:
            k = bk.size
            if bk.factorized:
                b, n, m = bk.geometry
                factors[bk.key] = (
                    jnp.zeros((k * b, n), jnp.float32),                  # r_m
                    jnp.zeros((k * b, m), jnp.float32),                  # c_m
                    jnp.zeros((k * b * n, packed_width(m)), jnp.uint8),  # sign
                    jnp.zeros((k * b, n), jnp.float32),                  # r_v
                    jnp.zeros((k * b, m), jnp.float32),                  # c_v
                )
            else:
                (numel,) = bk.geometry  # total numel for fused buckets
                factors[bk.key] = (
                    jnp.zeros((bk.stack, numel), jnp.float32),  # m
                    jnp.zeros((bk.stack, numel), jnp.float32),  # v
                )
        return SMMFState(jnp.zeros((), jnp.int32), factors)

    def update(grads, state, params):
        engine = plan(params)
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        beta1_t = (beta1 * jnp.power(growth_rate, t - 1.0)) if beta1 is not None else None
        beta2_t = 1.0 - jnp.power(t, decay_rate)

        flat_g = engine.leaves(grads)
        flat_p = engine.leaves(params)
        if weight_decay and weight_decay_mode == "adam":
            flat_g = [g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
                      for g, p in zip(flat_g, flat_p)]  # Algo 6

        out_flat: list = [None] * len(flat_g)
        factors = {}
        for bk in engine.buckets:
            k = bk.size
            fac = state.factors[bk.key]
            if bk.factorized:
                b, n, m = bk.geometry
                kb = k * b
                gm = engine.gather(flat_g, bk).reshape(kb, n, m)
                gm = constrain(gm, "smmf_matrix")
                r_m, c_m, sign, r_v, c_v = fac

                if bk.kernel_ok and beta1 is not None:
                    from repro.kernels.smmf_update import ops as _kops

                    pw = packed_width(m)
                    u, r_m2, c_m2, sign2, r_v2, c_v2 = _kops.smmf_update_batched(
                        gm, r_m, c_m, sign.reshape(kb, n, pw), r_v, c_v,
                        beta1_t=beta1_t, beta2_t=beta2_t, eps=eps,
                        block=kernel_block, interpret=interpret,
                    )
                    sign2 = sign2.reshape(kb * n, pw)
                else:
                    # Decompression (Algo 3)
                    v_hat = _decompress(r_v, c_v)
                    if beta1 is not None:
                        signs = unpack_signs(sign, m).reshape(kb, n, m)
                        m_hat = signs * _decompress(r_m, c_m)
                        # EMA update with the intact current gradient
                        m_t = beta1_t * m_hat + (1.0 - beta1_t) * gm
                    else:
                        m_t = None
                    v_t = beta2_t * v_hat + (1.0 - beta2_t) * gm * gm
                    # Compression (Algo 4)
                    if beta1 is not None:
                        sign2 = pack_signs((m_t >= 0).reshape(kb * n, m))
                        r_m2, c_m2 = _compress(jnp.abs(m_t))
                    else:
                        sign2, r_m2, c_m2 = sign, r_m, c_m
                    r_v2, c_v2 = _compress(v_t)
                    num = m_t if beta1 is not None else gm
                    u = num / (jnp.sqrt(v_t) + eps)

                # keep the re-compressed stacked state placed where
                # opt_state_shardings puts it (stack axis over "data" when
                # divisible) so donation aliases buffers without resharding
                r_m2 = constrain(r_m2, "smmf_rows")
                r_v2 = constrain(r_v2, "smmf_rows")
                c_m2 = constrain(c_m2, "smmf_cols")
                c_v2 = constrain(c_v2, "smmf_cols")
                sign2 = constrain(sign2, "smmf_sign")
                factors[bk.key] = (r_m2, c_m2, sign2, r_v2, c_v2)
                engine.scatter(bk, (-lr_t * u).reshape(k, b * n * m), out_flat)
            else:
                gm = engine.gather(flat_g, bk)  # (K, numel) / fused (1, total)
                m_, v_ = fac
                if beta1 is not None:
                    m2 = beta1_t * m_ + (1.0 - beta1_t) * gm
                else:
                    m2 = m_
                v2 = beta2_t * v_ + (1.0 - beta2_t) * gm * gm
                num = m2 if beta1 is not None else gm
                u = num / (jnp.sqrt(v2) + eps)
                if bk.fused:
                    m2 = constrain(m2, "dense_flat")
                    v2 = constrain(v2, "dense_flat")
                factors[bk.key] = (m2, v2)
                engine.scatter(bk, -lr_t * u, out_flat)

        if weight_decay and weight_decay_mode == "adamw":
            out_flat = [o - lr_t * weight_decay * p.astype(jnp.float32)
                        for o, p in zip(out_flat, flat_p)]  # Algo 7
        return engine.unflatten(out_flat), SMMFState(step, factors)

    return GradientTransformation(init, update, plan=plan)


def smmf_local(lr=1e-3, blocks: int = 16, **kw) -> GradientTransformation:
    """Beyond-paper local/blockwise SMMF (see module docstring)."""
    return smmf(lr=lr, blocks=blocks, **kw)
