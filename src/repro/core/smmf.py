"""SMMF (Square-Matricized Momentum Factorization) — paper Algorithm 1.

The optimizer state per weight tensor W (N elements, square-matricized to
(n_hat, m_hat)) is:

  r_m (n_hat,) f32   row factor of |M|
  c_m (m_hat,) f32   col factor of |M|
  sign (n_hat, ceil(m_hat/8)) uint8   bit-packed sign of M
  r_v (n_hat,) f32   row factor of V
  c_v (m_hat,) f32   col factor of V

i.e. O(n_hat + m_hat) floats + N bits, vs Adam's 2N floats — the paper's
up-to-96% optimizer-memory reduction. The momentum-free variant
(``beta1=None``) holds ONLY ``r_v``/``c_v`` (no momentum factors, no sign
matrix), and the qstate codec (``quant="int8"|"fp8"`` hyperparam,
``docs/memory.md``) stores the factor vectors as 1-byte payloads + per-row
scales — another ~4x on the factor state.

Each update step performs the paper's decompression -> compression scheme:

  G_bar  = reshape(G, (n_hat, m_hat))                       [Algo 2, static]
  M_hat  = sign * (r_m (x) c_m);  V_hat = r_v (x) c_v       [Algo 3]
  beta1_t = beta1 * lambda^(t-1);  beta2_t = 1 - t^gamma    [Algo 8]
  M_t = beta1_t M_hat + (1-beta1_t) G_bar
  V_t = beta2_t V_hat + (1-beta2_t) G_bar^2
  compress M_t (with sign), V_t                             [Algo 4]
  U = M_t / (sqrt(V_t) + eps)   (reference code form)
  update = -lr * reshape(U, shape(W))

Two factorization scopes:

* ``blocks=1`` (default) — the paper-faithful *global* variant: one rank-1
  factorization of the whole square-matricized momentum.
* ``blocks=K`` — the beyond-paper *blockwise/local* variant: the matrix is
  split into K row-blocks factorized independently (strictly better
  approximation; when the row axis is sharded K-way the factorization needs
  **zero cross-shard collectives**). State grows to K*(n_hat/K + m_hat)
  which is still O(sqrt(N)) per block.

As of the OptimizerSpec redesign the actual math lives in the **family
registry** (``repro.optim.families``, entry ``"smmf"``) and the execution
plumbing in the spec engine (``repro.optim.spec.build_optimizer``):
bucketed same-geometry launches, a fused per-dtype dense fallback, the
batched Pallas kernel (``use_kernel=True``), mesh-sharded bucket stacks and
donation safety are all engine-level behaviors shared by every family —
see ``repro.optim.engine`` and ``docs/optimizer_api.md``.

The :func:`smmf` / :func:`smmf_local` constructors below are kept as
**deprecation shims**: they build the equivalent single-group
``OptimizerSpec`` and delegate, so their output is bitwise-identical to
``build_optimizer(OptimizerSpec(family="smmf", ...))``.
"""

from __future__ import annotations

import warnings

from repro.core.plan import (  # re-export: the single source lives in core.plan
    DEFAULT_KERNEL_BLOCK,
)
from repro.optim.base import EngineState as SMMFState  # back-compat re-export
from repro.optim.base import GradientTransformation

__all__ = ["SMMFState", "smmf", "smmf_local"]


def _spec_hp(lr, beta1, eps, weight_decay, decay_rate, growth_rate,
             vector_reshape, weight_decay_mode, blocks, use_kernel, bucket,
             fuse_dense, kernel_block, interpret) -> dict:
    return dict(
        lr=lr, beta1=beta1, eps=eps, weight_decay=weight_decay,
        decay_rate=decay_rate, growth_rate=growth_rate,
        vector_reshape=vector_reshape, weight_decay_mode=weight_decay_mode,
        blocks=blocks, use_kernel=use_kernel, bucket=bucket,
        fuse_dense=fuse_dense, kernel_block=tuple(kernel_block),
        interpret=interpret,
    )


def smmf(
    lr=1e-3,
    beta1: float | None = 0.9,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    decay_rate: float = -0.5,
    growth_rate: float = 0.999,
    vector_reshape: bool = True,
    weight_decay_mode: str = "adamw",
    blocks: int = 1,
    use_kernel: bool = False,
    bucket: bool = True,
    fuse_dense: bool = True,
    kernel_block: tuple[int, int] = DEFAULT_KERNEL_BLOCK,
    interpret: bool | None = None,
) -> GradientTransformation:
    """Deprecated constructor shim: build SMMF via ``OptimizerSpec``.

    Args mirror the paper's reference implementation. ``decay_rate`` is the
    gamma of Algo 8 (-0.5 CNN / -0.8 Transformer recommended),
    ``growth_rate`` the lambda, ``blocks`` > 1 the beyond-paper local
    variant; ``bucket``/``fuse_dense``/``use_kernel``/``kernel_block``/
    ``interpret`` are the engine knobs (see ``docs/optimizer_api.md``).
    Prefer::

        build_optimizer(OptimizerSpec(family="smmf", hyperparams={...}))
    """
    from repro.optim.spec import OptimizerSpec, build_optimizer

    warnings.warn(
        "smmf(...) is deprecated; build via repro.optim.spec.OptimizerSpec "
        "(family='smmf') + build_optimizer", DeprecationWarning, stacklevel=2)
    hp = _spec_hp(lr, beta1, eps, weight_decay, decay_rate, growth_rate,
                  vector_reshape, weight_decay_mode, blocks, use_kernel,
                  bucket, fuse_dense, kernel_block, interpret)
    return build_optimizer(OptimizerSpec(family="smmf", hyperparams=hp))


def smmf_local(lr=1e-3, blocks: int = 16, **kw) -> GradientTransformation:
    """Deprecated shim: beyond-paper local/blockwise SMMF (module docstring)."""
    return smmf(lr=lr, blocks=blocks, **kw)
