"""SMMF (Square-Matricized Momentum Factorization) — paper Algorithm 1.

The optimizer state per weight tensor W (N elements, square-matricized to
(n_hat, m_hat)) is:

  r_m (n_hat,) f32   row factor of |M|
  c_m (m_hat,) f32   col factor of |M|
  sign (n_hat, ceil(m_hat/8)) uint8   bit-packed sign of M
  r_v (n_hat,) f32   row factor of V
  c_v (m_hat,) f32   col factor of V

i.e. O(n_hat + m_hat) floats + N bits, vs Adam's 2N floats — the paper's
up-to-96% optimizer-memory reduction.

Each update step performs the paper's decompression -> compression scheme:

  G_bar  = reshape(G, (n_hat, m_hat))                       [Algo 2, static]
  M_hat  = sign * (r_m (x) c_m);  V_hat = r_v (x) c_v       [Algo 3]
  beta1_t = beta1 * lambda^(t-1);  beta2_t = 1 - t^gamma    [Algo 8]
  M_t = beta1_t M_hat + (1-beta1_t) G_bar
  V_t = beta2_t V_hat + (1-beta2_t) G_bar^2
  compress M_t (with sign), V_t                             [Algo 4]
  U = M_t / (sqrt(V_t) + eps)   (reference code form)
  update = -lr * reshape(U, shape(W))

Two factorization scopes:

* ``blocks=1`` (default) — the paper-faithful *global* variant: one rank-1
  factorization of the whole square-matricized momentum.
* ``blocks=K`` — the beyond-paper *blockwise/local* variant: the matrix is
  split into K row-blocks factorized independently (strictly better
  approximation; when the row axis is sharded K-way the factorization needs
  **zero cross-shard collectives**). State grows to K*(n_hat/K + m_hat)
  which is still O(sqrt(N)) per block.

When ``use_kernel=True`` the fused Pallas TPU kernel
(repro.kernels.smmf_update) executes decompress + EMA + sign-extract +
row/col partial sums + update in one pass over HBM.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.matricize import effective_shape
from repro.core.signpack import pack_signs, packed_width, unpack_signs
from repro.distributed.ctx import constrain
from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation, as_schedule

PyTree = Any


class SMMFState(NamedTuple):
    step: jnp.ndarray
    factors: PyTree  # per-leaf tuple (r_m, c_m, sign_packed, r_v, c_v)


def _block_shape(numel: int, blocks: int) -> tuple[int, int, int]:
    """(B, rows_per_block, cols) for the blockwise factorization."""
    n, m = effective_shape(numel)
    if blocks <= 1:
        return 1, n, m
    if n % blocks == 0:
        return blocks, n // blocks, m
    if numel % blocks == 0:
        # re-matricize each of the `blocks` equal chunks to its own square
        n2, m2 = effective_shape(numel // blocks)
        return blocks, n2, m2
    return 1, n, m  # indivisible: degrade gracefully to global


def _compress(mat: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise Algo 4: mat (B, n, m) non-negative -> r (B, n), c (B, m).

    Normalizes the *smaller* vector per block (paper Algo 4) so the outer
    product keeps the matrix scale with a single division.
    """
    _, n, m = mat.shape
    r = jnp.sum(mat, axis=2)
    c = jnp.sum(mat, axis=1)
    if n <= m:
        tot = jnp.sum(r, axis=1, keepdims=True)
        r = jnp.where(tot > 0, r / tot, r)
    else:
        tot = jnp.sum(c, axis=1, keepdims=True)
        c = jnp.where(tot > 0, c / tot, c)
    return r, c


def _decompress(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Blockwise Algo 3: r (B, n), c (B, m) -> (B, n, m)."""
    return r[:, :, None] * c[:, None, :]


def smmf(
    lr=1e-3,
    beta1: float | None = 0.9,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    decay_rate: float = -0.5,
    growth_rate: float = 0.999,
    vector_reshape: bool = True,
    weight_decay_mode: str = "adamw",
    blocks: int = 1,
    use_kernel: bool = False,
) -> GradientTransformation:
    """Build the SMMF gradient transformation.

    Args mirror the paper's reference implementation. ``decay_rate`` is the
    gamma of Algo 8 (-0.5 CNN / -0.8 Transformer recommended), ``growth_rate``
    the lambda. ``blocks`` > 1 selects the beyond-paper local variant.
    """
    if isinstance(lr, (int, float)) and lr < 0.0:
        raise ValueError(f"lr must be >= 0, got {lr}")
    if beta1 is not None and not 0.0 <= beta1 <= 1.0:
        raise ValueError(f"beta1 must be in [0,1], got {beta1}")
    if not -1.0 <= decay_rate <= 0.0:
        raise ValueError(f"decay_rate must be in [-1,0], got {decay_rate}")
    if not 0.0 <= growth_rate <= 1.0:
        raise ValueError(f"growth_rate must be in [0,1], got {growth_rate}")
    if weight_decay_mode not in ("adam", "adamw"):
        raise ValueError(f"weight_decay_mode must be adam|adamw, got {weight_decay_mode}")
    lr_fn = as_schedule(lr)

    def _factorized(p) -> bool:
        # Reference code: rank-1 tensors bypass factorization unless
        # vector_reshape (default True). Scalars are never factorized.
        squeezed = [s for s in p.shape if s != 1]
        if len(squeezed) <= 1 and not vector_reshape:
            return False
        return p.size > 1

    def init(params):
        def mk(p):
            if not _factorized(p):
                # plain-Adam fallback leaf: full M, V (tiny tensors only)
                m = jnp.zeros(p.shape, jnp.float32)
                v = jnp.zeros(p.shape, jnp.float32)
                return ((m, v),)
            b, n, m = _block_shape(int(p.size), blocks)
            r_m = jnp.zeros((b, n), jnp.float32)
            c_m = jnp.zeros((b, m), jnp.float32)
            sign = jnp.zeros((b * n, packed_width(m)), jnp.uint8)
            r_v = jnp.zeros((b, n), jnp.float32)
            c_v = jnp.zeros((b, m), jnp.float32)
            return ((r_m, c_m, sign, r_v, c_v),)

        (factors,) = multimap(mk, params, nout=1)
        return SMMFState(jnp.zeros((), jnp.int32), factors)

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        beta1_t = (beta1 * jnp.power(growth_rate, t - 1.0)) if beta1 is not None else None
        beta2_t = 1.0 - jnp.power(t, decay_rate)

        def upd(g, fac, p):
            g = g.astype(jnp.float32)
            if weight_decay and weight_decay_mode == "adam":
                g = g + weight_decay * p.astype(jnp.float32)  # Algo 6

            if len(fac) == 2:  # non-factorized fallback leaf
                m, v = fac
                if beta1 is not None:
                    m2 = beta1_t * m + (1.0 - beta1_t) * g
                else:
                    m2 = m
                v2 = beta2_t * v + (1.0 - beta2_t) * g * g
                num = m2 if beta1 is not None else g
                u = num / (jnp.sqrt(v2) + eps)
                out = -lr_t * u
                if weight_decay and weight_decay_mode == "adamw":
                    out = out - lr_t * weight_decay * p.astype(jnp.float32)  # Algo 7
                return out, (m2, v2)

            r_m, c_m, sign, r_v, c_v = fac
            b, n = r_m.shape
            m = c_m.shape[1]
            gm = constrain(g.reshape(b, n, m), "smmf_matrix")

            if use_kernel and b == 1:
                from repro.kernels.smmf_update import ops as _kops

                u2d, r_m2, c_m2, sign2, r_v2, c_v2 = _kops.smmf_update(
                    gm[0], r_m[0], c_m[0], sign, r_v[0], c_v[0],
                    beta1_t=beta1_t, beta2_t=beta2_t, eps=eps,
                )
                u = u2d[None]
                r_m2, c_m2 = r_m2[None], c_m2[None]
                r_v2, c_v2 = r_v2[None], c_v2[None]
            else:
                # Decompression (Algo 3)
                v_hat = _decompress(r_v, c_v)
                if beta1 is not None:
                    signs = unpack_signs(sign, m).reshape(b, n, m)
                    m_hat = signs * _decompress(r_m, c_m)
                    # EMA update with the intact current gradient
                    m_t = beta1_t * m_hat + (1.0 - beta1_t) * gm
                else:
                    m_t = None
                v_t = beta2_t * v_hat + (1.0 - beta2_t) * gm * gm
                # Compression (Algo 4)
                if beta1 is not None:
                    sign2 = pack_signs((m_t >= 0).reshape(b * n, m))
                    r_m2, c_m2 = _compress(jnp.abs(m_t))
                else:
                    sign2, r_m2, c_m2 = sign, r_m, c_m
                r_v2, c_v2 = _compress(v_t)
                num = m_t if beta1 is not None else gm
                u = num / (jnp.sqrt(v_t) + eps)

            out = -lr_t * u.reshape(g.shape)
            if weight_decay and weight_decay_mode == "adamw":
                out = out - lr_t * weight_decay * p.astype(jnp.float32)
            return out, (r_m2, c_m2, sign2, r_v2, c_v2)

        updates, factors = multimap(upd, grads, state.factors, params, nout=2)
        return updates, SMMFState(step, factors)

    return GradientTransformation(init, update)


def smmf_local(lr=1e-3, blocks: int = 16, **kw) -> GradientTransformation:
    """Beyond-paper local/blockwise SMMF (see module docstring)."""
    return smmf(lr=lr, blocks=blocks, **kw)
