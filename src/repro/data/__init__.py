from repro.data.synthetic import SyntheticLMStream, SyntheticImageStream

__all__ = ["SyntheticLMStream", "SyntheticImageStream"]
