"""Deterministic, shard-aware synthetic data streams.

Production framing: each host produces only its slice of the global batch
(host-sliced data parallelism); the stream is a pure function of
(seed, step, host_id), so restart/elastic-reshard resumes exactly — the
checkpoint only has to record the step.

The token stream is a mixture of Zipf-distributed unigrams and a
deterministic periodic structure, so cross-entropy decreases measurably
during the example runs (unlike uniform noise).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticLMStream:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.local_batch = self.global_batch // self.num_hosts
        # fixed Zipf-ish unigram table over the true vocab
        v = self.cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = p / p.sum()

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id])
        )
        b, s = self.local_batch, self.seq_len
        base = rng.choice(self.cfg.vocab, size=(b, s + 1), p=self._probs)
        # deterministic periodic structure: token[t] == token[t-8] with p~0.5
        mask = rng.random((b, s + 1)) < 0.5
        base[:, 8:] = np.where(mask[:, 8:], base[:, :-8], base[:, 8:])
        tokens = base[:, :-1].astype(np.int32)
        labels = base[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (b, self.cfg.encoder_seq, self.cfg.d_model), dtype=np.float32
            ).astype(self.cfg.dtype)
        if self.cfg.family == "vlm":
            out["prefix_embeds"] = rng.standard_normal(
                (b, self.cfg.n_patches, self.cfg.d_model), dtype=np.float32
            ).astype(self.cfg.dtype)
        return out


@dataclasses.dataclass
class SyntheticImageStream:
    """CIFAR-scale labelled images for the CNN (paper Table 1) benchmarks."""

    num_classes: int = 100
    global_batch: int = 128
    res: int = 32
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        self.local_batch = self.global_batch // self.num_hosts
        rng = np.random.default_rng(self.seed)
        # one fixed prototype per class + noise -> learnable classification
        self._protos = rng.standard_normal((self.num_classes, self.res, self.res, 3)).astype(np.float32)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, self.host_id]))
        labels = rng.integers(0, self.num_classes, size=(self.local_batch,))
        images = self._protos[labels] + 0.5 * rng.standard_normal(
            (self.local_batch, self.res, self.res, 3)
        ).astype(np.float32)
        return {"images": images.astype(np.float32), "labels": labels.astype(np.int32)}
