"""Distribution layer: mesh-aware sharding rules and activation constraints."""

from repro.distributed.ctx import constrain, sharding_ctx
from repro.distributed.rules import param_shardings, activation_rules

__all__ = ["constrain", "sharding_ctx", "param_shardings", "activation_rules"]
