"""Distribution layer: mesh-aware sharding rules and activation constraints."""

from repro.distributed.ctx import (
    constrain,
    constrain_update,
    sharding_ctx,
    update_specs_ctx,
)
from repro.distributed.rules import param_shardings, activation_rules

__all__ = ["constrain", "constrain_update", "sharding_ctx",
           "update_specs_ctx", "param_shardings", "activation_rules"]
