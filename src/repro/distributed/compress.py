"""int8 gradient compression with error feedback (beyond-paper DP trick).

Wraps any optimizer: gradients are quantized to int8 (per-tensor absmax
scaling) before the (simulated) cross-replica reduction, with the
quantization residual carried in an error-feedback buffer so the bias
vanishes over steps (Seide et al. 2014; Karimireddy et al. 2019). On a real
pod the all-reduce then moves 4x fewer bytes; composed with SMMF the whole
optimizer pipeline (state AND traffic) is compressed.

Note the EF buffer costs a full-size f32 tensor per parameter — this is a
*bandwidth* trick, intentionally opposite in the memory/traffic trade to
SMMF itself; enable it on links-bound meshes only. (Recorded as such in
DESIGN.md / EXPERIMENTS.md.)

The **state-side counterpart** is the qstate codec
(``repro.optim.qstate`` + ``repro.core.quant``, docs/memory.md): it
quantizes the *stored* optimizer state (int8/fp8 payloads + per-row
scales) and needs NO error-feedback buffer — the re-quantization uses
stochastic rounding in-state, so its only overhead is the small scale
arrays. Use this module when the mesh is links-bound, qstate when it is
memory-bound; they compose.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation


class EFState(NamedTuple):
    err: dict


def int8_compress(inner: GradientTransformation) -> GradientTransformation:
    """Wrap ``inner`` with int8 gradient quantization + error feedback: the
    EF residual keeps the quantization bias out of the long-run trajectory."""
    class State(NamedTuple):
        ef: dict
        inner: object

    def init(params):
        (ef,) = multimap(lambda p: (jnp.zeros(p.shape, jnp.float32),), params, nout=1)
        return State(ef, inner.init(params))

    def update(grads, state, params, **extras):
        def q(g, e):
            g = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            qi = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            deq = qi.astype(jnp.float32) * scale
            return deq, g - deq

        deq, ef = multimap(q, grads, state.ef, nout=2)
        updates, inner_state = inner.update(deq, state.inner, params, **extras)
        return updates, State(ef, inner_state)

    return GradientTransformation(init, update)
