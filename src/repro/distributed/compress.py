"""DEPRECATED shim — gradient-traffic compression moved to
``repro.distributed.transport``.

The seed-era wrapper here quantized per-tensor and carried a **full-size
f32 error-feedback buffer** per parameter — the opposite memory/traffic
trade to everything SMMF stands for. The transport subsystem retires both
choices: seeded stochastic rounding is exactly unbiased per step, so no
residual needs feeding back (zero persistent state), and it operates per
bucket-row on the engine plan with an optional rank-1 factored mode. Use
the ``transport="int8"|"rank1"`` spec hyperparam (``--transport`` on the
train CLI, per-group via ``--optim-rule '...,transport=rank1'``).

:func:`int8_compress` remains as a DeprecationWarning shim so old call
sites keep converging: it wraps ``inner`` with the transport subsystem's
EF-free per-tensor int8 round-trip (``transport.int8_roundtrip``, seeded
by ``(step, leaf-index)``). Its state is ``(count, inner_state)`` — the
f32 EF buffers are gone. Tier-1 errors on this warning (pytest.ini), so
in-repo callers must build through OptimizerSpec instead.
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed import transport as T
from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation

_MSG = ("int8_compress is deprecated. build via repro.optim.spec."
        "OptimizerSpec with the transport='int8'|'rank1' hyperparam "
        "(repro.distributed.transport) — EF-free, per bucket-row, "
        "stateless")


class EFState(NamedTuple):
    """Legacy name kept importable; the shim no longer creates EF buffers."""

    err: dict


def int8_compress(inner: GradientTransformation) -> GradientTransformation:
    """Deprecated: delegate to the EF-free transport int8 round-trip.

    Emits ``DeprecationWarning`` (an *error* under tier-1, pytest.ini) and
    wraps ``inner`` with ``transport.int8_roundtrip`` per tensor — same
    wire bytes as the old shim, no error-feedback state.
    """
    warnings.warn(_MSG, DeprecationWarning, stacklevel=2)

    class State(NamedTuple):
        count: jnp.ndarray
        inner: object

    def init(params):
        return State(jnp.zeros((), jnp.int32), inner.init(params))

    def update(grads, state, params, **extras):
        step = state.count + 1

        leaves = list(range(len(jax.tree_util.tree_leaves(grads))))
        it = iter(leaves)

        def q(g):
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(T._BASE_KEY), step),
                next(it))
            return (T.int8_roundtrip(g, key),)

        (deq,) = multimap(q, grads, nout=1)
        updates, inner_state = inner.update(deq, state.inner, params, **extras)
        return updates, State(step, inner_state)

    return GradientTransformation(init, update)
