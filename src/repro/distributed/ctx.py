"""Activation-sharding context.

Models call ``constrain(x, kind)`` at well-known points ("residual", "ffn",
"heads", "moe_dispatch", "moe_ffn", "logits"). Outside a mesh context this
is the identity, so models are mesh-agnostic; the train/serve step factory
installs a rule function (kind, shape, meta) -> PartitionSpec|None while
tracing, baking ``with_sharding_constraint`` ops into the jaxpr.

``meta`` is an optional per-call annotation the caller may attach (the
optimizer engine passes its bucket's per-group ``state_sharding`` override
through it); rules that don't care ignore it.

A second, index-keyed channel serves the optimizer engine's scatter path:
``update_specs_ctx(leaf_shardings)`` installs one sharding per flattened
parameter leaf, and ``constrain_update(x, index)`` pins leaf ``index``'s
update tensor to its parameter's sharding. This is the param-spec-aware
constraint that keeps XLA's SPMD partitioner from involuntarily
rematerializing (and, for stacked-scan leaves, CHECK-crashing on) the
engine's scatter reshapes — the bucket-stack layout and the parameter
layout meet at exactly that reshape, so the partitioner needs the explicit
target sharding there.
"""

from __future__ import annotations

import contextlib
import contextvars
import functools
import inspect
from typing import Callable, Sequence

import jax

_RULE: contextvars.ContextVar[Callable | None] = contextvars.ContextVar("shard_rule", default=None)
_UPDATE_SPECS: contextvars.ContextVar[Sequence | None] = contextvars.ContextVar(
    "update_specs", default=None)


@functools.lru_cache(maxsize=64)
def _takes_meta(rule: Callable) -> bool:
    """True when ``rule`` accepts a third (meta) argument. Resolved once per
    rule via its signature, so an in-rule TypeError is never masked by a
    catch-and-retry and the rule body never runs twice."""
    try:
        params = inspect.signature(rule).parameters.values()
    except (TypeError, ValueError):
        return False
    positional = [p for p in params if p.kind in (
        p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    return len(positional) >= 3 or any(
        p.kind == p.VAR_POSITIONAL for p in params)


def constrain(x, kind: str, meta=None):
    """Apply the ambient sharding rule for ``kind`` to ``x`` (identity when
    no rule is installed or the rule returns None for this kind/shape).
    ``meta`` is forwarded to the rule (per-group overrides etc.); rules that
    take only (kind, shape) still work — unless a non-None ``meta`` would be
    dropped, which raises."""
    rule = _RULE.get()
    if rule is None:
        return x
    if _takes_meta(rule):
        spec = rule(kind, tuple(x.shape), meta)
    else:
        if meta is not None:
            raise TypeError(
                f"sharding rule {rule!r} takes no meta argument but the "
                f"caller passed meta={meta!r} for kind {kind!r} — the "
                f"override must not be silently dropped")
        spec = rule(kind, tuple(x.shape))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@contextlib.contextmanager
def sharding_ctx(rule: Callable):
    """Install ``rule(kind, shape, meta=None) -> sharding|None`` for the
    duration of a trace (see module docstring)."""
    tok = _RULE.set(rule)
    try:
        yield
    finally:
        _RULE.reset(tok)


def constrain_update(x, index: int):
    """Pin parameter leaf ``index``'s update tensor to the parameter's own
    sharding (identity outside an :func:`update_specs_ctx`, or when the
    ``smmf_no_constraint`` perf flag drops the optimizer constraints)."""
    specs = _UPDATE_SPECS.get()
    if specs is None:
        return x
    sh = specs[index]
    if sh is None:
        return x
    from repro.models.perf import flags as _pf

    if _pf().smmf_no_constraint:
        return x
    return jax.lax.with_sharding_constraint(x, sh)


@contextlib.contextmanager
def update_specs_ctx(leaf_shardings: Sequence | None):
    """Install one sharding per flattened parameter leaf (canonical
    ``jax.tree.flatten`` order — the optimizer engine's leaf order) for the
    duration of a trace. ``None`` entries (and a ``None`` sequence) leave
    those leaves unconstrained."""
    tok = _UPDATE_SPECS.set(leaf_shardings)
    try:
        yield
    finally:
        _UPDATE_SPECS.reset(tok)
