"""Activation-sharding context.

Models call ``constrain(x, kind)`` at well-known points ("residual", "ffn",
"heads", "moe_dispatch", "moe_ffn", "logits"). Outside a mesh context this
is the identity, so models are mesh-agnostic; the train/serve step factory
installs a rule function (kind, ndim) -> PartitionSpec|None while tracing,
baking ``with_sharding_constraint`` ops into the jaxpr.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Callable

import jax

_RULE: contextvars.ContextVar[Callable | None] = contextvars.ContextVar("shard_rule", default=None)


def constrain(x, kind: str):
    """Apply the ambient sharding rule for ``kind`` to ``x`` (identity when
    no rule is installed or the rule returns None for this kind/shape)."""
    rule = _RULE.get()
    if rule is None:
        return x
    spec = rule(kind, tuple(x.shape))
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


@contextlib.contextmanager
def sharding_ctx(rule: Callable):
    """Install ``rule(kind, shape) -> sharding|None`` for the duration of a
    trace (see module docstring)."""
    tok = _RULE.set(rule)
    try:
        yield
    finally:
        _RULE.reset(tok)
