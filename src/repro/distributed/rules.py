"""Sharding rules: parameters, optimizer state, activations.

Strategy (TPU v5e pods, mesh ("data", "model") or ("pod", "data", "model")):

* **DP/FSDP** — batch over ("pod","data"); parameter *storage* sharded over
  "data" (ZeRO-3; GSPMD all-gathers at use and reduce-scatters grads).
* **TP** — Megatron pattern over "model": attention heads + FFN hidden.
  Degrades per-tensor when a dimension is indivisible (e.g. GQA kv=8 on a
  16-way model axis -> KV projections replicated over "model"); this is
  computed from the config, never assumed.
* **EP** — MoE expert axis over "model" when divisible (deepseek-moe 64e),
  else expert-hidden TP (grok-1 8e).
* **SP** — residual stream sequence-sharded over "model" in training
  (Megatron sequence parallelism); decode caches sharded over "model" on
  KV-heads when divisible, else on sequence.

Every rule degrades to replication rather than failing: `_fit` drops a mesh
axis whenever the dimension is not divisible by it.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

PyTree = Any

# SMMF-layout bucket key: "fac:BxNxM", optionally rank-suffixed ("xr<k>",
# rank-k factor buckets) and/or split-indexed ("@<i>"). Groups: B, N, M, k.
_FAC3_RE = re.compile(r"fac:(\d+)x(\d+)x(\d+)(?:xr(\d+))?(?:@\d+)?")


def _axsize(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axsize(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.shape else 0


def dp_axes(mesh: Mesh):
    """Batch axes: ("pod","data") on multi-pod meshes, else "data"."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def mesh_axis_sizes(mesh: Mesh) -> dict[str, int]:
    """Mesh axis name -> size (plain dict; works for Mesh and AbstractMesh).
    The form ``repro.core.plan.bucket_partition_wants`` consumes."""
    return {str(name): int(size) for name, size in mesh.shape.items()}


def _fit(mesh: Mesh, dim: int, want):
    """Return `want` if the axis exists and divides `dim`, else None."""
    if want is None:
        return None
    size = _axsize(mesh, want)
    if size and dim % size == 0:
        return want
    return None


def fit_spec(mesh: Mesh, shape: tuple[int, ...], wants: tuple) -> P:
    """PartitionSpec with each axis kept only if it divides the dim."""
    assert len(wants) == len(shape), (shape, wants)
    return P(*[_fit(mesh, d, w) for d, w in zip(shape, wants)])


# ---------------------------------------------------------------------------
# parameter shardings
# ---------------------------------------------------------------------------

# (regex over '/'-joined param path) -> wants tuple builder. The leading L
# (scan-stacked) axis is never sharded. "F" = fsdp axis, "M" = model axis.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                 ("M", "F")),
    (r"head$",                  ("F", "M")),
    (r"pos_embed$|enc_pos$",    (None, "F")),
    (r"(attn|xattn)/wq$",       (None, "F", "M", None)),
    (r"(attn|xattn)/w[kv]$",    (None, "F", "M", None)),
    (r"(attn|xattn)/wo$",       (None, "M", None, "F")),
    (r"(attn|xattn)/b[qkv]$",   (None, "M", None)),
    (r"ffn/w[ig]$",             (None, "F", "M")),
    (r"ffn/wo$",                (None, "M", "F")),
    (r"shared/w[ig]$",          (None, "F", "M")),
    (r"shared/wo$",             (None, "M", "F")),
    (r"moe/router$",            (None, "F", None)),
    (r"moe/w[ig]$",             (None, "E", "F", "EM")),  # experts or expert-hidden
    (r"moe/wo$",                (None, "E", "EM", "F")),
    (r"mixer/in_proj$",         (None, "F", "M")),
    (r"mixer/out_proj$",        (None, "M", "F")),
    (r"mixer/(conv_w|conv_b|a_log|d_skip|dt_bias)$", None),  # tiny: replicate
    (r"mixer/w[xy]$",           (None, "F", "M")),
    (r"mixer/w[ia]_gate$",      (None, "F", "M")),
    (r"mixer/wo$",              (None, "M", "F")),
    (r"mixer/lam$|conv_b$",     None),
    (r"norm|scale$|bias$|lam$", None),
    (r"fc/w$",                  ("F", None)),
    (r"conv\d+[ab]/w$",         (None, None, None, "F")),
]


def _param_spec(mesh: Mesh, cfg: ModelConfig | None, path: str, shape: tuple[int, ...]) -> P:
    for pat, wants in _PARAM_RULES:
        if re.search(pat, path):
            if wants is None:
                return P()
            # stacked (scan) leaves have a leading L axis; unstacked don't
            w = list(wants)
            if len(shape) == len(w) - 1:
                w = w[1:]
            elif len(shape) != len(w):
                return P()  # unknown layout: replicate
            # expert axis: model iff the (possibly packed) dim divides it;
            # expert-hidden gets model only when the expert axis didn't
            e_idx = w.index("E") if "E" in w else None
            expert_on_model = bool(
                e_idx is not None and _fit(mesh, shape[e_idx], "model")
            )
            out = []
            for dim, want in zip(shape, w):
                if want == "F":
                    want = "data"
                elif want == "M":
                    want = "model"
                elif want == "E":
                    want = "model" if expert_on_model else None
                elif want == "EM":
                    want = None if expert_on_model else "model"
                out.append(_fit(mesh, dim, want))
            return P(*out)
    return P()


def param_shardings(mesh: Mesh, cfg: ModelConfig | None, params_shape: PyTree) -> PyTree:
    """Tree of NamedSharding for a (possibly abstract) params pytree."""

    def _one(path, leaf):
        spec = _param_spec(mesh, cfg, path, tuple(leaf.shape))
        return NamedSharding(mesh, spec)

    from repro.utils.tree import tree_map_with_path

    return tree_map_with_path(_one, params_shape)


# ---------------------------------------------------------------------------
# optimizer state shardings
# ---------------------------------------------------------------------------

def opt_state_shardings(mesh: Mesh, cfg: ModelConfig | None, params_shape: PyTree, opt,
                        offload: str | None = None) -> PyTree:
    """Shardings for an optimizer state built by ``opt.init(params)``.

    **Host offload** (``repro.optim.offload``): ``offload="cold"`` re-kinds
    the cold (quantized) buckets' shardings onto the pinned-host memory
    tier after the per-kind placement below, so a jitted step's boundary —
    and an elastic checkpoint restore — put those payloads on host memory
    directly. Placement-only, like the ``state_sharding`` override: the
    specs (and therefore the state layout/keys) are unchanged. No-op on
    backends without a distinct host memory kind.

    Bucket-stacked state is **sharded, not replicated** (the PR-1 layout
    replicated every stack axis; docs/sharding.md documents the contract):

    * SMMF factored tuples (r_m, c_m, sign, r_v, c_v): the leading ``K*B``
      stack axis carries "data" (fsdp) whenever it is divisible
      (:func:`repro.core.plan.bucket_stack_wants`); cols additionally carry
      "model". When the stack is indivisible (e.g. single-leaf buckets like
      the embedding) the working-matrix rules apply instead — rows over
      "data", cols over "model". The packed sign matrix is always
      2D-sharded. This keeps the optimizer state (and its checkpoint)
      O(sqrt(N)) *per chip* too.
    * Fused/stacked dense moments (``dense:flat:<dtype>`` rows, ``dense:N``
      stacks) shard their flat element axis over "data".
    * Bucket-stacked full-size rank>=2 moments (Adafactor/CAME/SM3 m) take
      the parameter's sharding shifted one axis right, with the stack axis
      picking up "data" when the param spec left it free.

    Every spec here must agree with the in-update constraint kinds emitted
    by the engine/optimizers ("smmf_matrix", "smmf_rows", "smmf_cols",
    "smmf_sign", "dense_flat" in :func:`activation_rules`) — both sides
    derive from :func:`repro.core.plan.bucket_partition_wants`, so a jitted
    train step neither reshards state at entry nor breaks buffer donation.

    **Group-aware** (``repro.optim.spec``): mixed-family specs prefix
    bucket keys with the partition-group label (``adam0/dense:flat:f32``).
    The prefix contains '/', the same separator this walk joins paths with,
    so ``parts[-2]`` below is always the *bare* bucket key — the per-kind
    rules apply unchanged per group, and frozen groups simply contribute no
    state leaves. Group labels are validated (``repro.optim.spec``) to
    exclude '/', '|' and ':', which keeps this invariant and the
    checkpoint path encoding unambiguous.

    **Per-group overrides**: a spec partition with ``state_sharding=(axes,)``
    (e.g. ``("model",)`` for expert groups) replaces the default
    ``("pod", "data")`` stack preference chain for every bucket of that
    group — the override is read off the lowered engine plan (bucket keys →
    ``state_axes``), so this function and the in-update constraints stay
    agreed (both sides call ``bucket_partition_wants`` with the same
    ``stack_over``).

    **Quantized state** (the qstate codec, ``repro.optim.qstate``):
    quantized slots nest one level deeper — ``<bucket key>/<slot>/q`` +
    ``/scale``. Payloads keep the exact shapes of their f32 twins and take
    the same per-kind placement (the rules here are dtype-agnostic except
    for the uint8 sign check, and int8 ≠ uint8); the per-row scale arrays
    ride the bucket's stack placement (their leading axis IS the stack
    axis), per-segment scales of fused rows replicate (tiny).
    """
    from repro.core.plan import DEFAULT_STACK_AXES, _stack_want, \
        bucket_partition_wants, stack_axes

    state_shape = jax.eval_shape(opt.init, params_shape)
    pspecs = param_shardings(mesh, cfg, params_shape)
    axis_sizes = mesh_axis_sizes(mesh)
    pspec_by_shape: dict[tuple, NamedSharding] = {}
    for leaf, sh in zip(jax.tree.leaves(params_shape), jax.tree.leaves(pspecs)):
        pspec_by_shape.setdefault(tuple(leaf.shape), sh)
    axes_by_key = _state_axes_by_bucket_key(opt, params_shape)

    def _one(path, leaf):
        shape = tuple(leaf.shape)
        key_i, parts = _bucket_key_index(path)
        bare = parts[key_i] if key_i is not None else None
        # per-group stack-axis override: bucket keys of override groups are
        # always group-prefixed ("<group>/<bare key>")
        over = None
        if key_i is not None and key_i >= 1:
            over = axes_by_key.get(f"{parts[key_i - 1]}/{bare}")
        # qstate QTensor slots sit one level below the slot index: .../q
        # and .../scale (namedtuple attr paths)
        is_scale = parts[-1] == "scale" and key_i is not None \
            and len(parts) == key_i + 3
        slot = parts[key_i + 1] if key_i is not None and len(parts) > key_i + 1 \
            else None
        mfac = _FAC3_RE.fullmatch(bare) if bare is not None else None
        if is_scale:
            if len(shape) in (2, 3) and mfac:
                # per-stack-row scales of an SMMF-layout factored bucket ride
                # the stack placement (leading axis = the bucket's stack
                # axis), matching the in-update "qscale" constraint. Rank-k
                # per-column and blockwise sub-row scales carry one extra
                # trailing axis; the padded "rows" wants leave it unsharded
                # (again matching "qscale"). Other families' scales
                # replicate — their payloads do too, and an unmatched
                # at-rest sharding would just reshard tiny arrays every
                # step.
                want = bucket_partition_wants("rows", shape, axis_sizes,
                                              stack_over=over)
                return NamedSharding(mesh, fit_spec(mesh, shape, want))
            return NamedSharding(mesh, P())  # per-segment / dense: tiny
        if len(shape) == 2 and leaf.dtype == np.uint8:  # packed sign matrix
            want = bucket_partition_wants("sign", shape, axis_sizes, stack_over=over)
            return NamedSharding(mesh, fit_spec(mesh, shape, want))
        if len(shape) == 3 and slot is not None and mfac:
            # rank-k factored bucket (adapprox layout): a 3-D state leaf
            # under an SMMF-style key is either the full-size momentum
            # (K*B, n, m) or a rank-k factor matrix (K*B, dim, k) —
            # classified against the key's dims. Adafactor/CAME stats that
            # happen to sit under a 3-int fac key (scan-stacked geometries)
            # lead with the bucket geometry instead of n/m and fall through
            # to the heuristics below.
            n_, m_ = int(mfac.group(2)), int(mfac.group(3))
            rk = int(mfac.group(4) or 1)
            kind = None
            if shape[1:] == (n_, m_):
                kind = "matrix"
            elif shape[1] == n_ and shape[2] == rk:
                kind = "rows"
            elif shape[1] == m_ and shape[2] == rk:
                kind = "cols"
            if kind is not None:
                want = bucket_partition_wants(kind, shape, axis_sizes,
                                              stack_over=over)
                return NamedSharding(mesh, fit_spec(mesh, shape, want))
        if shape in pspec_by_shape:  # full-size momentum: shard like the param
            return pspec_by_shape[shape]
        if len(shape) >= 3 and shape[1:] in pspec_by_shape:
            # bucket-stacked full-size rank>=2 moment (leaf-plan engine): the
            # param's sharding shifted one axis right; the stack axis picks
            # up the (pod, data) chain — or the group's override — when
            # divisible and the param spec left those axes free.
            # 2-D engine leaves stay on the factor-tuple heuristics below —
            # (K, n) factor vectors must not inherit a 1-D param's spec.
            base = tuple(pspec_by_shape[shape[1:]].spec)
            flat_base = [a for w in base if w is not None
                         for a in (w if isinstance(w, tuple) else (w,))]
            free = {a: s for a, s in axis_sizes.items() if a not in flat_base}
            stack = _stack_want(stack_axes(shape[0], free, over or DEFAULT_STACK_AXES))
            return NamedSharding(mesh, P(stack, *base))
        if len(shape) == 2 and slot is not None and mfac:
            # SMMF-layout factored-bucket tuple — the key "fac:BxNxM"
            # identifies it (adafactor/CAME/SM3 buckets never put 2-D
            # leaves under a 3-int fac key). Rectangular geometries
            # classify by the minor dim (n-sized -> row factor, m-sized ->
            # col factor), covering both SMMF's (r_m, c_m, sign, r_v, c_v)
            # layout and H-Fac's sign-free (r_m, c_m, r_v, c_v); square
            # geometries keep the SMMF slot-index convention (1 and 4 are
            # the col factors — H-Fac constrains its slot-3 col factor as
            # "smmf_rows" in that case, see families._hfac_update, so both
            # sides still agree). Quantized payloads (".../<slot>/q") take
            # their slot's placement unchanged.
            n_, m_ = int(mfac.group(2)), int(mfac.group(3))
            if n_ != m_:
                kind = "cols" if shape[1] == m_ else "rows"
            else:
                kind = "cols" if slot in ("1", "4") else "rows"
            want = bucket_partition_wants(kind, shape, axis_sizes, stack_over=over)
            return NamedSharding(mesh, fit_spec(mesh, shape, want))
        if len(shape) == 2 and bare is not None and bare.startswith("dense:"):
            # fused flat (1, total) rows or stacked (K, numel) dense moments:
            # elementwise math, shard the element axis over the stack chain
            want = bucket_partition_wants("dense", shape, axis_sizes, stack_over=over)
            return NamedSharding(mesh, fit_spec(mesh, shape, want))
        # everything else (row/col stats, SM3 accs, step scalars): replicate
        # — small vectors, same treatment as pre-engine layouts
        return NamedSharding(mesh, P())

    from repro.utils.tree import tree_map_with_path

    out = tree_map_with_path(_one, state_shape)
    if offload is not None:
        from repro.optim import offload as O

        plan = getattr(opt, "plan", None)
        if O.check_mode(offload) is not None and plan is not None:
            out = O.offload_shardings(out, state_shape, plan(params_shape),
                                      offload)
    return out


def _bucket_key_index(path: str) -> tuple[int | None, list[str]]:
    """Locate the bucket-key segment of a state-leaf path.

    Returns ``(index, parts)`` where ``parts`` is the '/'-split path with
    namedtuple attr-entries normalized (leading '.' stripped) and ``index``
    points at the last ``fac:...`` / ``dense:...`` segment (None when the
    leaf is not bucket state — e.g. the step scalar). Group labels cannot
    collide: partition names are validated to exclude ':'.
    """
    parts = [p.lstrip(".") for p in path.split("/")]
    key_i = None
    for i, p in enumerate(parts):
        if re.match(r"(fac|dense):", p):
            key_i = i
    return key_i, parts


def _state_axes_by_bucket_key(opt, params_shape) -> dict[str, tuple]:
    """{full bucket key -> state_sharding override} for a spec-built
    optimizer whose partitions carry ``state_sharding`` overrides; {} for
    plain transforms / specs without overrides. Best-effort and shape-only
    (the plan walk runs on abstract leaves)."""
    spec = getattr(opt, "spec", None)
    plan = getattr(opt, "plan", None)
    if spec is None or plan is None:
        return {}
    if not any(getattr(p, "state_sharding", None)
               for p in getattr(spec, "partitions", ())):
        return {}
    engine = plan(params_shape)
    return {bk.key: bk.state_axes for bk in engine.buckets if bk.state_axes}


def sharded_state_bytes(shardings: PyTree, state_shape: PyTree) -> int:
    """Per-device bytes of a sharded pytree: sum of each leaf's *shard*
    size under its NamedSharding (``shard_shape`` is pure spec math, so this
    works with AbstractMesh placeholders — no arrays are allocated).

    This is the accounting behind ``benchmarks/opt_memory_sharded.py`` and
    the tier-1 sharded-bucket memory test: replicated leaves contribute
    their full size on every device, stack-sharded buckets 1/axis of it.
    """
    total = 0
    for leaf, sh in zip(jax.tree.leaves(state_shape), jax.tree.leaves(shardings)):
        shard = sh.shard_shape(tuple(leaf.shape))
        total += int(np.prod(shard)) * np.dtype(leaf.dtype).itemsize
    return total


def sharded_state_bytes_by_group(shardings: PyTree, state_shape: PyTree,
                                 group_names=()) -> dict[str, int]:
    """Per-device sharded bytes of an engine state split by partition group.

    Walks the state by path: a leaf whose bucket key carries a group prefix
    (``<group>/<bare key>/<slot>`` with ``<group>`` in ``group_names``)
    bills that group, everything else (default-group buckets, the shared
    step scalar) bills ``"default"``. Pure spec math like
    :func:`sharded_state_bytes` — drives the pod×fsdp per-group grid of
    ``benchmarks/opt_memory_sharded.py``.
    """
    names = set(group_names)
    flat, _ = jax.tree_util.tree_flatten(shardings)
    paths = jax.tree_util.tree_flatten_with_path(state_shape)[0]
    out: dict[str, int] = {"default": 0}
    for lbl in names:
        out[lbl] = 0
    for (path, leaf), sh in zip(paths, flat):
        parts = [str(getattr(e, "key", getattr(e, "idx", e))) for e in path]
        key_i, parts = _bucket_key_index("/".join(parts))
        group = "default"
        if key_i is not None and key_i >= 1 and parts[key_i - 1] in names:
            group = parts[key_i - 1]
        shard = sh.shard_shape(tuple(leaf.shape))
        out[group] += int(np.prod(shard)) * np.dtype(leaf.dtype).itemsize
    return out


# ---------------------------------------------------------------------------
# XLA concatenate-partitioning miscompile probe (PR 4 boundary guard)
# ---------------------------------------------------------------------------

# Last jaxlib minor version where the override-axis gather-stack miscompile
# is known to reproduce (XLA partitions the stack as partial writes +
# all-reduce and over-counts replicated operands by the replication
# factor; observed through jaxlib 0.4.x). A jaxlib bump past this gate
# retires the replicated-boundary pin for override groups — the
# fully-sharded transport path — and the regression test
# (tests/test_multiaxis_sharding.py + tests/_concat_probe_child.py)
# asserts the *actual* behavior still agrees with this version gate, so a
# bump that fixes XLA flips the test and forces the gate (and the guard)
# to be updated rather than silently keeping the conservative boundary.
_CONCAT_MISCOMPILE_LAST_BAD = (0, 4)


def xla_concat_miscompile_present() -> bool:
    """True when the installed XLA (via jaxlib) is a version on which the
    concatenate-partitioning miscompile reproduces (see
    ``_CONCAT_MISCOMPILE_LAST_BAD`` and docs/sharding.md). Gates the
    ``"opt_update_row"`` replicated boundary for ``state_sharding``
    override groups and its ``boundary_transport_bytes`` pricing."""
    import jaxlib

    ver = tuple(int(x) for x in jaxlib.__version__.split(".")[:2])
    return ver <= _CONCAT_MISCOMPILE_LAST_BAD


def _override_boundary_needed(stack: int, over, axis_sizes: dict[str, int]) -> bool:
    """Shared predicate for the ``"opt_update_row"`` rule and its transport
    pricing: does this bucket's transient gather/scatter row need the
    replicated boundary pin?

    * stack not sharded over its (possibly overridden) chain → yes (no
      layout the row↔param reshape can preserve);
    * stack sharded over a per-group *override* chain → only while the
      XLA concatenate miscompile is present (the PR 4 guard, retried and
      version-gated here — PR 6); on fixed XLA the override group keeps
      the fully-sharded zero-collective transport like the default chain.
    """
    from repro.core.plan import DEFAULT_STACK_AXES, stack_axes

    if not stack_axes(stack, axis_sizes, tuple(over) if over else DEFAULT_STACK_AXES):
        return True
    return over is not None and xla_concat_miscompile_present()


# ---------------------------------------------------------------------------
# activation rules (installed via repro.distributed.ctx)
# ---------------------------------------------------------------------------

def activation_rules(mesh: Mesh, cfg: ModelConfig, mode: str):
    """(kind, shape, meta=None) -> NamedSharding|None for ctx.constrain.

    mode: "train" (SP: sequence over model) | "prefill" | "decode".
    Every returned spec is divisibility-checked (`fit_spec`) so indivisible
    dims silently degrade to replication instead of failing to compile.
    ``meta`` is the per-call annotation from ``ctx.constrain``: for the
    bucket-state kinds it is the group's ``state_sharding`` stack-axis
    override (None = the default ``("pod", "data")`` chain).
    """
    dp = dp_axes(mesh)
    msize = max(1, _axsize(mesh, "model"))
    heads_ok = bool(cfg.n_heads) and cfg.n_heads % msize == 0
    kv_ok = bool(cfg.kv_heads) and cfg.kv_heads % msize == 0
    expert_ok = bool(cfg.n_experts and _fit(mesh, cfg.n_experts, "model"))

    def _ns(shape, wants):
        return NamedSharding(mesh, fit_spec(mesh, shape, wants))

    def rule(kind: str, shape: tuple, meta=None):
        ndim = len(shape)
        if kind == "residual" and ndim == 3:
            from repro.models.perf import flags as _pf

            if mode == "decode" or _pf().no_sp_residual:
                return _ns(shape, (dp, None, None))
            return _ns(shape, (dp, "model", None))  # SP over sequence
        if kind == "heads" and ndim == 4:
            if mode == "decode" or not heads_ok:
                return None
            return _ns(shape, (dp, None, "model", None))
        if kind == "ffn" and ndim == 3:
            return _ns(shape, (dp, None, "model"))
        if kind == "moe_dispatch" and ndim == 5:  # (b, g, sg, e, cap)
            return _ns(shape, (dp, "model", None, None, None))
        if kind in ("moe_ffn", "moe_ffn_in") and ndim == 5:  # (b, e, g, cap, *)
            from repro.models.perf import flags as _pf

            e_on_model = shape[1] % msize == 0  # packed or natively divisible
            if e_on_model:
                return _ns(shape, (dp, "model", None, None, None))
            if _pf().moe_cap_sharding:
                # capacity-sharded expert compute: tokens stay sharded,
                # (small) expert weights are gathered instead
                return _ns(shape, (dp, None, None, "model", None))
            if kind == "moe_ffn":
                return _ns(shape, (dp, None, None, None, "model"))
            return None
        if kind == "logits" and ndim == 3:
            if mode == "decode":
                return _ns(shape, (dp, None, "model"))
            return _ns(shape, (dp, "model", None))
        if kind == "flash_q" and ndim == 6:  # (B, nb, bq, Hkv, grp, D)
            if kv_ok:
                return _ns(shape, (dp, None, None, "model", None, None))
            if heads_ok:
                # GSPMD factorizes the model axis across (Hkv x grp) itself;
                # constraining here forces involuntary rematerialization
                return None
            return _ns(shape, (dp, "model", None, None, None, None))
        if kind == "flash_kv" and ndim == 4:  # (B, Sk, Hkv, D)
            if kv_ok:
                return _ns(shape, (dp, None, "model", None))
            if heads_ok:
                return None
            return _ns(shape, (dp, None, None, None))  # gathered KV
        if kind == "ssd_heads" and ndim == 4:  # (B, S, H, P)
            from repro.models.perf import flags as _pf

            if _pf().no_sp_residual:
                # heads carry the model axis when the sequence doesn't
                return _ns(shape, (dp, None, "model", None))
            return None
        if kind == "ssd_dt" and ndim == 3:  # (B, S, H)
            from repro.models.perf import flags as _pf

            if _pf().no_sp_residual:
                return _ns(shape, (dp, None, "model"))
            return None
        if kind == "opt_update_row":
            # boundary transport for the engine's gather/scatter (and the
            # SMMF sign pack/unpack):
            #
            # * a bucket whose stack axis is NOT mesh-sharded has no layout
            #   the row<->param reshape can preserve, so the transient row
            #   is explicitly replicated — a representable all-gather in
            #   place of the SPMD partitioner's involuntary
            #   rematerialization (which CHECK-crashes on stacked-scan
            #   leaves, see docs/sharding.md);
            # * buckets on a per-group ``state_sharding`` OVERRIDE chain
            #   take the replicated boundary only while the installed XLA
            #   still miscompiles the partitioned concatenate
            #   (:func:`xla_concat_miscompile_present`): partitioning the
            #   gather stack directly onto an override axis while the
            #   other mesh axes hold replicas lowers the stack to
            #   dynamic-update-slice + all-reduce and over-counts by the
            #   replication factor — locked down by
            #   tests/_multiaxis_child.py, reproduced on demand by
            #   tests/_concat_probe_child.py. On fixed XLA the override
            #   group keeps the fully-sharded transport. While guarded,
            #   the persistent state still lives sharded on the override
            #   axis; only the transient gather/scatter rows go through
            #   the replicated pin, after which the explicit smmf_*
            #   constraints slice them out.
            #
            # Stack-sharded buckets otherwise return None and keep the
            # fully-sharded, zero-collective path. The `no_opt_boundary`
            # perf flag drops ONLY this pin (state constraints stay) — the
            # A/B hatch the miscompile probe child uses.
            from repro.models.perf import flags as _pf

            if _pf().smmf_no_constraint or _pf().no_opt_boundary:
                return None
            stack, over = meta if meta else (1, None)
            if not _override_boundary_needed(stack, over, mesh_axis_sizes(mesh)):
                return None
            return NamedSharding(mesh, P())
        if kind == "qscale" and ndim in (2, 3):
            # per-stack-row quantization scales (repro.optim.qstate): the
            # leading axis IS the bucket's stack axis, so the scales ride
            # the same (pod, data) chain — or the group's override (meta) —
            # as their payloads; the trailing keepdims axis is size 1.
            # Rank-k per-column and blockwise sub-row scales are 3-D; the
            # padded "rows" wants leave their trailing axes unsharded.
            from repro.core.plan import bucket_partition_wants
            from repro.models.perf import flags as _pf

            if _pf().smmf_no_constraint:
                return None
            return _ns(shape, bucket_partition_wants(
                "rows", shape, mesh_axis_sizes(mesh), stack_over=meta))
        if kind in ("smmf_matrix", "smmf_rows", "smmf_cols", "smmf_sign",
                    "dense_flat"):
            # bucket-stacked optimizer state: specs derive from the same
            # per-bucket wants as opt_state_shardings, so the in-update
            # constraints and the state layout always agree (no per-step
            # resharding, donation-friendly)
            from repro.core.plan import bucket_partition_wants
            from repro.models.perf import flags as _pf

            if _pf().smmf_no_constraint:
                return None
            sizes = mesh_axis_sizes(mesh)
            if kind == "smmf_matrix" and ndim == 3:  # (K*B, n_hat, m_hat)
                # keep the square-matricized momentum sharded through
                # decompress -> EMA -> compress (the transient full-size
                # tensors never materialize unsharded on any chip); the
                # stack axis carries the (pod, data) chain — or the group's
                # state_sharding override (meta) — whenever divisible
                return _ns(shape, bucket_partition_wants(
                    "matrix", shape, sizes, stack_over=meta))
            if ndim == 2:
                sub = {"smmf_rows": "rows", "smmf_cols": "cols",
                       "smmf_sign": "sign", "dense_flat": "dense"}[kind]
                return _ns(shape, bucket_partition_wants(
                    sub, shape, sizes, stack_over=meta))
            if ndim == 3 and kind in ("smmf_rows", "smmf_cols"):
                # rank-k factor matrices (K*B, dim, k): the 2-D wants
                # padded with None — the trailing factor axis never shards
                sub = "rows" if kind == "smmf_rows" else "cols"
                return _ns(shape, bucket_partition_wants(
                    sub, shape, sizes, stack_over=meta))
            return None
        return None

    return rule


def boundary_transport_bytes(engine, axis_sizes: dict[str, int]) -> dict:
    """Static per-step bytes the ``"opt_update_row"`` boundary rule
    transports explicitly (the PR 4 replicated-pin fix).

    A bucket whose stack axis is *not* sharded over the default
    ``("pod", "data")`` chain — or that carries a per-group
    ``state_sharding`` override *while the XLA concatenate miscompile is
    present* (:func:`xla_concat_miscompile_present`; on fixed XLA override
    groups keep the fully-sharded transport and price 0) — routes its
    transient gather/scatter rows through an explicit replicated pin
    instead of leaving the SPMD partitioner to invent a grouped sharding.
    This function prices that choice: per such bucket, the f32 gather row
    plus the scatter row (``2 × 4 × numel``), and for momentum-SMMF
    factored buckets (``plan.momentum`` — beta1=None buckets have no sign
    matrix and never take those boundaries) the two additional sign
    pack/unpack crossings (another ``2 × 4 × numel``). Stack-sharded
    default-chain buckets transport 0.

    Under an overlapped schedule (``make_train_step(overlap=True)``) these
    bytes are exactly the transport XLA hides behind the remaining
    backward's matmuls — the ``transport`` column of
    ``benchmarks/step_time.py`` prices what the interleave overlaps.

    Returns ``{"total": bytes, "by_group": {label: bytes}}`` — the
    ``transport`` column of ``benchmarks/step_time.py``. Pure plan math
    over a ``LeafPlanEngine`` (no mesh or arrays needed): ``axis_sizes``
    is the hypothetical mesh, e.g. ``{"data": 4}``.

    The ``"grad"`` sub-dict additionally prices the **gradient transport**
    boundary (``repro.distributed.transport``) — the data-parallel
    all-reduce traffic, orthogonal to the replicated-pin rows above:
    ``grad["total"]`` / ``grad["by_group"]`` use each bucket's *planned*
    mode (``LeafPlan.transport``, amortizing rank1's dense flush), and
    ``grad["by_mode"]`` prices the whole engine under each of
    ``none`` / ``int8`` / ``rank1`` for comparison (the
    ``BENCH_transport.json`` acceptance column).
    """
    from repro.distributed import transport as _transport

    total = 0
    by_group: dict[str, int] = {}
    for bk in engine.buckets:
        if not _override_boundary_needed(bk.stack, bk.state_axes, axis_sizes):
            continue  # fully stack-sharded: zero-collective path
        numel = sum(p.numel for p in bk.plans)
        crossings = 2  # gather row in, scatter row out
        if bk.factorized and bk.plans[0].constraint == "smmf_matrix" \
                and bk.plans[0].momentum:
            crossings += 2  # SMMF sign unpack + re-pack reshapes
        b = crossings * 4 * numel
        total += b
        label = bk.plans[0].group or "default"
        by_group[label] = by_group.get(label, 0) + b
    grad = _transport.grad_transport_bytes(engine)
    grad["by_mode"] = {
        mode: _transport.grad_transport_bytes(engine, mode)["total"]
        for mode in ("none",) + _transport.TRANSPORT_MODES
    }
    return {"total": total, "by_group": by_group, "grad": grad}


# ---------------------------------------------------------------------------
# cache / data shardings
# ---------------------------------------------------------------------------

def cache_shardings(mesh: Mesh, cfg: ModelConfig, cache_shape: PyTree) -> PyTree:
    """KV caches (L, B, S, Hkv, D): batch over dp; heads over "model" when
    divisible, else sequence over "model" (the one-hot append keeps that
    legal). SSM/RG-LRU states: batch over dp, width/heads over model."""
    dp = dp_axes(mesh)
    kv_ok = cfg.kv_heads % max(1, _axsize(mesh, "model")) == 0

    def _one(path, leaf):
        shape = tuple(leaf.shape)
        if len(shape) == 5 and path.endswith("ssm"):  # (L, B, H, P, N)
            return NamedSharding(mesh, fit_spec(mesh, shape, (None, dp, "model", None, None)))
        if len(shape) == 5:  # (L, B, S, H, D) attn cache
            want = (None, dp, None, "model", None) if kv_ok else (None, dp, "model", None, None)
            return NamedSharding(mesh, fit_spec(mesh, shape, want))
        if len(shape) == 4 and path.endswith("conv"):  # (L, B, K-1, C)
            return NamedSharding(mesh, fit_spec(mesh, shape, (None, dp, None, "model")))
        if len(shape) == 3:  # rglru h (L, B, W)
            return NamedSharding(mesh, fit_spec(mesh, shape, (None, dp, "model")))
        if len(shape) == 1:  # pos
            return NamedSharding(mesh, fit_spec(mesh, shape, (dp,)))
        return NamedSharding(mesh, P())

    from repro.utils.tree import tree_map_with_path

    return tree_map_with_path(_one, cache_shape)


def paged_cache_shardings(mesh: Mesh, cfg: ModelConfig,
                          pools_shape: PyTree) -> PyTree:
    """Serving page pools: payload (L, P, page, Hkv, D) and scale
    (L, P, page, Hkv) leaves go heads-over-"model" when divisible —
    the same placement :func:`cache_shardings` picks for dense caches,
    and exactly what the ``shard_map`` decode kernel expects. Pages are
    never sharded: every slot's block table indexes the whole pool, so
    a split page axis would turn each decode step into a cross-device
    gather. Non-divisible head counts replicate (decode still works via
    the non-shard_map paths)."""
    kv_ok = cfg.kv_heads % max(1, _axsize(mesh, "model")) == 0

    def _one(leaf):
        shape = tuple(leaf.shape)
        if not kv_ok:
            return NamedSharding(mesh, P())
        if len(shape) == 5:   # payload (L, P, page, Hkv, D)
            return NamedSharding(mesh, fit_spec(
                mesh, shape, (None, None, None, "model", None)))
        if len(shape) == 4:   # scales (L, P, page, Hkv)
            return NamedSharding(mesh, fit_spec(
                mesh, shape, (None, None, None, "model")))
        return NamedSharding(mesh, P())

    return jax.tree.map(_one, pools_shape)


def paged_enc_sharding(mesh: Mesh, cfg: ModelConfig,
                       enc_shape: tuple) -> NamedSharding:
    """Per-slot encoder states (slots, T_enc, D): slots over dp — each
    decode row reads only its own encoder sequence."""
    return NamedSharding(mesh, fit_spec(mesh, tuple(enc_shape),
                                        (dp_axes(mesh), None, None)))


def batch_shardings(mesh: Mesh, batch_shape: PyTree) -> PyTree:
    """Token/label/frame inputs: batch dim over dp axes."""
    dp = dp_axes(mesh)

    def _one(leaf):
        shape = tuple(leaf.shape)
        want = [dp] + [None] * (len(shape) - 1)
        if len(shape) >= 2:
            pass  # sequence stays unsharded at the boundary; SP starts inside
        return NamedSharding(mesh, fit_spec(mesh, shape, tuple(want)))

    return jax.tree.map(_one, batch_shape)
