"""EF-free factored gradient transport (ROADMAP item 2).

What crosses the links during data-parallel training is the gradient, and
SMMF's square-matricization argument applies to it exactly as it does to
the momenta: most of the signal in each bucket's gradient stack lives in
rank-1 row/col statistics plus a sign plane (Adafactor's factored second
moment and Adapprox's randomized low-rank analysis are the grounding, see
PAPERS.md). This module compresses that traffic through the *same*
numerics stack the qstate codec built for stored state — ``core/quant.py``
stochastic rounding, ``core/matricize.py`` square-matricization,
``core/signpack.py`` bit-packed signs — so state AND traffic share one
compression story.

Modes (the ``transport`` spec hyperparam, per-group overridable):

* ``"none"`` — dense f32 gradients on the wire (4 bytes/element).
* ``"int8"`` — symmetric absmax int8 per **bucket-row** (the engine plan's
  stacked-leaf axis; per contained-leaf segment for fused flat rows),
  stochastically rounded. SR is exactly unbiased per element, which is
  what retires the full-size f32 error-feedback buffer the seed-era
  ``compress.py`` carried: there is no bias to feed back, so transport
  keeps **zero persistent state**.
* ``"rank1"`` — square-matricize each bucket row to its nearest-square
  ``(n_hat, m_hat)`` matrix, all-reduce only the row/col sketches of the
  magnitude plane (paper Algo 4, int8-SR with blockwise sub-row scales)
  plus the bit-packed sign plane, and deliver their outer product. Every
  ``transport_flush_every``-th step ships the exact dense gradient
  instead (the *residual flush*), so the per-step rank-1 approximation
  error is bounded and never accumulates across steps — again with zero
  carried state.

Determinism: the SR stream is a pure function of ``(step, bucket-crc,
slot)`` — the same scheme as ``qstate.update_key`` under a different base
key — so runs are bit-reproducible and every data-parallel replica draws
identical rounding noise (a real deployment must agree on the rounding;
seeding by step achieves that with no extra communication).

This repo runs single-program, so the all-reduce itself is modeled: the
compress→deliver round-trip on the gathered bucket gradient is the wire
format, applied in ``spec.py``'s update loop right after ``gather`` (hence
composing with ``--overlap`` / ``--offload`` untouched), and the bytes a
mesh would move are priced analytically by :func:`bucket_grad_bytes` /
``rules.boundary_transport_bytes`` and gated in ``BENCH_transport.json``.
"""

from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp

from repro.core import quant as Q
from repro.core.matricize import effective_shape
from repro.core.plan import Bucket
from repro.core.signpack import pack_signs, packed_width, unpack_signs

TRANSPORT_MODES = ("int8", "rank1")

# Distinct from qstate's 0x5317: transport and state re-quantization must
# never share an SR stream (same step + bucket would correlate the noise).
_BASE_KEY = 0x7A41

# Blockwise sub-row scale width for the rank-1 sketches: one f32 scale per
# 256 int8 sketch elements (1.6% overhead). Long fused dense:flat rows
# matricize to sketches spanning many leaves; per-block absmax keeps the
# small leaves' quantization tight (see core/quant.py block_scale).
SKETCH_BLOCK = 256

_SLOT_PAYLOAD, _SLOT_ROW, _SLOT_COL = 0, 1, 2

DEFAULT_FLUSH_EVERY = 8


def check_mode(mode) -> str | None:
    """Validate a transport mode; ``None``/``"none"`` normalize to None."""
    if mode is None or mode == "none":
        return None
    if mode not in TRANSPORT_MODES:
        raise ValueError(f"unknown transport mode {mode!r}; "
                         f"supported: {('none',) + TRANSPORT_MODES}")
    return mode


def check_flush_every(k) -> int:
    """Validate the rank-1 dense-residual-flush period (positive int)."""
    if not isinstance(k, int) or isinstance(k, bool) or k < 1:
        raise ValueError(
            f"transport_flush_every must be a positive int, got {k!r}")
    return k


def transport_key(step, bucket: Bucket):
    """Deterministic per-(step, bucket) PRNG key for transport SR;
    callers fold in a slot index per quantized plane (payload/row/col)."""
    key = jax.random.fold_in(jax.random.PRNGKey(_BASE_KEY), step)
    return jax.random.fold_in(key, zlib.crc32(bucket.key.encode()) & 0x7FFFFFFF)


# ---------------------------------------------------------------------------
# int8 mode: per-bucket-row absmax + stochastic rounding, no EF
# ---------------------------------------------------------------------------


def _int8_deliver(bucket: Bucket, gm: jnp.ndarray, key) -> jnp.ndarray:
    x = gm.astype(jnp.float32)
    if bucket.fused and bucket.size > 1:
        seg = bucket.segment_ids()
        scale = Q.segment_scale(x, seg, bucket.size, "int8")
        row = scale[seg].reshape(x.shape)
    else:
        row = Q.row_scale(x, "int8")
    q = Q.quantize(x, row, "int8", key=jax.random.fold_in(key, _SLOT_PAYLOAD))
    return Q.dequantize(q, row).astype(gm.dtype)


# ---------------------------------------------------------------------------
# rank1 mode: sketches + packed signs, dense residual flush every k steps
# ---------------------------------------------------------------------------


def _row_matrix_shape(bucket: Bucket) -> tuple[int, int]:
    """Square-matricized shape of one bucket row's gradient (Algo 2 over
    the row's element count — transport picks its own matricization, the
    family's state geometry is irrelevant on the wire)."""
    if bucket.fused:  # one flat row concatenating every contained leaf
        numel = sum(p.numel for p in bucket.plans)
    else:  # stacked rows share one geometry
        numel = bucket.plans[0].numel
    return effective_shape(numel)


def _q_sketch(v: jnp.ndarray, key) -> jnp.ndarray:
    """Int8-SR round-trip of a non-negative sketch ``(K, L)`` with blockwise
    sub-row scales (`SKETCH_BLOCK`); returns the f32 delivered sketch."""
    length = v.shape[-1]
    scale = Q.block_scale(v, SKETCH_BLOCK, "int8")
    row = Q.block_expand(scale, SKETCH_BLOCK, length)
    q = Q.quantize(v, row, "int8", key=key)
    return jnp.maximum(Q.dequantize(q, row), 0.0)


def _rank1_deliver(bucket: Bucket, gm: jnp.ndarray, step, flush_every: int,
                   key) -> jnp.ndarray:
    n_hat, m_hat = _row_matrix_shape(bucket)
    stack = gm.shape[0]
    g = gm.astype(jnp.float32).reshape(stack, n_hat, m_hat)

    # 1-bit sign plane, honestly through the packed wire format
    packed = pack_signs((g >= 0).reshape(stack * n_hat, m_hat))
    signs = unpack_signs(packed, m_hat).reshape(stack, n_hat, m_hat)

    # rank-1 magnitude sketches (paper Algo 4, batched over the stack),
    # int8-SR'd with blockwise scales — these are the only dense-rank-free
    # payloads on the wire between flushes
    a = jnp.abs(g)
    r = jnp.sum(a, axis=2)
    c = jnp.sum(a, axis=1)
    # denominator guard: an all-zero gradient row would otherwise evaluate
    # 0/0 in the discarded where-branch (jax_debug_nans)
    if n_hat <= m_hat:
        tot = jnp.sum(r, axis=1, keepdims=True)
        r = r / jnp.where(tot > 0, tot, 1.0)
    else:
        tot = jnp.sum(c, axis=1, keepdims=True)
        c = c / jnp.where(tot > 0, tot, 1.0)
    r = _q_sketch(r, jax.random.fold_in(key, _SLOT_ROW))
    c = _q_sketch(c, jax.random.fold_in(key, _SLOT_COL))

    approx = signs * r[:, :, None] * c[:, None, :]

    # dense residual flush: every k-th step the wire carries the exact
    # gradient, so between-flush approximation error cannot accumulate
    flush = (step % flush_every) == 0
    out = jnp.where(flush, g, approx)
    return out.reshape(gm.shape).astype(gm.dtype)


# ---------------------------------------------------------------------------
# entry point (spec.py update loop) + per-tensor legacy helper
# ---------------------------------------------------------------------------


def compress_bucket(mode: str, bucket: Bucket, gm: jnp.ndarray, step,
                    flush_every: int = DEFAULT_FLUSH_EVERY,
                    telemetry=None) -> jnp.ndarray:
    """Round-trip one bucket's gathered gradient through the transport wire
    format. Stateless: the delivered array has ``gm``'s shape/dtype and is
    unbiased (int8) or flush-bounded (rank1); nothing is carried to the
    next step.

    ``telemetry`` is an optional :class:`repro.obs.jit.TelemetryCollector`
    — when set, the round-trip records ``transport/rt_err/<bucket key>``
    (relative L2 error of delivered vs gathered gradient) and, for rank1,
    adds this bucket's dense-flush indicator into ``transport/flush``. The
    delivered gradient itself is identical with or without a collector.
    """
    mode = check_mode(mode)
    if mode is None:
        return gm
    key = transport_key(step, bucket)
    if mode == "int8":
        out = _int8_deliver(bucket, gm, key)
    else:
        out = _rank1_deliver(bucket, gm, step, check_flush_every(flush_every),
                             key)
        if telemetry is not None:
            telemetry.add("transport/flush", (step % flush_every) == 0)
    if telemetry is not None:
        from repro.obs.jit import rel_error

        telemetry.record(f"transport/rt_err/{bucket.key}", rel_error(gm, out))
    return out


def int8_roundtrip(x: jnp.ndarray, key) -> jnp.ndarray:
    """Per-tensor int8-SR round-trip (one absmax scale for the whole
    tensor) — the EF-free replacement for the legacy ``compress.py``
    granularity; the deprecation shim delegates here."""
    row = x.astype(jnp.float32).reshape(1, -1)
    scale = Q.row_scale(row, "int8")
    q = Q.quantize(row, scale, "int8", key=key)
    return Q.dequantize(q, scale).reshape(x.shape).astype(x.dtype)


# ---------------------------------------------------------------------------
# honest pricing: bytes per step on the gradient boundary
# ---------------------------------------------------------------------------


def bucket_grad_bytes(bucket: Bucket, mode,
                      flush_every: int = DEFAULT_FLUSH_EVERY) -> int:
    """Analytic per-step wire bytes for one bucket's gradient under
    ``mode`` (amortizing rank1's dense flush over ``flush_every`` steps).

    Convention: one f32 gradient crossing = ``4 * numel`` bytes — the same
    per-crossing unit ``rules.boundary_transport_bytes`` uses, so ratios
    between modes are crossing-count-free.
    """
    mode = check_mode(mode)
    numel = sum(p.numel for p in bucket.plans)
    dense = 4 * numel
    if mode is None:
        return dense
    if mode == "int8":
        nscales = bucket.size if (bucket.fused and bucket.size > 1) \
            else bucket.stack
        return numel + 4 * nscales
    flush_every = check_flush_every(flush_every)
    n_hat, m_hat = _row_matrix_shape(bucket)
    stack = bucket.stack
    sketch = stack * (n_hat + m_hat)                       # int8 payloads
    sketch += 4 * stack * (Q.block_count(n_hat, SKETCH_BLOCK)
                           + Q.block_count(m_hat, SKETCH_BLOCK))  # scales
    sign = stack * n_hat * packed_width(m_hat)             # packed bits
    # k-step cycle: one dense flush + (k-1) sketch steps
    return (dense + (flush_every - 1) * (sketch + sign)) // flush_every


def grad_transport_bytes(engine, mode: str = "plan",
                         flush_every=None) -> dict:
    """Engine-wide gradient-boundary pricing.

    ``mode="plan"`` prices each bucket under its *own* planned transport
    (``LeafPlan.transport``); a concrete mode string prices the whole
    engine as if every bucket used it (the per-mode comparison column).
    Returns ``{"total", "by_group"}`` in bytes/step.
    """
    total, by_group = 0, {}
    for bk in engine.buckets:
        if mode == "plan":
            bmode = bk.transport
            bflush = bk.transport_flush_every
        else:
            bmode = mode
            bflush = flush_every or DEFAULT_FLUSH_EVERY
        b = bucket_grad_bytes(bk, bmode, bflush)
        total += b
        grp = bk.plans[0].group
        by_group[grp] = by_group.get(grp, 0) + b
    return {"total": int(total), "by_group": by_group}
