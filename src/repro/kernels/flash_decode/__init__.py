"""Flash-decode attention Pallas TPU kernel.

Single-token decode attention that streams the KV cache through VMEM once
(online softmax, accumulators resident in VMEM scratch) — the kernel-level
answer to the §Perf cell-A finding that XLA-level decode attention
materializes broadcast GEMV products.

The paged variant (``flash_decode_paged``) serves the continuous-batching
engine: the cache is a pool of fixed-size pages addressed through a
scalar-prefetched per-row page table, with optional int8/fp8 payloads
dequantized in-register.
"""

from repro.kernels.flash_decode.ops import flash_decode, flash_decode_paged
from repro.kernels.flash_decode.ref import flash_decode_paged_ref, flash_decode_ref

__all__ = [
    "flash_decode",
    "flash_decode_paged",
    "flash_decode_paged_ref",
    "flash_decode_ref",
]
