"""Flash-decode attention Pallas TPU kernel.

Single-token decode attention that streams the KV cache through VMEM once
(online softmax, accumulators resident in VMEM scratch) — the kernel-level
answer to the §Perf cell-A finding that XLA-level decode attention
materializes broadcast GEMV products.
"""

from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import flash_decode_ref

__all__ = ["flash_decode", "flash_decode_ref"]
