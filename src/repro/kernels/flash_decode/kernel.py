"""Pallas TPU kernels: flash-decode (single-token attention over a KV cache).

Two layouts share the same online-softmax inner loop:

* **Contiguous** (:func:`flash_decode_blocks`) — grid (B, S/bs): each batch
  row's dense (S, Hkv, D) cache streams through VMEM in (bs, Hkv, D)
  blocks; the online-softmax state (acc (Hkv, grp, D), running max m and
  sum l (Hkv, grp)) lives in VMEM scratch, persisting across the
  sequential S-axis grid steps. HBM traffic = one pass over the row's
  cache + one (Hq, D) output write — the roofline minimum for decode (the
  XLA-level path additionally materializes an (S, Hkv, D)-sized
  broadcast-product; see EXPERIMENTS.md §Perf cell A).

* **Paged** (:func:`flash_decode_pages`) — grid (B, npages): the serving
  engine's KV cache is a pool of fixed-size pages (P, page, Hkv, D) plus a
  per-row page table; the table and valid positions arrive via scalar
  prefetch (``pltpu.PrefetchScalarGridSpec``) so the BlockSpec index maps
  chase ``tbl[b, j]`` — the j-th page of row b streams straight from its
  pooled HBM location into VMEM with **no gathered contiguous copy ever
  materializing** (the XLA reference path pays that gather). This is the
  same grid generalization PR 1 applied to ``smmf_update`` (bucket×block
  3D grid): one more grid axis over a table-indirected block dimension.
  An optional quantized variant carries int8/fp8 page payloads plus
  per-(token, head) f32 scale pages and dequantizes in-register, so the
  at-rest cache stays 1 byte/element in HBM end to end.

The per-row valid length (pos) arrives via scalar prefetch (SMEM) and
masks the tail block; fully masked blocks still stream (static grid) but
contribute zeros.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _kernel(
    pos_ref,      # SMEM (B,) int32: last valid position per row
    q_ref,        # (1, hkv, grp, d)
    k_ref,        # (1, bs, hkv, d)
    v_ref,        # (1, bs, hkv, d)
    o_ref,        # out (1, hkv, grp, d) f32
    acc_ref,      # scratch (hkv, grp, d) f32
    m_ref,        # scratch (hkv, grp) f32
    l_ref,        # scratch (hkv, grp) f32
    *,
    bs: int,
    nsteps: int,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # (hkv, grp, d) f32 (pre-scaled)
    k = k_ref[0].astype(jnp.float32)              # (bs, hkv, d)
    v = v_ref[0].astype(jnp.float32)

    s_blk = jnp.einsum("hgd,shd->hgs", q, k)      # (hkv, grp, bs)
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = kpos <= pos_ref[b]
    s_blk = jnp.where(valid, s_blk, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1))
    p = jnp.exp(s_blk - m_new[..., None])
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * scale[..., None] + jnp.einsum("hgs,shd->hgd", p, v)
    m_ref[...] = m_new

    @pl.when(j == nsteps - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-30)


def _paged_kernel(
    tbl_ref,      # scalar-prefetch (B, npages) int32: page ids per row
    pos_ref,      # scalar-prefetch (B,) int32: last valid position per row
    q_ref,        # (1, hkv, grp, d)
    k_ref,        # (1, page, hkv, d) — page tbl[b, j] of the pool
    v_ref,        # (1, page, hkv, d)
    o_ref,        # out (1, hkv, grp, d) f32
    acc_ref,      # scratch (hkv, grp, d) f32
    m_ref,        # scratch (hkv, grp) f32
    l_ref,        # scratch (hkv, grp) f32
    *,
    page: int,
    npages: int,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # (hkv, grp, d) f32 (pre-scaled)
    k = k_ref[0].astype(jnp.float32)              # (page, hkv, d)
    v = v_ref[0].astype(jnp.float32)

    s_blk = jnp.einsum("hgd,shd->hgs", q, k)      # (hkv, grp, page)
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = kpos <= pos_ref[b]
    s_blk = jnp.where(valid, s_blk, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1))
    p = jnp.exp(s_blk - m_new[..., None])
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * scale[..., None] + jnp.einsum("hgs,shd->hgd", p, v)
    m_ref[...] = m_new

    @pl.when(j == npages - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-30)


def _paged_kernel_quant(
    tbl_ref,      # scalar-prefetch (B, npages) int32
    pos_ref,      # scalar-prefetch (B,) int32
    q_ref,        # (1, hkv, grp, d)
    k_ref,        # (1, page, hkv, d) int8 / fp8 payload
    ks_ref,       # (1, page, hkv) f32 per-(token, head) scales
    v_ref,        # (1, page, hkv, d) payload
    vs_ref,       # (1, page, hkv) f32
    o_ref,        # out (1, hkv, grp, d) f32
    acc_ref, m_ref, l_ref,
    *,
    page: int,
    npages: int,
):
    """Quantized-page variant: dequantize in-register so the at-rest cache
    stays 1 byte/element in HBM (exactly the PR 5 in-kernel-dequant move,
    applied to KV pages instead of factor rows)."""
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]
    k = k_ref[0].astype(jnp.float32) * ks_ref[0][..., None]   # (page, hkv, d)
    v = v_ref[0].astype(jnp.float32) * vs_ref[0][..., None]

    s_blk = jnp.einsum("hgd,shd->hgs", q, k)
    kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    valid = kpos <= pos_ref[b]
    s_blk = jnp.where(valid, s_blk, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1))
    p = jnp.exp(s_blk - m_new[..., None])
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * scale[..., None] + jnp.einsum("hgs,shd->hgd", p, v)
    m_ref[...] = m_new

    @pl.when(j == npages - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-30)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_pages(q, k_pages, v_pages, pos, tbl, interpret: bool = True,
                       k_scale=None, v_scale=None):
    """Paged flash-decode over a pooled KV cache.

    q (B, hkv, grp, d) f32 pre-scaled; k_pages/v_pages (P, page, hkv, d);
    pos (B,) i32 last valid position; tbl (B, npages) i32 page table
    (zero-padded — page 0 is the engine's scratch page and masked rows
    contribute nothing). When ``k_scale``/``v_scale`` (P, page, hkv) f32
    are given, the payload pools are quantized and dequant happens
    in-register. Returns o (B, hkv, grp, d) f32.
    """
    bsz, hkv, grp, d = q.shape
    _, page, _, _ = k_pages.shape
    npages = tbl.shape[1]
    grid = (bsz, npages)
    quant = k_scale is not None

    q_spec = pl.BlockSpec((1, hkv, grp, d), lambda b, j, tbl, pos: (b, 0, 0, 0))
    kv_spec = pl.BlockSpec((1, page, hkv, d),
                           lambda b, j, tbl, pos: (tbl[b, j], 0, 0, 0))
    if quant:
        sc_spec = pl.BlockSpec((1, page, hkv),
                               lambda b, j, tbl, pos: (tbl[b, j], 0, 0))
        kernel = functools.partial(_paged_kernel_quant, page=page, npages=npages)
        in_specs = [q_spec, kv_spec, sc_spec, kv_spec, sc_spec]
        operands = (q, k_pages, k_scale, v_pages, v_scale)
    else:
        kernel = functools.partial(_paged_kernel, page=page, npages=npages)
        in_specs = [q_spec, kv_spec, kv_spec]
        operands = (q, k_pages, v_pages)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, hkv, grp, d),
                               lambda b, j, tbl, pos: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, grp, d), jnp.float32),   # acc
            pltpu.VMEM((hkv, grp), jnp.float32),      # running max
            pltpu.VMEM((hkv, grp), jnp.float32),      # running sum
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, grp, d), jnp.float32),
        interpret=interpret,
    )(tbl, pos, *operands)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_blocks(q, k, v, pos, block_s: int = DEFAULT_BLOCK_S, interpret: bool = True):
    """q (B, hkv, grp, d) f32 pre-scaled; k/v (B, S, hkv, d); pos (B,) i32.

    Requires S % block_s == 0 (ops.py pads). Returns o (B, hkv, grp, d) f32.
    """
    bsz, hkv, grp, d = q.shape
    s = k.shape[1]
    nsteps = s // block_s

    grid = (bsz, nsteps)
    kernel = functools.partial(_kernel, bs=block_s, nsteps=nsteps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),                       # pos (SMEM-like)
            pl.BlockSpec((1, hkv, grp, d), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, d), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, d), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, grp, d), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, grp, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hkv, grp, d), jnp.float32),   # acc
            pltpu.VMEM((hkv, grp), jnp.float32),      # running max
            pltpu.VMEM((hkv, grp), jnp.float32),      # running sum
        ],
        interpret=interpret,
    )(pos, q, k, v)
