"""Pallas TPU kernel: flash-decode (single-token attention over a KV cache).

Grid (B, S/bs): for each batch row the KV cache streams through VMEM in
(bs, Hkv, D) blocks; the online-softmax state (acc (Hkv, grp, D), running
max m and sum l (Hkv, grp)) lives in VMEM scratch, persisting across the
sequential S-axis grid steps. HBM traffic = one pass over the row's cache
+ one (Hq, D) output write — the roofline minimum for decode (the
XLA-level path additionally materializes an (S, Hkv, D)-sized
broadcast-product; see EXPERIMENTS.md §Perf cell A).

The per-row valid length (pos) arrives via scalar prefetch (SMEM) and
masks the tail block; fully masked blocks still stream (static grid) but
contribute zeros.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _kernel(
    pos_ref,      # SMEM (B,) int32: last valid position per row
    q_ref,        # (1, hkv, grp, d)
    k_ref,        # (1, bs, hkv, d)
    v_ref,        # (1, bs, hkv, d)
    o_ref,        # out (1, hkv, grp, d) f32
    acc_ref,      # scratch (hkv, grp, d) f32
    m_ref,        # scratch (hkv, grp) f32
    l_ref,        # scratch (hkv, grp) f32
    *,
    bs: int,
    nsteps: int,
):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                  # (hkv, grp, d) f32 (pre-scaled)
    k = k_ref[0].astype(jnp.float32)              # (bs, hkv, d)
    v = v_ref[0].astype(jnp.float32)

    s_blk = jnp.einsum("hgd,shd->hgs", q, k)      # (hkv, grp, bs)
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    valid = kpos <= pos_ref[b]
    s_blk = jnp.where(valid, s_blk, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_blk, axis=-1))
    p = jnp.exp(s_blk - m_new[..., None])
    scale = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * scale + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * scale[..., None] + jnp.einsum("hgs,shd->hgd", p, v)
    m_ref[...] = m_new

    @pl.when(j == nsteps - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...][..., None], 1e-30)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode_blocks(q, k, v, pos, block_s: int = DEFAULT_BLOCK_S, interpret: bool = True):
    """q (B, hkv, grp, d) f32 pre-scaled; k/v (B, S, hkv, d); pos (B,) i32.

    Requires S % block_s == 0 (ops.py pads). Returns o (B, hkv, grp, d) f32.
    """
    bsz, hkv, grp, d = q.shape
    s = k.shape[1]
    nsteps = s // block_s

    grid = (bsz, nsteps)
    kernel = functools.partial(_kernel, bs=block_s, nsteps=nsteps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),                       # pos (SMEM-like)
            pl.BlockSpec((1, hkv, grp, d), lambda b, j: (b, 0, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, d), lambda b, j: (b, j, 0, 0)),
            pl.BlockSpec((1, block_s, hkv, d), lambda b, j: (b, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, grp, d), lambda b, j: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, grp, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((hkv, grp, d), jnp.float32),   # acc
            pltpu.VMEM((hkv, grp), jnp.float32),      # running max
            pltpu.VMEM((hkv, grp), jnp.float32),      # running sum
        ],
        interpret=interpret,
    )(pos, q, k, v)
