"""jit'd wrapper for the flash-decode kernel: GQA reshape, scaling, padding."""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import (
    DEFAULT_BLOCK_S,
    flash_decode_blocks,
    flash_decode_pages,
)


def flash_decode_paged(q, k_pages, v_pages, pos, tbl, interpret: bool = True,
                       k_scale=None, v_scale=None):
    """Paged variant: q (B,Hq,D); k/v pools (P,page,Hkv,D); pos (B,);
    tbl (B,npages) page table (zero-padded) -> o (B,Hq,D) f32.

    Semantics match ref.flash_decode_paged_ref (attend to positions <= pos
    along the gathered per-row sequence). Optional (P,page,Hkv) f32 scale
    pools mark quantized payloads (in-register dequant).
    """
    b, hq, d = q.shape
    hkv = k_pages.shape[2]
    grp = hq // hkv
    qg = (q.astype(jnp.float32) / math.sqrt(d)).reshape(b, hkv, grp, d)
    o = flash_decode_pages(qg, k_pages, v_pages, pos.astype(jnp.int32),
                           tbl.astype(jnp.int32), interpret=interpret,
                           k_scale=k_scale, v_scale=v_scale)
    return o.reshape(b, hq, d)


def flash_decode(q, k, v, pos, block_s: int = DEFAULT_BLOCK_S, interpret: bool = True):
    """q (B,Hq,D); k/v (B,S,Hkv,D); pos (B,) -> o (B,Hq,D) f32.

    Semantics match ref.flash_decode_ref (attend to positions <= pos).
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    grp = hq // hkv
    bs = min(block_s, s)
    s2 = -(-s // bs) * bs
    if s2 != s:  # pad cache; padded keys are masked by pos anyway
        padw = ((0, 0), (0, s2 - s), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    qg = (q.astype(jnp.float32) / math.sqrt(d)).reshape(b, hkv, grp, d)
    o = flash_decode_blocks(qg, k, v, pos.astype(jnp.int32), block_s=bs, interpret=interpret)
    return o.reshape(b, hq, d)
