"""jit'd wrapper for the flash-decode kernel: GQA reshape, scaling, padding."""

from __future__ import annotations

import math

import jax.numpy as jnp

from repro.kernels.flash_decode.kernel import DEFAULT_BLOCK_S, flash_decode_blocks


def flash_decode(q, k, v, pos, block_s: int = DEFAULT_BLOCK_S, interpret: bool = True):
    """q (B,Hq,D); k/v (B,S,Hkv,D); pos (B,) -> o (B,Hq,D) f32.

    Semantics match ref.flash_decode_ref (attend to positions <= pos).
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    grp = hq // hkv
    bs = min(block_s, s)
    s2 = -(-s // bs) * bs
    if s2 != s:  # pad cache; padded keys are masked by pos anyway
        padw = ((0, 0), (0, s2 - s), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    qg = (q.astype(jnp.float32) / math.sqrt(d)).reshape(b, hkv, grp, d)
    o = flash_decode_blocks(qg, k, v, pos.astype(jnp.int32), block_s=bs, interpret=interpret)
    return o.reshape(b, hq, d)
