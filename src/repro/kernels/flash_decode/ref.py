"""Pure-jnp oracle for single-token decode attention with a KV cache."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def flash_decode_ref(q, k, v, pos):
    """q (B,Hq,D); k/v (B,S,Hkv,D); pos (B,) valid lengths (attend to < pos+1).

    Returns o (B,Hq,D) f32.
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    grp = hq // hkv
    qg = q.reshape(b, hkv, grp, d).astype(jnp.float32) / math.sqrt(d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(s)[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, d)


def flash_decode_paged_ref(q, k_pages, v_pages, pos, tbl,
                           k_scale=None, v_scale=None):
    """Paged oracle: gather each row's pages into a dense (B,S,Hkv,D) cache
    (S = npages * page), dequantize if scale pools are given, and defer to
    :func:`flash_decode_ref`. This is the XLA-level path the Pallas kernel
    avoids — it materializes the contiguous gathered copy.

    q (B,Hq,D); k/v pools (P,page,Hkv,D); pos (B,); tbl (B,npages) i32.
    """
    k = k_pages[tbl]                       # (B, npages, page, Hkv, D)
    v = v_pages[tbl]
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[tbl][..., None]
        v = v.astype(jnp.float32) * v_scale[tbl][..., None]
    b, npages, page, hkv, d = k.shape
    k = k.reshape(b, npages * page, hkv, d)
    v = v.reshape(b, npages * page, hkv, d)
    return flash_decode_ref(q, k, v, pos)
