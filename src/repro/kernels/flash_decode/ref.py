"""Pure-jnp oracle for single-token decode attention with a KV cache."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def flash_decode_ref(q, k, v, pos):
    """q (B,Hq,D); k/v (B,S,Hkv,D); pos (B,) valid lengths (attend to < pos+1).

    Returns o (B,Hq,D) f32.
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    grp = hq // hkv
    qg = q.reshape(b, hkv, grp, d).astype(jnp.float32) / math.sqrt(d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(s)[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, d)
