"""Fused SMMF update Pallas TPU kernel.

One pass over HBM: decompress momentum factors, EMA-update with the intact
gradient, extract+pack signs, emit row/col partial sums for re-factorization,
and produce the Adam-style update — the eager reference makes ~6 passes.
"""

from repro.kernels.smmf_update.ops import smmf_update
from repro.kernels.smmf_update.ref import smmf_update_ref

__all__ = ["smmf_update", "smmf_update_ref"]
