"""Pallas TPU kernel: fused SMMF decompress -> EMA -> sign/compress -> update.

Operates on a batch of independently-factorized square matrices at once —
the leading ``B`` axis carries both the blockwise (``blocks=K``) variant and
the leaf-plan engine's *bucket* axis (K same-geometry leaves x their blocks),
so one kernel launch updates an entire bucket.

Tiling: grid (B, n/bn, m/bm) over the square-matricized momenta. Each grid
step holds one (bn, bm) gradient tile in VMEM plus the four factor slices
(bn / bm vectors) and the (bn, bm/8) packed sign tile, computes everything
in-register, and writes:

  u tile          (bn, bm)     the unscaled update M_t/(sqrt(V_t)+eps)
  sign tile       (bn, bm/8)   new packed signs
  row partials    (bn, 1) per grid column j  -> (B, n, nj) partial tensor
  col partials    (1, bm) per grid row i     -> (B, ni, m) partial tensor

Partial-sum outputs avoid cross-grid-step accumulation entirely (each output
block is written exactly once), so the kernel is safe under any grid
traversal order; the O(n*nj + ni*m) reduction of partials happens in ops.py
as a trivially small jnp op.

Default tile 256 x 512 (f32): working set ~= (256*512)*4 * 3 live tiles
~= 1.6 MiB of VMEM, well inside the ~16 MiB/core budget, with both tile dims
multiples of the 8x128 VPU lanes. ``block`` and ``interpret`` are real
config threaded from the engine (interpret auto-selects off-TPU in ops.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# the single source of the default tile is repro.core.plan (which imports
# only stdlib + jax, so no package cycle); DEFAULT_BLOCK is the kernel
# package's historical name for it
from repro.core.plan import DEFAULT_KERNEL_BLOCK as DEFAULT_BLOCK


def _bits3() -> jnp.ndarray:
    """(1, 1, 8) uint8 tensor [1, 2, 4, ..., 128] built in-kernel (TPU needs
    >=2D iota and Pallas forbids captured constants)."""
    k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 8), dimension=2)
    return jnp.left_shift(jnp.int32(1), k).astype(jnp.uint8)


def _unpack_tile(packed: jnp.ndarray, bm: int) -> jnp.ndarray:
    """(bn, bm//8) uint8 -> (bn, bm) f32 of +-1."""
    b = (packed[:, :, None] & _bits3()) > 0
    return jnp.where(b, 1.0, -1.0).astype(jnp.float32).reshape(packed.shape[0], bm)


def _pack_tile(nonneg: jnp.ndarray) -> jnp.ndarray:
    """(bn, bm) bool -> (bn, bm//8) uint8."""
    bn, bm = nonneg.shape
    b = nonneg.reshape(bn, bm // 8, 8).astype(jnp.uint8)
    return jnp.sum(b * _bits3(), axis=-1, dtype=jnp.uint8)


def _update_tile(scal_ref, g, signs, rm, cm, rv, cv,
                 u_ref, sign_out_ref, rmp_ref, cmp_ref, rvp_ref, cvp_ref):
    """Shared tile math for the f32 and quantized kernels: decompress ->
    EMA -> update -> sign/compress partials, factors already in f32."""
    beta1 = scal_ref[0, 0]
    beta2 = scal_ref[0, 1]
    eps = scal_ref[0, 2]

    # Decompression (Algo 3): rank-1 outer products of the factor slices.
    m_hat = signs * (rm * cm)
    v_hat = rv * cv

    # EMA with the intact current gradient (decompression -> compression).
    m_t = beta1 * m_hat + (1.0 - beta1) * g
    v_t = beta2 * v_hat + (1.0 - beta2) * (g * g)

    # Update term.
    u_ref[0] = m_t / (jnp.sqrt(v_t) + eps)

    # Compression (Algo 4): signs + unnormalized row/col sums.
    sign_out_ref[0] = _pack_tile(m_t >= 0)
    am = jnp.abs(m_t)
    rmp_ref[0] = jnp.sum(am, axis=1, keepdims=True)
    cmp_ref[0] = jnp.sum(am, axis=0, keepdims=True)
    rvp_ref[0] = jnp.sum(v_t, axis=1, keepdims=True)
    cvp_ref[0] = jnp.sum(v_t, axis=0, keepdims=True)


def _kernel(
    scal_ref,      # (1, 3) f32: [beta1_t, beta2_t, eps]
    g_ref,         # (1, bn, bm)
    rm_ref,        # (1, bn, 1)
    cm_ref,        # (1, 1, bm)
    sign_ref,      # (1, bn, bm//8) uint8
    rv_ref,        # (1, bn, 1)
    cv_ref,        # (1, 1, bm)
    u_ref,         # out (1, bn, bm)
    sign_out_ref,  # out (1, bn, bm//8)
    rmp_ref,       # out (1, bn, 1)   row partials of |M_t|
    cmp_ref,       # out (1, 1, bm)   col partials of |M_t|
    rvp_ref,       # out (1, bn, 1)
    cvp_ref,       # out (1, 1, bm)
):
    g = g_ref[0]
    signs = _unpack_tile(sign_ref[0], g.shape[1])
    _update_tile(scal_ref, g, signs, rm_ref[0], cm_ref[0], rv_ref[0],
                 cv_ref[0], u_ref, sign_out_ref, rmp_ref, cmp_ref,
                 rvp_ref, cvp_ref)


def _kernel_q(
    scal_ref,      # (1, 3) f32: [beta1_t, beta2_t, eps]
    g_ref,         # (1, bn, bm)
    rm_ref,        # (1, bn, 1) int8 qstate payload
    cm_ref,        # (1, 1, bm) int8
    sign_ref,      # (1, bn, bm//8) uint8
    rv_ref,        # (1, bn, 1) int8
    cv_ref,        # (1, 1, bm) int8
    rms_ref,       # (1, 1, 1) f32 per-matrix absmax scales
    cms_ref,       # (1, 1, 1)
    rvs_ref,       # (1, 1, 1)
    cvs_ref,       # (1, 1, 1)
    u_ref, sign_out_ref, rmp_ref, cmp_ref, rvp_ref, cvp_ref,  # outs (as above)
):
    # qstate in-register dequant: int8 payload * per-matrix f32 scale
    # (repro.optim.qstate kernel_deq slots) — the f32 factors exist only in
    # VMEM/registers, never as HBM tensors. The v factors arrive
    # sqrt-companded (SlotSpec.sqrt: denominator-side state needs
    # quasi-relative precision under a linear 8-bit code), so the kernel
    # squares them after the linear dequant.
    g = g_ref[0]
    signs = _unpack_tile(sign_ref[0], g.shape[1])
    rm = rm_ref[0].astype(jnp.float32) * rms_ref[0]
    cm = cm_ref[0].astype(jnp.float32) * cms_ref[0]
    rv_s = rv_ref[0].astype(jnp.float32) * rvs_ref[0]
    cv_s = cv_ref[0].astype(jnp.float32) * cvs_ref[0]
    _update_tile(scal_ref, g, signs, rm, cm, rv_s * rv_s, cv_s * cv_s,
                 u_ref, sign_out_ref, rmp_ref, cmp_ref, rvp_ref, cvp_ref)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def smmf_update_tiles(
    g: jnp.ndarray,        # (B, n, m)
    r_m: jnp.ndarray,      # (B, n)   f32, or 1-byte qstate payload
    c_m: jnp.ndarray,      # (B, m)
    sign: jnp.ndarray,     # (B, n, m//8)
    r_v: jnp.ndarray,      # (B, n)
    c_v: jnp.ndarray,      # (B, m)
    scalars: jnp.ndarray,  # (1, 3) [beta1_t, beta2_t, eps]
    factor_scales=None,    # None, or (rm_s, cm_s, rv_s, cv_s) each (B, 1) f32
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """Run the fused kernel on pre-padded batched operands.

    Requires n % bn == 0, m % bm == 0, bm % 8 == 0 (ops.py pads).
    ``factor_scales`` selects the quantized-state kernel: the four factor
    operands are then 1-byte qstate payloads dequantized **in-register**
    against their per-matrix scales (no f32 factor tensor in HBM).
    Returns (u, sign_new, rm_partial (B, n, nj), cm_partial (B, ni, m),
             rv_partial, cv_partial).
    """
    bsz, n, m = g.shape
    bn, bm = block
    ni, nj = n // bn, m // bm
    pw, bpw = m // 8, bm // 8

    grid = (bsz, ni, nj)
    out_shapes = (
        jax.ShapeDtypeStruct((bsz, n, m), jnp.float32),   # u
        jax.ShapeDtypeStruct((bsz, n, pw), jnp.uint8),    # sign
        jax.ShapeDtypeStruct((bsz, n, nj), jnp.float32),  # rm partials
        jax.ShapeDtypeStruct((bsz, ni, m), jnp.float32),  # cm partials
        jax.ShapeDtypeStruct((bsz, n, nj), jnp.float32),  # rv partials
        jax.ShapeDtypeStruct((bsz, ni, m), jnp.float32),  # cv partials
    )
    in_specs = [
        pl.BlockSpec((1, 3), lambda b, i, j: (0, 0)),             # scalars
        pl.BlockSpec((1, bn, bm), lambda b, i, j: (b, i, j)),     # g
        pl.BlockSpec((1, bn, 1), lambda b, i, j: (b, i, 0)),      # r_m
        pl.BlockSpec((1, 1, bm), lambda b, i, j: (b, 0, j)),      # c_m
        pl.BlockSpec((1, bn, bpw), lambda b, i, j: (b, i, j)),    # sign
        pl.BlockSpec((1, bn, 1), lambda b, i, j: (b, i, 0)),      # r_v
        pl.BlockSpec((1, 1, bm), lambda b, i, j: (b, 0, j)),      # c_v
    ]
    out_specs = [
        pl.BlockSpec((1, bn, bm), lambda b, i, j: (b, i, j)),     # u
        pl.BlockSpec((1, bn, bpw), lambda b, i, j: (b, i, j)),    # sign
        pl.BlockSpec((1, bn, 1), lambda b, i, j: (b, i, j)),      # rm partials
        pl.BlockSpec((1, 1, bm), lambda b, i, j: (b, i, j)),      # cm partials
        pl.BlockSpec((1, bn, 1), lambda b, i, j: (b, i, j)),      # rv partials
        pl.BlockSpec((1, 1, bm), lambda b, i, j: (b, i, j)),      # cv partials
    ]
    operands = [scalars, g, r_m[:, :, None], c_m[:, None, :], sign,
                r_v[:, :, None], c_v[:, None, :]]
    kernel = _kernel
    if factor_scales is not None:
        kernel = _kernel_q
        scale_spec = pl.BlockSpec((1, 1, 1), lambda b, i, j: (b, 0, 0))
        in_specs += [scale_spec] * 4
        operands += [s.reshape(bsz, 1, 1) for s in factor_scales]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(*operands)
