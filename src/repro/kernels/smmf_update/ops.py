"""jit'd wrapper around the fused SMMF Pallas kernel.

Handles padding to tile multiples, the final (tiny) partial-sum reductions
and Algo-4 normalization of the smaller factor, and crops outputs back to
the true (n, m). Semantics are bit-for-bit those of ref.smmf_update_ref.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.signpack import packed_width
from repro.kernels.smmf_update.kernel import DEFAULT_BLOCK, smmf_update_tiles


def _pad_to(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr or pc:
        x = jnp.pad(x, ((0, pr), (0, pc)))
    return x


def smmf_update(
    g: jnp.ndarray,
    r_m: jnp.ndarray,
    c_m: jnp.ndarray,
    sign: jnp.ndarray,
    r_v: jnp.ndarray,
    c_v: jnp.ndarray,
    *,
    beta1_t,
    beta2_t,
    eps: float,
    block: tuple[int, int] = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """Fused SMMF update for one square-matricized (n, m) gradient.

    Returns (u, r_m', c_m', sign', r_v', c_v') with unpadded shapes.
    """
    n, m = g.shape
    bn, bm = block
    # clamp tiles to the (padded-to-lane) problem size so tiny layers don't
    # blow up into a full 256x512 tile
    bn = min(bn, max(8, -(-n // 8) * 8))
    bm = min(bm, max(128, -(-m // 128) * 128))
    n2 = -(-n // bn) * bn
    m2 = -(-m // bm) * bm
    pw, pw2 = packed_width(m), m2 // 8

    gp = _pad_to(g.astype(jnp.float32), n2, m2)
    rmp = jnp.pad(r_m, (0, n2 - n))
    cmp_ = jnp.pad(c_m, (0, m2 - m))
    rvp = jnp.pad(r_v, (0, n2 - n))
    cvp = jnp.pad(c_v, (0, m2 - m))
    sgn = _pad_to(sign, n2, pw2)
    scalars = jnp.stack(
        [jnp.asarray(beta1_t, jnp.float32), jnp.asarray(beta2_t, jnp.float32), jnp.asarray(eps, jnp.float32)]
    ).reshape(1, 3)

    u, sign2, rm_part, cm_part, rv_part, cv_part = smmf_update_tiles(
        gp, rmp, cmp_, sgn, rvp, cvp, scalars, block=(bn, bm), interpret=interpret
    )

    r_m2 = jnp.sum(rm_part, axis=1)[:n]
    c_m2 = jnp.sum(cm_part, axis=0)[:m]
    r_v2 = jnp.sum(rv_part, axis=1)[:n]
    c_v2 = jnp.sum(cv_part, axis=0)[:m]

    def _norm(r, c):
        if n <= m:
            tot = jnp.sum(r)
            r = jnp.where(tot > 0, r / tot, r)
        else:
            tot = jnp.sum(c)
            c = jnp.where(tot > 0, c / tot, c)
        return r, c

    r_m2, c_m2 = _norm(r_m2, c_m2)
    r_v2, c_v2 = _norm(r_v2, c_v2)
    sign2 = sign2[:n, :pw]
    if m % 8:  # zero the padding bits of the last byte (keeps state bit-exact)
        mask = jnp.full((pw,), 0xFF, jnp.uint8).at[-1].set((1 << (m % 8)) - 1)
        sign2 = sign2 & mask[None, :]
    return u[:n, :m], r_m2, c_m2, sign2, r_v2, c_v2
