"""jit'd wrappers around the fused SMMF Pallas kernel.

``smmf_update_batched`` is the engine-facing entry point: it updates a batch
of ``B`` independently-factorized square matrices (a whole same-geometry
bucket, blocks included) in one kernel launch. It handles padding to tile
multiples, the final (tiny) partial-sum reductions and Algo-4 normalization
of the smaller factor per matrix, and crops outputs back to the true
(n, m). ``smmf_update`` keeps the original single-matrix API on top of it.
Semantics are bit-for-bit those of ref.smmf_update_ref applied per matrix.

``interpret=None`` auto-selects: compiled on TPU, interpreter elsewhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.plan import clamp_kernel_block
from repro.core.signpack import packed_width
from repro.kernels.smmf_update.kernel import DEFAULT_BLOCK, smmf_update_tiles

# Trace-time launch counter: incremented once per pallas_call issued. Used by
# the CLI smoke assertion (train.py --use-kernel) and the engine tests to
# prove the fused path is actually taken (no silent fallback).
KERNEL_LAUNCHES = 0


def _resolve_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def smmf_update_batched(
    g: jnp.ndarray,      # (B, n, m)
    r_m: jnp.ndarray,    # (B, n)   f32, or 1-byte qstate payload
    c_m: jnp.ndarray,    # (B, m)
    sign: jnp.ndarray,   # (B, n, packed_width(m)) uint8
    r_v: jnp.ndarray,    # (B, n)
    c_v: jnp.ndarray,    # (B, m)
    *,
    beta1_t,
    beta2_t,
    eps: float,
    block: tuple[int, int] | None = None,
    interpret: bool | None = None,
    factor_scales=None,  # None, or (rm_s, cm_s, rv_s, cv_s) each (B, 1) f32
):
    """Fused SMMF update for a batch of square-matricized (n, m) gradients.

    Returns (u, r_m', c_m', sign', r_v', c_v') with unpadded shapes, leading
    batch axis preserved. Each batch element is factorized independently
    (per-matrix Algo-4 normalization), exactly as B separate calls would.

    ``factor_scales`` selects the quantized-state path (the qstate codec's
    ``kernel_deq`` slots, ``repro.optim.qstate``): the four factor operands
    are 1-byte payloads the kernel dequantizes in-register against their
    per-matrix scales; zero padding quantizes/dequantizes losslessly, so
    the pad-and-crop plumbing is unchanged. Outputs are always f32 — the
    re-quantization (with stochastic rounding) happens codec-side after the
    Algo-4 normalization below.
    """
    global KERNEL_LAUNCHES
    bsz, n, m = g.shape
    # clamp tiles to the (padded-to-lane) problem size so tiny layers don't
    # blow up into a full 256x512 tile
    bn, bm = clamp_kernel_block(n, m, block if block is not None else DEFAULT_BLOCK)
    n2 = -(-n // bn) * bn
    m2 = -(-m // bm) * bm
    pw, pw2 = packed_width(m), m2 // 8

    gp = jnp.pad(g.astype(jnp.float32), ((0, 0), (0, n2 - n), (0, m2 - m)))
    rmp = jnp.pad(r_m, ((0, 0), (0, n2 - n)))
    cmp_ = jnp.pad(c_m, ((0, 0), (0, m2 - m)))
    rvp = jnp.pad(r_v, ((0, 0), (0, n2 - n)))
    cvp = jnp.pad(c_v, ((0, 0), (0, m2 - m)))
    sgn = jnp.pad(sign, ((0, 0), (0, n2 - n), (0, pw2 - pw)))
    scalars = jnp.stack(
        [jnp.asarray(beta1_t, jnp.float32), jnp.asarray(beta2_t, jnp.float32), jnp.asarray(eps, jnp.float32)]
    ).reshape(1, 3)

    KERNEL_LAUNCHES += 1
    u, sign2, rm_part, cm_part, rv_part, cv_part = smmf_update_tiles(
        gp, rmp, cmp_, sgn, rvp, cvp, scalars,
        factor_scales=factor_scales,
        block=(bn, bm), interpret=_resolve_interpret(interpret),
    )

    r_m2 = jnp.sum(rm_part, axis=2)[:, :n]
    c_m2 = jnp.sum(cm_part, axis=1)[:, :m]
    r_v2 = jnp.sum(rv_part, axis=2)[:, :n]
    c_v2 = jnp.sum(cv_part, axis=1)[:, :m]

    def _norm(r, c):
        # per-matrix Algo-4 normalization of the smaller factor; the
        # denominator guard keeps all-zero moments from evaluating 0/0 in
        # the discarded where-branch (jax_debug_nans)
        if n <= m:
            tot = jnp.sum(r, axis=1, keepdims=True)
            r = r / jnp.where(tot > 0, tot, 1.0)
        else:
            tot = jnp.sum(c, axis=1, keepdims=True)
            c = c / jnp.where(tot > 0, tot, 1.0)
        return r, c

    r_m2, c_m2 = _norm(r_m2, c_m2)
    r_v2, c_v2 = _norm(r_v2, c_v2)
    sign2 = sign2[:, :n, :pw]
    if m % 8:  # zero the padding bits of the last byte (keeps state bit-exact)
        mask = jnp.full((pw,), 0xFF, jnp.uint8).at[-1].set((1 << (m % 8)) - 1)
        sign2 = sign2 & mask[None, None, :]
    return u[:, :n, :m], r_m2, c_m2, sign2, r_v2, c_v2


def smmf_update(
    g: jnp.ndarray,
    r_m: jnp.ndarray,
    c_m: jnp.ndarray,
    sign: jnp.ndarray,
    r_v: jnp.ndarray,
    c_v: jnp.ndarray,
    *,
    beta1_t,
    beta2_t,
    eps: float,
    block: tuple[int, int] | None = None,
    interpret: bool | None = None,
):
    """Fused SMMF update for one square-matricized (n, m) gradient.

    Returns (u, r_m', c_m', sign', r_v', c_v') with unpadded shapes.
    """
    u, r_m2, c_m2, sign2, r_v2, c_v2 = smmf_update_batched(
        g[None], r_m[None], c_m[None], sign[None], r_v[None], c_v[None],
        beta1_t=beta1_t, beta2_t=beta2_t, eps=eps, block=block, interpret=interpret,
    )
    return u[0], r_m2[0], c_m2[0], sign2[0], r_v2[0], c_v2[0]
