"""Pure-jnp oracle for the fused SMMF update (paper Algo 1 inner loop).

Given the square-matricized gradient G (n, m) and the factorized state
(r_m, c_m, sign_packed, r_v, c_v), returns

  u        (n, m)  M_t / (sqrt(V_t) + eps)        [unscaled update]
  r_m, c_m          new |M| factors (smaller vector normalized, Algo 4)
  sign     (n, pw)  new bit-packed sign of M_t
  r_v, c_v          new V factors

This is the semantics the Pallas kernel must reproduce exactly.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.signpack import pack_signs, unpack_signs


def _normalize(r: jnp.ndarray, c: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    n, m = r.shape[0], c.shape[0]
    if n <= m:
        tot = jnp.sum(r)
        r = jnp.where(tot > 0, r / tot, r)
    else:
        tot = jnp.sum(c)
        c = jnp.where(tot > 0, c / tot, c)
    return r, c


def smmf_update_ref(
    g: jnp.ndarray,
    r_m: jnp.ndarray,
    c_m: jnp.ndarray,
    sign: jnp.ndarray,
    r_v: jnp.ndarray,
    c_v: jnp.ndarray,
    *,
    beta1_t,
    beta2_t,
    eps: float,
):
    n, m = g.shape
    g = g.astype(jnp.float32)
    signs = unpack_signs(sign, m)
    m_hat = signs * jnp.outer(r_m, c_m)
    v_hat = jnp.outer(r_v, c_v)
    m_t = beta1_t * m_hat + (1.0 - beta1_t) * g
    v_t = beta2_t * v_hat + (1.0 - beta2_t) * g * g
    sign2 = pack_signs(m_t >= 0)
    am = jnp.abs(m_t)
    r_m2, c_m2 = _normalize(jnp.sum(am, axis=1), jnp.sum(am, axis=0))
    r_v2, c_v2 = _normalize(jnp.sum(v_t, axis=1), jnp.sum(v_t, axis=0))
    u = m_t / (jnp.sqrt(v_t) + eps)
    return u, r_m2, c_m2, sign2, r_v2, c_v2
