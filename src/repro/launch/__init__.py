"""Launch layer: production meshes, abstract input specs, jit'd step factories."""
