import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline terms from the compiled artifact.

MUST be run as its own process (the XLA flag above is read at first jax
import):

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi_6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell it records: lowering+compile wall time, memory_analysis (per-device
bytes), cost_analysis (FLOPs / bytes accessed), and per-collective-kind byte
counts parsed from the post-SPMD HLO, into results/dryrun/<cell>.json.
"""

import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, cell_status, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.models.config import SHAPES
from repro.optim.spec import OptimizerSpec, build_optimizer

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# bytes-on-the-wire multiplier per chip for ring algorithms:
#   all-gather out=full        -> ~1x full size
#   all-reduce out=full        -> ~2x (reduce-scatter + all-gather)
#   reduce-scatter out=shard   -> ~1x full = out * group
#   all-to-all  out=full-ish   -> ~1x
#   collective-permute         -> 1x
_COLL_RE = re.compile(
    r"=\s*(?P<dtype>[a-z0-9]+)\[(?P<shape>[0-9,]*)\][^ ]*\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-chip wire bytes by collective kind from post-SPMD HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-start" in line and m.group("op") + "-start" not in line:
            pass
        dt = _DTYPE_BYTES.get(m.group("dtype"))
        if dt is None:
            continue
        shape = m.group("shape")
        numel = 1
        if shape:
            for d in shape.split(","):
                numel *= int(d)
        size = numel * dt
        op = m.group("op")
        group = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            group = gm.group(1).count(",") + 1
        else:
            gm = _GROUPS_IOTA_RE.search(line)
            if gm:
                group = int(gm.group(2))
        if op == "all-reduce":
            size *= 2
        elif op == "reduce-scatter":
            size *= group
        out[op] += size
        out["count"] += 1
    return out


def cell_optimizer_spec(cfg, opt_name: str, *, use_kernel: bool = False,
                        blocks: int | None = None, bucket: bool = True,
                        quant: str | None = None,
                        rules: list[str] | None = None) -> OptimizerSpec:
    """The dry-run cell's OptimizerSpec for one arch + ``--opt`` name
    (``smmf_local`` = smmf with blocks default 16 here), with any
    ``--optim-rule`` partitions appended. ``quant`` stores the default
    group's optimizer state through the qstate codec (int8/fp8)."""
    from repro.configs import recommended_decay_rate

    gamma = recommended_decay_rate(cfg.family)
    hp: dict = {"lr": 1e-3}
    name = opt_name
    if opt_name in ("smmf", "smmf_local"):
        hp.update(decay_rate=gamma,
                  blocks=blocks or (16 if opt_name == "smmf_local" else 1),
                  use_kernel=use_kernel, bucket=bucket, fuse_dense=bucket)
        name = "smmf"
    if quant:
        hp["quant"] = quant
    spec = OptimizerSpec(family=name, hyperparams=hp)
    for rule in rules or []:
        spec = spec.with_rule(rule)
    return spec


def run_cell(arch: str, shape_name: str, multi_pod: bool, opt_name: str = "smmf",
             variant: str = "", flags_spec: str = "", verbose: bool = True,
             use_kernel: bool = False, blocks: int | None = None,
             bucket: bool = True, quant: str | None = None,
             optim_rules: list[str] | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    status = cell_status(cfg, shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    opt_tag = opt_name + (f".{quant}" if quant else "")
    tag = f"{arch}.{shape_name}.{mesh_tag}.{opt_tag}" + (f".{variant}" if variant else "")
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag, "opt": opt_name,
           "quant": quant, "variant": variant, "status": status}
    if status != "run":
        return rec

    opt = None
    if shape.kind == "train":
        spec = cell_optimizer_spec(cfg, opt_name, use_kernel=use_kernel,
                                   blocks=blocks, bucket=bucket, quant=quant,
                                   rules=optim_rules)
        rec["spec_hash"] = spec.spec_hash()
        opt = build_optimizer(spec)

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models.perf import parse_flags, perf_flags

    t0 = time.time()
    with perf_flags(**parse_flags(flags_spec)):
        lowered = lower_cell(mesh, cfg, shape, opt=opt)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    if shape.kind == "train":
        from repro.launch.steps import donation_report

        rec["donation"] = donation_report(lowered)

    mem = compiled.memory_analysis()
    mem_rec = {}
    for k in ("temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            mem_rec[k] = int(v)
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # some jax versions: one dict per program
        cost = cost[0] if cost else {}
    cost_rec = {k: float(v) for k, v in cost.items() if isinstance(v, (int, float))}

    # loop-trip-aware per-device analysis (cost_analysis counts while bodies
    # once; see repro.launch.hloanalysis)
    from repro.launch.hloanalysis import analyze_compiled

    ana = analyze_compiled(compiled)

    # persist the post-SPMD HLO so analyzer improvements never require
    # recompiling the whole matrix
    import gzip

    RESULTS.mkdir(parents=True, exist_ok=True)
    with gzip.open(RESULTS / f"{tag}.hlo.gz", "wt") as f:
        f.write(compiled.as_text())

    rec.update({
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "devices": int(mesh.devices.size),
        "memory": mem_rec,
        "raw_cost_flops": cost_rec.get("flops", 0.0),
        "raw_cost_bytes": cost_rec.get("bytes accessed", 0.0),
        "flops": ana["flops"],
        "bytes_accessed": ana["bytes"],
        "coll_bytes": ana["coll_bytes"],
        "collectives": ana["coll_by_kind"],
        "coll_count": ana["coll_count"],
        "hlo_bytes": ana["hlo_chars"],
    })
    if verbose:
        print(f"[{tag}] lower {rec['lower_s']}s compile {rec['compile_s']}s "
              f"flops/dev {rec['flops']:.3e} bytes/dev {rec['bytes_accessed']:.3e} "
              f"coll/dev {rec['coll_bytes']:.3e}B ({int(rec['coll_count'])} ops)", flush=True)
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def build_parser() -> argparse.ArgumentParser:
    """CLI definition (separate from main so tests/docs can introspect it —
    every flag here must be documented in docs/cli.md; a parity test
    enforces that)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--opt", default="smmf")
    ap.add_argument("--optim-rule", action="append", default=[],
                    metavar="PATTERN=FAMILY[,K=V...]",
                    help="append an OptimizerSpec partition rule to the train "
                         "cell's optimizer (same syntax as the train launcher)")
    ap.add_argument("--variant", default="", help="tag suffix for perf experiments")
    ap.add_argument("--flags", default="", help="PerfFlags, e.g. bf16_accum_attention,ssd_chunk_override=128")
    ap.add_argument("--use-kernel", action="store_true", help="fused Pallas SMMF update")
    ap.add_argument("--quant", default=None, choices=["int8", "fp8"],
                    help="quantized optimizer-state storage for the train "
                         "cell (qstate codec; composes with --use-kernel "
                         "via the in-kernel dequant path)")
    ap.add_argument("--blocks", type=int, default=0, help="SMMF blockwise factorization (0 = opt default)")
    ap.add_argument("--no-bucket", action="store_true", help="per-leaf baseline (no geometry bucketing)")
    ap.add_argument("--no-scatter-constraints", action="store_true",
                    help="A/B hatch: drop ALL in-update optimizer sharding "
                         "constraints (smmf_*/dense_flat, the param-spec "
                         "scatter constraints and the opt_update_row "
                         "boundary — the smmf_no_constraint perf flag). The "
                         "transformer_base/train_4k SPMD CHECK crash these "
                         "constraints once triggered is fixed at the root; "
                         "this remains for propagation-only perf "
                         "experiments")
    ap.add_argument("--all", action="store_true")
    return ap


def main() -> None:
    """Lower + compile every requested (arch x shape x mesh) cell and record
    memory/FLOP/collective/donation analysis under results/dryrun/."""
    args = build_parser().parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    flags_spec = args.flags
    if args.no_scatter_constraints:
        flags_spec = f"{flags_spec},smmf_no_constraint" if flags_spec else "smmf_no_constraint"

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp, args.opt, args.variant, flags_spec,
                                   use_kernel=args.use_kernel, blocks=args.blocks or None,
                                   bucket=not args.no_bucket, quant=args.quant,
                                   optim_rules=args.optim_rule)
                    if rec["status"] != "run":
                        print(f"[{arch}.{shape}] {rec['status']}", flush=True)
                except Exception as e:  # noqa: BLE001 - report and continue
                    failures.append((arch, shape, mp, repr(e)[:500]))
                    print(f"[{arch}.{shape} mp={mp}] FAILED: {e!r}"[:600], flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
