"""Recursive post-SPMD HLO cost analyzer.

``compiled.cost_analysis()`` counts every while-loop body ONCE, which
undercounts scan-over-layers models by ~L x and flash-attention KV scans by
~nkv x. This module re-derives the three roofline quantities from
``compiled.as_text()`` with loop-trip multipliers (XLA annotates
``known_trip_count`` on while ops):

  flops       MXU work: 2*M*N*K for every dot, times enclosing trip counts
  bytes       fusion-boundary HBM traffic: operands+output of every
              top-level op (fusion interiors are free), times trip counts
  collectives per-chip wire bytes by kind (ring model: all-gather ~1x full,
              all-reduce ~2x, reduce-scatter ~1x full = out*group,
              all-to-all 1x, collective-permute 1x), times trip counts

All values are PER DEVICE (the SPMD module is the per-device program).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<type>\(?[^=]*?)\s*"
    r"(?P<op>[a-z][a-z0-9\-]*)\((?P<args>.*?)\)(?P<attrs>.*)$"
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TRIP = re.compile(r"known_trip_count.{0,6}?n.{0,4}?(\d+)")
_CALLS = re.compile(r"(?:calls|body|to_apply)=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0, "tuple": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {
    "tuple", "get-tuple-element", "bitcast", "parameter", "constant",
    "iota", "after-all", "partition-id", "replica-id", "reshape",
    "bitcast-convert", "opt-barrier",
}

# ops whose flops ~= numel(output) (elementwise arithmetic, comparisons,
# transcendentals). XLA:CPU frequently lowers einsum contractions to
# broadcast-multiply + reduce loop fusions; counting multiply by its
# (broadcasted) output numel and reduce by its input numel reproduces the
# exact 2*M*N*K of the equivalent dot.
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "rsqrt",
    "sqrt", "tanh", "logistic", "negate", "abs", "sign", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "compare", "select", "and",
    "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "atan2", "remainder", "clamp", "cbrt", "erf",
    "expm1", "log1p", "cosine", "sine", "tan", "is-finite",
}


def _type_bytes_numel(type_str: str) -> tuple[int, int]:
    """Total bytes and element count of a (possibly tuple) type string."""
    total_b = 0
    total_n = 0
    for dt, dims in _SHAPE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * _DTYPE_BYTES.get(dt, 4)
        total_n += n
    return total_b, total_n


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_count: float = 0.0

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
        self.coll_count += other.coll_count * mult


@dataclass
class _Instr:
    name: str
    op: str
    type_str: str
    args: str
    attrs: str


_COMMENT = re.compile(r"/\*.*?\*/")
_ARG_NAME = re.compile(r"%([\w\.\-]+)")


def _arg_names(args: str) -> list[str]:
    """Operand instruction names from an HLO arg list.

    Handles both typed operands ("f32[64,64]{1,0} %dot.0, ...") and bare
    names ("%dot.0, ..." or "dot.0, ...")."""
    names = _ARG_NAME.findall(args)
    if names:
        return names
    return [a.strip() for a in args.split(",") if a.strip()]


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: list[_Instr] | None = None
    entry = None
    for line in text.splitlines():
        if "/*" in line:  # XLA tuple-index comments contain '=' — strip them
            line = _COMMENT.sub("", line)
        if cur is None:
            m = _COMP_HDR.match(line.strip()) if "{" in line else None
            if line.strip().startswith(("HloModule", "//")):
                continue
            m = _COMP_HDR.match(line.rstrip())
            if m:
                cur_name = m.group(2)
                comps[cur_name] = []
                cur = comps[cur_name]
                if m.group(1):
                    entry = cur_name
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            cur.append(_Instr(m.group("name"), m.group("op"), m.group("type"),
                              m.group("args"), m.group("attrs")))
    comps["__entry__"] = comps.get(entry, [])
    comps["__entry_name__"] = entry  # type: ignore[assignment]
    return comps


def _dot_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    out_b, out_n = _type_bytes_numel(instr.type_str)
    # contracted dims: lhs shape at lhs_contracting_dims
    mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    argn = _arg_names(instr.args)
    lhs_name = argn[0] if argn else ""
    lhs_type = shapes.get(lhs_name, "")
    sm = _SHAPE.search(lhs_type)
    k = 1
    if mm and sm and sm.group(2):
        dims = [int(d) for d in sm.group(2).split(",")]
        for idx in (mm.group(1).split(",") if mm.group(1) else []):
            i = int(idx)
            if i < len(dims):
                k *= dims[i]
    return 2.0 * out_n * k


def _conv_flops(instr: _Instr, shapes: dict[str, str]) -> float:
    out_b, out_n = _type_bytes_numel(instr.type_str)
    argn = _arg_names(instr.args)
    rhs_name = argn[1] if len(argn) > 1 else ""
    sm = _SHAPE.search(shapes.get(rhs_name, ""))
    k = 1
    if sm and sm.group(2):
        dims = [int(d) for d in sm.group(2).split(",")]
        # kernel flops per output element ~ prod(kernel dims) / out_features;
        # approximate with prod of all-but-largest dim
        if dims:
            dims_sorted = sorted(dims)
            k = 1
            for d in dims_sorted[:-1]:
                k *= d
    return 2.0 * out_n * k


def _collective_bytes(instr: _Instr) -> tuple[str, float]:
    op = instr.op.replace("-start", "").replace("-done", "")
    base = op
    for c in _COLLECTIVES:
        if op == c:
            base = c
            break
    out_b, _ = _type_bytes_numel(instr.type_str)
    group = 1
    gm = _GROUPS_LIST.search(instr.attrs)
    if gm:
        group = gm.group(1).count(",") + 1
    else:
        gm = _GROUPS_IOTA.search(instr.attrs)
        if gm:
            group = int(gm.group(2))
    if base == "all-reduce":
        return base, 2.0 * out_b
    if base == "reduce-scatter":
        return base, float(out_b) * group
    return base, float(out_b)


def analyze_text(text: str) -> Costs:
    comps = _parse_computations(text)
    entry_name = comps.pop("__entry_name__")
    comps.pop("__entry__")
    memo: dict[str, Costs] = {}

    def comp_cost(name: str) -> Costs:
        if name in memo:
            return memo[name]
        memo[name] = Costs()  # break cycles defensively
        instrs = comps.get(name, [])
        shapes = {i.name: i.type_str for i in instrs}
        total = Costs()
        for ins in instrs:
            op = ins.op
            opn = op.replace("-start", "").replace("-done", "")
            if op in _FREE_OPS:
                continue
            if opn in _COLLECTIVES:
                if op.endswith("-done"):
                    continue  # counted at -start
                kind, b = _collective_bytes(ins)
                total.coll[kind] += b
                total.coll_count += 1
                ob, _ = _type_bytes_numel(ins.type_str)
                total.bytes += ob
                continue
            if op == "while":
                trips = 1.0
                tm = _TRIP.search(ins.attrs)
                if tm:
                    trips = float(tm.group(1))
                bm = _CALLS.search(ins.attrs)
                if bm:
                    total.add(comp_cost(bm.group(1)), trips)
                cm = _COND.search(ins.attrs)
                if cm:
                    total.add(comp_cost(cm.group(1)), trips)
                continue
            if op == "scatter":
                # in-place: traffic ~= 2x the updates operand (+ indices)
                parts = _arg_names(ins.args)
                ub = 0
                for a in parts[1:]:
                    if a in shapes:
                        b_, _ = _type_bytes_numel(shapes[a])
                        ub += b_
                total.bytes += 2.0 * ub
                continue
            if op in ("fusion", "call", "map", "reduce", "reduce-window",
                      "sort", "custom-call", "conditional",
                      "async-start"):
                cm = _CALLS.search(ins.attrs)
                sub_name = cm.group(1) if cm and cm.group(1) in comps else None
                # in-place dynamic-update-slice fusions (scan carries, cache
                # writes): traffic is the updated slice, not the full buffer
                dus_bytes = None
                if op == "fusion" and sub_name:
                    sub_instrs = comps[sub_name]
                    sub_shapes = {i.name: i.type_str for i in sub_instrs}
                    # walk through convert/bitcast/copy wrappers to the root
                    root = sub_instrs[-1] if sub_instrs else None
                    seen = 0
                    while root is not None and root.op in ("convert", "bitcast", "copy") and seen < 8:
                        rn = _arg_names(root.args)
                        nxt = rn[0] if rn else ""
                        root = next((i for i in sub_instrs if i.name == nxt), None)
                        seen += 1
                    if root is not None and root.op == "dynamic-update-slice":
                        rn = _arg_names(root.args)
                        upd = rn[1] if len(rn) > 1 else ""
                        if upd in sub_shapes:
                            ub, _ = _type_bytes_numel(sub_shapes[upd])
                            dus_bytes = 2.0 * ub
                if dus_bytes is not None:
                    total.bytes += dus_bytes
                else:
                    # boundary bytes: operands + output
                    ob, _ = _type_bytes_numel(ins.type_str)
                    ib = 0
                    for a in _arg_names(ins.args):
                        if a in shapes:
                            b, _ = _type_bytes_numel(shapes[a])
                            ib += b
                    total.bytes += ob + ib
                if sub_name:
                    sub = comp_cost(sub_name)
                    total.flops += sub.flops
                    for k in _COLLECTIVES:
                        total.coll[k] += sub.coll[k]
                    total.coll_count += sub.coll_count
                continue
            if op == "dot":
                total.flops += _dot_flops(ins, shapes)
                ob, _ = _type_bytes_numel(ins.type_str)
                ib = 0
                for a in _arg_names(ins.args):
                    if a in shapes:
                        b, _ = _type_bytes_numel(shapes[a])
                        ib += b
                total.bytes += ob + ib
                continue
            if op == "convolution":
                total.flops += _conv_flops(ins, shapes)
                ob, _ = _type_bytes_numel(ins.type_str)
                total.bytes += 2 * ob
                continue
            if op == "dynamic-update-slice":
                # in place: traffic = 2x the updated slice
                argn = _arg_names(ins.args)
                upd = argn[1] if len(argn) > 1 else ""
                if upd in shapes:
                    ub, _ = _type_bytes_numel(shapes[upd])
                    total.bytes += 2.0 * ub
                else:
                    ob, _ = _type_bytes_numel(ins.type_str)
                    total.bytes += 2 * ob
                continue
            if op in ("copy", "transpose", "copy-start", "dynamic-slice",
                      "slice", "concatenate", "pad",
                      "broadcast", "gather", "convert", "reverse"):
                ob, _ = _type_bytes_numel(ins.type_str)
                total.bytes += 2 * ob
                continue
            if op == "reduce" or op == "reduce-window":
                # flops ~= numel of the reduced input
                argn = _arg_names(ins.args)
                a0 = argn[0] if argn else ""
                if a0 in shapes:
                    _, n_in = _type_bytes_numel(shapes[a0])
                    total.flops += n_in
                    b_in, _ = _type_bytes_numel(shapes[a0])
                    total.bytes += b_in
                ob, _ = _type_bytes_numel(ins.type_str)
                total.bytes += ob
                continue
            ob, on = _type_bytes_numel(ins.type_str)
            if op in _EW_OPS:
                total.flops += on
            total.bytes += 2 * ob
        memo[name] = total
        return total

    return comp_cost(entry_name) if entry_name else Costs()


def analyze_compiled(compiled) -> dict:
    """Full per-device analysis dict for a compiled executable."""
    text = compiled.as_text()
    c = analyze_text(text)
    coll_total = sum(c.coll.values())
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "coll_bytes": coll_total,
        "coll_by_kind": dict(c.coll),
        "coll_count": c.coll_count,
        "hlo_chars": len(text),
    }
