"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Dry-run processes set
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* importing jax
(see dryrun.py); everything else sees the real device count.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips single pod, or 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
