import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=1")

"""Re-run hloanalysis over saved .hlo.gz artifacts and refresh the JSONs
(no recompilation). Usage:

    PYTHONPATH=src python -m repro.launch.reanalyze
"""

import gzip
import json
from pathlib import Path

from repro.launch.hloanalysis import analyze_text

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def main() -> None:
    n = 0
    for hf in sorted(RESULTS.glob("*.hlo.gz")):
        jf = RESULTS / (hf.name[: -len(".hlo.gz")] + ".json")
        if not jf.exists():
            continue
        rec = json.loads(jf.read_text())
        with gzip.open(hf, "rt") as f:
            text = f.read()
        c = analyze_text(text)
        rec.update({
            "flops": c.flops,
            "bytes_accessed": c.bytes,
            "coll_bytes": sum(c.coll.values()),
            "collectives": dict(c.coll),
            "coll_count": c.coll_count,
        })
        jf.write_text(json.dumps(rec, indent=1))
        n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
