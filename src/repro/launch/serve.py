"""Serving launcher: continuous batching on the paged, quantized KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch transformer_base \
        --smoke --requests 8 --slots 4 --max-new 16 --kv-quant int8 \
        --use-kernel

Drives the paged :class:`~repro.serving.engine.GenerationEngine` (or the
seed slot-batcher via ``--engine legacy``, the bench baseline) over a
deterministic synthetic request set. Enc-dec archs (transformer_base) are
served natively: each request carries synthetic encoder frames, run
through the encoder once at admission. Sampling flags apply to every
request; the default (temperature 0) is exact greedy, which the smoke
check relies on: with ``--check`` (implied by ``--smoke``) the launcher
re-runs each request solo on the dense f32 reference decode step and
asserts the paged engine's greedy stream matches token for token —
that is the serving acceptance gate CI runs on transformer_base with
``--kv-quant int8 --use-kernel``.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import init_encdec, init_lm
from repro.serving import GenerationEngine, LegacyRequest, LegacySlotEngine
from repro.serving.engine import Request


def build_parser() -> argparse.ArgumentParser:
    """CLI definition (separate from main so tests/docs can introspect it —
    every flag here must be documented in docs/cli.md; a parity test
    enforces that)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-sized config + reference parity check")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="paged", choices=["paged", "legacy"],
                    help="legacy = the seed slot-batcher (bench baseline; "
                         "dense/moe only, greedy only)")
    ap.add_argument("--page", type=int, default=16,
                    help="KV page size in tokens")
    ap.add_argument("--kv-quant", default=None, choices=["int8", "fp8"],
                    help="quantized KV-page storage (per-token/head scales)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="flash_decode_paged Pallas kernel on the decode "
                         "hot path")
    ap.add_argument("--prefill-budget", type=int, default=4096,
                    help="max prompt tokens admitted per prefill batch")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (exact argmax)")
    ap.add_argument("--top-k", type=int, default=0, help="0 = off")
    ap.add_argument("--top-p", type=float, default=1.0, help="1 = off")
    ap.add_argument("--check", action="store_true",
                    help="assert greedy parity vs the solo dense f32 "
                         "reference for every request (implied by --smoke)")
    ap.add_argument("--metrics-dir", default=None,
                    help="write serving telemetry here: events.jsonl "
                         "(admission/prefill/decode/retire spans+events), "
                         "trace.json (Perfetto/Chrome trace_event) and "
                         "metrics.json (engine.metrics() snapshot: queue "
                         "depth, page-pool utilization, TTFT/TPOT "
                         "histograms, tokens/s); summarize with "
                         "tools/metrics_report.py")
    return ap


def _requests(cfg, args):
    rng = np.random.default_rng(args.seed)
    out = []
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, size=4 + i % 8).astype(np.int32)
        frames = None
        if cfg.family == "encdec":
            frames = rng.standard_normal(
                (cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        out.append(Request(rid=i, prompt=prompt, max_new=args.max_new,
                           temperature=args.temperature, top_k=args.top_k,
                           top_p=args.top_p, seed=args.seed + i,
                           frames=frames))
    return out


def _solo_reference(params, cfg, req):
    """Dense f32 unpaged greedy decode of one request (the parity oracle)."""
    import jax.numpy as jnp

    if cfg.family == "encdec":
        from repro.models import encdec_decode_step, encode, init_encdec_cache

        enc = encode(params, cfg, jnp.asarray(req.frames)[None])
        cache = init_encdec_cache(cfg, 1, len(req.prompt) + req.max_new)
        step = lambda t, c: encdec_decode_step(
            params, cfg, jnp.asarray([[int(t)]]), c, enc)
    else:
        from repro.models import init_cache, lm_decode_step

        cache = init_cache(cfg, 1, len(req.prompt) + req.max_new)
        step = lambda t, c: lm_decode_step(
            params, cfg, jnp.asarray([[int(t)]]), c)
    for t in req.prompt:
        logits, cache = step(t, cache)
    out = [int(jnp.argmax(logits[0, 0, : cfg.vocab]))]
    while len(out) < req.max_new:
        logits, cache = step(out[-1], cache)
        out.append(int(jnp.argmax(logits[0, 0, : cfg.vocab])))
    return out


def main() -> None:
    args = build_parser().parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    init = init_encdec if cfg.family == "encdec" else init_lm
    params = init(jax.random.PRNGKey(args.seed), cfg)

    # structured events (repro.obs): launcher lines echo to stdout exactly
    # as before; with --metrics-dir the engine's admission/prefill/decode/
    # retire spans and the launcher events land in one events.jsonl
    from pathlib import Path

    from repro.obs import (
        EventLog,
        MetricsRegistry,
        write_chrome_trace,
        write_metrics,
    )

    registry = MetricsRegistry()
    events_path = None
    if args.metrics_dir:
        events_path = Path(args.metrics_dir) / "events.jsonl"
    ev = EventLog(tag=f"serve:{cfg.name}", path=events_path, registry=registry)
    # the engine's own span/event stream: silent on stdout (per-request
    # retire events would be noise), same registry + JSONL file
    eng_events = EventLog(tag="serve", path=events_path, echo=False,
                          registry=registry)

    if args.engine == "legacy":
        if cfg.family not in ("dense", "moe"):
            raise SystemExit(
                f"--engine legacy is the seed decoder-only slot-batcher and "
                f"cannot serve family={cfg.family!r} ({cfg.name}); use the "
                f"default paged engine")
        eng = LegacySlotEngine(params, cfg, slots=args.slots,
                               max_len=args.max_len)
        reqs = [LegacyRequest(rid=r.rid, prompt=r.prompt, max_new=r.max_new)
                for r in _requests(cfg, args)]
    else:
        eng = GenerationEngine(params, cfg, slots=args.slots,
                               max_len=args.max_len, page=args.page,
                               kv_quant=args.kv_quant,
                               use_kernel=args.use_kernel,
                               prefill_budget=args.prefill_budget,
                               registry=registry, events=eng_events)
        reqs = _requests(cfg, args)
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    steps = 0
    while eng.step():
        steps += 1
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in reqs)
    ev.event("run",
             f"{len(reqs)} requests, {tokens} tokens, "
             f"{steps} decode steps, {dt:.2f}s ({tokens/max(dt,1e-9):.1f} tok/s)",
             requests=len(reqs), tokens=tokens, steps=steps, sec=dt)
    assert all(r.done for r in reqs)

    if (args.check or args.smoke) and args.engine == "paged" \
            and args.temperature == 0.0:
        for r in reqs:
            ref = _solo_reference(params, cfg, r)
            assert r.out == ref, (
                f"request {r.rid}: paged stream {r.out} != dense f32 "
                f"reference {ref}")
        ev.event("parity",
                 f"parity OK: paged"
                 f"{'+' + args.kv_quant if args.kv_quant else ''}"
                 f"{'+kernel' if args.use_kernel else ''} greedy matches the "
                 f"dense f32 reference on all {len(reqs)} requests",
                 requests=len(reqs))

    if args.metrics_dir:
        records = sorted(ev.records() + eng_events.records(),
                         key=lambda r: r["t"])
        trace = write_chrome_trace(records, Path(args.metrics_dir) / "trace.json")
        snapshot = eng.metrics() if isinstance(eng, GenerationEngine) \
            else registry.snapshot()
        metrics = write_metrics(snapshot, Path(args.metrics_dir) / "metrics.json")
        ev.event("metrics_dump",
                 f"metrics written: {metrics}, trace: {trace}, "
                 f"events: {events_path}")
        ev.close()
        eng_events.close()


if __name__ == "__main__":
    main()
