"""Serving launcher: batched generation with the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi_6b --smoke \
        --requests 8 --slots 4 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import init_lm
from repro.serving import GenerationEngine
from repro.serving.engine import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        raise SystemExit("enc-dec serving: use the decode step factory directly")
    params = init_lm(jax.random.PRNGKey(args.seed), cfg)
    eng = GenerationEngine(params, cfg, slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8 + i % 8).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        eng.submit(r)

    t0 = time.time()
    steps = 0
    while eng.step():
        steps += 1
    dt = time.time() - t0
    tokens = sum(len(r.out) for r in reqs)
    print(f"[serve:{cfg.name}] {len(reqs)} requests, {tokens} tokens, "
          f"{steps} decode steps, {dt:.2f}s ({tokens/max(dt,1e-9):.1f} tok/s)")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
