"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) cell.

No device allocation ever happens here — these stand-ins feed
``jax.jit(...).lower()`` for the multi-pod dry-run, and double as the shape
contract for the data pipeline and serving driver.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import SHAPES, ModelConfig, ShapeConfig

PyTree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((b, s), jnp.int32),
        "labels": _sds((b, s), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((b, s), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = _sds((b, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    b = shape.global_batch
    batch = {"token": _sds((b, 1), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc"] = _sds((b, cfg.encoder_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def params_specs(cfg: ModelConfig) -> PyTree:
    from repro.models import init_encdec, init_lm

    init = init_encdec if cfg.family == "encdec" else init_lm
    return jax.eval_shape(lambda k: init(k, cfg), jax.ShapeDtypeStruct((2,), jnp.uint32))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    from repro.models import init_cache, init_encdec_cache

    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        return jax.eval_shape(lambda: init_encdec_cache(cfg, b, s))
    return jax.eval_shape(lambda: init_cache(cfg, b, s))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> PyTree:
    """The step-function operand specs for one cell (excluding params/state)."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape)}
    return {"batch": decode_batch_specs(cfg, shape), "cache": cache_specs(cfg, shape)}
