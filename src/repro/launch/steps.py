"""Step factories: train_step / prefill_step / decode_step, mesh-aware.

``make_*`` returns (jitted_fn, in_shardings, out_shardings-compatible
abstract signature). The model's activation constraints are installed while
*tracing* via the sharding context, so the same model code serves 1-device
tests and 512-chip dry-runs.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import rules
from repro.distributed.ctx import sharding_ctx, update_specs_ctx
from repro.models import (
    encdec_decode_step,
    encdec_loss,
    init_cache,
    init_encdec_cache,
    lm_decode_step,
    lm_loss,
    lm_prefill,
)
from repro.models.config import ModelConfig
from repro.optim.base import GradientTransformation, apply_updates

PyTree = Any


def loss_fn_for(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_loss
    return lm_loss


def optimizer_launch_stats(opt: GradientTransformation, params: PyTree) -> dict | None:
    """Static per-step update-launch accounting for engine-based optimizers.

    Returns the leaf-plan engine's stats dict (leaves, buckets,
    update_launches, kernel_buckets, ...) or None for plain transforms.
    ``params`` may be concrete arrays or ShapeDtypeStructs — only shapes are
    read. Used by the train launcher's kernel-path assertion and by
    benchmarks/step_time.py's launch column.
    """
    from repro.optim.engine import engine_stats

    return engine_stats(opt, params)


def make_train_step(cfg: ModelConfig, opt: GradientTransformation, grad_accum: int = 1,
                    overlap: bool = False, offload: str | None = None,
                    telemetry: bool = False):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    The returned step is **donation-safe**: the non-finite-loss guard runs
    *inside* the jitted function (a per-leaf select between the new and old
    state), so callers may jit it with ``donate_argnums=(0, 1)`` — the
    caller never needs the pre-call params/opt_state buffers again, even on
    a skipped (NaN/inf) step. With ``grad_accum > 1`` the batch's leading
    dim is split into that many sequential microbatches (gradients averaged
    in f32); the accumulation buffer lives inside the jit so gradient
    donation composes with accumulation.

    ``overlap=True`` threads the engine's ``schedule="grad"`` through the
    optimizer update: per-bucket launches are emitted in reverse-mode
    gradient-availability order and chained with optimization-barrier
    links, so XLA's latency-hiding scheduler interleaves bucket
    gather→update→scatter (and its boundary transport —
    ``rules.boundary_transport_bytes``) with the remaining backward
    compute. Bitwise-identical to the barrier step and donation-safe
    (``docs/architecture.md``). ``offload="cold"`` adds the host tier for
    quantized buckets (``repro.optim.offload``): double-buffered prefetch
    one bucket ahead, park after re-encode. Both are execution-only knobs —
    spec-built (engine) optimizers honor them, plain transforms ignore the
    extras per the widened update protocol.

    ``telemetry=True`` (execution-only, ``docs/observability.md``) builds a
    fresh :class:`repro.obs.jit.TelemetryCollector` per trace, threads it
    through ``opt.update``, and returns the collected in-jit numerics
    scalars (per-bucket update-RMS, quant clip-saturation / requant error,
    transport round-trip error / rank-1 flushes, plus the NaN-guard trip
    indicator) as ``metrics["telemetry"]`` — riding the existing
    device->host metrics transfer, no callbacks, and bitwise-identical
    params/opt-state outputs when off (asserted in
    ``tests/test_telemetry_step.py``).
    """
    loss_fn = loss_fn_for(cfg)
    from repro.optim.offload import check_mode

    upd_extras: dict = {}
    if overlap:
        upd_extras["schedule"] = "grad"
    if check_mode(offload) is not None:
        upd_extras["offload"] = offload

    def train_step(params, opt_state, batch):
        def compute(p, b):
            loss, metrics = loss_fn(p, cfg, b)
            return loss, metrics

        if grad_accum > 1:
            def micro(carry, mb):
                gsum, msum = carry
                (_, metrics), grads = jax.value_and_grad(compute, has_aux=True)(params, mb)
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                msum = jax.tree.map(lambda a, x: a + x, msum, metrics)
                return (gsum, msum), None

            mbs = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum, *x.shape[1:]), batch
            )
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            # metrics structure differs per family (e.g. "aux" only for MoE):
            # derive the accumulator from the loss fn's abstract output
            m_sds = jax.eval_shape(lambda p, b: compute(p, b)[1], params,
                                   jax.tree.map(lambda x: x[0], mbs))
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m_sds)
            (grads, metrics), _ = jax.lax.scan(micro, (g0, m0), mbs)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = jax.tree.map(lambda x: x / grad_accum, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(compute, has_aux=True)(params, batch)

        extras = dict(upd_extras)
        col = None
        if telemetry:
            from repro.obs.jit import TelemetryCollector

            # fresh collector per trace: the dict holds tracers of THIS
            # trace, so it must be born inside the traced body
            col = TelemetryCollector()
            extras["telemetry"] = col
        updates, new_opt_state = opt.update(grads, opt_state, params,
                                            **extras)
        new_params = apply_updates(params, updates)
        # in-jit divergence guard (paper Sec. 6 loss spikes): on a
        # non-finite loss keep the previous params/optimizer state. Done
        # here (not in the host loop) so the old buffers can be donated.
        ok = jnp.isfinite(metrics["loss"])
        new_params = jax.tree.map(lambda n, o: jnp.where(ok, n, o), new_params, params)
        new_opt_state = jax.tree.map(lambda n, o: jnp.where(ok, n, o),
                                     new_opt_state, opt_state)
        if col is not None:
            col.record("train/nan_guard_trip",
                       1.0 - ok.astype(jnp.float32))
            metrics = dict(metrics)
            metrics["telemetry"] = col.asdict()
        return new_params, new_opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    """(params, batch) -> (next_token, cache). Greedy sampling."""

    def prefill_step(params, batch):
        if cfg.family == "encdec":
            from repro.models import encode, encdec_logits

            enc = encode(params, cfg, batch["frames"])
            # teacher prefix not modeled for enc-dec serving: start decode
            b = batch["frames"].shape[0]
            cache = init_encdec_cache(cfg, b, batch["tokens"].shape[1])
            logits, cache = encdec_decode_step(params, cfg, batch["tokens"][:, :1], cache, enc)
            return jnp.argmax(logits[:, -1], axis=-1), cache
        logits, cache = lm_prefill(params, cfg, batch["tokens"], batch.get("prefix_embeds"))
        return jnp.argmax(logits[:, -1], axis=-1), cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, batch{token[,enc]}, cache) -> (next_token, cache)."""

    def decode_step(params, batch, cache):
        if cfg.family == "encdec":
            logits, cache = encdec_decode_step(params, cfg, batch["token"], cache, batch["enc"])
        else:
            logits, cache = lm_decode_step(params, cfg, batch["token"], cache)
        return jnp.argmax(logits[:, -1], axis=-1), cache

    return decode_step


# ---------------------------------------------------------------------------
# buffer-donation introspection (jax.stages)
# ---------------------------------------------------------------------------

def donation_report(lowered) -> dict:
    """Summarize buffer donation for a ``jax.stages.Lowered`` step.

    Reads the lowering's ``args_info`` (the jax.stages record of which
    argument buffers were marked donatable via ``donate_argnums``) and
    returns::

        {"donated_args": int, "total_args": int,
         "donated_bytes": int, "undonated_bytes": int}

    Used by the train launcher and tests to assert the optimizer-state and
    parameter buffers actually flow through the jitted update in place.
    """
    import numpy as _np

    donated_args = total_args = donated_bytes = undonated_bytes = 0
    for info in jax.tree.leaves(lowered.args_info):
        aval = getattr(info, "aval", None) or info._aval  # ArgInfo aval
        size = int(_np.prod(aval.shape)) * _np.dtype(aval.dtype).itemsize
        total_args += 1
        if info.donated:
            donated_args += 1
            donated_bytes += size
        else:
            undonated_bytes += size
    return {"donated_args": donated_args, "total_args": total_args,
            "donated_bytes": donated_bytes, "undonated_bytes": undonated_bytes}


def assert_donation(lowered, compiled, min_alias_fraction: float = 0.5) -> dict:
    """Assert a compiled train step donates and aliases its big buffers.

    Two layers (both required):

    * **static** — ``lowered.args_info`` must mark at least one argument
      donated (the params/opt-state donate_argnums actually applied);
    * **executable** — the compiled module's ``alias_size_in_bytes`` (XLA's
      input-output alias table, i.e. buffers updated in place with no copy)
      must cover at least ``min_alias_fraction`` of the donated bytes.
      Donated-but-unaliased buffers mean XLA inserted unexpected copies —
      exactly the allocation regression this guard exists to catch.

    Returns the merged report dict (donation_report + ``alias_bytes``).
    Raises RuntimeError on violation.
    """
    rep = donation_report(lowered)
    if rep["donated_args"] == 0:
        raise RuntimeError("no argument is marked donated — jit the step with "
                           "donate_argnums=(0, 1) (params, opt_state)")
    mem = compiled.memory_analysis()
    alias = int(getattr(mem, "alias_size_in_bytes", 0) or 0)
    rep["alias_bytes"] = alias
    if alias < min_alias_fraction * rep["donated_bytes"]:
        raise RuntimeError(
            f"buffer donation degraded: {alias} aliased bytes vs "
            f"{rep['donated_bytes']} donated "
            f"(min fraction {min_alias_fraction}) — the update step is "
            f"re-allocating state buffers instead of updating in place")
    return rep


# ---------------------------------------------------------------------------
# mesh-aware lowering helpers (used by dryrun + real launchers)
# ---------------------------------------------------------------------------

def shardings_for_cell(mesh, cfg: ModelConfig, kind: str, opt=None, shape=None,
                       offload: str | None = None):
    """(in_shardings pytree factory) for each step kind. ``offload`` re-kinds
    the train cell's cold optimizer-state shardings onto the host memory
    tier (``rules.opt_state_shardings(offload=...)``)."""
    from repro.launch import specs as S

    p_sds = S.params_specs(cfg)
    p_sh = rules.param_shardings(mesh, cfg, p_sds)
    if kind == "train":
        o_sh = rules.opt_state_shardings(mesh, cfg, p_sds, opt, offload=offload)
        b_sh = rules.batch_shardings(mesh, S.train_batch_specs(cfg, shape))
        return (p_sh, o_sh, b_sh)
    if kind == "prefill":
        b_sh = rules.batch_shardings(mesh, S.prefill_batch_specs(cfg, shape))
        return (p_sh, b_sh)
    c_sds = S.cache_specs(cfg, shape)
    c_sh = rules.cache_shardings(mesh, cfg, c_sds)
    b_sh = rules.batch_shardings(mesh, S.decode_batch_specs(cfg, shape))
    return (p_sh, b_sh, c_sh)


def lower_cell(mesh, cfg: ModelConfig, shape, opt=None, donate: bool = True,
               overlap: bool = False, offload: str | None = None):
    """Lower (not compile) one (arch x shape) cell's step on `mesh`.

    Returns the jax.stages.Lowered object. Tracing runs inside the
    activation-rule context so with_sharding_constraint ops are baked in.
    ``overlap``/``offload`` thread the scheduled/host-tier execution knobs
    into the train cell (see :func:`make_train_step`).
    """
    from repro.launch import specs as S

    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]
    rule = rules.activation_rules(mesh, cfg, mode)

    p_sds = S.params_specs(cfg)
    # all shardings below are explicit NamedShardings (mesh embedded), so no
    # ambient-mesh context is required
    with sharding_ctx(rule):
        if shape.kind == "train":
            step = make_train_step(cfg, opt, overlap=overlap, offload=offload)
            in_sh = shardings_for_cell(mesh, cfg, "train", opt=opt, shape=shape,
                                       offload=offload)
            o_sds = jax.eval_shape(opt.init, p_sds)
            b_sds = S.train_batch_specs(cfg, shape)
            fn = jax.jit(
                step,
                in_shardings=in_sh,
                out_shardings=(in_sh[0], in_sh[1], None),
                donate_argnums=(0, 1) if donate else (),
            )
            # per-leaf param shardings for the engine's scatter constraints
            # (ctx.constrain_update): pins every reshaped update tensor to
            # its parameter's sharding, which is what keeps the SPMD
            # partitioner from rematerializing the bucket-stack -> param
            # reshapes (the transformer_base/train_4k CHECK crash)
            with update_specs_ctx(jax.tree.leaves(in_sh[0])):
                return fn.lower(p_sds, o_sds, b_sds)
        if shape.kind == "prefill":
            step = make_prefill_step(cfg)
            in_sh = shardings_for_cell(mesh, cfg, "prefill", shape=shape)
            b_sds = S.prefill_batch_specs(cfg, shape)
            fn = jax.jit(step, in_shardings=in_sh)
            return fn.lower(p_sds, b_sds)
        step = make_decode_step(cfg)
        in_sh = shardings_for_cell(mesh, cfg, "decode", shape=shape)
        b_sds = S.decode_batch_specs(cfg, shape)
        c_sds = S.cache_specs(cfg, shape)
        fn = jax.jit(
            step,
            in_shardings=in_sh,
            out_shardings=(None, in_sh[2]),
            donate_argnums=(2,) if donate else (),
        )
        return fn.lower(p_sds, b_sds, c_sds)
