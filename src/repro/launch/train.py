"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2_370m --smoke \
        --steps 50 --opt smmf

Optimizer construction is spec-driven (``repro.optim.spec``): ``--opt``
names the default family, ``--optim spec.json`` loads a full declarative
``OptimizerSpec``, and ``--optim-rule 'PATTERN=FAMILY[,K=V...]'`` appends
partition rules for mixed-family trees (e.g. ``'norm|bias=adam'`` runs
plain Adam on norms/biases while SMMF handles the matrices; ``=freeze``
gives a group zero state and zero updates; ``state_sharding=("model",)``
rides that group's moment stacks on an override mesh axis — see
``docs/sharding.md``). The spec's hash is stored in every checkpoint and
verified on resume.

On the CPU container this runs reduced (smoke) configs end-to-end; on a real
pod the same entry point takes --mesh production and the full config. The
XLA latency-hiding-scheduler flags used on TPU pods are set here (no-ops on
CPU).
"""

from __future__ import annotations

import argparse
import os
from pathlib import Path

# TPU pods: overlap collectives with compute (no-op on CPU)
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true",
)

import jax

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLMStream
from repro.launch.steps import (
    assert_donation,
    make_train_step,
    optimizer_launch_stats,
)
from repro.models import init_encdec, init_lm
from repro.optim.spec import OptimizerSpec, build_optimizer, state_bytes_by_group
from repro.train import TrainLoop, TrainLoopConfig

FAMILY_CHOICES = ("smmf", "smmf_local", "adam", "adafactor", "came",
                  "came_conf", "sm3", "sgd", "adapprox", "hfac")


def spec_from_args(args, family: str) -> OptimizerSpec:
    """Compose the run's OptimizerSpec from the CLI surface.

    ``--optim FILE`` loads a full JSON spec (the engine knob flags then only
    apply to specs they are compatible with — mixing them with a file is an
    error to avoid silently overriding the file). Otherwise the spec is
    built from ``--opt``/``--lr``/knob flags exactly like the legacy
    constructors did (``smmf_local`` = smmf with blocks default 4).
    ``--optim-rule`` partitions append to either base spec in order.
    """
    if args.optim:
        if args.blocks or args.use_kernel or args.no_bucket or args.quant \
                or args.transport:
            raise SystemExit("--optim FILE cannot be combined with "
                             "--blocks/--use-kernel/--no-bucket/--quant/"
                             "--transport; put the knobs in the spec's "
                             "hyperparams")
        spec = OptimizerSpec.from_json(Path(args.optim).read_text())
    else:
        from repro.configs import recommended_decay_rate

        gamma = recommended_decay_rate(family)
        name = args.opt
        hp: dict = {"lr": args.lr}
        if name in ("smmf", "smmf_local"):
            hp.update(decay_rate=gamma,
                      blocks=args.blocks or (4 if name == "smmf_local" else 1),
                      use_kernel=args.use_kernel, bucket=not args.no_bucket,
                      fuse_dense=not args.no_bucket)
            name = "smmf"
        elif name == "adapprox":
            hp.update(decay_rate=gamma, bucket=not args.no_bucket,
                      fuse_dense=not args.no_bucket)
        elif name in ("adafactor", "came", "came_conf", "sm3", "hfac"):
            hp.update(bucket=not args.no_bucket)
        if args.quant:
            hp["quant"] = args.quant  # sm3 rejects it at spec validation
        if args.transport:
            hp["transport"] = args.transport
            hp["transport_flush_every"] = args.transport_flush_every
        spec = OptimizerSpec(family=name, hyperparams=hp)
    for rule in args.optim_rule:
        spec = spec.with_rule(rule)
    return spec


def build_parser() -> argparse.ArgumentParser:
    """CLI definition (separate from main so tests/docs can introspect it —
    every flag here must be documented in docs/cli.md; a parity test
    enforces that)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--opt", default="smmf", choices=FAMILY_CHOICES,
                    help="default optimizer family")
    ap.add_argument("--optim", default=None, metavar="SPEC.json",
                    help="load a full OptimizerSpec from a JSON file "
                         "(overrides --opt and the engine knob flags)")
    ap.add_argument("--optim-rule", action="append", default=[],
                    metavar="PATTERN=FAMILY[,K=V...]",
                    help="append a partition rule: leaves whose path matches "
                         "PATTERN use FAMILY (or 'freeze') with optional "
                         "hyperparam overrides; repeatable, first match wins")
    ap.add_argument("--blocks", type=int, default=0,
                    help="SMMF blockwise factorization (0 = optimizer default)")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route factored buckets through the fused Pallas kernel")
    ap.add_argument("--quant", default=None, choices=("int8", "fp8"),
                    help="store the default group's optimizer state "
                         "quantized (qstate codec: 1-byte payloads + "
                         "per-row scales, stochastic-rounding requant)")
    ap.add_argument("--transport", default=None, choices=("int8", "rank1"),
                    help="gradient-transport compression for the default "
                         "group (repro.distributed.transport): int8 = "
                         "per-bucket-row absmax + stochastic rounding "
                         "(EF-free); rank1 = square-matricized row/col "
                         "sketches + packed sign plane with a dense "
                         "residual flush. Per-group form: --optim-rule "
                         "'ffn/=smmf,transport=rank1'")
    ap.add_argument("--transport-flush-every", type=int, default=8,
                    help="rank1 transport: ship the exact dense gradient "
                         "every K-th step so approximation error cannot "
                         "accumulate (priced into the boundary bytes)")
    ap.add_argument("--no-bucket", action="store_true",
                    help="per-leaf baseline (disable geometry bucketing)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="split the batch into N sequential microbatches "
                         "(gradient accumulation inside the jitted step)")
    ap.add_argument("--overlap", action="store_true",
                    help="overlapped optimizer step: emit per-bucket updates "
                         "in reverse-mode gradient-availability order, "
                         "chained with optimization-barrier links so XLA "
                         "interleaves them with the remaining backward "
                         "(bitwise-identical to the barrier order)")
    ap.add_argument("--offload", default="none", choices=("cold", "none"),
                    help="host-offload tier for optimizer state: 'cold' "
                         "parks quantized buckets' payloads on pinned-host "
                         "memory with double-buffered device prefetch one "
                         "bucket ahead (structural no-op on backends "
                         "without a host memory kind, e.g. CPU)")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable params/opt-state buffer donation (debug)")
    ap.add_argument("--telemetry", action="store_true",
                    help="collect in-jit numerics telemetry (per-bucket "
                         "update-RMS, quant clip-saturation/requant error, "
                         "transport round-trip error, NaN-guard trips) as "
                         "extra step metrics — execution-only, bitwise-"
                         "identical updates, <= 1.1x step time "
                         "(docs/observability.md)")
    ap.add_argument("--metrics-dir", default=None,
                    help="write structured telemetry here: events.jsonl "
                         "(event/span log), trace.json (Perfetto/Chrome "
                         "trace_event) and metrics.json (registry "
                         "snapshot); summarize with tools/metrics_report.py")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main() -> None:
    """Entry point: compose the OptimizerSpec, build model + optimizer,
    compile the (donating) train step, verify the kernel and donation
    paths, run the fault-tolerant loop."""
    ap = build_parser()
    args = ap.parse_args()
    if args.use_kernel and args.opt not in ("smmf", "smmf_local"):
        ap.error(f"--use-kernel is only supported with --opt smmf|smmf_local "
                 f"(got --opt {args.opt})")
    if args.grad_accum < 1 or args.batch % args.grad_accum:
        ap.error(f"--grad-accum must be >= 1 and divide --batch "
                 f"(got {args.grad_accum} vs batch {args.batch})")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    spec = spec_from_args(args, cfg.family)
    spec_hash = spec.spec_hash()

    # structured events (repro.obs): every status line below goes through
    # the event log — echoed to stdout exactly as before, and additionally
    # written to <metrics-dir>/events.jsonl when --metrics-dir is given
    from repro.obs import EventLog, MetricsRegistry, write_chrome_trace, write_metrics

    registry = MetricsRegistry()
    events_path = None
    if args.metrics_dir:
        events_path = Path(args.metrics_dir) / "events.jsonl"
    ev = EventLog(tag="train", path=events_path, registry=registry)
    ev.event("config",
             f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
             f"opt={spec.family}"
             + (f"+{len(spec.partitions)} partitions" if spec.partitions else "")
             + f" spec={spec_hash}",
             arch=cfg.name, family=spec.family, spec_hash=spec_hash,
             telemetry=bool(args.telemetry))

    key = jax.random.PRNGKey(args.seed)
    init = init_encdec if cfg.family == "encdec" else init_lm
    params = init(key, cfg)
    opt = build_optimizer(spec, params)
    opt_state = opt.init(params)

    from repro.optim import offload as offload_mod

    offload = offload_mod.check_mode(args.offload)
    place_state = None
    if offload is not None:
        if not hasattr(opt, "plan"):
            raise SystemExit(f"--offload {args.offload} needs an engine-backed "
                             f"optimizer (--opt {args.opt} has no bucket plan)")
        engine = opt.plan(params)
        place_state = lambda st: offload_mod.place_host(st, engine, offload)
        opt_state = place_state(opt_state)
        split = offload_mod.state_bytes_split(
            engine, jax.eval_shape(lambda s: s, opt_state), offload)
        cold = offload_mod.cold_keys(engine, offload)
        mode_note = ("async pinned-host tier" if offload_mod.supported()
                     else "structural (backend has no host memory kind)")
        ev.event("offload",
                 f"offload=cold: {len(cold)} cold buckets, "
                 f"device {split['device']/1e6:.3f}MB / host {split['host']/1e6:.3f}MB "
                 f"({mode_note})",
                 cold_buckets=len(cold), device_bytes=split["device"],
                 host_bytes=split["host"])

    from repro.utils.tree import tree_bytes

    ev.event("memory",
             f"param bytes {tree_bytes(params)/1e6:.2f}MB, "
             f"optimizer state bytes {tree_bytes(opt_state)/1e6:.3f}MB",
             param_bytes=tree_bytes(params), opt_state_bytes=tree_bytes(opt_state))
    if spec.partitions:
        by_group = state_bytes_by_group(opt, params)
        ev.event("state_by_group",
                 "state bytes by group: "
                 + ", ".join(f"{g}={b/1e6:.3f}MB" for g, b in sorted(by_group.items())),
                 **{g: b for g, b in sorted(by_group.items())})

    stats = optimizer_launch_stats(opt, params)
    if stats is not None:
        ev.event("engine",
                 f"update engine: {stats['leaves']} leaves -> "
                 f"{stats['update_launches']} launches/step "
                 f"({stats['factored_buckets']} factored, {stats['dense_buckets']} dense, "
                 f"{stats['kernel_buckets']} kernel, {stats['quantized_buckets']} "
                 f"quantized, {stats['transport_buckets']} transported, "
                 f"{stats['groups']} groups, "
                 f"{stats['frozen_leaves']} frozen)",
                 **stats)
    if args.use_kernel:
        # static half of the no-silent-fallback assertion: every factored
        # bucket must be planned onto the fused kernel path
        if not stats or stats["kernel_buckets"] == 0 or \
                stats["kernel_buckets"] != stats["factored_buckets"]:
            raise RuntimeError(
                f"--use-kernel requested but the plan routes "
                f"{0 if not stats else stats['kernel_buckets']}/"
                f"{0 if not stats else stats['factored_buckets']} factored "
                f"buckets through the fused kernel")
        from repro.kernels.smmf_update import ops as _kops

        kernel_launches0 = _kops.KERNEL_LAUNCHES

    if args.overlap:
        sched = opt.plan(params).schedule("grad") if hasattr(opt, "plan") else None
        ev.event("overlap",
                 f"overlap: bucket updates interleaved with the backward "
                 f"(schedule {sched})")

    stream = SyntheticLMStream(cfg, args.batch, args.seq, seed=args.seed)
    donate = () if args.no_donate else (0, 1)
    step_fn = jax.jit(make_train_step(cfg, opt, grad_accum=args.grad_accum,
                                      overlap=args.overlap, offload=offload,
                                      telemetry=args.telemetry),
                      donate_argnums=donate)
    # AOT-compile against the real shapes so the donation contract can be
    # checked (jax.stages args_info + the executable's alias table) before
    # any step runs — the step must update params/opt state in place, not
    # re-allocate every moment buffer
    with ev.span("train/compile"):
        lowered = step_fn.lower(params, opt_state, stream.batch(0))
        compiled = lowered.compile()
    if not args.no_donate:
        rep = assert_donation(lowered, compiled)
        ev.event("donation",
                 f"donation verified: {rep['donated_args']}/{rep['total_args']} "
                 f"args donated, {rep['alias_bytes']/1e6:.2f}MB aliased in place "
                 f"of {rep['donated_bytes']/1e6:.2f}MB donated",
                 **rep)
    loop_events = EventLog(tag="trainloop", path=events_path, registry=registry)
    loop = TrainLoop(
        compiled, params, opt_state, stream,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir, log_every=10,
                        spec_hash=spec_hash),
        place_state=place_state,
        registry=registry,
        events=loop_events,
    )
    out = loop.run()
    if args.use_kernel:
        # dynamic half: tracing the train step must have issued pallas_calls
        # (catches a silent degrade to the unfused branch)
        issued = _kops.KERNEL_LAUNCHES - kernel_launches0
        if issued == 0:
            raise RuntimeError("--use-kernel requested but no fused kernel "
                               "launch was traced (silent fallback)")
        ev.event("kernel",
                 f"fused kernel path verified: {issued} bucket launches traced",
                 launches=issued)
    if out["history"]:
        ev.event("done", f"done: {out['final_step']} steps, "
                         f"last loss {out['history'][-1]['loss']:.4f}",
                 final_step=out["final_step"], loss=out["history"][-1]["loss"])
    else:
        ev.event("done", "done", final_step=out["final_step"])
    if args.metrics_dir:
        records = sorted(ev.records() + loop_events.records(),
                         key=lambda r: r["t"])
        trace = write_chrome_trace(records, Path(args.metrics_dir) / "trace.json")
        metrics = write_metrics(registry.snapshot(),
                                Path(args.metrics_dir) / "metrics.json")
        ev.event("metrics_dump",
                 f"metrics written: {metrics}, trace: {trace}, "
                 f"events: {events_path}")
        ev.close()
        loop_events.close()


if __name__ == "__main__":
    main()
