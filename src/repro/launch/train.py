"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2_370m --smoke \
        --steps 50 --opt smmf

On the CPU container this runs reduced (smoke) configs end-to-end; on a real
pod the same entry point takes --mesh production and the full config. The
XLA latency-hiding-scheduler flags used on TPU pods are set here (no-ops on
CPU).
"""

from __future__ import annotations

import argparse
import os

# TPU pods: overlap collectives with compute (no-op on CPU)
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_latency_hiding_scheduler=true",
)

import jax

from repro.configs import get_config, smoke_config
from repro.data import SyntheticLMStream
from repro.launch.steps import make_train_step
from repro.models import init_encdec, init_lm
from repro.optim import adafactor, adam, came, sm3
from repro.core.smmf import smmf
from repro.train import TrainLoop, TrainLoopConfig


def build_optimizer(name: str, lr: float, family: str):
    gamma = -0.5 if family == "cnn" else -0.8
    return {
        "smmf": lambda: smmf(lr, decay_rate=gamma),
        "smmf_local": lambda: smmf(lr, decay_rate=gamma, blocks=4),
        "adam": lambda: adam(lr),
        "adafactor": lambda: adafactor(lr),
        "came": lambda: came(lr),
        "sm3": lambda: sm3(lr),
    }[name]()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--opt", default="smmf")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, opt={args.opt}")

    key = jax.random.PRNGKey(args.seed)
    init = init_encdec if cfg.family == "encdec" else init_lm
    params = init(key, cfg)
    opt = build_optimizer(args.opt, args.lr, cfg.family)
    opt_state = opt.init(params)

    from repro.utils.tree import tree_bytes

    print(f"[train] param bytes {tree_bytes(params)/1e6:.2f}MB, "
          f"optimizer state bytes {tree_bytes(opt_state)/1e6:.3f}MB")

    stream = SyntheticLMStream(cfg, args.batch, args.seq, seed=args.seed)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0, 1))
    loop = TrainLoop(
        step_fn, params, opt_state, stream,
        TrainLoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                        ckpt_dir=args.ckpt_dir, log_every=10),
    )
    out = loop.run()
    print(f"[train] done: {out['final_step']} steps, "
          f"last loss {out['history'][-1]['loss']:.4f}" if out["history"] else "[train] done")


if __name__ == "__main__":
    main()
