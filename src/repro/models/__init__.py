"""Model zoo: LM families, encoder-decoder backbone, CNN."""

from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.models.lm import (
    init_cache,
    init_lm,
    lm_decode_step,
    lm_logits,
    lm_loss,
    lm_prefill,
    lm_prefill_batch,
    vocab_padded,
)
from repro.models.encdec import (
    encdec_decode_step,
    encdec_logits,
    encdec_loss,
    encdec_prefill_batch,
    encode,
    init_encdec,
    init_encdec_cache,
)
from repro.models.cnn import cnn_apply, cnn_loss, init_cnn

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "init_lm",
    "lm_logits",
    "lm_loss",
    "lm_prefill",
    "lm_prefill_batch",
    "lm_decode_step",
    "init_cache",
    "vocab_padded",
    "init_encdec",
    "encode",
    "encdec_logits",
    "encdec_loss",
    "encdec_decode_step",
    "encdec_prefill_batch",
    "init_encdec_cache",
    "init_cnn",
    "cnn_apply",
    "cnn_loss",
]
