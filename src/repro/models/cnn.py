"""Small CNN classifier — the paper's high-rank-momentum regime (Table 1).

Rank-4 conv kernels (Ci, Co, Kh, Kw) are where SMMF's square-matricization
beats Adafactor/CAME's slice-into-matrices factorization; this model feeds
the memory and convergence benchmarks (CIFAR-scale synthetic data).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def init_cnn(key, num_classes: int = 100, width: int = 32, depth: int = 3) -> PyTree:
    ks = jax.random.split(key, depth * 2 + 2)
    params: dict = {}
    cin = 3
    for i in range(depth):
        cout = width * (2 ** i)
        params[f"conv{i}a"] = {
            "w": jax.random.normal(ks[2 * i], (3, 3, cin, cout), jnp.float32) * (1.0 / (3 * jnp.sqrt(float(cin)))),
            "b": jnp.zeros((cout,), jnp.float32),
        }
        params[f"conv{i}b"] = {
            "w": jax.random.normal(ks[2 * i + 1], (3, 3, cout, cout), jnp.float32) * (1.0 / (3 * jnp.sqrt(float(cout)))),
            "b": jnp.zeros((cout,), jnp.float32),
        }
        cin = cout
    params["fc"] = {
        "w": jax.random.normal(ks[-1], (cin, num_classes), jnp.float32) * 0.02,
        "b": jnp.zeros((num_classes,), jnp.float32),
    }
    return params


def _conv(p, x, stride=1):
    out = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + p["b"][None, None, None, :]


def cnn_apply(params, images):
    """images (B, H, W, 3) -> logits (B, num_classes)."""
    x = images
    depth = sum(1 for k in params if k.startswith("conv") and k.endswith("a"))
    for i in range(depth):
        x = jax.nn.relu(_conv(params[f"conv{i}a"], x))
        x = jax.nn.relu(_conv(params[f"conv{i}b"], x, stride=2))
    x = jnp.mean(x, axis=(1, 2))  # global average pool
    return jnp.einsum("bc,cn->bn", x, params["fc"]["w"]) + params["fc"]["b"][None]


def cnn_loss(params, batch):
    logits = cnn_apply(params, batch["images"])
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"loss": loss, "acc": acc}
