"""Model configuration shared by every architecture family.

One frozen dataclass covers dense / MoE / hybrid (RG-LRU) / SSM (Mamba2-SSD)
/ enc-dec (Whisper) / VLM-backbone (LLaVA) families; family-specific fields
default to "off". Configs for the 10 assigned architectures live in
``repro/configs/<id>.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    n_kv_heads: int = 0          # 0 -> = n_heads (MHA)
    head_dim: int = 0            # 0 -> d_model // n_heads
    activation: str = "silu"     # silu | gelu | relu | sq_relu
    gated_mlp: bool = True       # SwiGLU-style gate (llama family)
    qkv_bias: bool = False       # qwen1.5
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert hidden dim (0 -> d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- hybrid (RecurrentGemma) ---
    attn_window: int = 0         # sliding-window size for local attention
    rglru_ratio: int = 0         # N recurrent blocks per attention block
    lru_width: int = 0           # 0 -> d_model
    conv_width: int = 4
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # --- enc-dec (Whisper backbone; conv frontend is a stub) ---
    encoder_layers: int = 0
    encoder_seq: int = 0         # stub frontend output length (audio frames)
    # --- VLM backbone (LLaVA; anyres tiling frontend is a stub) ---
    n_patches: int = 0           # stub image-patch prefix length
    # --- numerics / structure ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode with O(1)/O(window) state (long_500k cell)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v
        total += d  # final norm
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_headdim
            per = (
                d * (2 * d_in + 2 * self.ssm_state + nheads)  # in_proj(z,x,B,C,dt)
                + self.conv_width * (d_in + 2 * self.ssm_state)
                + 3 * nheads  # A_log, D, dt_bias
                + d_in * d  # out_proj
                + d
            )
            return total + self.n_layers * per
        hd, hq, hkv = self.hd, self.n_heads, self.kv_heads
        attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
        if self.qkv_bias:
            attn += (hq + 2 * hkv) * hd
        def _ffn(f):
            return d * f * (3 if self.gated_mlp else 2)
        if self.family == "moe":
            ffn = d * self.n_experts + self.n_experts * _ffn(self.expert_ff)
            ffn += self.n_shared_experts * _ffn(self.expert_ff)
        else:
            ffn = _ffn(self.d_ff)
        per = attn + ffn + 2 * d
        total += self.n_layers * per
        if self.family == "encdec":
            # encoder blocks + decoder cross-attention
            total += self.encoder_layers * (attn + _ffn(self.d_ff) + 2 * d)
            total += self.n_layers * (attn + d)
        if self.family == "hybrid":
            pass  # approximation: recurrent blocks ~ attention blocks
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell of the evaluation matrix."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
