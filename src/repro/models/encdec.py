"""Encoder-decoder transformer backbone (Whisper-style).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, T_enc, D). The encoder is bidirectional
self-attention; the decoder is causal self-attention + cross-attention.
Whisper uses LayerNorm, learned positions (we use RoPE-free sinusoidal-free
learned embeddings for enc, RoPE for dec self-attn is disabled -> learned),
and non-gated GELU MLPs; cfg should set norm="layernorm", gated_mlp=False,
activation="gelu".
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.lm import vocab_padded

PyTree = Any


def init_encdec(key, cfg: ModelConfig) -> PyTree:
    vp = vocab_padded(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": L.init_norm(cfg.d_model, cfg.norm),
            "attn": L.init_attention(k1, cfg),
            "norm2": L.init_norm(cfg.d_model, cfg.norm),
            "ffn": L.init_ffn(k2, cfg),
        }

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": L.init_norm(cfg.d_model, cfg.norm),
            "attn": L.init_attention(k1, cfg),
            "norm_x": L.init_norm(cfg.d_model, cfg.norm),
            "xattn": L.init_attention(k2, cfg, cross=True),
            "norm2": L.init_norm(cfg.d_model, cfg.norm),
            "ffn": L.init_ffn(k3, cfg),
        }

    enc = [enc_block(k) for k in enc_keys]
    dec = [dec_block(k) for k in dec_keys]
    return {
        "embed": (jax.random.normal(ks[2], (vp, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "pos_embed": (jax.random.normal(ks[3], (4096 * 16, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "enc_pos": (jax.random.normal(ks[4], (cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": L.init_norm(cfg.d_model, cfg.norm),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames (B, T_enc, D) stub frontend output -> encoder states."""
    b, t, _ = frames.shape
    x = frames.astype(jnp.dtype(cfg.dtype)) + params["enc_pos"][None, :t]
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def body(h, p):
        o, _ = L.attention(p["attn"], L.norm(h, p["norm1"], cfg.norm), cfg, positions,
                           causal=False, use_rope=False)
        h = h + o
        return h + L.ffn(p["ffn"], L.norm(h, p["norm2"], cfg.norm), cfg), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.norm(x, params["enc_norm"], cfg.norm)


def _dec_stack(params, cfg, x, positions, enc, caches=None, ring=False):
    def body(carry, scanned):
        h = carry
        p, c = scanned
        o, c2 = L.attention(p["attn"], L.norm(h, p["norm1"], cfg.norm), cfg, positions,
                            cache=c, use_rope=False, ring=ring)
        h = h + o
        o, _ = L.attention(p["xattn"], L.norm(h, p["norm_x"], cfg.norm), cfg, positions,
                           kv_x=enc, use_rope=False)
        h = h + o
        h = h + L.ffn(p["ffn"], L.norm(h, p["norm2"], cfg.norm), cfg)
        return h, c2

    if cfg.remat and caches is None:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.lax.scan(body, x, (params["dec_blocks"], caches))


def encdec_logits(params, cfg: ModelConfig, tokens, frames):
    """Teacher-forced decoder logits. tokens (B,S), frames (B,T_enc,D)."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_embed"][None, :s]
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, _ = _dec_stack(params, cfg, x, positions, enc)
    x = L.norm(x, params["final_norm"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    return constrain(logits, "logits")


def encdec_loss(params, cfg: ModelConfig, batch):
    logits = encdec_logits(params, cfg, batch["tokens"], batch["frames"])
    labels = batch["labels"]
    vp = logits.shape[-1]
    if vp != cfg.vocab:
        col = jnp.arange(vp)
        logits = jnp.where(col[None, None, :] < cfg.vocab, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce, {"ce": ce, "loss": ce}


def init_encdec_cache(cfg: ModelConfig, batch: int, s_max: int):
    return {
        "attn": L.init_attn_cache(cfg, batch, s_max, layers=cfg.n_layers),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


def encdec_prefill_batch(params, cfg: ModelConfig, tokens, valid, enc):
    """Right-padded batched decoder prefill for the paged serving engine.

    tokens (B, S) int32 right-padded; valid (B,) real lengths; enc
    (B, T_enc, D) encoder states (from :func:`encode` at admission).
    Returns (last-valid-position logits (B, Vpad), per-layer self-attn K/V
    (L, B, S, Hkv, D)) — exactly the K/V a step-by-step
    :func:`encdec_decode_step` would have written (no RoPE; positions enter
    through the learned ``pos_embed``), so the paged cache is bitwise-equal
    to the dense one over each row's valid prefix.
    """
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0) + params["pos_embed"][None, :s]
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    dt = jnp.dtype(cfg.dtype)

    def body(h, p):
        hn = L.norm(h, p["norm1"], cfg.norm)
        o, _ = L.attention(p["attn"], hn, cfg, positions, use_rope=False)
        q, k, v = L._qkv(p["attn"], hn, hn, cfg)
        h = h + o
        o, _ = L.attention(p["xattn"], L.norm(h, p["norm_x"], cfg.norm), cfg, positions,
                           kv_x=enc, use_rope=False)
        h = h + o
        h = h + L.ffn(p["ffn"], L.norm(h, p["norm2"], cfg.norm), cfg)
        return h, {"k": k.astype(dt), "v": v.astype(dt)}

    x, kv = jax.lax.scan(body, x, params["dec_blocks"])
    last = jnp.take_along_axis(x, (valid - 1)[:, None, None], axis=1)
    last = L.norm(last, params["final_norm"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", last, params["embed"]).astype(jnp.float32)
    return constrain(logits, "logits")[:, 0], kv


def encdec_decode_step(params, cfg: ModelConfig, token, cache, enc):
    """One decoder step with self-attn cache + cross-attn to `enc`."""
    pos = cache["pos"]
    b = token.shape[0]
    x = jnp.take(params["embed"], token, axis=0)
    x = x + jnp.take(params["pos_embed"], pos[:, None], axis=0)
    positions = pos[:, None]
    x, new_kv = _dec_stack(params, cfg, x, positions, enc, caches=cache["attn"])
    x = L.norm(x, params["final_norm"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]).astype(jnp.float32)
    return constrain(logits, "logits"), {"attn": new_kv, "pos": pos + 1}
