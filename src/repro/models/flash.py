"""Flash-style blockwise attention in pure JAX (XLA-level, TPU-friendly).

Online-softmax over KV blocks with the query axis pre-blocked, so the peak
live tensor is O(B * nb * bq * H * bkv) instead of O(B * H * S^2). The
query-block axis (nb) carries the sequence-parallel sharding when attention
heads don't divide the model axis; otherwise heads carry it — both are
plain GSPMD shardings via ctx.constrain("flash_q"/"flash_kv").

Causal and sliding-window masks are generated from block-index iota (never a
materialized (S, S) mask). This is the memory-hierarchy adaptation of the
FlashAttention idea to the XLA/TPU stack: blocks sized for VMEM residency,
with the MXU contraction shapes left to XLA fusion.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain

NEG_INF = -1e30


def flash_attention(
    q: jnp.ndarray,       # (B, S, Hq, D)
    k: jnp.ndarray,       # (B, Sk, Hkv, D)
    v: jnp.ndarray,       # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 256,
    block_kv: int = 1024,
) -> jnp.ndarray:
    from repro.models.perf import flags

    b, s, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    grp = hq // hkv
    if flags().flash_block_kv:
        block_kv = flags().flash_block_kv

    def _pick(size, target):
        nb = 1  # double the block count while blocks stay above target size
        while size % (nb * 2) == 0 and size // nb > target:
            nb *= 2
        return nb

    nb = _pick(s, block_q)
    nkv = _pick(sk, block_kv)
    bq, bkv = s // nb, sk // nkv

    bf16_ops = flags().bf16_accum_attention
    qdt = q.dtype if bf16_ops else jnp.float32
    qb = (q.astype(jnp.float32) / math.sqrt(d)).astype(qdt).reshape(b, nb, bq, hkv, grp, d)
    qb = constrain(qb, "flash_q")
    k = constrain(k.astype(qdt), "flash_kv")
    v = constrain(v.astype(qdt), "flash_kv")

    q_pos = (jnp.arange(nb)[:, None] * bq + jnp.arange(bq)[None, :])  # (nb, bq)

    def body(carry, j):
        acc, m_run, l_run = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * bkv, bkv, axis=1)    # (b,bkv,hkv,d)
        vj = jax.lax.dynamic_slice_in_dim(v, j * bkv, bkv, axis=1)
        s_blk = jnp.einsum("bnqhgd,bkhd->bnqhgk", qb, kj,
                           preferred_element_type=jnp.float32)        # (b,nb,bq,hkv,grp,bkv)
        k_pos = j * bkv + jnp.arange(bkv)                             # (bkv,)
        if causal:
            mask = k_pos[None, None, :] <= q_pos[:, :, None]          # (nb,bq,bkv)
            if window:
                mask &= k_pos[None, None, :] > q_pos[:, :, None] - window
            s_blk = jnp.where(mask[None, :, :, None, None, :], s_blk, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s_blk, axis=-1))
        p = jnp.exp(s_blk - m_new[..., None])
        scale = jnp.exp(m_run - m_new)
        l_new = l_run * scale + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bnqhgk,bkhd->bnqhgd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * scale[..., None] + pv
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, nb, bq, hkv, grp, d), jnp.float32)
    m0 = jnp.full((b, nb, bq, hkv, grp), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, nb, bq, hkv, grp), jnp.float32)
    acc0 = constrain(acc0, "flash_q")
    (acc, m_run, l_run), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nkv))
    out = acc / jnp.maximum(l_run[..., None], 1e-30)
    return out.reshape(b, s, hq, d)
