"""Neural-net building blocks (pure functions over param dicts).

Everything is written against abstract named-axis einsums so GSPMD can
propagate shardings; activation sharding hints go through
``repro.distributed.ctx.constrain`` (identity unless a mesh context is
installed by the train/serve step factory).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models.config import ModelConfig

PyTree = Any


# ---------------------------------------------------------------------------
# initializers / norms
# ---------------------------------------------------------------------------

def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def norm(x, p, kind: str):
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def init_norm(d: int, kind: str) -> PyTree:
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


def activate(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu":
        return jax.nn.relu(x)
    if kind == "sq_relu":  # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind}")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate-half RoPE. x (B, S, H, D); positions (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, optional cross, optional cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> PyTree:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, hq, hd), 1 / math.sqrt(d), dt),
        "wk": _init(ks[1], (d, hkv, hd), 1 / math.sqrt(d), dt),
        "wv": _init(ks[2], (d, hkv, hd), 1 / math.sqrt(d), dt),
        "wo": _init(ks[3], (hq, hd, d), 1 / math.sqrt(hq * hd), dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq, hd), dt)
        p["bk"] = jnp.zeros((hkv, hd), dt)
        p["bv"] = jnp.zeros((hkv, hd), dt)
    return p


def _qkv(p, x, kv_x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, None]
        k = k + p["bk"][None, None]
        v = v + p["bv"][None, None]
    return q, k, v


def gqa_scores(q, k):
    """q (B,Sq,Hq,D), k (B,Sk,Hkv,D) -> (B,Hq,Sq,Sk) with KV-head grouping."""
    from repro.models.perf import flags

    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    grp = hq // hkv
    qg = q.reshape(b, sq, hkv, grp, d)
    if flags().attn_bf16_scores:
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.bfloat16), k.astype(jnp.bfloat16),
                       preferred_element_type=jnp.bfloat16).astype(jnp.float32)
    elif flags().bf16_accum_attention:
        # bf16 operands, f32 MXU accumulation: no materialized f32 K copy
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k, preferred_element_type=jnp.float32)
    else:
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    return s.reshape(b, hq, sq, k.shape[1]) / math.sqrt(d)


def gqa_combine(w, v):
    """w (B,Hq,Sq,Sk), v (B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    from repro.models.perf import flags

    b, hq, sq, sk = w.shape
    hkv = v.shape[2]
    grp = hq // hkv
    wg = w.reshape(b, hkv, grp, sq, sk)
    if flags().attn_bf16_scores:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", wg.astype(jnp.bfloat16), v.astype(jnp.bfloat16),
                       preferred_element_type=jnp.bfloat16)
    elif flags().bf16_accum_attention:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", wg.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
    else:
        o = jnp.einsum("bhgqk,bkhd->bqhgd", wg, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, v.shape[3])


def attention(
    p: PyTree,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    *,
    kv_x: jnp.ndarray | None = None,       # cross-attention source
    cache: PyTree | None = None,           # {"k","v" (B,S,Hkv,D)}
    window: int = 0,
    causal: bool = True,
    use_rope: bool = True,
    ring: bool = False,                    # cache is a ring buffer over `window`
) -> tuple[jnp.ndarray, PyTree | None]:
    """Full attention: self (train/prefill) or single-token decode with cache.

    ``positions`` are always *absolute* (used for RoPE). In decode, the K/V
    write index is ``pos`` (or ``pos % cache_len`` for ring buffers); ring
    buffers attend to every filled slot (they hold exactly the window).
    Returns (output (B,S,D_model), new_cache).
    """
    cross = kv_x is not None
    q, k, v = _qkv(p, x, kv_x if cross else x, cfg)
    if use_rope and not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "heads")

    new_cache = None
    if cache is not None and not cross:
        from repro.models.perf import flags

        s_max = cache["k"].shape[1]
        pos = positions[:, 0]  # (B,) absolute
        widx = pos % s_max if ring else pos
        if flags().scatter_cache_update:
            # in-place scatter: slice-sized traffic. Legal when the cache's
            # sequence dim is unsharded (kv-heads carry the model axis).
            bidx = jnp.arange(cache["k"].shape[0])
            ck = cache["k"].at[bidx, widx].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, widx].set(v[:, 0].astype(cache["v"].dtype))
        else:
            # one-hot masked update: elementwise, safe for any cache
            # sharding incl. seq-sharded (cost: full-slice rewrite)
            hot = jax.nn.one_hot(widx, s_max, dtype=cache["k"].dtype)[:, :, None, None]
            ck = cache["k"] * (1 - hot) + hot * k.astype(cache["k"].dtype)
            cv = cache["v"] * (1 - hot) + hot * v.astype(cache["v"].dtype)
        new_cache = {"k": ck, "v": cv}
        k, v = ck, cv

    # long full-sequence self-attention: blockwise online-softmax path
    # (never materializes (S, S) scores; see repro.models.flash)
    if cache is None and not cross and causal and q.shape[1] >= 4096:
        from repro.models.flash import flash_attention

        o = flash_attention(q, k, v, causal=True, window=window).astype(x.dtype)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
        return constrain(out, "residual"), None

    out = attend(p, q, k, v, positions, x.dtype,
                 decode=cache is not None and not cross,
                 causal=causal and not cross, window=window, ring=ring)
    return out, new_cache


def attend(p, q, k, v, positions, out_dtype, *, decode: bool, causal: bool = True,
           window: int = 0, ring: bool = False):
    """Post-QKV attention: scores -> mask -> softmax -> combine -> out-proj.

    Shared by the internal cache path and the cache-as-carry decode path
    (where K/V were scattered into the carried cache before this call).
    """
    scores = gqa_scores(q, k)  # (B,Hq,Sq,Sk) f32

    sq, sk = scores.shape[2], scores.shape[3]
    if decode:
        kpos = jnp.arange(sk)[None, None, None, :]
        pos_b = positions[:, None, None, :]
        # valid slots: <= pos normally; every filled slot for ring buffers
        mask = kpos < jnp.minimum(pos_b + 1, sk) if ring else kpos <= pos_b
        if window and not ring:
            mask = mask & (kpos > pos_b - window)
    elif causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        mask = (kpos <= qpos)[None, None]
        if window:
            mask = mask & (kpos > qpos - window)[None, None]
    else:
        mask = None

    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o = gqa_combine(w, v).astype(out_dtype)
    from repro.models.perf import flags as _pf

    if _pf().bf16_rowparallel_reduce:
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"], preferred_element_type=jnp.bfloat16)
    else:
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return constrain(out, "residual")


def init_attn_cache(cfg: ModelConfig, batch: int, s_max: int, layers: int | None = None):
    """Stacked (L, B, S, Hkv, D) KV cache of zeros."""
    l = cfg.n_layers if layers is None else layers
    dt = jnp.dtype(cfg.dtype)
    shape = (l, batch, s_max, cfg.kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: int | None = None) -> PyTree:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"wi": _init(ks[0], (d, f), dtype=dt), "wo": _init(ks[1], (f, d), dtype=dt)}
    if cfg.gated_mlp:
        p["wg"] = _init(ks[2], (d, f), dtype=dt)
    return p


def ffn(p, x, cfg: ModelConfig):
    from repro.models.perf import flags

    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.gated_mlp:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = activate(g, cfg.activation) * h
    else:
        h = activate(h, cfg.activation)
    h = constrain(h, "ffn")
    if flags().bf16_rowparallel_reduce:
        # partial sums of the row-parallel (TP) matmul reduced in bf16:
        # halves the all-reduce wire bytes (numerics note in EXPERIMENTS.md)
        out = jnp.einsum("bsf,fd->bsd", h, p["wo"], preferred_element_type=jnp.bfloat16)
    else:
        out = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    return constrain(out, "residual")


# ---------------------------------------------------------------------------
# MoE FFN (GShard-style grouped top-k dispatch with capacity)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> PyTree:
    from repro.models.perf import flags

    d, e, fe = cfg.d_model, cfg.n_experts, cfg.expert_ff
    pack = max(1, flags().moe_expert_pack)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    p = {
        "router": _init(ks[0], (d, e), dtype=jnp.float32),
        # packed layout (E*P, D, F/P): expert axis divisible by the TP degree
        "wi": _init(ks[1], (e * pack, d, fe // pack), dtype=dt),
        "wo": _init(ks[2], (e * pack, fe // pack, d), dtype=dt),
    }
    if cfg.gated_mlp:
        p["wg"] = _init(ks[3], (e * pack, d, fe // pack), dtype=dt)
    if cfg.n_shared_experts:
        sub = ModelConfig(**{**cfg.__dict__, "d_ff": fe * cfg.n_shared_experts})
        p["shared"] = init_ffn(ks[4], sub, fe * cfg.n_shared_experts)
    return p


def moe_ffn(p, x, cfg: ModelConfig, n_groups: int = 16, token_mask=None):
    """x (B, S, D) -> (out, aux_loss). Tokens are routed in G groups per
    batch row; each group gets its own capacity so the position cumsum stays
    group-local (no cross-shard cumsum when S is sharded G-way).

    ``token_mask`` (B, S) bool marks real tokens in a right-padded batch
    (serving's batched prefill): padding tokens are dropped from the routing
    one-hots *before* the capacity cumsum, so they never consume a real
    token's expert-capacity slot — a padded row routes its valid prefix
    exactly as the unpadded row would."""
    from repro.models.perf import flags

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = math.gcd(n_groups, s)
    sg = s // g
    cf = flags().moe_capacity_override or cfg.capacity_factor
    cap = max(4, int(cf * k * sg / e + 0.999))
    xg = x.reshape(b, g, sg, d)

    logits = jnp.einsum("bgsd,de->bgse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)                      # (b,g,sg,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)            # (b,g,sg,k,e)
    if token_mask is not None:
        onehot = onehot * token_mask.reshape(b, g, sg, 1, 1).astype(jnp.float32)
    # position of each (token, choice) within its expert queue, group-local
    flat = onehot.reshape(b, g, sg * k, e)
    pos = jnp.cumsum(flat, axis=2) - 1.0
    pos = pos.reshape(b, g, sg, k, e)
    keep = (pos < cap) & (onehot > 0)
    pos = jnp.where(keep, pos, 0.0).astype(jnp.int32)

    # combine tensor (b,g,sg,e,cap): gate value at the kept slot
    cap_hot = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
    combine = jnp.einsum("bgske,bgskec->bgsec", onehot * gate_vals[..., None], cap_hot)
    combine = constrain(combine.astype(x.dtype), "moe_dispatch")
    dispatch = (combine != 0).astype(x.dtype)

    pack = max(1, flags().moe_expert_pack)
    if pack > 1:
        # duplicate the (small) dispatch one-hots per expert F-chunk so the
        # dispatch einsum directly produces the packed-expert token tensor
        # (b, E*P, g, cap, d) -- the einsum output resharding g->E is a
        # single all-to-all instead of a gather of a broadcasted copy
        dispatch = jnp.repeat(dispatch, pack, axis=3)
    xe = jnp.einsum("bgsec,bgsd->begcd", dispatch, xg)                 # (b,E*P,g,cap,d)
    if flags().moe_bf16_dispatch:
        xe = xe.astype(x.dtype)
    xe = constrain(xe, "moe_ffn_in")
    h = jnp.einsum("begcd,edf->begcf", xe, p["wi"])
    if cfg.gated_mlp:
        gg = jnp.einsum("begcd,edf->begcf", xe, p["wg"])
        h = activate(gg, cfg.activation) * h
    else:
        h = activate(h, cfg.activation)
    if flags().moe_bf16_dispatch:
        h = h.astype(x.dtype)
    h = constrain(h, "moe_ffn")
    ye = jnp.einsum("begcf,efd->begcd", h, p["wo"])
    if pack > 1:
        # sum the P partial products of each expert's split hidden dim
        ye = ye.reshape(b, e, pack, g, ye.shape[-2], d).sum(axis=2)
    if flags().moe_bf16_dispatch:
        ye = ye.astype(x.dtype)
    ye = constrain(ye, "moe_ffn_in")
    out = jnp.einsum("bgsec,begcd->bgsd", combine, ye).reshape(b, s, d)

    if cfg.n_shared_experts:
        out = out + ffn(p["shared"], x, cfg)

    # load-balance auxiliary loss (Switch/GShard form)
    me = jnp.mean(probs, axis=(0, 1, 2))                               # mean router prob
    ce = jnp.mean(onehot[..., 0, :] if k == 1 else jnp.max(onehot, axis=3), axis=(0, 1, 2))
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)
    return constrain(out, "residual"), aux


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

def init_rglru(key, cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    w = cfg.lru_width or d
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "wx": _init(ks[0], (d, w), dtype=dt),          # input branch
        "wy": _init(ks[1], (d, w), dtype=dt),          # gate branch
        "conv_w": _init(ks[2], (cfg.conv_width, w), 0.1, dt),
        "conv_b": jnp.zeros((w,), dt),
        "wi_gate": _init(ks[3], (w, w), dtype=dt),     # input gate (i_t)
        "wa_gate": _init(ks[4], (w, w), dtype=dt),     # recurrence gate (r_t)
        "lam": jnp.full((w,), 2.0, jnp.float32),       # softplus^-1 decay param
        "wo": _init(ks[5], (w, d), dtype=dt),
    }


def _causal_conv1d(x, w, b):
    """x (B,S,W), w (K,W) depthwise causal conv."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def rglru(p, x, cfg: ModelConfig, state: PyTree | None = None):
    """Gated linear recurrence h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * u_t).

    Train/prefill: associative scan over S. Decode: one-step with carried
    state {"h" (B,W), "conv" (B,K-1,W)}. Returns (out, new_state).
    """
    u = jnp.einsum("bsd,dw->bsw", x, p["wx"])
    gate_in = jnp.einsum("bsd,dw->bsw", x, p["wy"])

    if state is None:
        uc = _causal_conv1d(u, p["conv_w"], p["conv_b"])
        new_conv = None
    else:
        k = p["conv_w"].shape[0]
        hist = jnp.concatenate([state["conv"], u], axis=1)  # (B, K, W)
        uc = jnp.einsum("bkw,kw->bw", hist, p["conv_w"])[:, None, :] + p["conv_b"][None, None, :]
        new_conv = hist[:, 1:, :]

    i_t = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", gate_in, p["wi_gate"]))
    r_t = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", gate_in, p["wa_gate"]))
    c = 8.0
    log_a = -c * jax.nn.softplus(p["lam"])[None, None, :] * r_t.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (i_t * uc).astype(jnp.float32)
    b_t = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated

    if state is None:
        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(comb, (a, b_t), axis=1)
        new_state = {"h": h[:, -1, :]}
    else:
        h = a * state["h"][:, None, :] + b_t
        new_state = {"h": h[:, -1, :], "conv": new_conv}

    out = jnp.einsum("bsw,wd->bsd", h.astype(x.dtype), p["wo"])
    return constrain(out, "residual"), new_state


def init_rglru_state(cfg: ModelConfig, batch: int, layers: int):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((layers, batch, w), jnp.float32),
        "conv": jnp.zeros((layers, batch, cfg.conv_width - 1, w), jnp.dtype(cfg.dtype)),
    }


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space duality, chunked)
# ---------------------------------------------------------------------------

def init_ssd(key, cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    nh = din // cfg.ssm_headdim
    ns = cfg.ssm_state
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _init(ks[0], (d, 2 * din + 2 * ns + nh), dtype=dt),
        "conv_w": _init(ks[1], (cfg.conv_width, din + 2 * ns), 0.1, dt),
        "conv_b": jnp.zeros((din + 2 * ns,), dt),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": _init(ks[2], (din, d), dtype=dt),
        "norm": init_norm(din, "rmsnorm"),
    }


def _ssd_chunked(xh, dt_h, a_log, bmat, cmat, chunk: int, bf16_intra: bool = False):
    """Chunked SSD scan.

    xh (B,S,H,P) head inputs; dt_h (B,S,H) step sizes; a_log (H,);
    bmat/cmat (B,S,N). Returns (y (B,S,H,P), final state (B,H,P,N)).
    ``bf16_intra`` keeps the O(c^2) intra-chunk tensors in bf16 (halves
    their HBM traffic; inter-chunk state math stays f32).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = max(1, s // chunk)
    c = s // nc
    xc = xh.reshape(b, nc, c, h, p)
    dtc = dt_h.reshape(b, nc, c, h)
    bc = bmat.reshape(b, nc, c, n)
    cc = cmat.reshape(b, nc, c, n)

    da = -jnp.exp(a_log)[None, None, None, :] * dtc          # (b,nc,c,h) log-decay
    cum = jnp.cumsum(da, axis=2)                              # within-chunk cumulative
    seg_tot = cum[:, :, -1, :]                                # (b,nc,h)

    idt = jnp.bfloat16 if bf16_intra else jnp.float32

    # intra-chunk (quadratic within chunk, causal)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (b,nc,c_q,c_k,h)
    causal = jnp.tril(jnp.ones((c, c), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0).astype(idt)
    sc = jnp.einsum("bgqn,bgkn->bgqk", cc.astype(idt), bc.astype(idt),
                    preferred_element_type=idt)               # (b,nc,c,c)
    w = sc[..., None] * decay * dtc[:, :, None, :, :].astype(idt)  # (b,nc,q,k,h)
    y_intra = jnp.einsum("bgqkh,bgkhp->bgqhp", w, xc.astype(idt),
                         preferred_element_type=jnp.float32)

    # chunk states: contribution of each chunk to the carried state
    decay_to_end = jnp.exp(seg_tot[:, :, None, :] - cum)      # (b,nc,c,h)
    sstate = jnp.einsum("bgkn,bgkh,bgkhp->bghpn", bc, decay_to_end * dtc, xc)

    # inter-chunk recurrence over nc chunks
    def comb(c1, c2):
        a1, s1 = c1
        a2, s2 = c2
        return a1 + a2, s2 + s1 * jnp.exp(a2)[..., None, None]
    init_a = seg_tot.transpose(1, 0, 2)                       # (nc,b,h)
    init_s = sstate.transpose(1, 0, 2, 3, 4)                  # (nc,b,h,p,n)
    _, states = jax.lax.associative_scan(comb, (init_a, init_s), axis=0)
    states = states.transpose(1, 0, 2, 3, 4)                  # (b,nc,h,p,n) state at chunk END
    prev = jnp.concatenate([jnp.zeros_like(states[:, :1]), states[:, :-1]], axis=1)

    # inter-chunk output: y += C_t exp(cum_t) prev_state
    y_inter = jnp.einsum("bgqn,bgqh,bghpn->bgqhp", cc, jnp.exp(cum), prev)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, states[:, -1]


def ssd_block(p, x, cfg: ModelConfig, state: PyTree | None = None):
    """Mamba2 block. state (decode): {"ssm" (B,H,P,N), "conv" (B,K-1,C)}."""
    from repro.models.perf import flags

    b, s, d = x.shape
    din = cfg.ssm_expand * d
    nh = din // cfg.ssm_headdim
    ns = cfg.ssm_state
    ph = cfg.ssm_headdim

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xin, bmat, cmat, dt_raw = jnp.split(zxbcdt, [din, 2 * din, 2 * din + ns, 2 * din + 2 * ns], axis=-1)

    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    if state is None:
        conv_out = _causal_conv1d(conv_in, p["conv_w"], p["conv_b"])
        new_conv = None
    else:
        k = p["conv_w"].shape[0]
        hist = jnp.concatenate([state["conv"], conv_in], axis=1)
        conv_out = jnp.einsum("bkc,kc->bc", hist, p["conv_w"])[:, None, :] + p["conv_b"][None, None, :]
        new_conv = hist[:, 1:, :]
    conv_out = jax.nn.silu(conv_out)
    xin, bmat, cmat = jnp.split(conv_out, [din, din + ns], axis=-1)

    dt_h = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])  # (B,S,H)
    xh = xin.reshape(b, s, nh, ph).astype(jnp.float32)
    xh = constrain(xh, "ssd_heads")          # (B,S,H,P): heads over model
    dt_h = constrain(dt_h, "ssd_dt")

    if state is None:
        chunk = flags().ssd_chunk_override or cfg.ssm_chunk
        y, last = _ssd_chunked(xh, dt_h, p["a_log"], bmat.astype(jnp.float32),
                               cmat.astype(jnp.float32), chunk,
                               bf16_intra=flags().ssd_bf16_intra)
        new_state = {"ssm": last}
    else:
        da = jnp.exp(-jnp.exp(p["a_log"])[None, :] * dt_h[:, 0])           # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt_h[:, 0], xh[:, 0], bmat[:, 0].astype(jnp.float32))
        h_new = state["ssm"] * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), h_new)[:, None]
        new_state = {"ssm": h_new, "conv": new_conv}

    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"]["scale"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return constrain(out, "residual"), new_state


def init_ssd_state(cfg: ModelConfig, batch: int, layers: int):
    din = cfg.ssm_expand * cfg.d_model
    nh = din // cfg.ssm_headdim
    return {
        "ssm": jnp.zeros((layers, batch, nh, cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((layers, batch, cfg.conv_width - 1, din + 2 * cfg.ssm_state), jnp.dtype(cfg.dtype)),
    }
