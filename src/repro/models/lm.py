"""Decoder-only LM covering the dense / MoE / hybrid / SSM / VLM families.

Entry points:

  init_lm(key, cfg)                                   -> params
  lm_logits(params, cfg, tokens, prefix_embeds=None)  -> (logits, aux_loss)
  lm_loss(params, cfg, batch)                         -> (loss, metrics)
  init_cache(cfg, batch, s_max)                       -> decode cache pytree
  lm_prefill(params, cfg, tokens, cache, ...)         -> (last_logits, cache)
  lm_decode_step(params, cfg, token, pos, cache)      -> (logits, cache)

Homogeneous stacks (dense/moe/ssm/vlm) run under lax.scan over stacked
(L, ...) layer params with optional remat; the hybrid (RG-LRU + local
attention, 1:R pattern) unrolls a Python loop over two per-type stacks.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.ctx import constrain
from repro.models import layers as L
from repro.models.config import ModelConfig

PyTree = Any


def vocab_padded(cfg: ModelConfig) -> int:
    return -(-cfg.vocab // 256) * 256


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig) -> PyTree:
    """One decoder block's params (unstacked)."""
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": L.init_norm(cfg.d_model, cfg.norm)}
    if cfg.family == "ssm":
        p["mixer"] = L.init_ssd(ks[0], cfg)
        return p  # mamba2 blocks have a single mixer, no separate FFN
    p["attn"] = L.init_attention(ks[0], cfg)
    p["norm2"] = L.init_norm(cfg.d_model, cfg.norm)
    if cfg.family == "moe":
        p["moe"] = L.init_moe(ks[1], cfg)
    else:
        p["ffn"] = L.init_ffn(ks[1], cfg)
    return p


def _hybrid_layout(cfg: ModelConfig) -> list[str]:
    """Layer types, e.g. ['rec','rec','attn', ...] (1 attn per rglru_ratio+1)."""
    kinds = []
    period = cfg.rglru_ratio + 1
    for i in range(cfg.n_layers):
        kinds.append("attn" if (i % period) == period - 1 else "rec")
    return kinds


def init_lm(key, cfg: ModelConfig) -> PyTree:
    vp = vocab_padded(cfg)
    dt = jnp.dtype(cfg.dtype)
    k_embed, k_blocks, k_head = jax.random.split(key, 3)
    params: dict = {
        "embed": (jax.random.normal(k_embed, (vp, cfg.d_model), jnp.float32) * 0.02).astype(dt),
        "final_norm": L.init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(k_head, (cfg.d_model, vp), jnp.float32) * 0.02).astype(dt)

    if cfg.family == "hybrid":
        kinds = _hybrid_layout(cfg)
        n_rec = sum(k == "rec" for k in kinds)
        n_att = len(kinds) - n_rec
        kr = jax.random.split(jax.random.fold_in(k_blocks, 0), max(n_rec, 1))
        ka = jax.random.split(jax.random.fold_in(k_blocks, 1), max(n_att, 1))
        kf = jax.random.split(jax.random.fold_in(k_blocks, 2), cfg.n_layers)
        rec = [
            {"norm1": L.init_norm(cfg.d_model, cfg.norm), "mixer": L.init_rglru(kr[i], cfg)}
            for i in range(n_rec)
        ]
        att = [
            {"norm1": L.init_norm(cfg.d_model, cfg.norm), "attn": L.init_attention(ka[i], cfg)}
            for i in range(n_att)
        ]
        ffn = [
            {"norm2": L.init_norm(cfg.d_model, cfg.norm), "ffn": L.init_ffn(kf[i], cfg)}
            for i in range(cfg.n_layers)
        ]
        params["rec_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *rec) if rec else {}
        params["attn_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *att) if att else {}
        params["ffn_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *ffn)
        return params

    keys = jax.random.split(k_blocks, cfg.n_layers)
    blocks = [_init_block(keys[i], cfg) for i in range(cfg.n_layers)]
    params["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------

def _apply_block(p, x, cfg: ModelConfig, positions, cache=None, window=0):
    """Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        h, new_state = L.ssd_block(p["mixer"], L.norm(x, p["norm1"], cfg.norm), cfg, state=cache)
        return x + h, aux, new_state
    h, new_cache = L.attention(
        p["attn"], L.norm(x, p["norm1"], cfg.norm), cfg, positions, cache=cache, window=window
    )
    x = x + h
    hn = L.norm(x, p["norm2"], cfg.norm)
    if cfg.family == "moe":
        h, aux = L.moe_ffn(p["moe"], hn, cfg)
    else:
        h = L.ffn(p["ffn"], hn, cfg)
    return x + h, aux, new_cache


def _remat_policy():
    from repro.models.perf import flags

    if flags().remat_policy == "dots":
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable


def _run_stack(params, cfg: ModelConfig, x, positions, caches=None):
    """Scan homogeneous blocks. caches: stacked pytree or None.

    Returns (x, aux_total, new_caches).
    """
    window = cfg.attn_window

    def body(carry, scanned):
        h, aux = carry
        p, c = scanned
        h2, a, c2 = _apply_block(p, h, cfg, positions, cache=c, window=window)
        return (h2, aux + a), c2

    from repro.models.perf import flags as _pf

    if cfg.remat and _pf().remat_policy != "none":
        body = jax.checkpoint(body, policy=_remat_policy())

    xs = (params["blocks"], caches)
    (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, new_caches


def _run_hybrid(params, cfg: ModelConfig, x, positions, att_caches=None, rec_states=None):
    """Unrolled RG-LRU / local-attention interleave (RecurrentGemma)."""
    kinds = _hybrid_layout(cfg)
    ir = ia = 0
    new_att, new_rec = [], []
    aux = jnp.zeros((), jnp.float32)
    for li, kind in enumerate(kinds):
        fp = jax.tree.map(lambda a, _li=li: a[_li], params["ffn_blocks"])
        if kind == "rec":
            rp = jax.tree.map(lambda a, _i=ir: a[_i], params["rec_blocks"])
            st = jax.tree.map(lambda a, _i=ir: a[_i], rec_states) if rec_states is not None else None
            h, st2 = L.rglru(rp["mixer"], L.norm(x, rp["norm1"], cfg.norm), cfg, state=st)
            new_rec.append(st2)
            ir += 1
        else:
            ap = jax.tree.map(lambda a, _i=ia: a[_i], params["attn_blocks"])
            ca = jax.tree.map(lambda a, _i=ia: a[_i], att_caches) if att_caches is not None else None
            h, ca2 = L.attention(
                ap["attn"], L.norm(x, ap["norm1"], cfg.norm), cfg, positions,
                cache=ca, window=cfg.attn_window,
            )
            new_att.append(ca2)
            ia += 1
        x = x + h
        x = x + L.ffn(fp["ffn"], L.norm(x, fp["norm2"], cfg.norm), cfg)
    stack = lambda lst: jax.tree.map(lambda *xs: jnp.stack(xs), *lst) if lst and lst[0] is not None else None
    return x, aux, (stack(new_att), stack(new_rec))


# ---------------------------------------------------------------------------
# full-sequence logits (train / prefill-style)
# ---------------------------------------------------------------------------

def lm_logits(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    """tokens (B, S) -> (logits (B, S_total, Vpad), aux_loss)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:  # VLM: stub image-patch embeddings
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family == "hybrid":
        x, aux, _ = _run_hybrid(params, cfg, x, positions)
    else:
        x, aux, _ = _run_stack(params, cfg, x, positions)

    x = L.norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)
    return constrain(logits, "logits"), aux


def lm_loss(params, cfg: ModelConfig, batch):
    """batch: {"tokens","labels" (B,S)} (+ "prefix_embeds" for VLM).

    Cross-entropy over the true vocab (padded logit columns are masked),
    plus the MoE router auxiliary loss when applicable.
    """
    logits, aux = lm_logits(params, cfg, batch["tokens"], batch.get("prefix_embeds"))
    if batch.get("prefix_embeds") is not None:
        logits = logits[:, batch["prefix_embeds"].shape[1]:, :]
    labels = batch["labels"]
    vp = logits.shape[-1]
    if vp != cfg.vocab:  # mask padded vocab columns
        col = jnp.arange(vp)
        logits = jnp.where(col[None, None, :] < cfg.vocab, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, s_max: int) -> PyTree:
    if cfg.family == "ssm":
        return {"state": L.init_ssd_state(cfg, batch, cfg.n_layers), "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.family == "hybrid":
        kinds = _hybrid_layout(cfg)
        n_att = sum(k == "attn" for k in kinds)
        n_rec = cfg.n_layers - n_att
        s_window = min(s_max, cfg.attn_window) if cfg.attn_window else s_max
        return {
            "attn": L.init_attn_cache(cfg, batch, s_window, layers=n_att),
            "rec": L.init_rglru_state(cfg, batch, n_rec),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    return {"attn": L.init_attn_cache(cfg, batch, s_max), "pos": jnp.zeros((batch,), jnp.int32)}


def _head_logits(params, cfg, x):
    x = L.norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, head).astype(jnp.float32)


def lm_decode_step(params, cfg: ModelConfig, token, cache):
    """One decode step. token (B, 1) int32; cache from init_cache.

    For hybrid archs the attention cache is a ring buffer over the local
    window (cache position = pos % window); SSM archs carry O(1) state.
    Returns (logits (B, 1, Vpad), new_cache).
    """
    pos = cache["pos"]  # (B,)
    x = jnp.take(params["embed"], token, axis=0)
    positions = pos[:, None]

    if cfg.family == "ssm":
        def body(h, scanned):
            p, st = scanned
            h2, _, st2 = _apply_block(p, h, cfg, positions, cache=st)
            return h2, st2
        x, new_state = jax.lax.scan(body, x, (params["blocks"], cache["state"]))
        new_cache = {"state": new_state, "pos": pos + 1}
    elif cfg.family == "hybrid":
        kinds = _hybrid_layout(cfg)
        ir = ia = 0
        new_att_k, new_att_v, new_rec = [], [], []
        h = x
        for kind in kinds:
            if kind == "rec":
                rp = jax.tree.map(lambda a, _i=ir: a[_i], params["rec_blocks"])
                st = jax.tree.map(lambda a, _i=ir: a[_i], cache["rec"])
                o, st2 = L.rglru(rp["mixer"], L.norm(h, rp["norm1"], cfg.norm), cfg, state=st)
                new_rec.append(st2)
                ir += 1
            else:
                ap = jax.tree.map(lambda a, _i=ia: a[_i], params["attn_blocks"])
                ca = {"k": cache["attn"]["k"][ia], "v": cache["attn"]["v"][ia]}
                # ring buffer: write at pos % window; attend to all valid slots
                o, ca2 = L.attention(
                    ap["attn"], L.norm(h, ap["norm1"], cfg.norm), cfg, positions,
                    cache=ca, ring=bool(cfg.attn_window),
                )
                new_att_k.append(ca2["k"])
                new_att_v.append(ca2["v"])
                ia += 1
            h = h + o
            fp = jax.tree.map(lambda a, _li=ir + ia - 1: a[_li], params["ffn_blocks"])
            h = h + L.ffn(fp["ffn"], L.norm(h, fp["norm2"], cfg.norm), cfg)
        x = h
        new_cache = {
            "attn": {"k": jnp.stack(new_att_k), "v": jnp.stack(new_att_v)},
            "rec": jax.tree.map(lambda *xs: jnp.stack(xs), *new_rec),
            "pos": pos + 1,
        }
    else:
        from repro.models.perf import flags as _pf

        if _pf().cache_as_carry:
            # thread the WHOLE stacked cache as a scan carry: each layer
            # scatters its one new K/V row in place and reads its slice --
            # no per-layer full-slice rewrite through the ys buffer
            kc, vc = cache["attn"]["k"], cache["attn"]["v"]
            bidx = jnp.arange(kc.shape[1])

            def body(carry, scanned):
                h, kc, vc = carry
                p, l = scanned
                hn = L.norm(h, p["norm1"], cfg.norm)
                q, k1, v1 = L._qkv(p["attn"], hn, hn, cfg)
                q = L.rope(q, positions, cfg.rope_theta)
                k1 = L.rope(k1, positions, cfg.rope_theta)
                kc = kc.at[l, bidx, pos].set(k1[:, 0].astype(kc.dtype))
                vc = vc.at[l, bidx, pos].set(v1[:, 0].astype(vc.dtype))
                o = L.attend(p["attn"], q, kc[l], vc[l], positions, h.dtype,
                             decode=True, window=cfg.attn_window)
                h = h + o
                hn2 = L.norm(h, p["norm2"], cfg.norm)
                if cfg.family == "moe":
                    f, _ = L.moe_ffn(p["moe"], hn2, cfg)
                else:
                    f = L.ffn(p["ffn"], hn2, cfg)
                return (h + f, kc, vc), None

            (x, kc, vc), _ = jax.lax.scan(
                body, (x, kc, vc),
                (params["blocks"], jnp.arange(cfg.n_layers)),
            )
            new_cache = {"attn": {"k": kc, "v": vc}, "pos": pos + 1}
        else:
            def body(h, scanned):
                p, c = scanned
                h2, _, c2 = _apply_block(p, h, cfg, positions, cache=c, window=cfg.attn_window)
                return h2, c2
            h, new_kv = jax.lax.scan(body, x, (params["blocks"], cache["attn"]))
            x = h
            new_cache = {"attn": new_kv, "pos": pos + 1}

    logits = _head_logits(params, cfg, x)
    return constrain(logits, "logits"), new_cache


def lm_prefill_batch(params, cfg: ModelConfig, tokens, valid):
    """Right-padded batched prefill for the paged serving engine.

    tokens (B, S) int32 right-padded to a shared bucket length; valid (B,)
    int32 real prompt lengths. Returns (last-valid-position logits
    (B, Vpad), per-layer rope'd K/V (L, B, S, Hkv, D)) — the caller
    scatters the K/V prefix into its paged pool. Dense + MoE families only
    (causal masking makes each row's valid prefix independent of the
    padding; MoE additionally threads ``token_mask`` so pads don't consume
    expert capacity).
    """
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"lm_prefill_batch: unsupported family {cfg.family}")
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s, _ = x.shape
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    tmask = jnp.arange(s, dtype=jnp.int32)[None] < valid[:, None]
    dt = jnp.dtype(cfg.dtype)

    def body(h, p):
        hn = L.norm(h, p["norm1"], cfg.norm)
        o, _ = L.attention(p["attn"], hn, cfg, positions, window=cfg.attn_window)
        q, k, v = L._qkv(p["attn"], hn, hn, cfg)
        k = L.rope(k, positions, cfg.rope_theta)
        h = h + o
        hn2 = L.norm(h, p["norm2"], cfg.norm)
        if cfg.family == "moe":
            f, _ = L.moe_ffn(p["moe"], hn2, cfg, token_mask=tmask)
        else:
            f = L.ffn(p["ffn"], hn2, cfg)
        return h + f, {"k": k.astype(dt), "v": v.astype(dt)}

    x, kv = jax.lax.scan(body, x, params["blocks"])
    last = jnp.take_along_axis(x, (valid - 1)[:, None, None], axis=1)  # (B,1,D)
    logits = _head_logits(params, cfg, last)
    return constrain(logits, "logits")[:, 0], kv


def lm_prefill(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    """Full-sequence prefill: returns (last-position logits, filled cache).

    The cache is produced by running the full-sequence path and emitting the
    per-layer K/V (attention) or final state (SSM/RG-LRU).
    """
    x = jnp.take(params["embed"], tokens, axis=0)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    x = constrain(x, "residual")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    if cfg.family == "ssm":
        def body(carry, p):
            h = carry
            hn = L.norm(h, p["norm1"], cfg.norm)
            o, st = L.ssd_block(p["mixer"], hn, cfg, state=None)
            return h + o, st["ssm"]
        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, states = jax.lax.scan(body, x, params["blocks"])
        conv_tail = jnp.zeros(
            (cfg.n_layers, b, cfg.conv_width - 1, cfg.ssm_expand * cfg.d_model + 2 * cfg.ssm_state),
            jnp.dtype(cfg.dtype),
        )
        cache = {"state": {"ssm": states, "conv": conv_tail}, "pos": jnp.full((b,), s, jnp.int32)}
    elif cfg.family == "hybrid":
        kinds = _hybrid_layout(cfg)
        ir = ia = 0
        att_k, att_v, rec_h = [], [], []
        win = min(s, cfg.attn_window) if cfg.attn_window else s
        h = x
        for kind in kinds:
            if kind == "rec":
                rp = jax.tree.map(lambda a, _i=ir: a[_i], params["rec_blocks"])
                o, st = L.rglru(rp["mixer"], L.norm(h, rp["norm1"], cfg.norm), cfg, state=None)
                rec_h.append(st["h"])
                ir += 1
            else:
                ap = jax.tree.map(lambda a, _i=ia: a[_i], params["attn_blocks"])
                hn = L.norm(h, ap["norm1"], cfg.norm)
                o, _ = L.attention(ap["attn"], hn, cfg, positions, window=cfg.attn_window)
                # keep the last `win` K/V, laid out so abs position a sits at
                # ring slot a % win (decode writes at pos % win)
                q, k, v = L._qkv(ap["attn"], hn, hn, cfg)
                k = L.rope(k, positions, cfg.rope_theta)
                shift = s % win
                att_k.append(jnp.roll(k[:, -win:], shift, axis=1))
                att_v.append(jnp.roll(v[:, -win:], shift, axis=1))
                ia += 1
            h = h + o
            fp = jax.tree.map(lambda a, _li=ir + ia - 1: a[_li], params["ffn_blocks"])
            h = h + L.ffn(fp["ffn"], L.norm(h, fp["norm2"], cfg.norm), cfg)
        x = h
        cache = {
            "attn": {"k": jnp.stack(att_k), "v": jnp.stack(att_v)},
            "rec": {
                "h": jnp.stack(rec_h),
                "conv": jnp.zeros((ir, b, cfg.conv_width - 1, cfg.lru_width or cfg.d_model), jnp.dtype(cfg.dtype)),
            },
            "pos": jnp.full((b,), s, jnp.int32),
        }
    else:
        def body(carry, p):
            h = carry
            hn = L.norm(h, p["norm1"], cfg.norm)
            o, _ = L.attention(p["attn"], hn, cfg, positions, window=cfg.attn_window)
            q, k, v = L._qkv(p["attn"], hn, hn, cfg)
            k = L.rope(k, positions, cfg.rope_theta)
            h = h + o
            hn2 = L.norm(h, p["norm2"], cfg.norm)
            if cfg.family == "moe":
                f, _ = L.moe_ffn(p["moe"], hn2, cfg)
            else:
                f = L.ffn(p["ffn"], hn2, cfg)
            return h + f, {"k": k.astype(jnp.dtype(cfg.dtype)), "v": v.astype(jnp.dtype(cfg.dtype))}
        if cfg.remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, kv = jax.lax.scan(body, x, params["blocks"])
        cache = {"attn": kv, "pos": jnp.full((b,), s, jnp.int32)}

    logits = _head_logits(params, cfg, x[:, -1:, :])
    return constrain(logits, "logits"), cache
