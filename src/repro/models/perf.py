"""Performance variant flags (the §Perf hillclimb switchboard).

The paper-faithful baseline runs with all flags False/None. Each hillclimb
iteration toggles one flag; `repro.launch.dryrun --flags f1,f2` compiles
the same cell with those flags and records the roofline delta under a
variant tag. Flags are a context-var so they bake in at trace time.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses


@dataclasses.dataclass(frozen=True)
class PerfFlags:
    # attention: keep operands bf16 and accumulate in f32 inside the MXU
    # instead of materializing f32 copies of Q/K/V (decode reads the whole
    # KV cache -- the f32 convert doubles its HBM traffic)
    bf16_accum_attention: bool = False
    # decode cache append via scatter (in-place, slice-sized) instead of the
    # one-hot full-slice rewrite; legal when the cache's sequence dim is
    # unsharded (kv-heads carry the model axis)
    scatter_cache_update: bool = False
    # decode: thread the stacked KV cache as a scan CARRY (in-place scatter
    # + slice reads) instead of xs->ys (which copies a full layer slice per
    # step). Implies scatter updates; same sharding legality condition.
    cache_as_carry: bool = False
    # SSD intra-chunk quadratic tensors: smaller chunks / bf16 decay math
    ssd_chunk_override: int = 0
    ssd_bf16_intra: bool = False
    # flash attention: bigger KV blocks (fewer accumulator round-trips)
    flash_block_kv: int = 0
    # decode scores in bf16 end-to-end (XLA:CPU materializes the GEMV
    # broadcast-product; bf16 halves it). Numerics: scores rounded to bf16
    # before softmax -- decode-only experiment
    attn_bf16_scores: bool = False
    # MoE: capacity factor override (dispatch tensor size ~ capacity)
    moe_capacity_override: float = 0.0
    # remat policy: "" = nothing_saveable (max recompute, min memory);
    # "dots" = dots_saveable (skip recomputing matmuls in backward at the
    # cost of keeping their outputs resident)
    remat_policy: str = ""
    # drop sequence-parallel residual sharding (batch-only): for SSM archs
    # the inter-chunk associative scan otherwise spans shards and GSPMD
    # lowers it into a storm of tiny cross-shard permutes
    no_sp_residual: bool = False
    # drop the explicit 2-D sharding constraint on the square-matricized
    # momentum (let GSPMD propagate through the reshape instead)
    smmf_no_constraint: bool = False
    # drop ONLY the "opt_update_row" replicated boundary pin (the smmf_*
    # state constraints stay): the A/B hatch that reproduces the XLA
    # concatenate-partitioning miscompile on override-sharded groups
    # (tests/_concat_probe_child.py) — the behavior probe behind the
    # version-gated guard retirement in distributed/rules.py
    no_opt_boundary: bool = False
    # row-parallel matmul partial sums reduced in bf16 (halves the TP
    # all-reduce bytes; numerics note in EXPERIMENTS.md)
    bf16_rowparallel_reduce: bool = False
    # MoE (indivisible expert count): shard expert activations on the
    # CAPACITY axis so GSPMD gathers the (small) F-sharded expert weights
    # instead of the (huge) token tensor
    moe_cap_sharding: bool = False
    # cast the dispatched token tensor / expert activations to the model
    # dtype before they cross the wire (default einsum output is f32)
    moe_bf16_dispatch: bool = False
    # pack factor P: store expert FFNs as (E*P, D, F/P) so the expert axis
    # divides the model axis (grok: 8 experts * P=2 = 16) -> fully local
    # expert matmuls + one tiny pair-sum reduction; the only big collective
    # left is the token all-to-all
    moe_expert_pack: int = 0


_FLAGS: contextvars.ContextVar[PerfFlags] = contextvars.ContextVar("perf_flags", default=PerfFlags())


def flags() -> PerfFlags:
    return _FLAGS.get()


@contextlib.contextmanager
def perf_flags(**kw):
    tok = _FLAGS.set(PerfFlags(**kw))
    try:
        yield
    finally:
        _FLAGS.reset(tok)


def parse_flags(spec: str) -> dict:
    """'bf16_accum_attention,ssd_chunk_override=128' -> kwargs dict."""
    out: dict = {}
    if not spec:
        return out
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            k, v = part.split("=", 1)
            cur = getattr(PerfFlags(), k)
            if isinstance(cur, bool):
                out[k] = v.lower() in ("1", "true")
            elif isinstance(cur, float):
                out[k] = float(v)
            elif isinstance(cur, str):
                out[k] = v
            else:
                out[k] = int(v)
        else:
            out[part] = True
    return out
