"""repro.obs: unified telemetry — in-jit counters, events/spans, metrics.

Three layers (``docs/observability.md``):

* :mod:`repro.obs.jit` — :class:`TelemetryCollector` + scalar reductions
  for the opt-in ``telemetry=`` knob on the jitted train step (per-bucket
  update-RMS, quant clip-saturation / requant error, transport round-trip
  error / rank-1 flushes, NaN-guard trips) riding out as a metrics pytree.
* :mod:`repro.obs.registry` / :mod:`repro.obs.trace` — host-side
  :class:`MetricsRegistry` (counters / gauges / fixed-bucket histograms)
  and :class:`EventLog` structured events with ``span()`` phase timing,
  JSONL-backed.
* :mod:`repro.obs.export` — JSONL <-> Chrome ``trace_event`` (Perfetto)
  conversion and metrics snapshots, consumed by
  ``tools/metrics_report.py``.

Everything is stdlib + jax-only and strictly opt-in: with no collector,
no log path, and echo left on, instrumented code behaves exactly as
before (bitwise-identical step outputs, unchanged CLI output).
"""

from repro.obs.export import (
    chrome_trace,
    read_jsonl,
    write_chrome_trace,
    write_metrics,
)
from repro.obs.jit import TelemetryCollector, clip_saturation, rel_error, rms
from repro.obs.registry import (
    DEFAULT_BUCKETS,
    Histogram,
    MetricsRegistry,
    get_registry,
)
from repro.obs.trace import EventLog, NullEventLog

__all__ = [
    "DEFAULT_BUCKETS",
    "EventLog",
    "Histogram",
    "MetricsRegistry",
    "NullEventLog",
    "TelemetryCollector",
    "chrome_trace",
    "clip_saturation",
    "get_registry",
    "read_jsonl",
    "rel_error",
    "rms",
    "write_chrome_trace",
    "write_metrics",
]
