"""Exporters: JSONL event logs -> Chrome trace_event / metrics dumps.

``chrome_trace(records)`` converts :class:`~repro.obs.trace.EventLog`
records into the Chrome ``trace_event`` JSON format (the subset Perfetto
and ``chrome://tracing`` both load): spans become complete ``"X"`` slices
with microsecond timestamps, instantaneous events become ``"i"`` instants.
``write_chrome_trace`` / ``read_jsonl`` are the file-shaped halves used by
``launch/{train,serve}.py --metrics-dir`` and ``tools/metrics_report.py``.

Record-to-slice mapping (``docs/observability.md`` has the schema):

* span ``{"t": s, "dur_ms": d, "name": n, ...}`` ->
  ``{"ph": "X", "ts": s*1e6, "dur": d*1e3, "name": n, "args": {...}}``
* event ``{"t": s, "name": n, ...}`` ->
  ``{"ph": "i", "ts": s*1e6, "s": "p", "name": n, "args": {...}}``

``pid``/``tid`` default to the record's ``pid``/``track`` fields when
present (serving uses per-request tracks) and 0 otherwise.
"""

from __future__ import annotations

import json
import os

_META_KEYS = ("t", "kind", "name", "dur_ms", "pid", "track")


def chrome_trace(records: list[dict]) -> dict:
    """Convert event-log records to a Chrome trace_event document."""
    events = []
    for rec in records:
        args = {k: v for k, v in rec.items() if k not in _META_KEYS}
        ts_us = float(rec.get("t", 0.0)) * 1e6
        base = {
            "name": rec.get("name", "?"),
            "ts": ts_us,
            "pid": int(rec.get("pid", 0)),
            "tid": int(rec.get("track", 0)),
            "args": args,
        }
        if rec.get("kind") == "span":
            events.append({**base, "ph": "X",
                           "dur": float(rec.get("dur_ms", 0.0)) * 1e3})
        else:
            events.append({**base, "ph": "i", "s": "p"})
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(records: list[dict], path: str | os.PathLike) -> str:
    """Write ``records`` as a Perfetto-loadable trace JSON; returns path."""
    path = os.fspath(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace(records), f)
    return path


def read_jsonl(path: str | os.PathLike) -> list[dict]:
    """Load an EventLog JSONL file back into records (skips blank lines)."""
    out = []
    with open(os.fspath(path)) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def write_metrics(snapshot: dict, path: str | os.PathLike) -> str:
    """Dump a ``MetricsRegistry.snapshot()`` as pretty JSON; returns path."""
    path = os.fspath(path)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
