"""In-jit telemetry: a trace-time collector + cheap scalar reductions.

The hot-path half of the telemetry subsystem. A
:class:`TelemetryCollector` is a plain Python dict filled **while
tracing** a jitted step: instrumented call sites (``optim/spec.py``,
``optim/qstate.py``, ``distributed/transport.py``, ``launch/steps.py``)
call ``collector.record(name, scalar)`` with a traced f32 scalar, and the
step returns ``collector.asdict()`` as one extra entry of its metrics
pytree. The reductions ride the existing device->host metrics transfer —
no host callbacks, no extra syncs, no effect on the update math.

Strictly opt-in: every instrumented site takes ``telemetry=None`` and is
a no-op (bitwise-identical output, asserted in
``tests/test_telemetry_step.py``) when no collector is passed. The knob is
execution-only — it is excluded from ``OptimizerSpec.spec_hash`` like
``use_kernel``/``transport``, so flipping it never invalidates a
checkpoint.

Naming convention (``docs/observability.md``): '/'-separated paths,
``<subsystem>/<metric>/<bucket key>[ / s<slot index>]``, e.g.
``optim/update_rms/fac:(512, 512)x10``, ``qstate/clip_sat/fac:...x10/s1``,
``transport/rt_err/fac:...x10``.
"""

from __future__ import annotations

import jax.numpy as jnp


class TelemetryCollector:
    """Trace-time sink for scalar telemetry riding out of a jitted step.

    Create a **fresh instance inside the traced function body** (one per
    trace — reusing a collector across traces would leak tracers). Keys
    must be unique per step; a duplicate means two call sites chose the
    same name, which is a bug, not data to silently average.
    """

    def __init__(self):
        self._vals: dict = {}

    def record(self, name: str, value) -> None:
        """Record one named f32 scalar (reduces anything array-shaped)."""
        if name in self._vals:
            raise ValueError(f"duplicate telemetry key {name!r}")
        v = jnp.asarray(value)
        if v.ndim:
            v = jnp.mean(v)
        self._vals[name] = v.astype(jnp.float32)

    def add(self, name: str, value) -> None:
        """Accumulate into a named scalar (for counters summed across call
        sites, e.g. rank-1 flush count over buckets)."""
        v = jnp.asarray(value)
        if v.ndim:
            v = jnp.sum(v)
        v = v.astype(jnp.float32)
        self._vals[name] = self._vals.get(name, jnp.float32(0)) + v

    def asdict(self) -> dict:
        """The collected {name: f32 scalar} dict — return this from the
        jitted step as ``metrics["telemetry"]``."""
        return dict(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def __contains__(self, name: str) -> bool:
        return name in self._vals


# -- reduction helpers (all O(numel) elementwise + one reduce, f32 scalar) --

def rms(x) -> jnp.ndarray:
    """Root-mean-square of ``x`` in f32."""
    x = jnp.asarray(x, jnp.float32)
    return jnp.sqrt(jnp.mean(jnp.square(x)))


def clip_saturation(q, qmax) -> jnp.ndarray:
    """Fraction of quantized payload entries pinned at the clip boundary
    (|q| >= qmax). Rising saturation means the quantizer's dynamic range no
    longer covers the slot distribution — the leading indicator of the PR 5
    linear-int8 divergence."""
    q = jnp.asarray(q)
    if jnp.issubdtype(q.dtype, jnp.integer):
        mag = jnp.abs(q.astype(jnp.float32))
    else:  # fp8 payloads compare in f32
        mag = jnp.abs(q.astype(jnp.float32))
    return jnp.mean((mag >= jnp.float32(qmax)).astype(jnp.float32))


def rel_error(ref, approx) -> jnp.ndarray:
    """Relative L2 error ||approx - ref|| / (||ref|| + eps) in f32 — the
    requant / transport round-trip error measure."""
    ref = jnp.asarray(ref, jnp.float32)
    approx = jnp.asarray(approx, jnp.float32)
    num = jnp.sqrt(jnp.sum(jnp.square(approx - ref)))
    den = jnp.sqrt(jnp.sum(jnp.square(ref))) + jnp.float32(1e-30)
    return num / den
