"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

The host-side half of the telemetry subsystem (``docs/observability.md``).
Everything here is plain Python on the host — metric updates happen at
admission/retire/checkpoint boundaries and after each step's device_get,
never inside a jitted function (the in-jit half lives in
``repro.obs.jit`` and rides *out* of the step as an extra metrics pytree).

Three instrument kinds, Prometheus-shaped but dependency-free:

* **counter** — monotone float; ``inc(name, v)``. Straggler steps, NaN-skip
  steps, admission deferrals, tokens emitted.
* **gauge** — last-write-wins float; ``set(name, v)``. Queue depth,
  page-pool utilization, per-step loss.
* **histogram** — fixed bucket boundaries chosen at first observation
  (:data:`DEFAULT_BUCKETS` or per-call); tracks per-bucket counts plus
  exact ``count/sum/min/max`` so tests can check the recorded population
  against independently-tracked samples (monotone consistency: ``min <=
  sum/count <= max`` and quantiles are non-decreasing in ``q``).

``snapshot()`` returns a plain-JSON dict (stable key order) — the thing
``engine.metrics()`` and the ``--metrics-dir`` dumps expose; ``merge()``
folds another registry's instruments in (used by run summarizers, never on
a hot path).
"""

from __future__ import annotations

import dataclasses
import math
import threading

# Default histogram boundaries: exponential ms-scale grid covering sub-ms
# jit dispatch up to multi-minute stragglers. Fixed (not adaptive) so two
# runs' histograms are mergeable bucket-for-bucket.
DEFAULT_BUCKETS = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0,
    250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0, 30000.0, 120000.0,
)


@dataclasses.dataclass
class Histogram:
    """Fixed-boundary histogram with exact count/sum/min/max sidecars.

    ``boundaries`` are upper-inclusive bucket edges; observations above the
    last edge land in the implicit overflow bucket (``counts`` has
    ``len(boundaries) + 1`` entries).
    """

    boundaries: tuple[float, ...] = DEFAULT_BUCKETS
    counts: list[int] = None
    count: int = 0
    sum: float = 0.0
    min: float = math.inf
    max: float = -math.inf

    def __post_init__(self):
        self.boundaries = tuple(float(b) for b in self.boundaries)
        if list(self.boundaries) != sorted(set(self.boundaries)):
            raise ValueError(f"histogram boundaries must be strictly "
                             f"increasing, got {self.boundaries}")
        if self.counts is None:
            self.counts = [0] * (len(self.boundaries) + 1)

    def observe(self, value: float) -> None:
        v = float(value)
        i = 0
        for b in self.boundaries:
            if v <= b:
                break
            i += 1
        self.counts[i] += 1
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile estimate (upper edge of the bucket the
        q-th observation falls in; exact ``max`` for the overflow bucket).
        Returns NaN on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile wants q in [0, 1], got {q}")
        if self.count == 0:
            return math.nan
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                if i < len(self.boundaries):
                    return self.boundaries[i]
                return self.max
        return self.max

    def mean(self) -> float:
        return self.sum / self.count if self.count else math.nan

    def to_dict(self) -> dict:
        return {
            "boundaries": list(self.boundaries),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": None if self.count == 0 else self.min,
            "max": None if self.count == 0 else self.max,
        }


class MetricsRegistry:
    """One process-local bag of named counters/gauges/histograms.

    Thread-safe (a serving engine's caller may poll ``snapshot()`` from
    another thread); by convention metric names are '/'-separated paths
    with a subsystem prefix (``train/...``, ``serve/...``, ``optim/...``).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._histograms: dict[str, Histogram] = {}

    # -- instruments ---------------------------------------------------------

    def inc(self, name: str, value: float = 1.0) -> float:
        """Add ``value`` (>= 0) to counter ``name``; returns the new total."""
        v = float(value)
        if v < 0:
            raise ValueError(f"counter {name!r}: negative increment {v}")
        with self._lock:
            total = self._counters.get(name, 0.0) + v
            self._counters[name] = total
        return total

    def set(self, name: str, value: float) -> None:
        """Set gauge ``name`` to ``value`` (last write wins)."""
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float,
                buckets: tuple[float, ...] | None = None) -> None:
        """Record one observation into histogram ``name`` (created on first
        use with ``buckets`` or :data:`DEFAULT_BUCKETS`)."""
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = Histogram(buckets if buckets is not None else DEFAULT_BUCKETS)
                self._histograms[name] = h
            h.observe(value)

    # -- reads ---------------------------------------------------------------

    def counter(self, name: str) -> float:
        return self._counters.get(name, 0.0)

    def gauge(self, name: str) -> float | None:
        return self._gauges.get(name)

    def histogram(self, name: str) -> Histogram | None:
        return self._histograms.get(name)

    def snapshot(self) -> dict:
        """Plain-JSON view of every instrument (sorted keys, stable)."""
        with self._lock:
            return {
                "counters": dict(sorted(self._counters.items())),
                "gauges": dict(sorted(self._gauges.items())),
                "histograms": {k: h.to_dict()
                               for k, h in sorted(self._histograms.items())},
            }

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold ``other`` in: counters add, gauges last-write-win, same-name
        histograms must share boundaries and merge bucket-for-bucket."""
        snap = other.snapshot()
        for k, v in snap["counters"].items():
            self.inc(k, v)
        for k, v in snap["gauges"].items():
            self.set(k, v)
        with self._lock:
            for k, hd in snap["histograms"].items():
                h = self._histograms.setdefault(
                    k, Histogram(tuple(hd["boundaries"])))
                if list(h.boundaries) != hd["boundaries"]:
                    raise ValueError(
                        f"histogram {k!r}: boundary mismatch on merge")
                h.counts = [a + b for a, b in zip(h.counts, hd["counts"])]
                h.count += hd["count"]
                h.sum += hd["sum"]
                if hd["count"]:
                    h.min = min(h.min, hd["min"])
                    h.max = max(h.max, hd["max"])

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# Process-default registry: the launchers' structured events and the train
# loop bind to this unless handed an explicit registry (tests construct
# their own to stay isolated).
_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-default registry (module-level singleton)."""
    return _DEFAULT
