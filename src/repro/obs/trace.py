"""Structured events and span tracing for train/serve phases.

The :class:`EventLog` replaces bare ``print`` status lines in the launchers
with structured records that are simultaneously (a) echoed to stdout in the
familiar ``[train] ...`` form so CLI behavior is unchanged, (b) appended to
a JSONL file when a ``--metrics-dir`` is given, and (c) kept in a bounded
in-memory ring for tests and the run summarizer.

Two record kinds share one schema (``docs/observability.md``):

* **event** — instantaneous: ``{"t": <unix s>, "kind": "event",
  "name": ..., **fields}``.
* **span** — a phase with a duration: emitted once at exit as
  ``{"t": <start>, "kind": "span", "name": ..., "dur_ms": ..., **fields}``.
  Spans are what the Chrome-trace exporter (``repro.obs.export``) turns
  into Perfetto ``X`` slices; they also feed ``<name>_ms`` histograms in
  the attached :class:`~repro.obs.registry.MetricsRegistry` so phase
  timings are queryable without parsing the log.

Timestamps come from ``time.time()`` (wall clock, JSON-friendly) plus a
``time.perf_counter()`` base for durations; nothing here touches JAX.
"""

from __future__ import annotations

import collections
import contextlib
import json
import os
import threading
import time

from .registry import MetricsRegistry, get_registry

# Span-duration histograms use the registry's default ms grid; ring size
# bounds memory for long serve runs that never dump to disk.
_RING_MAX = 4096


class EventLog:
    """Structured event sink: stdout echo + optional JSONL file + ring.

    ``tag`` is the stdout prefix (``[train]``, ``[serve]``); ``path`` the
    JSONL file (appended, created eagerly so an interrupted run still
    leaves a valid log); ``registry`` receives ``<span>_ms`` histogram
    observations and an ``obs/events`` counter.
    """

    def __init__(self, tag: str = "obs", path: str | os.PathLike | None = None,
                 echo: bool = True,
                 registry: MetricsRegistry | None = None):
        self.tag = tag
        self.echo = echo
        self.registry = registry if registry is not None else get_registry()
        self._lock = threading.Lock()
        self._ring: collections.deque = collections.deque(maxlen=_RING_MAX)
        self._path = os.fspath(path) if path is not None else None
        self._fh = None
        if self._path is not None:
            os.makedirs(os.path.dirname(self._path) or ".", exist_ok=True)
            self._fh = open(self._path, "a", buffering=1)

    # -- core ---------------------------------------------------------------

    def _write(self, rec: dict) -> None:
        with self._lock:
            self._ring.append(rec)
            if self._fh is not None:
                self._fh.write(json.dumps(rec, sort_keys=True,
                                          default=_jsonable) + "\n")
        self.registry.inc("obs/events")

    def event(self, name: str, message: str | None = None, **fields) -> None:
        """Emit an instantaneous event; ``message`` (or the fields) echoes
        to stdout as ``[tag] message``."""
        rec = {"t": time.time(), "kind": "event", "name": name, **fields}
        if message is not None:
            rec["message"] = message
        self._write(rec)
        if self.echo:
            body = message if message is not None else _kv(fields)
            print(f"[{self.tag}] {body}" if body else f"[{self.tag}] {name}",
                  flush=True)

    @contextlib.contextmanager
    def span(self, name: str, echo: bool = False, **fields):
        """Time a phase; yields a dict whose entries are folded into the
        span record at exit (annotate mid-phase: ``s["tokens"] = n``)."""
        t0_wall = time.time()
        t0 = time.perf_counter()
        extra: dict = {}
        try:
            yield extra
        finally:
            dur_ms = (time.perf_counter() - t0) * 1e3
            rec = {"t": t0_wall, "kind": "span", "name": name,
                   "dur_ms": dur_ms, **fields, **extra}
            self._write(rec)
            self.registry.observe(f"{name}_ms", dur_ms)
            if echo and self.echo:
                print(f"[{self.tag}] {name}: {dur_ms:.1f} ms" +
                      (f" {_kv({**fields, **extra})}" if fields or extra else ""),
                      flush=True)

    # -- reads / lifecycle --------------------------------------------------

    def records(self) -> list[dict]:
        with self._lock:
            return list(self._ring)

    @property
    def path(self) -> str | None:
        return self._path

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def _kv(fields: dict) -> str:
    return " ".join(f"{k}={_fmt(v)}" for k, v in fields.items())


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.4g}"
    return v


def _jsonable(v):
    # numpy / jax scalars arrive from device_get'd metrics; coerce rather
    # than crash the log write.
    try:
        return float(v)
    except (TypeError, ValueError):
        return repr(v)


class NullEventLog(EventLog):
    """An EventLog that drops everything (no echo, no file, no registry
    traffic) — the default for library call sites so telemetry stays
    strictly opt-in."""

    def __init__(self):
        super().__init__(tag="null", path=None, echo=False,
                        registry=MetricsRegistry())

    def _write(self, rec: dict) -> None:  # keep the ring for debuggability
        with self._lock:
            self._ring.append(rec)
