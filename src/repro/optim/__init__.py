"""Optimizer package: the optax-style base protocol, the bucketed leaf-plan
engine, the family registry, and the declarative ``OptimizerSpec``
construction API (``build_optimizer``). The per-family constructors
(adam/adamw, adafactor, came, sm3, sgd here; smmf in ``repro.core.smmf``)
are deprecation shims over specs."""

from repro.optim.adafactor import adafactor
from repro.optim.adam import adam, adamw
from repro.optim.base import (
    EngineState,
    GradientTransformation,
    apply_updates,
    chain,
    clip_by_global_norm,
    optimizer_state_bytes,
)
from repro.optim.came import came
from repro.optim.engine import LeafPlanEngine, engine_stats
from repro.optim.families import Family, family_names, get_family, register
from repro.optim.sgd import sgd
from repro.optim.sm3 import sm3
from repro.optim.spec import (
    OptimizerSpec,
    Partition,
    build_optimizer,
    parse_rule,
    state_bytes_by_group,
)

__all__ = [
    "LeafPlanEngine",
    "engine_stats",
    "EngineState",
    "GradientTransformation",
    "OptimizerSpec",
    "Partition",
    "build_optimizer",
    "parse_rule",
    "state_bytes_by_group",
    "Family",
    "family_names",
    "get_family",
    "register",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "optimizer_state_bytes",
    "adam",
    "adamw",
    "adafactor",
    "came",
    "sgd",
    "sm3",
]
