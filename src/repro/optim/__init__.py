"""Optimizer package: the optax-style base protocol, the bucketed leaf-plan
engine, and the SMMF-paper baseline family (adam/adamw, adafactor, came,
sm3, sgd). The SMMF optimizer itself lives in ``repro.core.smmf``."""

from repro.optim.adafactor import adafactor
from repro.optim.adam import adam, adamw
from repro.optim.base import (
    GradientTransformation,
    apply_updates,
    chain,
    clip_by_global_norm,
    optimizer_state_bytes,
)
from repro.optim.came import came
from repro.optim.engine import LeafPlanEngine, engine_stats
from repro.optim.sgd import sgd
from repro.optim.sm3 import sm3

__all__ = [
    "LeafPlanEngine",
    "engine_stats",
    "GradientTransformation",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "optimizer_state_bytes",
    "adam",
    "adamw",
    "adafactor",
    "came",
    "sgd",
    "sm3",
]
