"""Helper: map a per-leaf function over several state trees safely.

Optimizer states may store a *subtree* (e.g. a tuple of per-axis accumulators)
per parameter leaf. ``multimap`` flattens against the params/grads treedef and
returns one output tree per output of ``fn`` — no is_leaf ambiguity.

Used by the dense per-leaf optimizers (adam, sgd). The factored optimizers
(smmf, adafactor, came, sm3) run on the bucketed leaf-plan engine instead
(``repro.optim.engine``), which replaces the per-leaf loop with one stacked
launch per same-geometry bucket.
"""

from __future__ import annotations

import jax


def multimap(fn, ref_tree, *trees, nout: int):
    """Map ``fn(ref_leaf, *state_leaves) -> nout-tuple`` over ``ref_tree``'s
    structure, returning ``nout`` trees (state trees may hold subtrees per
    ref leaf — they are flattened up to the ref treedef)."""
    flat_ref, treedef = jax.tree.flatten(ref_tree)
    flats = [treedef.flatten_up_to(t) for t in trees]
    results = [fn(r, *(f[i] for f in flats)) for i, r in enumerate(flat_ref)]
    return tuple(treedef.unflatten([res[k] for res in results]) for k in range(nout))
