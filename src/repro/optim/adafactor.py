"""Adafactor baseline (Shazeer & Stern 2018), faithful to the paper's setup.

Factors the second moment of every rank>=2 tensor over its *last two* axes
(slicing leading axes, as the SMMF paper describes for CNNs / stacked experts:
memory O(prod_{r<d-1} n_r * (n_{d-1}+n_d))). Rank<=1 tensors keep a full
second moment. First moment is optional (the SMMF paper runs Adafactor with
beta1=0.9, so we default it on to match their comparisons).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation, as_schedule


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    m: dict      # first moment (full) or size-0 placeholder
    vr: dict     # row statistics  (..., n_{d-1})
    vc: dict     # col statistics  (..., n_d)
    vfull: dict  # full second moment for rank<=1 leaves, else size-0


_EMPTY = lambda: jnp.zeros((0,), jnp.float32)


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def adafactor(
    lr=1e-3,
    beta1: float | None = 0.9,
    decay_rate: float = -0.8,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    lr_fn = as_schedule(lr)
    factored = lambda p: p.ndim >= 2

    def init(params):
        def mk(p):
            m = jnp.zeros(p.shape, jnp.float32) if beta1 is not None else _EMPTY()
            if factored(p):
                vr = jnp.zeros(p.shape[:-1], jnp.float32)
                vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                vfull = _EMPTY()
            else:
                vr, vc = _EMPTY(), _EMPTY()
                vfull = jnp.zeros(p.shape, jnp.float32)
            return m, vr, vc, vfull

        m, vr, vc, vfull = multimap(mk, params, nout=4)
        return AdafactorState(jnp.zeros((), jnp.int32), m, vr, vc, vfull)

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2t = 1.0 - jnp.power(t, decay_rate)
        lr_t = lr_fn(step)

        def upd(g, m, vr, vc, vfull, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            g2 = g * g + eps1
            if factored(p):
                vr2 = beta2t * vr + (1 - beta2t) * jnp.mean(g2, axis=-1)
                vc2 = beta2t * vc + (1 - beta2t) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr2, axis=-1, keepdims=True)
                vhat = vr2[..., :, None] * vc2[..., None, :] / (denom[..., None] + eps1)
                vfull2 = vfull
            else:
                vfull2 = beta2t * vfull + (1 - beta2t) * g2
                vhat = vfull2
                vr2, vc2 = vr, vc
            u = g / jnp.sqrt(vhat + eps1)
            u = u / jnp.maximum(1.0, _rms(u) / clip_threshold)  # update clipping, d=1.0
            if beta1 is not None:
                m2 = beta1 * m + (1 - beta1) * u
                u = m2
            else:
                m2 = m
            return -lr_t * u, m2, vr2, vc2, vfull2

        updates, m, vr, vc, vfull = multimap(
            upd, grads, state.m, state.vr, state.vc, state.vfull, params, nout=5
        )
        return updates, AdafactorState(step, m, vr, vc, vfull)

    return GradientTransformation(init, update)
