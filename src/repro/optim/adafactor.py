"""Adafactor baseline (Shazeer & Stern 2018), faithful to the paper's setup.

Factors the second moment of every rank>=2 tensor over its *last two* axes
(slicing leading axes, as the SMMF paper describes for CNNs / stacked
experts: memory O(prod_{r<d-1} n_r * (n_{d-1}+n_d))). Rank<=1 tensors keep
a full second moment. First moment is optional (the SMMF paper runs
Adafactor with beta1=0.9, so we default it on to match their comparisons).

The math lives in the family registry (``repro.optim.families``, entry
``"adafactor"``) and runs on the bucketed leaf-plan engine. The per-leaf
RMS update clip is **segment-aware**, so the dense rank<=1 fallback may be
flat-fused into one launch per (group, dtype) — a registry capability
(``fuse_dense_ok``) that used to be smmf-only; it defaults off here to keep
the per-geometry ``dense:NUM`` state layout, enable with
``hyperparams={"fuse_dense": True}``. :func:`adafactor` below is a
deprecation shim building the equivalent single-group ``OptimizerSpec``.
"""

from __future__ import annotations

import warnings

from repro.optim.base import GradientTransformation


def adafactor(
    lr=1e-3,
    beta1: float | None = 0.9,
    decay_rate: float = -0.8,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    bucket: bool = True,
) -> GradientTransformation:
    """Deprecated shim: Adafactor on the leaf-plan engine. Prefer
    ``build_optimizer(OptimizerSpec(family="adafactor", ...))``."""
    from repro.optim.spec import OptimizerSpec, build_optimizer

    warnings.warn(
        "adafactor(...) is deprecated; build via repro.optim.spec."
        "OptimizerSpec (family='adafactor') + build_optimizer",
        DeprecationWarning, stacklevel=2)
    hp = dict(lr=lr, beta1=beta1, decay_rate=decay_rate, eps1=eps1, eps2=eps2,
              clip_threshold=clip_threshold, weight_decay=weight_decay,
              bucket=bucket)
    return build_optimizer(OptimizerSpec(family="adafactor", hyperparams=hp))
