"""Adafactor baseline (Shazeer & Stern 2018), faithful to the paper's setup.

Factors the second moment of every rank>=2 tensor over its *last two* axes
(slicing leading axes, as the SMMF paper describes for CNNs / stacked experts:
memory O(prod_{r<d-1} n_r * (n_{d-1}+n_d))). Rank<=1 tensors keep a full
second moment. First moment is optional (the SMMF paper runs Adafactor with
beta1=0.9, so we default it on to match their comparisons).

Runs on the leaf-plan engine (repro.optim.engine): same-shape rank>=2 leaves
are stacked into one (K, ...) bucket and updated with a single vectorized
launch; rank<=1 leaves bucket by element count. The RMS update clip stays
*per leaf* (reduced over all but the stack axis). State per bucket:

  factors["fac:SHAPE"]  = (m (K, *shape)?, vr (K, *shape[:-1]),
                           vc (K, *shape[:-2] + shape[-1:]))
  factors["dense:NUM"]  = (m (K, NUM)?, vfull (K, NUM))

(the m slot is present iff beta1 is not None).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.plan import lasttwo_planner
from repro.optim.base import GradientTransformation, as_schedule
from repro.optim.engine import LeafPlanEngine


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    factors: dict  # bucket key -> stacked moment tuple (see module doc)


def _rms(x):
    """Per-leaf RMS: reduced over all but the leading stack axis."""
    axes = tuple(range(1, x.ndim))
    return jnp.sqrt(jnp.mean(jnp.square(x), axis=axes, keepdims=True) + 1e-30)


def adafactor(
    lr=1e-3,
    beta1: float | None = 0.9,
    decay_rate: float = -0.8,
    eps1: float = 1e-30,
    eps2: float = 1e-3,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    bucket: bool = True,
) -> GradientTransformation:
    """Adafactor on the leaf-plan engine (see module docstring). Dense
    rank<=1 leaves keep per-geometry buckets — the per-leaf RMS update clip
    reduces over each leaf, so they cannot legally be flat-fused."""
    lr_fn = as_schedule(lr)
    plan_fn = lasttwo_planner()

    def plan(params) -> LeafPlanEngine:
        """Static leaf-plan engine for ``params`` (see LeafPlanEngine)."""
        return LeafPlanEngine(params, plan_fn, bucket=bucket)

    def init(params):
        engine = plan(params)
        factors = {}
        for bk in engine.buckets:
            k = bk.size
            if bk.factorized:
                shape = bk.geometry
                vr = jnp.zeros((k,) + shape[:-1], jnp.float32)
                vc = jnp.zeros((k,) + shape[:-2] + shape[-1:], jnp.float32)
                second = (vr, vc)
            else:
                second = (jnp.zeros((k,) + bk.geometry, jnp.float32),)
            if beta1 is not None:
                m = jnp.zeros((k,) + bk.geometry, jnp.float32)
                factors[bk.key] = (m,) + second
            else:
                factors[bk.key] = second
        return AdafactorState(jnp.zeros((), jnp.int32), factors)

    def update(grads, state, params):
        engine = plan(params)
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta2t = 1.0 - jnp.power(t, decay_rate)
        lr_t = lr_fn(step)

        flat_g = engine.leaves(grads)
        if weight_decay:
            flat_p = engine.leaves(params)
            flat_g = [g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
                      for g, p in zip(flat_g, flat_p)]

        out_flat: list = [None] * len(flat_g)
        factors = {}
        for bk in engine.buckets:
            fac = state.factors[bk.key]
            m = fac[0] if beta1 is not None else None
            g = engine.gather(flat_g, bk)  # (K, *geometry)
            g2 = g * g + eps1
            if bk.factorized:
                vr, vc = fac[-2:]
                vr2 = beta2t * vr + (1 - beta2t) * jnp.mean(g2, axis=-1)
                vc2 = beta2t * vc + (1 - beta2t) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr2, axis=-1, keepdims=True)
                vhat = vr2[..., :, None] * vc2[..., None, :] / (denom[..., None] + eps1)
                second = (vr2, vc2)
            else:
                vfull2 = beta2t * fac[-1] + (1 - beta2t) * g2
                vhat = vfull2
                second = (vfull2,)
            u = g / jnp.sqrt(vhat + eps1)
            u = u / jnp.maximum(1.0, _rms(u) / clip_threshold)  # update clipping, d=1.0
            if beta1 is not None:
                m2 = beta1 * m + (1 - beta1) * u
                u = m2
                factors[bk.key] = (m2,) + second
            else:
                factors[bk.key] = second
            engine.scatter(bk, -lr_t * u, out_flat)

        return engine.unflatten(out_flat), AdafactorState(step, factors)

    return GradientTransformation(init, update, plan=plan)
