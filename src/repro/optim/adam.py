"""Adam / AdamW baselines (Kingma & Ba 2014; Loshchilov & Hutter 2019).

The math now lives in the family registry (``repro.optim.families``, entry
``"adam"``) and runs on the bucketed leaf-plan engine: every leaf is a
dense ``(numel,)`` plan, same-size leaves stack, and — the math being
purely elementwise — the whole dense set flat-fuses into one launch per
(group, dtype). The constructors below are deprecation shims building the
equivalent single-group ``OptimizerSpec``.
"""

from __future__ import annotations

import warnings

from repro.optim.base import GradientTransformation


def adam(
    lr=1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    bias_correction: bool = True,
    decoupled_weight_decay: bool = False,
) -> GradientTransformation:
    """Deprecated shim: Adam with full f32 moments (the paper's 2N-floats
    memory baseline); ``decoupled_weight_decay=True`` gives AdamW. Prefer
    ``build_optimizer(OptimizerSpec(family="adam", ...))``."""
    from repro.optim.spec import OptimizerSpec, build_optimizer

    warnings.warn(
        "adam(...) is deprecated; build via repro.optim.spec.OptimizerSpec "
        "(family='adam') + build_optimizer", DeprecationWarning, stacklevel=2)
    hp = dict(lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
              bias_correction=bias_correction,
              weight_decay_mode="adamw" if decoupled_weight_decay else "adam")
    return build_optimizer(OptimizerSpec(family="adam", hyperparams=hp))


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> GradientTransformation:
    """Deprecated shim: AdamW = Adam with decoupled weight decay."""
    warnings.warn(
        "adamw(...) is deprecated; build via repro.optim.spec.OptimizerSpec "
        "(family='adam', weight_decay_mode='adamw') + build_optimizer",
        DeprecationWarning, stacklevel=2)
    from repro.optim.spec import OptimizerSpec, build_optimizer

    hp = dict(lr=lr, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay,
              weight_decay_mode="adamw")
    return build_optimizer(OptimizerSpec(family="adam", hyperparams=hp))
