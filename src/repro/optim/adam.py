"""Adam / AdamW baselines (Kingma & Ba 2014; Loshchilov & Hutter 2019)."""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation, as_schedule


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adam(
    lr=1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    bias_correction: bool = True,
    decoupled_weight_decay: bool = False,
) -> GradientTransformation:
    """Adam with full f32 moments (the paper's 2N-floats memory baseline);
    ``decoupled_weight_decay=True`` gives AdamW."""
    lr_fn = as_schedule(lr)

    def init(params):
        (m,) = multimap(lambda p: (jnp.zeros(p.shape, jnp.float32),), params, nout=1)
        (v,) = multimap(lambda p: (jnp.zeros(p.shape, jnp.float32),), params, nout=1)
        return AdamState(jnp.zeros((), jnp.int32), m, v)

    def update(grads, state, params):
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            if weight_decay and not decoupled_weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)  # Adam-style decay (paper Algo 6)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            if bias_correction:
                mhat = m2 / (1 - b1**t)
                vhat = v2 / (1 - b2**t)
            else:
                mhat, vhat = m2, v2
            u = -lr_t * mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay and decoupled_weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)  # AdamW (paper Algo 7)
            return u, m2, v2

        updates, m, v = multimap(upd, grads, state.m, state.v, params, nout=3)
        return updates, AdamState(step, m, v)

    return GradientTransformation(init, update)


def adamw(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> GradientTransformation:
    """AdamW: Adam with decoupled weight decay (Loshchilov & Hutter 2019)."""
    return adam(lr, b1, b2, eps, weight_decay=weight_decay, decoupled_weight_decay=True)
