"""Minimal from-scratch optax-style optimizer API.

optax is not available in this environment, so the framework defines its own
``GradientTransformation`` protocol (the widened **extra-args form**):

  init(params) -> state
  update(grads, state, params, *, step=None, **extras) -> (updates, new_state)

``updates`` are *deltas* to be added to params (they already include the
negative learning rate), matching optax semantics. ``step`` optionally
overrides the optimizer's own (single, shared) step counter so callers with
an external step source — checkpoint-resume, eval-time replays — drive
every group's schedule from one place; extra keyword args flow through
``chain`` untouched for forward compatibility. Plain three-arg calls
``update(grads, state, params)`` remain valid everywhere.

Optimizers are built declaratively from an ``OptimizerSpec``
(``repro.optim.spec``); the per-family constructors (``smmf(...)``,
``adam(...)``, ...) are deprecation shims over it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.tree import tree_bytes

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]  # step -> scalar


@dataclasses.dataclass(frozen=True)
class GradientTransformation:
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple[PyTree, PyTree]]
    # engine-based optimizers expose their static leaf-plan for a given
    # params pytree (launch/bucket introspection); None for plain transforms
    plan: Callable[[PyTree], Any] | None = None
    # the OptimizerSpec this transformation was built from (spec-hash for
    # checkpoints, per-group accounting); None for plain transforms
    spec: Any = None


class EngineState(NamedTuple):
    """State of a spec-built (engine-backed) optimizer: ONE shared step
    counter for every partition group + a flat dict of per-bucket state
    subtrees keyed ``[<group>/]fac:GEOM`` / ``[<group>/]dense:...`` (layout
    and donation/sharding contracts in ``repro.optim.engine``). Groups
    built with ``quant=`` store their quantized slots as
    ``repro.optim.qstate.QTensor`` payload+scale pairs under the same
    keys."""

    step: jnp.ndarray
    factors: dict


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    """params + updates, preserving param dtype (updates may be f32)."""
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params,
        updates,
    )


class ChainState(NamedTuple):
    inner: tuple


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Compose transformations left-to-right (like optax.chain). Extra
    keyword args (``step=...`` and friends) are forwarded to every stage."""

    def init(params):
        return ChainState(tuple(t.init(params) for t in transforms))

    def update(grads, state, params, **extras):
        new_states = []
        for t, s in zip(transforms, state.inner):
            grads, s = t.update(grads, s, params, **extras)
            new_states.append(s)
        return grads, ChainState(tuple(new_states))

    return GradientTransformation(init, update)


class ClipState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    """Scale the whole gradient tree so its global L2 norm is <= max_norm."""
    def init(params):
        del params
        return ClipState()

    def update(grads, state, params=None, **extras):
        del params, extras
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
        return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), state

    return GradientTransformation(init, update)


def as_schedule(lr) -> Schedule:
    """Lift a constant learning rate to a step->lr schedule (callables pass
    through unchanged)."""
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def optimizer_state_bytes(state: PyTree) -> int:
    """Bytes held by persistent optimizer state (the paper's 'optimizer memory')."""
    return tree_bytes(state)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1) -> Schedule:
    """Linear warmup to peak_lr then cosine decay to min_ratio * peak_lr."""
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(1.0, warmup_steps)
        prog = jnp.clip((step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return sched
