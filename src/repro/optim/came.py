"""CAME baseline (Luo et al. 2023): confidence-guided Adafactor variant.

Keeps Adafactor's factored second moment, a full first moment, and a
*factored confidence* term U_t = EMA_{beta3} of (m_t - u_t)^2, used to rescale
the momentum-based update. Rank>=2 tensors factored over last two axes;
rank<=1 kept full. Memory ~ Adafactor + full first moment (matches paper's
tables where CAME >= Adafactor).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation, as_schedule


class CAMEState(NamedTuple):
    step: jnp.ndarray
    m: dict
    vr: dict
    vc: dict
    vfull: dict
    ur: dict   # confidence row stats
    uc: dict   # confidence col stats
    ufull: dict


_EMPTY = lambda: jnp.zeros((0,), jnp.float32)


def _rms(x):
    return jnp.sqrt(jnp.mean(jnp.square(x)) + 1e-30)


def came(
    lr=1e-3,
    beta1: float = 0.9,
    beta2: float = 0.999,
    beta3: float = 0.9999,
    eps1: float = 1e-30,
    eps2: float = 1e-16,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> GradientTransformation:
    lr_fn = as_schedule(lr)
    factored = lambda p: p.ndim >= 2

    def init(params):
        def mk(p):
            m = jnp.zeros(p.shape, jnp.float32)
            if factored(p):
                vr = jnp.zeros(p.shape[:-1], jnp.float32)
                vc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                ur = jnp.zeros(p.shape[:-1], jnp.float32)
                uc = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                vfull = _EMPTY()
                ufull = _EMPTY()
            else:
                vr = vc = ur = uc = _EMPTY()
                vfull = jnp.zeros(p.shape, jnp.float32)
                ufull = jnp.zeros(p.shape, jnp.float32)
            return m, vr, vc, vfull, ur, uc, ufull

        m, vr, vc, vfull, ur, uc, ufull = multimap(mk, params, nout=7)
        return CAMEState(jnp.zeros((), jnp.int32), m, vr, vc, vfull, ur, uc, ufull)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def recon(r, c):
            denom = jnp.mean(r, axis=-1, keepdims=True)
            return r[..., :, None] * c[..., None, :] / (denom[..., None] + eps1)

        def upd(g, m, vr, vc, vfull, ur, uc, ufull, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            g2 = g * g + eps1
            if factored(p):
                vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                vhat = recon(vr2, vc2)
                vfull2 = vfull
            else:
                vfull2 = beta2 * vfull + (1 - beta2) * g2
                vhat = vfull2
                vr2, vc2 = vr, vc
            u = g / jnp.sqrt(vhat + eps1)
            u = u / jnp.maximum(1.0, _rms(u) / clip_threshold)
            m2 = beta1 * m + (1 - beta1) * u
            # confidence: instability of momentum vs update
            inst = (u - m2) ** 2 + eps2
            if factored(p):
                ur2 = beta3 * ur + (1 - beta3) * jnp.mean(inst, axis=-1)
                uc2 = beta3 * uc + (1 - beta3) * jnp.mean(inst, axis=-2)
                uhat = recon(ur2, uc2)
                ufull2 = ufull
            else:
                ufull2 = beta3 * ufull + (1 - beta3) * inst
                uhat = ufull2
                ur2, uc2 = ur, uc
            out = -lr_t * m2 / jnp.sqrt(uhat + eps2)
            return out, m2, vr2, vc2, vfull2, ur2, uc2, ufull2

        updates, m, vr, vc, vfull, ur, uc, ufull = multimap(
            upd, grads, state.m, state.vr, state.vc, state.vfull, state.ur, state.uc, state.ufull,
            params, nout=8,
        )
        return updates, CAMEState(step, m, vr, vc, vfull, ur, uc, ufull)

    return GradientTransformation(init, update)
