"""CAME baseline (Luo et al. 2023): confidence-guided Adafactor variant.

Keeps Adafactor's factored second moment, a full first moment, and a
*factored confidence* term U_t = EMA_{beta3} of (m_t - u_t)^2, used to
rescale the momentum-based update. Rank>=2 tensors factored over last two
axes; rank<=1 kept full. Memory ~ Adafactor + full first moment (matches
the paper's tables where CAME >= Adafactor).

The math lives in the family registry (``repro.optim.families``, entry
``"came"``) and runs on the bucketed leaf-plan engine; like Adafactor its
per-leaf RMS clip is segment-aware, so the dense fallback may flat-fuse
(``fuse_dense_ok`` capability, default off). Confidence-style variants
compose as further registry entries instead of new constructors.
:func:`came` below is a deprecation shim building the equivalent
single-group ``OptimizerSpec``.
"""

from __future__ import annotations

import warnings

from repro.optim.base import GradientTransformation


def came(
    lr=1e-3,
    beta1: float = 0.9,
    beta2: float = 0.999,
    beta3: float = 0.9999,
    eps1: float = 1e-30,
    eps2: float = 1e-16,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    bucket: bool = True,
) -> GradientTransformation:
    """Deprecated shim: CAME on the leaf-plan engine. Prefer
    ``build_optimizer(OptimizerSpec(family="came", ...))``."""
    from repro.optim.spec import OptimizerSpec, build_optimizer

    warnings.warn(
        "came(...) is deprecated; build via repro.optim.spec.OptimizerSpec "
        "(family='came') + build_optimizer", DeprecationWarning, stacklevel=2)
    hp = dict(lr=lr, beta1=beta1, beta2=beta2, beta3=beta3, eps1=eps1,
              eps2=eps2, clip_threshold=clip_threshold,
              weight_decay=weight_decay, bucket=bucket)
    return build_optimizer(OptimizerSpec(family="came", hyperparams=hp))
