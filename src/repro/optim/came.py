"""CAME baseline (Luo et al. 2023): confidence-guided Adafactor variant.

Keeps Adafactor's factored second moment, a full first moment, and a
*factored confidence* term U_t = EMA_{beta3} of (m_t - u_t)^2, used to rescale
the momentum-based update. Rank>=2 tensors factored over last two axes;
rank<=1 kept full. Memory ~ Adafactor + full first moment (matches paper's
tables where CAME >= Adafactor).

Runs on the leaf-plan engine (repro.optim.engine): same-shape leaves are
stacked into one (K, ...) bucket per geometry and updated with a single
vectorized launch (RMS clip stays per leaf). State per bucket:

  factors["fac:SHAPE"]  = (m, vr, vc, ur, uc)   all (K, ...)-stacked
  factors["dense:NUM"]  = (m, vfull, ufull)
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.plan import lasttwo_planner
from repro.optim.base import GradientTransformation, as_schedule
from repro.optim.engine import LeafPlanEngine


class CAMEState(NamedTuple):
    step: jnp.ndarray
    factors: dict  # bucket key -> stacked moment tuple (see module doc)


def _rms(x):
    """Per-leaf RMS: reduced over all but the leading stack axis."""
    axes = tuple(range(1, x.ndim))
    return jnp.sqrt(jnp.mean(jnp.square(x), axis=axes, keepdims=True) + 1e-30)


def came(
    lr=1e-3,
    beta1: float = 0.9,
    beta2: float = 0.999,
    beta3: float = 0.9999,
    eps1: float = 1e-30,
    eps2: float = 1e-16,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    bucket: bool = True,
) -> GradientTransformation:
    """CAME on the leaf-plan engine (see module docstring). Dense rank<=1
    leaves keep per-geometry buckets — the per-leaf RMS clip reduces over
    each leaf, so they cannot legally be flat-fused."""
    lr_fn = as_schedule(lr)
    plan_fn = lasttwo_planner()

    def plan(params) -> LeafPlanEngine:
        """Static leaf-plan engine for ``params`` (see LeafPlanEngine)."""
        return LeafPlanEngine(params, plan_fn, bucket=bucket)

    def init(params):
        engine = plan(params)
        factors = {}
        for bk in engine.buckets:
            k = bk.size
            m = jnp.zeros((k,) + bk.geometry, jnp.float32)
            if bk.factorized:
                shape = bk.geometry
                row = (k,) + shape[:-1]
                col = (k,) + shape[:-2] + shape[-1:]
                factors[bk.key] = (
                    m,
                    jnp.zeros(row, jnp.float32), jnp.zeros(col, jnp.float32),  # vr, vc
                    jnp.zeros(row, jnp.float32), jnp.zeros(col, jnp.float32),  # ur, uc
                )
            else:
                full = (k,) + bk.geometry
                factors[bk.key] = (
                    m, jnp.zeros(full, jnp.float32), jnp.zeros(full, jnp.float32)
                )
        return CAMEState(jnp.zeros((), jnp.int32), factors)

    def update(grads, state, params):
        engine = plan(params)
        step = state.step + 1
        lr_t = lr_fn(step)

        def recon(r, c):
            denom = jnp.mean(r, axis=-1, keepdims=True)
            return r[..., :, None] * c[..., None, :] / (denom[..., None] + eps1)

        flat_g = engine.leaves(grads)
        if weight_decay:
            flat_p = engine.leaves(params)
            flat_g = [g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
                      for g, p in zip(flat_g, flat_p)]

        out_flat: list = [None] * len(flat_g)
        factors = {}
        for bk in engine.buckets:
            g = engine.gather(flat_g, bk)  # (K, *geometry)
            g2 = g * g + eps1
            if bk.factorized:
                m, vr, vc, ur, uc = state.factors[bk.key]
                vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                vhat = recon(vr2, vc2)
            else:
                m, vfull, ufull = state.factors[bk.key]
                vfull2 = beta2 * vfull + (1 - beta2) * g2
                vhat = vfull2
            u = g / jnp.sqrt(vhat + eps1)
            u = u / jnp.maximum(1.0, _rms(u) / clip_threshold)
            m2 = beta1 * m + (1 - beta1) * u
            # confidence: instability of momentum vs update
            inst = (u - m2) ** 2 + eps2
            if bk.factorized:
                ur2 = beta3 * ur + (1 - beta3) * jnp.mean(inst, axis=-1)
                uc2 = beta3 * uc + (1 - beta3) * jnp.mean(inst, axis=-2)
                uhat = recon(ur2, uc2)
                factors[bk.key] = (m2, vr2, vc2, ur2, uc2)
            else:
                ufull2 = beta3 * ufull + (1 - beta3) * inst
                uhat = ufull2
                factors[bk.key] = (m2, vfull2, ufull2)
            engine.scatter(bk, -lr_t * m2 / jnp.sqrt(uhat + eps2), out_flat)

        return engine.unflatten(out_flat), CAMEState(step, factors)

    return GradientTransformation(init, update, plan=plan)
