"""Leaf-plan update engine: bucketed pytree optimizer plumbing.

Factored optimizers (SMMF, Adafactor, CAME, SM3) all share the same
structure: classify each parameter leaf (factorized vs dense fallback), pick
a working geometry, then run elementwise-plus-reduction math per leaf. The
:class:`LeafPlanEngine` centralizes that plumbing:

* at ``init`` it computes a static :class:`repro.core.plan.LeafPlan` per
  leaf and groups same-geometry leaves into buckets
  (:func:`repro.core.plan.build_buckets`);
* at ``update`` it **stacks** each bucket's gradients along a new leading
  axis, so the optimizer runs one vectorized (or fused Pallas) launch per
  bucket instead of one per leaf, and scatters the stacked result back to
  the original leaves;
* with ``fuse_dense=True`` (SMMF default) every dense-fallback leaf of a
  dtype is **concatenated** into a single flat ``(1, total)`` row — dense
  math is elementwise, so fallback-heavy trees pay one launch per dtype.

Because stacking only adds a leading batch axis (and fused concatenation
only reorders elementwise work), the bucketed math is element-for-element
identical to the per-leaf path (``bucket=False`` recovers it exactly — one
single-leaf bucket per parameter).

State layout convention: each optimizer stores ``dict[bucket.key ->
tuple(arrays)]`` with the leading axis of every array indexing the bucket's
leaves (length ``bucket.stack``; 1 for fused dense). Bucket keys are
deterministic functions of the parameter shapes and engine config, so
checkpoints are reproducible. Groups built with ``quant="int8"|"fp8"``
store quantized slots as ``repro.optim.qstate.QTensor`` pairs (1-byte
payload + per-stack-row scales) under the SAME bucket keys — the codec
sits between this engine and the family callbacks (``docs/memory.md``).

Distribution invariants (see ``docs/sharding.md``):

* Bucket-stacked state is **not replicated** on a mesh: the stack axis
  carries the "data"/fsdp axis whenever it is divisible
  (:func:`repro.core.plan.bucket_partition_wants`), and the engine's gather
  emits ``with_sharding_constraint`` on fused dense rows so the placement
  agrees with ``repro.distributed.rules.opt_state_shardings``.
* The whole optimizer state is **donation-safe**: ``update`` consumes every
  state array exactly once and returns fresh arrays of identical
  shape/dtype/sharding, so callers may jit the train step with
  ``donate_argnums`` covering params and optimizer state and XLA will alias
  the buffers in place (asserted by ``repro.launch.steps.assert_donation``).
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.plan import (
    DEFAULT_KERNEL_BLOCK,  # re-exported: the single source lives in core.plan
    Bucket,
    LeafPlan,
    bucket_schedule,
    build_buckets,
)
from repro.distributed.ctx import constrain, constrain_update

PyTree = Any


class LeafPlanEngine:
    """Static per-params plan: built at trace time, drives bucketed updates.

    ``plan_fn(index, shape) -> LeafPlan`` encodes the optimizer's
    factorization policy (see ``repro.core.plan`` planners). ``bucket=False``
    is the per-leaf baseline; ``fuse_dense=True`` concatenates all
    dense-fallback leaves of a dtype into one flat launch — a registry
    capability (``repro.optim.families.Family.fuse_dense_ok``): legal for
    purely elementwise dense math (SMMF's plain-Adam fallback, adam, sgd)
    and for Adafactor/CAME via their segment-aware RMS clip.

    Plans may be **group-aware** (``repro.optim.spec``): each LeafPlan's
    ``group``/``freeze``/``solo``/``fuse`` fields drive per-partition
    bucketing — buckets never span groups, frozen leaves hold no state and
    join no bucket.
    """

    def __init__(self, params: PyTree, plan_fn: Callable[[int, tuple[int, ...]], LeafPlan],
                 *, bucket: bool = True, fuse_dense: bool = False):
        import dataclasses

        flat, treedef = jax.tree.flatten(params)
        self.treedef = treedef
        self.plans: tuple[LeafPlan, ...] = tuple(
            dataclasses.replace(
                plan_fn(i, tuple(p.shape)),
                dtype=str(jnp.dtype(getattr(p, "dtype", jnp.float32))),
            )
            for i, p in enumerate(flat)
        )
        self.buckets: tuple[Bucket, ...] = build_buckets(
            self.plans, bucket, fuse_dense=fuse_dense
        )

    # -- pytree plumbing ---------------------------------------------------

    def leaves(self, tree: PyTree) -> list:
        """Flatten ``tree`` in the engine's canonical leaf order."""
        return self.treedef.flatten_up_to(tree)

    def unflatten(self, flat: Sequence) -> PyTree:
        """Rebuild a pytree from the engine's canonical leaf order."""
        return jax.tree.unflatten(self.treedef, list(flat))

    def gather(self, flat: Sequence, bucket: Bucket) -> jnp.ndarray:
        """Stack a bucket's leaves to (K, *geometry) float32.

        Fused dense buckets concatenate instead: the result is a single
        ``(1, total_numel)`` row, sharding-constrained ("dense_flat") so the
        transient gradient row lands where the fused moments live.

        Each leaf is routed through the ``"opt_update_row"`` boundary rule
        before the param→geometry reshape (the mirror of :meth:`scatter`):
        non-stack-sharded buckets get their gradient transported explicitly
        instead of leaving the SPMD partitioner to invent a grouped
        sharding for the reshape (see scatter's docstring).
        """
        def _b(x):
            return constrain(x, "opt_update_row",
                             meta=(bucket.stack, bucket.state_axes))

        if bucket.fused:
            parts = [_b(flat[i]).reshape(-1).astype(jnp.float32)
                     for i in bucket.indices]
            row = parts[0] if len(parts) == 1 else _b(jnp.concatenate(parts))
            return constrain(row[None], "dense_flat", meta=bucket.state_axes)
        parts = [_b(flat[i]).reshape(bucket.geometry).astype(jnp.float32)
                 for i in bucket.indices]
        if len(parts) == 1:
            return parts[0][None]
        # the boundary pin must cover the stack OUTPUT too: a concatenate
        # whose consumer demands a sharded layout lowers to partial writes
        # + all-reduce, which over-counts replicated operands (the XLA
        # miscompile tests/_multiaxis_child.py locks down)
        return _b(jnp.stack(parts))

    def scatter(self, bucket: Bucket, stacked: jnp.ndarray, out_flat: list) -> None:
        """Split a (K, ...) stacked (or (1, total) fused) result back into
        per-leaf shapes at their flat-param indices.

        This is where the bucket-stack layout and the parameter layout
        meet, and the SPMD partitioner needs **param-spec-aware
        constraints** here (the transformer_base/train_4k device_groups
        CHECK crash, regression-tested in tests/test_spec_e2e.py):

        * each per-leaf update segment is first routed through the
          ``"opt_update_row"`` rule — for buckets whose stack axis is *not*
          mesh-sharded it replicates the transient row, making the
          row→param reshape trivially partitionable (an explicit,
          representable all-gather in place of XLA's involuntary — and for
          stacked-scan leaves, crashing — rematerialization); stack-sharded
          buckets return None and keep their fully-sharded path;
        * the reshaped per-leaf update is then pinned to its parameter's
          own sharding (``ctx.constrain_update``; identity outside a mesh
          trace).
        """
        if bucket.fused:
            row = stacked.reshape(-1)
            for off, p in zip(bucket.offsets, bucket.plans):
                seg = constrain(row[off:off + p.numel], "opt_update_row",
                                meta=(bucket.stack, bucket.state_axes))
                out_flat[p.index] = constrain_update(seg.reshape(p.shape), p.index)
            return
        for k, p in enumerate(bucket.plans):
            seg = constrain(stacked[k], "opt_update_row",
                            meta=(bucket.stack, bucket.state_axes))
            out_flat[p.index] = constrain_update(seg.reshape(p.shape), p.index)

    # -- scheduling --------------------------------------------------------

    def schedule(self, order: str | None = "plan") -> tuple[int, ...]:
        """Dispatch order of the per-bucket update launches (a permutation
        of ``range(len(self.buckets))``; :func:`repro.core.plan.bucket_schedule`).

        ``"plan"``/None is the construction-order barrier baseline;
        ``"grad"`` orders buckets by reverse-mode gradient availability so
        the scheduled update chain (``repro.optim.spec``) interleaves with
        the remaining backward compute. Static plan math — the order is
        baked in at trace time and never changes values.
        """
        return bucket_schedule(self.buckets, order)

    # -- introspection -----------------------------------------------------

    def stats(self) -> dict:
        """Static launch/footprint accounting.

        Used by the CLI smoke assert and ``benchmarks/step_time.py``: one
        update launch per bucket vs one per leaf in the unbucketed baseline.
        A fused dense bucket counts as **one** launch regardless of how many
        leaves it concatenates (``dense_buckets`` is the post-fusion launch
        count; ``fused_dense_leaves`` is how many leaves it swallowed), so
        the ``launches`` column stays truthful after dense fusion.

        Group-aware plans (``repro.optim.spec``) additionally report the
        number of distinct partition groups and the frozen (stateless,
        zero-update, bucket-less) leaf count.
        """
        fac = [b for b in self.buckets if b.factorized]
        dense = [b for b in self.buckets if not b.factorized]
        return {
            "leaves": len(self.plans),
            "buckets": len(self.buckets),
            "update_launches": len(self.buckets),
            "factored_buckets": len(fac),
            "dense_buckets": len(dense),
            "fused_dense_leaves": sum(b.size for b in dense if b.fused),
            "kernel_buckets": sum(1 for b in fac if b.kernel_ok),
            "groups": len({p.group for p in self.plans}),
            "frozen_leaves": sum(1 for p in self.plans if p.freeze),
            # qstate codec coverage (repro.optim.qstate): buckets whose
            # persistent state stores as 1-byte payloads + scale rows
            "quantized_buckets": sum(1 for b in self.buckets if b.quant),
            "transport_buckets": sum(1 for b in self.buckets if b.transport),
        }


def engine_stats(opt, params) -> dict | None:
    """Launch stats for an engine-based GradientTransformation, else None."""
    plan = getattr(opt, "plan", None)
    if plan is None:
        return None
    return plan(params).stats()
