"""Leaf-plan update engine: bucketed pytree optimizer plumbing.

Factored optimizers (SMMF, Adafactor, CAME, SM3) all share the same
structure: classify each parameter leaf (factorized vs dense fallback), pick
a working geometry, then run elementwise-plus-reduction math per leaf. The
:class:`LeafPlanEngine` centralizes that plumbing:

* at ``init`` it computes a static :class:`repro.core.plan.LeafPlan` per
  leaf and groups same-geometry leaves into buckets
  (:func:`repro.core.plan.build_buckets`);
* at ``update`` it **stacks** each bucket's gradients along a new leading
  axis, so the optimizer runs one vectorized (or fused Pallas) launch per
  bucket instead of one per leaf, and scatters the stacked result back to
  the original leaves.

Because stacking only adds a leading batch axis, the bucketed math is
element-for-element identical to the per-leaf path (``bucket=False``
recovers it exactly — one single-leaf bucket per parameter).

State layout convention: each optimizer stores ``dict[bucket.key ->
tuple(arrays)]`` with the leading axis of every array indexing the bucket's
leaves. Bucket keys are deterministic functions of the parameter shapes and
engine config, so checkpoints are reproducible.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core.plan import Bucket, LeafPlan, build_buckets

PyTree = Any

# Default Pallas tile; kept in sync with kernels/smmf_update/kernel.py but
# duplicated here so the engine stays importable without the kernel package.
DEFAULT_KERNEL_BLOCK = (256, 512)


class LeafPlanEngine:
    """Static per-params plan: built at trace time, drives bucketed updates.

    ``plan_fn(index, shape) -> LeafPlan`` encodes the optimizer's
    factorization policy (see ``repro.core.plan`` planners).
    """

    def __init__(self, params: PyTree, plan_fn: Callable[[int, tuple[int, ...]], LeafPlan],
                 *, bucket: bool = True):
        flat, treedef = jax.tree.flatten(params)
        self.treedef = treedef
        self.plans: tuple[LeafPlan, ...] = tuple(
            plan_fn(i, tuple(p.shape)) for i, p in enumerate(flat)
        )
        self.buckets: tuple[Bucket, ...] = build_buckets(self.plans, bucket)

    # -- pytree plumbing ---------------------------------------------------

    def leaves(self, tree: PyTree) -> list:
        return self.treedef.flatten_up_to(tree)

    def unflatten(self, flat: Sequence) -> PyTree:
        return jax.tree.unflatten(self.treedef, list(flat))

    def gather(self, flat: Sequence, bucket: Bucket) -> jnp.ndarray:
        """Stack a bucket's leaves to (K, *geometry) float32."""
        parts = [flat[i].reshape(bucket.geometry).astype(jnp.float32) for i in bucket.indices]
        if len(parts) == 1:
            return parts[0][None]
        return jnp.stack(parts)

    def scatter(self, bucket: Bucket, stacked: jnp.ndarray, out_flat: list) -> None:
        """Split a (K, ...) stacked result back into per-leaf shapes."""
        for k, p in enumerate(bucket.plans):
            out_flat[p.index] = stacked[k].reshape(p.shape)

    # -- introspection -----------------------------------------------------

    def stats(self) -> dict:
        """Static launch/footprint accounting (used by the CLI smoke assert
        and benchmarks/step_time.py): one update launch per bucket vs one
        per leaf in the unbucketed baseline."""
        fac = [b for b in self.buckets if b.factorized]
        return {
            "leaves": len(self.plans),
            "buckets": len(self.buckets),
            "update_launches": len(self.buckets),
            "factored_buckets": len(fac),
            "dense_buckets": len(self.buckets) - len(fac),
            "kernel_buckets": sum(1 for b in fac if b.kernel_ok),
        }


def engine_stats(opt, params) -> dict | None:
    """Launch stats for an engine-based GradientTransformation, else None."""
    plan = getattr(opt, "plan", None)
    if plan is None:
        return None
    return plan(params).stats()
