"""Optimizer-family registry: planner + bucket-math entries for the engine.

Every optimizer family (smmf, adafactor, came, sm3, adam, sgd) is one
:class:`Family` record instead of a hand-rolled ``init``/``update`` pair:

* ``make_plan_fn(hp)`` — the family's factorization policy as a
  ``(index, shape) -> LeafPlan`` planner (``repro.core.plan`` planners);
* ``init_bucket(bucket, hp)`` — zero state for one engine bucket;
* ``update_bucket(ctx, bucket, g, fac)`` — the bucket's math: gathered
  gradient stack in, ``(descent_direction, new_state)`` out. The caller
  (``repro.optim.spec.build_optimizer``) scales by ``-lr_t`` and scatters.

Capability flags replace special-casing: ``fuse_dense_ok`` says the dense
fallback may legally be concatenated into one flat row per (group, dtype) —
true for the purely elementwise families (smmf's plain-Adam fallback, adam,
sgd) and now also for adafactor/came whose per-leaf RMS update clip is
computed **segment-aware** on fused rows (:func:`_per_leaf_rms`), so the
clip still reduces over each original leaf. ``quant_slots`` declares which
state slots may store in int8/fp8 under the qstate codec
(``repro.optim.qstate``): SMMF quantizes its ``r``/``c`` moment factors
(the packed sign matrix is already 1 bit/element), Adafactor/CAME their
row/col second-moment and confidence stats (the full-size momentum stays
exact), and the dense-fallback flat buffers quantize whole; SM3's
min-combined cover accumulators are excluded (``quant_slots=None`` — a
spec asking for ``quant`` on sm3 is rejected at resolve time).

Weight decay is handled generically by the spec engine (grad-coupled
"adam" mode before the bucket math, decoupled "adamw" mode after), so the
family math here never sees it.

The registry is the extension point for new families (e.g. further CAME
confidence variants): ``register(Family(...))`` makes the family available
to every ``OptimizerSpec``, the CLI, and mixed-family partition rules.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.nnmf import nnmf_compress_k, nnmf_decompress_k
from repro.core.plan import (
    DEFAULT_KERNEL_BLOCK,  # re-exported: the single source lives in core.plan
    Bucket,
    LeafPlan,
    axiscover_planner,
    lasttwo_planner,
    smmf_planner,
)
from repro.core.signpack import pack_signs, packed_width, unpack_signs
from repro.distributed.ctx import constrain
from repro.optim.qstate import QTensor, SlotSpec

PyTree = Any
PlanFn = Callable[[int, tuple[int, ...]], LeafPlan]

# hp keys that configure the engine/planner rather than the math; shared by
# every family (plan-level keys like blocks/use_kernel live in the family's
# own defaults)
ENGINE_KEYS = ("bucket", "fuse_dense")


@dataclasses.dataclass(frozen=True)
class UpdateCtx:
    """Per-update scalars handed to ``Family.update_bucket``.

    ``step`` is the *shared* step counter of the spec-built optimizer (one
    source for every group — replaces the six per-state counters of the
    legacy constructors); ``t`` is the same value as f32; ``hp`` the
    resolved hyperparams of the bucket's partition group.
    """

    step: jnp.ndarray   # int32 scalar, already incremented
    t: jnp.ndarray      # step as float32
    hp: dict


@dataclasses.dataclass(frozen=True)
class Family:
    """One optimizer family as a registry entry (see module docstring).

    ``defaults`` doubles as the schema: a hyperparam key is legal for this
    family iff it appears here (``repro.optim.spec`` validates merged
    hyperparams against it). ``wd_mode_key`` names the hyperparam that
    selects grad-coupled vs decoupled weight decay ("adam"/"adamw");
    ``None`` pins the family to grad-coupled decay.
    """

    name: str
    defaults: dict
    make_plan_fn: Callable[[dict], PlanFn]
    init_bucket: Callable[[Bucket, dict], Any]
    update_bucket: Callable[[UpdateCtx, Bucket, jnp.ndarray, Any], tuple[jnp.ndarray, Any]]
    fuse_dense_ok: bool = False          # dense fallback may be flat-fused
    wd_mode_key: str | None = None
    validate: Callable[[dict], None] | None = None
    # (bucket, hp) -> one repro.optim.qstate.SlotSpec per state slot; None
    # means the family's state cannot be quantized (hp key "quant" is then
    # absent from `defaults`, so specs asking for it fail validation)
    quant_slots: Callable[[Bucket, dict], tuple] | None = None

    def wd_mode(self, hp: dict) -> str:
        """Weight-decay style for resolved hyperparams: "adam" (grad-coupled,
        paper Algo 6) or "adamw" (decoupled, Algo 7)."""
        if self.wd_mode_key is None:
            return "adam"
        return hp.get(self.wd_mode_key, "adam")


_REGISTRY: dict[str, Family] = {}


def register(family: Family) -> Family:
    """Add ``family`` to the registry (name must be unused). Returns it, so
    third-party variants can do ``came2 = register(dataclasses.replace(...))``."""
    if family.name in _REGISTRY:
        raise ValueError(f"optimizer family {family.name!r} already registered")
    _REGISTRY[family.name] = family
    return family


def get_family(name: str) -> Family:
    """Look up a registered family by name (ValueError with the known names
    on miss — the CLI surfaces this directly)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown optimizer family {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def family_names() -> list[str]:
    """Registered family names, sorted (CLI help / docs)."""
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def _per_leaf_rms(u: jnp.ndarray, bk: Bucket) -> jnp.ndarray:
    """Per-leaf RMS of an update stack (the Adafactor/CAME update clip).

    Regular buckets reduce over all but the leading stack axis. Fused flat
    rows reduce **per contained leaf segment** instead (static segment ids
    from the bucket's leaf offsets), so the clip normalizes each original
    leaf exactly as the unfused path does — this segment-awareness is what
    makes ``fuse_dense`` legal for families with a per-leaf reduction.
    """
    if bk.fused and bk.size > 1:
        seg = bk.segment_ids()
        flat = u.reshape(-1)
        sums = jax.ops.segment_sum(flat * flat, seg, num_segments=bk.size,
                                   indices_are_sorted=True)
        counts = jnp.asarray([float(p.numel) for p in bk.plans], jnp.float32)
        rms = jnp.sqrt(sums / counts + 1e-30)
        return rms[seg].reshape(u.shape)
    axes = tuple(range(1, u.ndim))
    return jnp.sqrt(jnp.mean(jnp.square(u), axis=axes, keepdims=True) + 1e-30)


def _dense_planner() -> PlanFn:
    """Planner for fully-dense families (adam, sgd): every leaf is a
    ``(numel,)`` fallback, so same-size leaves stack and — elementwise math —
    the whole dense set may flat-fuse into one row per dtype."""

    def plan(index: int, shape: tuple[int, ...]) -> LeafPlan:
        numel = int(math.prod(shape)) if shape else 1
        return LeafPlan(index, shape, False, (numel,))

    return plan


# ---------------------------------------------------------------------------
# SMMF (paper Algorithms 1-8) — square-matricized rank-1 factors + signs
# ---------------------------------------------------------------------------

def _compress(mat: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched Algo 4: mat (B, n, m) non-negative -> r (B, n), c (B, m).

    Normalizes the *smaller* vector per matrix (paper Algo 4) so the outer
    product keeps the matrix scale with a single division.
    """
    _, n, m = mat.shape
    r = jnp.sum(mat, axis=2)
    c = jnp.sum(mat, axis=1)
    # guard the denominator so all-zero moments (step-1 state, frozen
    # groups) never evaluate 0/0 in the discarded where-branch (debug-nans)
    if n <= m:
        tot = jnp.sum(r, axis=1, keepdims=True)
        r = r / jnp.where(tot > 0, tot, 1.0)
    else:
        tot = jnp.sum(c, axis=1, keepdims=True)
        c = c / jnp.where(tot > 0, tot, 1.0)
    return r, c


def _decompress(r: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """Batched Algo 3: r (B, n), c (B, m) -> (B, n, m)."""
    return r[:, :, None] * c[:, None, :]


def _smmf_validate(hp: dict) -> None:
    lr = hp["lr"]
    if isinstance(lr, (int, float)) and lr < 0.0:
        raise ValueError(f"lr must be >= 0, got {lr}")
    beta1 = hp["beta1"]
    if beta1 is not None and not 0.0 <= beta1 <= 1.0:
        raise ValueError(f"beta1 must be in [0,1], got {beta1}")
    if not -1.0 <= hp["decay_rate"] <= 0.0:
        raise ValueError(f"decay_rate must be in [-1,0], got {hp['decay_rate']}")
    if not 0.0 <= hp["growth_rate"] <= 1.0:
        raise ValueError(f"growth_rate must be in [0,1], got {hp['growth_rate']}")
    if hp["weight_decay_mode"] not in ("adam", "adamw"):
        raise ValueError(
            f"weight_decay_mode must be adam|adamw, got {hp['weight_decay_mode']}")
    bn_k, bm_k = hp["kernel_block"]
    if bn_k <= 0 or bm_k <= 0 or bn_k % 8 or bm_k % 8:
        # the packed-sign tile is bm/8 bytes wide; a non-multiple-of-8 tile
        # mis-tiles the sign array deep inside the kernel
        raise ValueError(
            f"kernel_block dims must be positive multiples of 8, got {hp['kernel_block']}")


def _smmf_plan_fn(hp: dict) -> PlanFn:
    return smmf_planner(
        blocks=hp["blocks"], vector_reshape=hp["vector_reshape"],
        # the fused kernel always computes the momentum EMA; the
        # momentum-free variant keeps the unfused path
        use_kernel=hp["use_kernel"] and hp["beta1"] is not None,
        momentum=hp["beta1"] is not None,
    )


def _smmf_quant_slots(bk: Bucket, hp: dict) -> tuple:
    """SlotSpecs for SMMF state: quantize the ``r``/``c`` moment factors
    (the packed sign matrix is already 1 bit/element). When the bucket runs
    the fused kernel with int8 state, the factors are flagged
    ``kernel_deq`` — the kernel dequantizes them in-register instead of
    materializing f32 copies in HBM."""
    momentum = hp["beta1"] is not None
    if bk.factorized:
        kd = bool(bk.kernel_ok) and hp.get("quant") == "int8" and momentum
        # v factors are denominator-side -> sqrt-companded under int8 (the
        # quantized kernel bakes the matching un-companding in)
        rows_v = SlotSpec(True, "smmf_rows", kernel_deq=kd, sqrt=True)
        cols_v = SlotSpec(True, "smmf_cols", kernel_deq=kd, sqrt=True)
        if momentum:
            return (SlotSpec(True, "smmf_rows", kernel_deq=kd),
                    SlotSpec(True, "smmf_cols", kernel_deq=kd),
                    SlotSpec(False), rows_v, cols_v)
        return (rows_v, cols_v)
    kind = "dense_flat" if bk.fused else None
    v = SlotSpec(True, kind, sqrt=True)
    return (SlotSpec(True, kind), v) if momentum else (v,)


def _smmf_init(bk: Bucket, hp: dict):
    k = bk.size
    momentum = hp["beta1"] is not None
    if bk.factorized:
        b, n, m = bk.geometry
        second = (_zeros((k * b, n)), _zeros((k * b, m)))        # r_v, c_v
        if not momentum:
            # momentum-free SMMF (beta1=None) holds ONLY the second-moment
            # factors — no momentum factors, no sign matrix (the sign bits
            # are what dominate the momentum variant's state bytes)
            return second
        return (
            _zeros((k * b, n)),                                  # r_m
            _zeros((k * b, m)),                                  # c_m
            _zeros((k * b * n, packed_width(m)), jnp.uint8),     # sign
        ) + second
    (numel,) = bk.geometry  # total numel for fused buckets
    v = (_zeros((bk.stack, numel)),)                             # v
    return ((_zeros((bk.stack, numel)),) + v) if momentum else v  # [m,] v


def _smmf_update(ctx: UpdateCtx, bk: Bucket, gm: jnp.ndarray, fac):
    hp = ctx.hp
    beta1, eps, t = hp["beta1"], hp["eps"], ctx.t
    beta1_t = (beta1 * jnp.power(hp["growth_rate"], t - 1.0)) if beta1 is not None else None
    beta2_t = 1.0 - jnp.power(t, hp["decay_rate"])

    if bk.factorized:
        k = bk.size
        b, n, m = bk.geometry
        kb = k * b
        gm = constrain(gm.reshape(kb, n, m), "smmf_matrix", meta=bk.state_axes)
        if beta1 is not None:
            r_m, c_m, sign, r_v, c_v = fac
        else:  # momentum-free layout: second-moment factors only
            r_v, c_v = fac

        if bk.kernel_ok and beta1 is not None:
            from repro.kernels.smmf_update import ops as _kops

            # qstate kernel_deq path: the codec left the r/c factors as
            # int8 QTensor pairs; hand payloads + scales to the kernel,
            # which dequantizes in-register (no f32 factor copy in HBM)
            factor_scales = None
            if isinstance(r_m, QTensor):
                (r_m, rms), (c_m, cms) = r_m, c_m
                (r_v, rvs), (c_v, cvs) = r_v, c_v
                factor_scales = (rms, cms, rvs, cvs)
            pw = packed_width(m)
            u, r_m2, c_m2, sign2, r_v2, c_v2 = _kops.smmf_update_batched(
                gm, r_m, c_m, sign.reshape(kb, n, pw), r_v, c_v,
                beta1_t=beta1_t, beta2_t=beta2_t, eps=eps,
                block=hp["kernel_block"], interpret=hp["interpret"],
                factor_scales=factor_scales,
            )
            sign2 = sign2.reshape(kb * n, pw)
        else:
            # Decompression (Algo 3)
            v_hat = _decompress(r_v, c_v)
            if beta1 is not None:
                # the (K*B*n, pw) -> (K*B, n, m) unpack reshape is the other
                # boundary where the SPMD partitioner rematerializes without
                # a target: route the unpacked signs through the same
                # "opt_update_row" boundary rule as the scatter (replicated
                # for non-stack-sharded buckets, untouched otherwise), then
                # pin the result to the working-matrix layout
                signs = constrain(unpack_signs(sign, m), "opt_update_row",
                                  meta=(kb, bk.state_axes))
                signs = constrain(signs.reshape(kb, n, m), "smmf_matrix",
                                  meta=bk.state_axes)
                m_hat = signs * _decompress(r_m, c_m)
                # EMA update with the intact current gradient
                m_t = beta1_t * m_hat + (1.0 - beta1_t) * gm
            else:
                m_t = None
            v_t = beta2_t * v_hat + (1.0 - beta2_t) * gm * gm
            # Compression (Algo 4)
            if beta1 is not None:
                # mirror boundary of the sign unpack: route the (K*B, n, m)
                # -> (K*B*n, m) re-pack reshape through "opt_update_row" so
                # non-stack-sharded buckets transport explicitly
                nonneg = constrain((m_t >= 0).reshape(kb * n, m),
                                   "opt_update_row", meta=(kb, bk.state_axes))
                sign2 = pack_signs(nonneg)
                r_m2, c_m2 = _compress(jnp.abs(m_t))
            r_v2, c_v2 = _compress(v_t)
            num = m_t if beta1 is not None else gm
            u = num / (jnp.sqrt(v_t) + eps)

        # keep the re-compressed stacked state placed where
        # opt_state_shardings puts it (stack axis over "data" when
        # divisible) so donation aliases buffers without resharding
        r_v2 = constrain(r_v2, "smmf_rows", meta=bk.state_axes)
        c_v2 = constrain(c_v2, "smmf_cols", meta=bk.state_axes)
        u = u.reshape(k, b * n * m)
        if beta1 is None:
            return u, (r_v2, c_v2)
        r_m2 = constrain(r_m2, "smmf_rows", meta=bk.state_axes)
        c_m2 = constrain(c_m2, "smmf_cols", meta=bk.state_axes)
        sign2 = constrain(sign2, "smmf_sign", meta=bk.state_axes)
        return u, (r_m2, c_m2, sign2, r_v2, c_v2)

    # dense fallback: plain Adam on the paper's beta schedules
    if beta1 is not None:
        m_, v_ = fac
        m2 = beta1_t * m_ + (1.0 - beta1_t) * gm
    else:
        (v_,) = fac
    v2 = beta2_t * v_ + (1.0 - beta2_t) * gm * gm
    num = m2 if beta1 is not None else gm
    u = num / (jnp.sqrt(v2) + eps)
    v2 = constrain(v2, "dense_flat", meta=bk.state_axes) if bk.fused else v2
    if beta1 is None:
        return u, (v2,)
    m2 = constrain(m2, "dense_flat", meta=bk.state_axes) if bk.fused else m2
    return u, (m2, v2)


register(Family(
    name="smmf",
    defaults=dict(
        lr=1e-3, beta1=0.9, eps=1e-8, weight_decay=0.0, decay_rate=-0.5,
        growth_rate=0.999, vector_reshape=True, weight_decay_mode="adamw",
        blocks=1, use_kernel=False, kernel_block=DEFAULT_KERNEL_BLOCK,
        interpret=None, bucket=True, fuse_dense=True, quant=None,
        transport=None, transport_flush_every=8, telemetry=True,
    ),
    make_plan_fn=_smmf_plan_fn,
    init_bucket=_smmf_init,
    update_bucket=_smmf_update,
    fuse_dense_ok=True,
    wd_mode_key="weight_decay_mode",
    validate=_smmf_validate,
    quant_slots=_smmf_quant_slots,
))


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018) — last-two-axes factored second moment
# ---------------------------------------------------------------------------

MOMENTUM_QUANT_BLOCK = 128
"""Sub-row scale block for full-size momentum slots (Adafactor/CAME).

The momentum is signed and full-size — per-stack-row absmax scales lose
too much resolution on long rows, so it rides the PR 8 blockwise sub-row
scales (``core.quant.block_scale``/``block_expand``) instead: one scale
per 128 trailing-axis elements."""


def _adafactor_quant_slots(bk: Bucket, hp: dict) -> tuple:
    """SlotSpecs for Adafactor: quantize the row/col second-moment stats
    (denominator-side -> sqrt-companded under int8, and the dense fallback
    whole) and the full-size momentum with blockwise sub-row scales."""
    if bk.factorized:
        second = (SlotSpec(True, sqrt=True), SlotSpec(True, sqrt=True))
        mom = (SlotSpec(True, block=MOMENTUM_QUANT_BLOCK),)
        return (mom if hp["beta1"] is not None else ()) + second
    kind = "dense_flat" if bk.fused else None
    v = (SlotSpec(True, kind, sqrt=True),)
    if hp["beta1"] is not None:
        return (SlotSpec(True, kind),) + v
    return v


def _adafactor_init(bk: Bucket, hp: dict):
    k = bk.stack
    if bk.factorized:
        shape = bk.geometry
        vr = _zeros((k,) + shape[:-1])
        vc = _zeros((k,) + shape[:-2] + shape[-1:])
        second = (vr, vc)
        full = (k,) + shape
    else:
        full = (k,) + bk.geometry
        second = (_zeros(full),)
    if hp["beta1"] is not None:
        return (_zeros(full),) + second
    return second


def _adafactor_update(ctx: UpdateCtx, bk: Bucket, g: jnp.ndarray, fac):
    hp = ctx.hp
    beta1, eps1 = hp["beta1"], hp["eps1"]
    beta2t = 1.0 - jnp.power(ctx.t, hp["decay_rate"])
    m = fac[0] if beta1 is not None else None
    g2 = g * g + eps1
    if bk.factorized:
        vr, vc = fac[-2:]
        vr2 = beta2t * vr + (1 - beta2t) * jnp.mean(g2, axis=-1)
        vc2 = beta2t * vc + (1 - beta2t) * jnp.mean(g2, axis=-2)
        denom = jnp.mean(vr2, axis=-1, keepdims=True)
        vhat = vr2[..., :, None] * vc2[..., None, :] / (denom[..., None] + eps1)
        second = (vr2, vc2)
    else:
        vfull2 = beta2t * fac[-1] + (1 - beta2t) * g2
        vhat = vfull2
        if bk.fused:
            vfull2 = constrain(vfull2, "dense_flat", meta=bk.state_axes)
        second = (vfull2,)
    u = g / jnp.sqrt(vhat + eps1)
    u = u / jnp.maximum(1.0, _per_leaf_rms(u, bk) / hp["clip_threshold"])  # update clipping, d=1.0
    if beta1 is not None:
        m2 = beta1 * m + (1 - beta1) * u
        m2_state = constrain(m2, "dense_flat", meta=bk.state_axes) if bk.fused else m2
        return m2, (m2_state,) + second
    return u, second


register(Family(
    name="adafactor",
    defaults=dict(
        lr=1e-3, beta1=0.9, decay_rate=-0.8, eps1=1e-30, eps2=1e-3,
        clip_threshold=1.0, weight_decay=0.0, bucket=True, fuse_dense=False,
        quant=None, transport=None, transport_flush_every=8, telemetry=True,
    ),
    make_plan_fn=lambda hp: lasttwo_planner(),
    init_bucket=_adafactor_init,
    update_bucket=_adafactor_update,
    # segment-aware RMS clip makes flat fusion legal; defaults['fuse_dense']
    # is off so the unfused layout (and its state keys) stays the baseline
    fuse_dense_ok=True,
    quant_slots=_adafactor_quant_slots,
))


# ---------------------------------------------------------------------------
# CAME (Luo et al. 2023) — Adafactor + factored confidence rescaling
# ---------------------------------------------------------------------------

def _came_quant_slots(bk: Bucket, hp: dict) -> tuple:
    """SlotSpecs for CAME: quantize the row/col second-moment AND
    confidence stats (both denominator-side -> sqrt-companded under int8),
    plus the full-size momentum with blockwise sub-row scales; the dense
    fallback quantizes whole (its v/u buffers companded the same way)."""
    del hp
    if bk.factorized:
        return (SlotSpec(True, block=MOMENTUM_QUANT_BLOCK),) + (
            SlotSpec(True, sqrt=True),) * 4
    kind = "dense_flat" if bk.fused else None
    return (SlotSpec(True, kind),) + (SlotSpec(True, kind, sqrt=True),) * 2


def _came_init(bk: Bucket, hp: dict):
    k = bk.stack
    if bk.factorized:
        shape = bk.geometry
        m = _zeros((k,) + shape)
        row = (k,) + shape[:-1]
        col = (k,) + shape[:-2] + shape[-1:]
        return (m, _zeros(row), _zeros(col), _zeros(row), _zeros(col))  # m, vr, vc, ur, uc
    full = (k,) + bk.geometry
    return (_zeros(full), _zeros(full), _zeros(full))  # m, vfull, ufull


def _came_update(ctx: UpdateCtx, bk: Bucket, g: jnp.ndarray, fac):
    hp = ctx.hp
    beta1, beta2, beta3 = hp["beta1"], hp["beta2"], hp["beta3"]
    eps1, eps2 = hp["eps1"], hp["eps2"]

    def recon(r, c):
        denom = jnp.mean(r, axis=-1, keepdims=True)
        return r[..., :, None] * c[..., None, :] / (denom[..., None] + eps1)

    g2 = g * g + eps1
    if bk.factorized:
        m, vr, vc, ur, uc = fac
        vr2 = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
        vc2 = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
        vhat = recon(vr2, vc2)
    else:
        m, vfull, ufull = fac
        vfull2 = beta2 * vfull + (1 - beta2) * g2
        vhat = vfull2
    u = g / jnp.sqrt(vhat + eps1)
    u = u / jnp.maximum(1.0, _per_leaf_rms(u, bk) / hp["clip_threshold"])
    m2 = beta1 * m + (1 - beta1) * u
    # confidence: instability of momentum vs update
    inst = (u - m2) ** 2 + eps2
    if bk.factorized:
        ur2 = beta3 * ur + (1 - beta3) * jnp.mean(inst, axis=-1)
        uc2 = beta3 * uc + (1 - beta3) * jnp.mean(inst, axis=-2)
        uhat = recon(ur2, uc2)
        new_fac = (m2, vr2, vc2, ur2, uc2)
    else:
        ufull2 = beta3 * ufull + (1 - beta3) * inst
        uhat = ufull2
        if bk.fused:
            m2c = constrain(m2, "dense_flat", meta=bk.state_axes)
            new_fac = (m2c, constrain(vfull2, "dense_flat", meta=bk.state_axes),
                       constrain(ufull2, "dense_flat", meta=bk.state_axes))
        else:
            new_fac = (m2, vfull2, ufull2)
    return m2 / jnp.sqrt(uhat + eps2), new_fac


_CAME = register(Family(
    name="came",
    defaults=dict(
        lr=1e-3, beta1=0.9, beta2=0.999, beta3=0.9999, eps1=1e-30, eps2=1e-16,
        clip_threshold=1.0, weight_decay=0.0, bucket=True, fuse_dense=False,
        quant=None, transport=None, transport_flush_every=8, telemetry=True,
    ),
    make_plan_fn=lambda hp: lasttwo_planner(),
    init_bucket=_came_init,
    update_bucket=_came_update,
    fuse_dense_ok=True,          # segment-aware RMS clip (see adafactor)
    quant_slots=_came_quant_slots,
))


# ---------------------------------------------------------------------------
# CAME-conf (registry-composition demo) — CAME + confidence-clipped output
# ---------------------------------------------------------------------------

def _came_conf_update(ctx: UpdateCtx, bk: Bucket, g: jnp.ndarray, fac):
    """CAME update whose confidence-rescaled *output* is RMS-clipped per
    leaf a second time: the ``u - m`` instability estimate spikes early in
    training (and after quantized-state resumes), and the extra clip bounds
    the resulting step exactly like the pre-confidence clip bounds ``u``.
    State layout is identical to CAME (same ``_came_init``)."""
    u, new_fac = _came_update(ctx, bk, g, fac)
    u = u / jnp.maximum(1.0, _per_leaf_rms(u, bk) / ctx.hp["clip_threshold"])
    return u, new_fac


# registry composition (docs/optimizer_api.md): a variant family is a
# dataclasses.replace of its base entry — planner, state init, capability
# flags and quant slots are inherited, only the update math differs
register(dataclasses.replace(
    _CAME, name="came_conf", update_bucket=_came_conf_update))


# ---------------------------------------------------------------------------
# Adapprox (Zhao et al. 2024) — randomized rank-k second moment on the
# square-matricized SMMF bucket layout
# ---------------------------------------------------------------------------

def _adapprox_validate(hp: dict) -> None:
    lr = hp["lr"]
    if isinstance(lr, (int, float)) and lr < 0.0:
        raise ValueError(f"lr must be >= 0, got {lr}")
    beta1 = hp["beta1"]
    if beta1 is not None and not 0.0 <= beta1 <= 1.0:
        raise ValueError(f"beta1 must be in [0,1], got {beta1}")
    if not -1.0 <= hp["decay_rate"] <= 0.0:
        raise ValueError(f"decay_rate must be in [-1,0], got {hp['decay_rate']}")
    if not 0.0 <= hp["growth_rate"] <= 1.0:
        raise ValueError(f"growth_rate must be in [0,1], got {hp['growth_rate']}")
    if hp["weight_decay_mode"] not in ("adam", "adamw"):
        raise ValueError(
            f"weight_decay_mode must be adam|adamw, got {hp['weight_decay_mode']}")
    rank = hp["rank"]
    if not isinstance(rank, int) or isinstance(rank, bool) or rank < 1:
        raise ValueError(f"rank must be an int >= 1, got {rank!r}")


def _adapprox_plan_fn(hp: dict) -> PlanFn:
    # rank-k factors never take the (rank-1-only) fused kernel; momentum is
    # full-size (no packed sign matrix), so the plan's momentum flag — which
    # gates SMMF sign-transport pricing — stays off
    return smmf_planner(
        blocks=hp["blocks"], vector_reshape=hp["vector_reshape"],
        use_kernel=False, momentum=False, rank=hp["rank"],
    )


def _adapprox_quant_slots(bk: Bucket, hp: dict) -> tuple:
    """SlotSpecs for Adapprox: the rank-k second-moment factors quantize
    with per-(stack row, factor column) scales — the QR basis and the
    projected coefficients live on very different magnitudes per column —
    and the full-size momentum with blockwise sub-row scales. Both are
    signed (range-finder output / momentum), so linear code, no
    companding; the non-negative reconstruction is clamped in the update
    instead."""
    if bk.factorized:
        facs = (SlotSpec(True, "smmf_rows", percol=True),
                SlotSpec(True, "smmf_cols", percol=True))
        if hp["beta1"] is not None:
            return (SlotSpec(True, "smmf_matrix",
                             block=MOMENTUM_QUANT_BLOCK),) + facs
        return facs
    kind = "dense_flat" if bk.fused else None
    v = SlotSpec(True, kind, sqrt=True)
    return (SlotSpec(True, kind), v) if hp["beta1"] is not None else (v,)


def _adapprox_init(bk: Bucket, hp: dict):
    k = bk.size
    momentum = hp["beta1"] is not None
    if bk.factorized:
        b, n, m = bk.geometry
        facs = (_zeros((k * b, n, bk.rank)),                     # R_v
                _zeros((k * b, m, bk.rank)))                     # C_v
        if momentum:
            return (_zeros((k * b, n, m)),) + facs               # m (full)
        return facs
    (numel,) = bk.geometry
    v = (_zeros((bk.stack, numel)),)
    return ((_zeros((bk.stack, numel)),) + v) if momentum else v


def _adapprox_update(ctx: UpdateCtx, bk: Bucket, gm: jnp.ndarray, fac):
    hp = ctx.hp
    beta1, eps, t = hp["beta1"], hp["eps"], ctx.t
    beta1_t = (beta1 * jnp.power(hp["growth_rate"], t - 1.0)) if beta1 is not None else None
    beta2_t = 1.0 - jnp.power(t, hp["decay_rate"])

    if bk.factorized:
        k = bk.size
        b, n, m = bk.geometry
        kb = k * b
        gm = constrain(gm.reshape(kb, n, m), "smmf_matrix", meta=bk.state_axes)
        if beta1 is not None:
            m_, r_v, c_v = fac
        else:
            r_v, c_v = fac
        # the rank-k reconstruction is a signed range-finder product;
        # clamp it before it feeds the denominator
        v_hat = jnp.maximum(nnmf_decompress_k(r_v, c_v), 0.0)
        v_t = beta2_t * v_hat + (1.0 - beta2_t) * gm * gm
        if beta1 is not None:
            m_t = beta1_t * m_ + (1.0 - beta1_t) * gm
            num = m_t
        else:
            num = gm
        u = num / (jnp.sqrt(v_t) + eps)
        # re-sketch (one-shot, Adapprox): rank-1 delegates to Algorithm 4
        r_v2, c_v2 = nnmf_compress_k(v_t, bk.rank)
        r_v2 = constrain(r_v2, "smmf_rows", meta=bk.state_axes)
        c_v2 = constrain(c_v2, "smmf_cols", meta=bk.state_axes)
        u = u.reshape(k, b * n * m)
        if beta1 is None:
            return u, (r_v2, c_v2)
        m_t = constrain(m_t, "smmf_matrix", meta=bk.state_axes)
        return u, (m_t, r_v2, c_v2)

    # dense fallback: plain Adam on the paper's beta schedules (as smmf)
    if beta1 is not None:
        m_, v_ = fac
        m2 = beta1_t * m_ + (1.0 - beta1_t) * gm
    else:
        (v_,) = fac
    v2 = beta2_t * v_ + (1.0 - beta2_t) * gm * gm
    num = m2 if beta1 is not None else gm
    u = num / (jnp.sqrt(v2) + eps)
    v2 = constrain(v2, "dense_flat", meta=bk.state_axes) if bk.fused else v2
    if beta1 is None:
        return u, (v2,)
    m2 = constrain(m2, "dense_flat", meta=bk.state_axes) if bk.fused else m2
    return u, (m2, v2)


register(Family(
    name="adapprox",
    defaults=dict(
        lr=1e-3, beta1=0.9, eps=1e-8, weight_decay=0.0, decay_rate=-0.5,
        growth_rate=0.999, rank=2, vector_reshape=True,
        weight_decay_mode="adamw", blocks=1, bucket=True, fuse_dense=True,
        quant=None, transport=None, transport_flush_every=8, telemetry=True,
    ),
    make_plan_fn=_adapprox_plan_fn,
    init_bucket=_adapprox_init,
    update_bucket=_adapprox_update,
    fuse_dense_ok=True,
    wd_mode_key="weight_decay_mode",
    validate=_adapprox_validate,
    quant_slots=_adapprox_quant_slots,
))


# ---------------------------------------------------------------------------
# H-Fac (Nguyen & Mondelli 2024) — factorized Hamiltonian descent on the
# rank-1 SMMF factored-state layout (factor-level EMAs, no recompression)
# ---------------------------------------------------------------------------

def _hfac_validate(hp: dict) -> None:
    lr = hp["lr"]
    if isinstance(lr, (int, float)) and lr < 0.0:
        raise ValueError(f"lr must be >= 0, got {lr}")
    if not 0.0 <= hp["beta1"] <= 1.0:
        raise ValueError(f"beta1 must be in [0,1], got {hp['beta1']}")
    if not 0.0 <= hp["beta2"] <= 1.0:
        raise ValueError(f"beta2 must be in [0,1], got {hp['beta2']}")
    if hp["weight_decay_mode"] not in ("adam", "adamw"):
        raise ValueError(
            f"weight_decay_mode must be adam|adamw, got {hp['weight_decay_mode']}")


def _hfac_plan_fn(hp: dict) -> PlanFn:
    # same square-matricized geometry as SMMF but no sign matrix (the
    # momentum factors are kept directly, never re-signed), so the plan's
    # momentum flag — which gates sign-transport pricing — stays off
    return smmf_planner(
        blocks=hp["blocks"], vector_reshape=hp["vector_reshape"],
        use_kernel=False, momentum=False,
    )


def _hfac_quant_slots(bk: Bucket, hp: dict) -> tuple:
    """SlotSpecs for H-Fac: all four factor vectors quantize — the (signed)
    momentum factors linearly, the (non-negative, denominator-side) second
    -moment factors sqrt-companded, per the SMMF discipline. Square
    geometries constrain the slot-3 column factor as "smmf_rows" to match
    the slot-index fallback in ``rules.opt_state_shardings`` (see there)."""
    del hp
    if bk.factorized:
        _, n, m = bk.geometry
        ckind_v = "smmf_cols" if n != m else "smmf_rows"
        return (SlotSpec(True, "smmf_rows"),
                SlotSpec(True, "smmf_cols"),
                SlotSpec(True, "smmf_rows", sqrt=True),
                SlotSpec(True, ckind_v, sqrt=True))
    kind = "dense_flat" if bk.fused else None
    return (SlotSpec(True, kind), SlotSpec(True, kind, sqrt=True))


def _hfac_init(bk: Bucket, hp: dict):
    k = bk.size
    if bk.factorized:
        b, n, m = bk.geometry
        return (_zeros((k * b, n)), _zeros((k * b, m)),     # r_m, c_m
                _zeros((k * b, n)), _zeros((k * b, m)))     # r_v, c_v
    (numel,) = bk.geometry
    return (_zeros((bk.stack, numel)), _zeros((bk.stack, numel)))  # m, v


def _hfac_update(ctx: UpdateCtx, bk: Bucket, gm: jnp.ndarray, fac):
    """Factorized Hamiltonian descent: EMAs live at the *factor* level
    (row/col means of the gradient and its square) — no decompress → EMA →
    recompress round trip. The momentum estimate is the least-squares
    additive fit ``m̂_ij = r_i + c_j − mean(r)`` (row/col means of ``m̂``
    reproduce the factors exactly), the preconditioner the Adafactor-style
    multiplicative fit."""
    hp = ctx.hp
    beta1, beta2, eps = hp["beta1"], hp["beta2"], hp["eps"]

    if bk.factorized:
        k = bk.size
        b, n, m = bk.geometry
        kb = k * b
        gm = constrain(gm.reshape(kb, n, m), "smmf_matrix", meta=bk.state_axes)
        r_m, c_m, r_v, c_v = fac
        g2 = gm * gm
        g_r = jnp.mean(gm, axis=2)
        g_c = jnp.mean(gm, axis=1)
        r_m2 = beta1 * r_m + (1.0 - beta1) * g_r
        c_m2 = beta1 * c_m + (1.0 - beta1) * g_c
        r_v2 = beta2 * r_v + (1.0 - beta2) * jnp.mean(g2, axis=2)
        c_v2 = beta2 * c_v + (1.0 - beta2) * jnp.mean(g2, axis=1)
        mhat = (r_m2[:, :, None] + c_m2[:, None, :]
                - jnp.mean(r_m2, axis=1, keepdims=True)[:, :, None])
        # the factors can only remember the additive component of the
        # momentum; the current gradient's non-additive residual enters at
        # its fresh-EMA weight so no per-entry descent signal is dropped
        ghat = (g_r[:, :, None] + g_c[:, None, :]
                - jnp.mean(g_r, axis=1, keepdims=True)[:, :, None])
        num = mhat + (1.0 - beta1) * (gm - ghat)
        vhat = (r_v2[:, :, None] * c_v2[:, None, :]
                / (jnp.mean(r_v2, axis=1, keepdims=True)[:, :, None] + eps))
        u = (num / (jnp.sqrt(vhat) + eps)).reshape(k, b * n * m)
        # square geometries constrain slot 3 as rows (see _hfac_quant_slots)
        ckind_v = "smmf_cols" if n != m else "smmf_rows"
        r_m2 = constrain(r_m2, "smmf_rows", meta=bk.state_axes)
        c_m2 = constrain(c_m2, "smmf_cols", meta=bk.state_axes)
        r_v2 = constrain(r_v2, "smmf_rows", meta=bk.state_axes)
        c_v2 = constrain(c_v2, ckind_v, meta=bk.state_axes)
        return u, (r_m2, c_m2, r_v2, c_v2)

    # dense fallback: plain EMA pair (Adam without bias correction)
    m_, v_ = fac
    m2 = beta1 * m_ + (1.0 - beta1) * gm
    v2 = beta2 * v_ + (1.0 - beta2) * gm * gm
    u = m2 / (jnp.sqrt(v2) + eps)
    if bk.fused:
        m2 = constrain(m2, "dense_flat", meta=bk.state_axes)
        v2 = constrain(v2, "dense_flat", meta=bk.state_axes)
    return u, (m2, v2)


register(Family(
    name="hfac",
    defaults=dict(
        lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0,
        vector_reshape=True, weight_decay_mode="adamw", blocks=1,
        bucket=True, fuse_dense=True, quant=None, transport=None,
        transport_flush_every=8, telemetry=True,
    ),
    make_plan_fn=_hfac_plan_fn,
    init_bucket=_hfac_init,
    update_bucket=_hfac_update,
    fuse_dense_ok=True,
    wd_mode_key="weight_decay_mode",
    validate=_hfac_validate,
    quant_slots=_hfac_quant_slots,
))


# ---------------------------------------------------------------------------
# SM3 (Anil et al. 2019) — per-axis cover-set accumulators
# ---------------------------------------------------------------------------

def _sm3_init(bk: Bucket, hp: dict):
    k = bk.size
    acc = tuple(_zeros((k, n)) for n in bk.geometry)
    if hp["beta1"] is not None:
        return (_zeros((k,) + bk.geometry), acc)
    return (acc,)


def _sm3_update(ctx: UpdateCtx, bk: Bucket, g: jnp.ndarray, fac):
    hp = ctx.hp
    beta1, eps = hp["beta1"], hp["eps"]
    k, geom = bk.size, bk.geometry
    acc = fac[-1]
    # min-combine the per-axis cover accumulators (SM3-II)
    nu = None
    for ax, a in enumerate(acc):
        bshape = [k] + [1] * len(geom)
        bshape[ax + 1] = geom[ax]
        ab = a.reshape(bshape)
        nu = ab if nu is None else jnp.minimum(nu, ab)
    nu = nu + g * g
    new_acc = tuple(
        jnp.max(nu, axis=tuple(i + 1 for i in range(len(geom)) if i != ax))
        for ax in range(len(geom))
    )
    u = g / (jnp.sqrt(nu) + eps)
    if beta1 is not None:
        m2 = beta1 * fac[0] + (1 - beta1) * u
        return m2, (m2, new_acc)
    return u, (new_acc,)


register(Family(
    name="sm3",
    defaults=dict(lr=1e-3, beta1=0.9, eps=1e-30, weight_decay=0.0, bucket=True,
                  fuse_dense=False, transport=None, transport_flush_every=8,
                  telemetry=True),
    make_plan_fn=lambda hp: axiscover_planner(),
    init_bucket=_sm3_init,
    update_bucket=_sm3_update,
    fuse_dense_ok=False,  # every leaf is axis-covered; no dense fallback
))


# ---------------------------------------------------------------------------
# Adam / AdamW (Kingma & Ba 2014; Loshchilov & Hutter 2019) — dense engine
# ---------------------------------------------------------------------------

def _adam_init(bk: Bucket, hp: dict):
    full = (bk.stack,) + bk.geometry
    return (_zeros(full), _zeros(full))  # m, v


def _adam_update(ctx: UpdateCtx, bk: Bucket, g: jnp.ndarray, fac):
    hp = ctx.hp
    b1, b2, t = hp["b1"], hp["b2"], ctx.t
    m, v = fac
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    if hp["bias_correction"]:
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
    else:
        mhat, vhat = m2, v2
    u = mhat / (jnp.sqrt(vhat) + hp["eps"])
    if bk.fused:
        m2 = constrain(m2, "dense_flat", meta=bk.state_axes)
        v2 = constrain(v2, "dense_flat", meta=bk.state_axes)
    return u, (m2, v2)


register(Family(
    name="adam",
    defaults=dict(
        lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
        bias_correction=True, weight_decay_mode="adam", bucket=True,
        fuse_dense=True, quant=None, transport=None, transport_flush_every=8,
        telemetry=True,
    ),
    make_plan_fn=lambda hp: _dense_planner(),
    init_bucket=_adam_init,
    update_bucket=_adam_update,
    fuse_dense_ok=True,
    wd_mode_key="weight_decay_mode",
    quant_slots=lambda bk, hp: (
        SlotSpec(True, "dense_flat" if bk.fused else None),
        SlotSpec(True, "dense_flat" if bk.fused else None, sqrt=True),  # v
    ),
))


# ---------------------------------------------------------------------------
# SGD (+momentum)
# ---------------------------------------------------------------------------

def _sgd_init(bk: Bucket, hp: dict):
    if hp["momentum"]:
        return (_zeros((bk.stack,) + bk.geometry),)
    return ()


def _sgd_update(ctx: UpdateCtx, bk: Bucket, g: jnp.ndarray, fac):
    momentum = ctx.hp["momentum"]
    if momentum:
        m2 = momentum * fac[0] + g  # heavy-ball, no dampening
        if bk.fused:
            m2 = constrain(m2, "dense_flat", meta=bk.state_axes)
        return m2, (m2,)
    return g, ()


register(Family(
    name="sgd",
    defaults=dict(lr=1e-2, momentum=0.0, weight_decay=0.0, bucket=True,
                  fuse_dense=True, quant=None, transport=None,
                  transport_flush_every=8, telemetry=True),
    make_plan_fn=lambda hp: _dense_planner(),
    init_bucket=_sgd_init,
    update_bucket=_sgd_update,
    fuse_dense_ok=True,
    quant_slots=lambda bk, hp: (
        SlotSpec(True, "dense_flat" if bk.fused else None),
    ) * (1 if hp["momentum"] else 0),
))
