"""Host-offload tier for cold quantized optimizer state.

AdaPM's partial-momentum observation — most optimizer state is *cold*
most of the step — composes naturally with the qstate codec
(``repro.optim.qstate``): a quantized bucket's persistent payload is
1 byte/element, so round-tripping it over PCIe once per step costs far
less than keeping it resident in HBM. This module implements that tier:

* **cold policy** — a bucket is cold exactly when its group stores
  quantized state (``Bucket.quant``); opting a group into ``quant`` is the
  repo's declaration that its state tolerates a storage tier
  (:func:`is_cold`, mode ``"cold"``; mode ``None`` offloads nothing);
* **at-rest placement** — cold buckets' state subtrees live on the host
  memory kind between steps (:func:`place_host` outside jit,
  :func:`offload_shardings` for jit in/out shardings and elastic
  checkpoint restore);
* **in-step round-trip** — the scheduled update loop
  (``repro.optim.spec``) calls :func:`fetch` (host → device) when a cold
  bucket's turn comes and :func:`park` (device → host) on its fresh
  state, emitting the *next* cold bucket's fetch one position ahead
  (double-buffering): with the async transfer streams of a real
  accelerator the prefetch of bucket *i+1* hides behind bucket *i*'s
  update math;
* **capability probe** — host memory kinds are a backend capability
  (``pinned_host`` on TPU/GPU jaxlib builds; the CPU backend only exposes
  its default ``unpinned_host``). :func:`supported` probes once;
  unsupported backends run the tier *structurally* (placement and
  transfers are identity, the schedule and double-buffer emission are
  unchanged), so CPU tests exercise the exact program shape that runs on
  device. The **accounting** (:func:`state_bytes_split`,
  :func:`transport_bytes`) is analytic plan math keyed only on the cold
  policy, so device-HBM numbers are backend-independent.

Donation safety: fetch/park are ``jax.device_put`` ops — every cold
state array is still consumed exactly once and returned with identical
shape/dtype, so ``donate_argnums`` keeps aliasing the resident (hot)
buffers; cold buffers round-trip through the transfer engine instead of
aliasing in place. Checkpoint transparency: the state pytree is
unchanged (one logical state — keys, shapes, dtypes identical), so
``repro.checkpoint.ckpt`` saves and restores it through the ordinary
path-keyed flow; restoring onto :func:`offload_shardings` re-parks cold
payloads on the host tier directly.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import numpy as np

try:  # public alias appears in newer jax; 0.4.x keeps it private
    from jax.sharding import TransferToMemoryKind  # type: ignore
except ImportError:  # pragma: no cover - version-dependent import path
    try:
        from jax._src.sharding_impls import TransferToMemoryKind
    except ImportError:
        TransferToMemoryKind = None

PyTree = Any

MODES = (None, "cold")

# The host-side memory kind this tier parks cold state on. Real
# accelerator backends expose it as "pinned_host" (DMA-able, required for
# async device prefetch); the CPU backend's only kind is its default
# "unpinned_host", which makes every transfer an identity — the
# structural-fallback case.
HOST_KIND = "pinned_host"


def check_mode(mode: str | None) -> str | None:
    """Validate an offload mode (``None`` | ``"cold"``; "none" lifts to
    None so the CLI surface can use a plain string choice)."""
    if mode == "none":
        mode = None
    if mode not in MODES:
        raise ValueError(f"unknown offload mode {mode!r} (want one of {MODES})")
    return mode


@functools.cache
def _memory_kinds() -> tuple[str, ...]:
    try:
        dev = jax.devices()[0]
        return tuple(m.kind for m in dev.addressable_memories())
    except Exception:  # pragma: no cover - exotic backends without memories API
        return ()


@functools.cache
def default_memory_kind() -> str | None:
    """The backend's default (device-resident) memory kind — "device" on
    TPU/GPU, "unpinned_host" on the CPU backend."""
    try:
        return jax.devices()[0].default_memory().kind
    except Exception:  # pragma: no cover
        return None


def supported() -> bool:
    """True when the backend exposes a distinct pinned-host memory kind
    (so transfers actually move bytes off HBM). False on the CPU backend:
    the tier then runs structurally — same program shape, identity
    placement — while the analytic accounting stays exact."""
    return TransferToMemoryKind is not None and HOST_KIND in _memory_kinds() \
        and HOST_KIND != default_memory_kind()


# ---------------------------------------------------------------------------
# cold policy
# ---------------------------------------------------------------------------

def is_cold(bucket, mode: str | None) -> bool:
    """True when ``bucket``'s persistent state parks on the host tier:
    mode ``"cold"`` offloads exactly the quantized buckets (1-byte
    payloads — cheap to round-trip), ``None`` offloads nothing."""
    return check_mode(mode) == "cold" and bucket.quant is not None


def cold_keys(engine, mode: str | None) -> frozenset[str]:
    """Bucket keys of the engine's cold buckets under ``mode``."""
    return frozenset(bk.key for bk in engine.buckets if is_cold(bk, mode))


# ---------------------------------------------------------------------------
# in-step round-trip (traceable; identity on unsupported backends)
# ---------------------------------------------------------------------------

def fetch(tree: PyTree) -> PyTree:
    """Host → device transfer of one cold bucket's state subtree (emitted
    one bucket ahead by the scheduled update loop — the double-buffered
    prefetch). Traceable inside jit via ``TransferToMemoryKind``."""
    if not supported():
        return tree
    return jax.device_put(tree, TransferToMemoryKind(default_memory_kind()))


def park(tree: PyTree) -> PyTree:
    """Device → host transfer of one cold bucket's fresh state (the write
    half of the round-trip; the returned arrays are what the step hands
    back, so the at-rest state stays on the host tier across steps)."""
    if not supported():
        return tree
    return jax.device_put(tree, TransferToMemoryKind(HOST_KIND))


# ---------------------------------------------------------------------------
# at-rest placement (outside jit / for jit boundary shardings)
# ---------------------------------------------------------------------------

def place_host(state, engine, mode: str | None):
    """Park the cold buckets' state subtrees on the host memory kind
    (outside jit — initial placement after ``init`` or checkpoint
    restore). Identity for mode None or on unsupported backends."""
    if check_mode(mode) is None or not supported():
        return state
    cold = cold_keys(engine, mode)
    factors = {
        k: (jax.device_put(v, TransferToMemoryKind(HOST_KIND)) if k in cold
            else v)
        for k, v in state.factors.items()
    }
    return type(state)(state.step, factors)


def offload_shardings(shardings, state_shape, engine, mode: str | None):
    """Re-kind a state shardings pytree for the offload tier: cold
    buckets' leaves get ``with_memory_kind(HOST_KIND)`` so a jitted step's
    in/out shardings — and an elastic checkpoint restore
    (``repro.checkpoint.ckpt.restore(shardings=...)``) — place them on
    host directly. ``state_shape``/``shardings`` mirror ``opt.init``'s
    pytree. Identity for mode None or on unsupported backends.
    """
    if check_mode(mode) is None or not supported():
        return shardings
    cold = cold_keys(engine, mode)

    def _one(path, sh):
        if _cold_path(path, cold):
            return sh.with_memory_kind(HOST_KIND)
        return sh

    from repro.utils.tree import tree_map_with_path

    del state_shape  # structure mirrors `shardings`; kept for call symmetry
    return tree_map_with_path(_one, shardings)


def _cold_path(path: str, cold: frozenset[str]) -> bool:
    """True when a '/'-joined state-leaf path belongs to a cold bucket.

    Mirrors ``rules._bucket_key_index``: the bucket key is the last
    ``fac:``/``dense:`` segment, optionally group-prefixed by the segment
    before it (group labels cannot contain ':'); containers above it
    (``factors``) and slot paths below (``.../0/q``) are ignored."""
    import re

    parts = [p.lstrip(".") for p in path.split("/")]
    key_i = None
    for i, p in enumerate(parts):
        if re.match(r"(fac|dense):", p):
            key_i = i
    if key_i is None:
        return False
    if parts[key_i] in cold:
        return True
    return key_i >= 1 and f"{parts[key_i - 1]}/{parts[key_i]}" in cold


# ---------------------------------------------------------------------------
# analytic accounting (pure plan math; backend-independent)
# ---------------------------------------------------------------------------

def state_bytes_split(engine, state_shape, mode: str | None,
                      shardings=None) -> dict[str, int]:
    """Device-resident vs host-resident optimizer-state bytes under
    ``mode``: ``{"device": .., "host": ..}`` (their sum is the total state
    footprint). With ``shardings`` the numbers are **per-device** (each
    leaf's shard size, spec math like ``rules.sharded_state_bytes``);
    without, totals. Keyed purely on the cold policy, so the device-HBM
    claim of the offload tier (``BENCH_opt_memory.json``'s offload rows,
    asserted by ``tools/bench_compare.py``) holds on any backend.
    """
    check_mode(mode)
    cold = cold_keys(engine, mode)
    flat = jax.tree_util.tree_flatten_with_path(state_shape)[0]
    flat_sh = jax.tree.leaves(shardings) if shardings is not None \
        else [None] * len(flat)
    out = {"device": 0, "host": 0}
    for (path, leaf), sh in zip(flat, flat_sh):
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        shape = tuple(leaf.shape)
        if sh is not None:
            shape = sh.shard_shape(shape)
        nbytes = int(np.prod(shape)) * np.dtype(leaf.dtype).itemsize
        out["host" if _cold_path(name, cold) else "device"] += nbytes
    return out


def transport_bytes(engine, state_shape, mode: str | None) -> int:
    """Host↔device bytes one scheduled step moves for the offload tier:
    every cold bucket's state subtree crosses twice (prefetch in, park
    out). The PCIe-side price of the HBM the tier frees — reported next to
    ``rules.boundary_transport_bytes`` in ``benchmarks/step_time.py``."""
    split = state_bytes_split(engine, state_shape, mode)
    return 2 * split["host"]
