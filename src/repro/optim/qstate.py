"""qstate: quantized storage codec for bucketed optimizer state.

This is the layer between the leaf-plan engine and the family
``init_bucket``/``update_bucket`` callbacks (``repro.optim.spec`` installs
it when a group's resolved hyperparams carry ``quant="int8"|"fp8"``):

* persistent state tensors live as :class:`QTensor` pairs — a 1-byte
  payload (int8 or emulated fp8-e4m3, ``repro.core.quant``) plus small f32
  absmax scales (one per leading-stack row; per contained-leaf segment for
  fused flat dense rows);
* at gather time the codec **dequantizes** the quantized slots to f32
  (:func:`decode`), the family math runs unchanged in f32, and at scatter
  time the codec **re-quantizes with stochastic rounding**
  (:func:`encode`) — in-state rounding instead of an error-feedback
  buffer, so the only memory overhead over the payload is the scale rows;
* which slots of a bucket's state tuple quantize is a **family
  capability**: ``repro.optim.families.Family.quant_slots`` returns one
  :class:`SlotSpec` per state slot (SMMF quantizes its ``r``/``c`` moment
  factors — the packed sign matrix is already 1 bit/element; Adafactor and
  CAME their row/col second-moment (and confidence) stats; dense-fallback
  flat buffers quantize whole; SM3 has no entry and rejects ``quant``);
* a :class:`SlotSpec` may flag ``kernel_deq``: :func:`decode` then leaves
  the slot quantized and the SMMF family feeds the raw int8 payload +
  scales straight into the fused Pallas kernel, which dequantizes
  **in-register** (``repro.kernels.smmf_update``) — ``use_kernel`` never
  materializes a dequantized factor copy in HBM.

Layout/placement contracts: payloads keep the exact shapes (and bucket
keys) of their f32 twins, so checkpoints store raw int8 + scales through
the ordinary path-keyed flow (``repro.checkpoint.ckpt`` bit-preserves fp8
payloads) and ``rules.opt_state_shardings`` shards payloads like the f32
state and rides the scale rows on the same stack placement (constraint
kind ``"qscale"``). Donation safety is preserved: every payload/scale is
consumed once and returned fresh with identical shape/dtype/sharding.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as Q
from repro.core.plan import Bucket
from repro.distributed.ctx import constrain


class QTensor(NamedTuple):
    """One quantized state tensor: 1-byte payload + f32 absmax scales.

    ``q`` has the exact shape of the f32 tensor it replaces (int8 or
    float8_e4m3fn); ``scale`` is ``(rows, 1, ...)`` per leading-stack row,
    or ``(num_leaves,)`` per contained-leaf segment for fused flat rows.
    """

    q: jnp.ndarray
    scale: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SlotSpec:
    """Codec recipe for one slot of a bucket's state tuple.

    ``quantize=False`` passes the slot through untouched (full precision —
    e.g. the packed sign matrix, or a full-size momentum the family keeps
    exact). ``kind`` is the ``ctx.constrain`` kind re-applied to the fresh
    payload after re-quantization (None = unconstrained, matching the f32
    behavior of that slot); ``kernel_deq`` marks slots the family
    dequantizes *inside* its fused kernel — :func:`decode` passes the
    :class:`QTensor` through instead of materializing f32 in HBM.

    ``sqrt=True`` compands the slot through the sqrt domain under the
    linear ``"int8"`` code (payload ``q ≈ √x / s``, dequant ``(q·s)²``).
    This is REQUIRED for non-negative *denominator-side* state (second
    moments): a factored preconditioner keeps ``m̂/√v̂`` bounded only
    because numerator and denominator share their rank-1 row/col profile,
    and linear absmax error on ``v`` factors rounds small entries to zero
    while their ``m`` counterparts survive — the update explodes (observed
    within 10 steps on transformer_base). Companding squares the dynamic
    range the 8-bit code covers, restoring quasi-relative precision like
    the fp8-e4m3 mode (which needs no companding and ignores the flag).

    Two alternative scale granularities (mutually exclusive, both None =
    the default per-leading-row absmax):

    * ``block=<B>`` — blockwise sub-row scales (``core.quant.block_scale``
      / ``block_expand``): one absmax per ``B`` trailing-axis elements.
      For signed full-size slots (the Adafactor/CAME momentum) whose rows
      are too long for a single absmax to keep 8-bit resolution.
    * ``percol=True`` — per-column absmax over the middle axes of a
      ``(rows, ..., k)`` tensor (one scale per (stack row, factor
      column)). For rank-k factor matrices, whose k columns carry
      per-column magnitudes (QR basis vs projected coefficients).
    """

    quantize: bool
    kind: str | None = None
    kernel_deq: bool = False
    sqrt: bool = False
    block: int | None = None
    percol: bool = False


def quant_mode(hp: dict) -> str | None:
    """The group's quantization mode (validated), or None when off."""
    mode = hp.get("quant")
    if mode is None:
        return None
    return Q.check_mode(mode)


def fused_segments(bucket: Bucket) -> np.ndarray:
    """Static contained-leaf segment ids for a fused flat row (delegates to
    ``Bucket.segment_ids`` — the same source the segment-aware RMS clip in
    ``repro.optim.families`` reduces over, so scales and clips agree)."""
    return bucket.segment_ids()


def _uses_segments(bucket: Bucket) -> bool:
    return bucket.fused and bucket.size > 1


def _companded(slot: SlotSpec, mode: str) -> bool:
    return slot.sqrt and mode == "int8"


def _percol_scale(x, mode: str) -> jnp.ndarray:
    """Absmax over the middle axes: (rows, ..., k) -> (rows, 1..., k)."""
    mid = tuple(range(1, x.ndim - 1))
    s = jnp.max(jnp.abs(x), axis=mid, keepdims=True) / Q.qmax(mode)
    return jnp.maximum(s.astype(jnp.float32), Q._SCALE_FLOOR)


def _quantize_slot(x, bucket: Bucket, slot: SlotSpec, mode: str,
                   key=None) -> QTensor:
    if _companded(slot, mode):
        x = jnp.sqrt(jnp.maximum(x, 0.0))
    if slot.block is not None:
        scale = Q.block_scale(x, slot.block, mode)
        full = Q.block_expand(scale, slot.block, x.shape[-1])
        return QTensor(Q.quantize(x, full, mode, key=key), scale)
    if slot.percol:
        scale = _percol_scale(x, mode)
        return QTensor(Q.quantize(x, scale, mode, key=key), scale)
    if _uses_segments(bucket):
        seg = fused_segments(bucket)
        scale = Q.segment_scale(x, seg, bucket.size, mode)
        row = scale[seg].reshape(x.shape)
        return QTensor(Q.quantize(x, row, mode, key=key), scale)
    scale = Q.row_scale(x, mode)
    return QTensor(Q.quantize(x, scale, mode, key=key), scale)


def dequantize_slot(qt: QTensor, bucket: Bucket, slot: SlotSpec,
                    mode: str) -> jnp.ndarray:
    """f32 view of one quantized slot (segment-aware for fused rows,
    blockwise/per-column-scale-aware, un-companding ``sqrt`` slots)."""
    if slot.block is not None:
        full = Q.block_expand(qt.scale, slot.block, qt.q.shape[-1])
        x = Q.dequantize(qt.q, full)
    elif _uses_segments(bucket):
        row = qt.scale[fused_segments(bucket)].reshape(qt.q.shape)
        x = Q.dequantize(qt.q, row)
    else:
        x = Q.dequantize(qt.q, qt.scale)
    if _companded(slot, mode):
        x = x * x
    return x


def encode_init(slots, bucket: Bucket, hp: dict, state):
    """Quantize a freshly-initialized bucket state tuple (round-to-nearest
    — init state is exact zeros, which quantize losslessly)."""
    mode = quant_mode(hp)
    return tuple(
        _quantize_slot(x, bucket, s, mode) if s.quantize else x
        for s, x in zip(slots, state, strict=True)
    )


def decode(slots, bucket: Bucket, hp: dict, state):
    """Dequantize a stored state tuple for the family math (the gather-side
    half of the codec). ``kernel_deq`` slots stay :class:`QTensor` — the
    family's fused kernel dequantizes them in-register."""
    mode = quant_mode(hp)
    return tuple(
        (x if s.kernel_deq else dequantize_slot(x, bucket, s, mode))
        if s.quantize else x
        for s, x in zip(slots, state, strict=True)
    )


def encode(slots, bucket: Bucket, hp: dict, state, key, telemetry=None):
    """Re-quantize a bucket's fresh f32 state with stochastic rounding (the
    scatter-side half). Payloads and scale rows are re-pinned to the same
    sharding kinds as their f32 twins so donation aliases in place.

    ``telemetry`` is an optional :class:`repro.obs.jit.TelemetryCollector`;
    when set, each quantized slot records its clip-saturation fraction
    (payload entries pinned at the code boundary —
    ``qstate/clip_sat/<bucket key>/s<i>``) and its requantization error
    (relative L2 of the dequantized payload vs the fresh f32 slot —
    ``qstate/requant_err/<bucket key>/s<i>``). These are the counters that
    spike when a slot's dynamic range outruns its code (the PR 5
    linear-int8 denominator failure) — see ``docs/observability.md``.
    Encoded output is identical with or without a collector.
    """
    mode = quant_mode(hp)
    out = []
    for i, (s, x) in enumerate(zip(slots, state, strict=True)):
        if not s.quantize:
            out.append(x)
            continue
        qt = _quantize_slot(x, bucket, s, mode, key=jax.random.fold_in(key, i))
        if telemetry is not None:
            from repro.obs.jit import clip_saturation, rel_error

            telemetry.record(f"qstate/clip_sat/{bucket.key}/s{i}",
                             clip_saturation(qt.q, Q.qmax(mode)))
            telemetry.record(f"qstate/requant_err/{bucket.key}/s{i}",
                             rel_error(x, dequantize_slot(qt, bucket, s, mode)))
        q, scale = qt
        if s.kind:
            q = constrain(q, s.kind, meta=bucket.state_axes)
            if scale.ndim in (2, 3):
                # per-row (2-D) and rank-k per-column / blockwise (3-D)
                # scales ride the bucket's stack placement
                scale = constrain(scale, "qscale", meta=bucket.state_axes)
        out.append(QTensor(q, scale))
    return tuple(out)


_BASE_KEY = 0x5317  # arbitrary fixed base; SR stream is a pure function of
                    # (step, bucket key, slot), so runs are reproducible


def update_key(step: jnp.ndarray, bucket: Bucket) -> jnp.ndarray:
    """Deterministic per-(step, bucket) PRNG key for stochastic rounding;
    :func:`encode` folds in the slot index per quantized slot."""
    key = jax.random.fold_in(jax.random.PRNGKey(_BASE_KEY), step)
    return jax.random.fold_in(key, zlib.crc32(bucket.key.encode()) & 0x7FFFFFFF)
