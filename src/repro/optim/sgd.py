"""SGD (+momentum) baseline."""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation, as_schedule


class SGDState(NamedTuple):
    step: jnp.ndarray
    m: dict


def sgd(lr=1e-2, momentum: float = 0.0, weight_decay: float = 0.0) -> GradientTransformation:
    """Plain SGD; ``momentum > 0`` adds a heavy-ball momentum buffer."""
    lr_fn = as_schedule(lr)

    def init(params):
        if momentum:
            (m,) = multimap(lambda p: (jnp.zeros(p.shape, jnp.float32),), params, nout=1)
        else:
            (m,) = multimap(lambda p: (jnp.zeros((0,), jnp.float32),), params, nout=1)
        return SGDState(jnp.zeros((), jnp.int32), m)

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if momentum:
                m2 = momentum * m + g
                return -lr_t * m2, m2
            return -lr_t * g, m

        updates, m = multimap(upd, grads, state.m, params, nout=2)
        return updates, SGDState(step, m)

    return GradientTransformation(init, update)
