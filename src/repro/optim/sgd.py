"""SGD (+momentum) baseline.

The math lives in the family registry (``repro.optim.families``, entry
``"sgd"``) and runs on the bucketed leaf-plan engine (dense plans,
flat-fused per dtype — momentum-free SGD holds zero state). :func:`sgd`
below is a deprecation shim building the equivalent single-group
``OptimizerSpec``.
"""

from __future__ import annotations

import warnings

from repro.optim.base import GradientTransformation


def sgd(lr=1e-2, momentum: float = 0.0, weight_decay: float = 0.0) -> GradientTransformation:
    """Deprecated shim: plain SGD; ``momentum > 0`` adds a heavy-ball
    buffer. Prefer ``build_optimizer(OptimizerSpec(family="sgd", ...))``."""
    from repro.optim.spec import OptimizerSpec, build_optimizer

    warnings.warn(
        "sgd(...) is deprecated; build via repro.optim.spec.OptimizerSpec "
        "(family='sgd') + build_optimizer", DeprecationWarning, stacklevel=2)
    hp = dict(lr=lr, momentum=momentum, weight_decay=weight_decay)
    return build_optimizer(OptimizerSpec(family="sgd", hyperparams=hp))
