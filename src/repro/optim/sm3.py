"""SM3 baseline (Anil, Gupta, Koren & Singer 2019).

SM3-II with per-axis cover sets: for a rank-d tensor, keeps one accumulator
vector per axis (memory O(sum_r n_r)). Optional momentum (the SMMF paper runs
SM3 with beta1; momentum then dominates SM3's memory — matching the paper's
tables where SM3 ~= Adafactor on Transformers).

Runs on the leaf-plan engine (repro.optim.engine): same-shape leaves stack
into one (K, ...) bucket updated by a single vectorized launch. State per
bucket (scalars lift to shape (1,)):

  factors["fac:SHAPE"] = (m (K, *shape)?, (acc_ax0 (K, n_0), acc_ax1 ...))

(the m slot is present iff beta1 is not None).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.plan import axiscover_planner
from repro.optim.base import GradientTransformation, as_schedule
from repro.optim.engine import LeafPlanEngine


class SM3State(NamedTuple):
    step: jnp.ndarray
    factors: dict  # bucket key -> (momentum?, per-axis accumulator tuple)


def sm3(lr=1e-3, beta1: float | None = 0.9, eps: float = 1e-30,
        bucket: bool = True) -> GradientTransformation:
    """SM3-II on the leaf-plan engine (see module docstring); every leaf is
    'factorized' into per-axis cover accumulators, so there are no dense
    fallback buckets to fuse."""
    lr_fn = as_schedule(lr)
    plan_fn = axiscover_planner()

    def plan(params) -> LeafPlanEngine:
        """Static leaf-plan engine for ``params`` (see LeafPlanEngine)."""
        return LeafPlanEngine(params, plan_fn, bucket=bucket)

    def init(params):
        engine = plan(params)
        factors = {}
        for bk in engine.buckets:
            k = bk.size
            acc = tuple(jnp.zeros((k, n), jnp.float32) for n in bk.geometry)
            if beta1 is not None:
                factors[bk.key] = (jnp.zeros((k,) + bk.geometry, jnp.float32), acc)
            else:
                factors[bk.key] = (acc,)
        return SM3State(jnp.zeros((), jnp.int32), factors)

    def update(grads, state, params):
        engine = plan(params)
        step = state.step + 1
        lr_t = lr_fn(step)

        flat_g = engine.leaves(grads)
        out_flat: list = [None] * len(flat_g)
        factors = {}
        for bk in engine.buckets:
            k = bk.size
            geom = bk.geometry
            fac = state.factors[bk.key]
            acc = fac[-1]
            g = engine.gather(flat_g, bk)  # (K, *geometry)
            # min-combine the per-axis cover accumulators (SM3-II)
            nu = None
            for ax, a in enumerate(acc):
                bshape = [k] + [1] * len(geom)
                bshape[ax + 1] = geom[ax]
                ab = a.reshape(bshape)
                nu = ab if nu is None else jnp.minimum(nu, ab)
            nu = nu + g * g
            new_acc = tuple(
                jnp.max(nu, axis=tuple(i + 1 for i in range(len(geom)) if i != ax))
                for ax in range(len(geom))
            )
            u = g / (jnp.sqrt(nu) + eps)
            if beta1 is not None:
                m2 = beta1 * fac[0] + (1 - beta1) * u
                u = m2
                factors[bk.key] = (m2, new_acc)
            else:
                factors[bk.key] = (new_acc,)
            engine.scatter(bk, -lr_t * u, out_flat)

        return engine.unflatten(out_flat), SM3State(step, factors)

    return GradientTransformation(init, update, plan=plan)
