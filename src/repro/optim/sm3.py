"""SM3 baseline (Anil, Gupta, Koren & Singer 2019).

SM3-II with per-axis cover sets: for a rank-d tensor, keeps one accumulator
vector per axis (memory O(sum_r n_r)). Optional momentum (the SMMF paper runs
SM3 with beta1; momentum then dominates SM3's memory — matching the paper's
tables where SM3 ~= Adafactor on Transformers).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.optim._multimap import multimap
from repro.optim.base import GradientTransformation, as_schedule


class SM3State(NamedTuple):
    step: jnp.ndarray
    m: dict    # optional momentum (full)
    acc: dict  # per-leaf tuple of per-axis accumulator vectors


def sm3(lr=1e-3, beta1: float | None = 0.9, eps: float = 1e-30) -> GradientTransformation:
    lr_fn = as_schedule(lr)

    def init(params):
        def mk(p):
            shape = p.shape if p.ndim > 0 else (1,)
            acc = tuple(jnp.zeros((n,), jnp.float32) for n in shape)
            m = jnp.zeros(p.shape, jnp.float32) if beta1 is not None else jnp.zeros((0,), jnp.float32)
            return m, acc

        m, acc = multimap(mk, params, nout=2)
        return SM3State(jnp.zeros((), jnp.int32), m, acc)

    def update(grads, state, params):
        del params
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd(g, m, acc):
            g = g.astype(jnp.float32)
            shape = g.shape if g.ndim > 0 else (1,)
            gr = g.reshape(shape)
            nu = None
            for ax, a in enumerate(acc):
                bshape = [1] * len(shape)
                bshape[ax] = shape[ax]
                ab = a.reshape(bshape)
                nu = ab if nu is None else jnp.minimum(nu, ab)
            nu = nu + gr * gr
            new_acc = tuple(
                jnp.max(nu, axis=tuple(i for i in range(len(shape)) if i != ax)) for ax in range(len(shape))
            )
            u = (gr / (jnp.sqrt(nu) + eps)).reshape(g.shape)
            if beta1 is not None:
                m2 = beta1 * m + (1 - beta1) * u
                u = m2
            else:
                m2 = m
            return -lr_t * u, m2, new_acc

        updates, m, acc = multimap(upd, grads, state.m, state.acc, nout=3)
        return updates, SM3State(step, m, acc)

    return GradientTransformation(init, update)
