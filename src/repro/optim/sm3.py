"""SM3 baseline (Anil, Gupta, Koren & Singer 2019).

SM3-II with per-axis cover sets: for a rank-d tensor, keeps one accumulator
vector per axis (memory O(sum_r n_r)). Optional momentum (the SMMF paper
runs SM3 with beta1; momentum then dominates SM3's memory — matching the
paper's tables where SM3 ~= Adafactor on Transformers).

The math lives in the family registry (``repro.optim.families``, entry
``"sm3"``) and runs on the bucketed leaf-plan engine: every leaf is
'factorized' into per-axis cover accumulators, so there are no dense
fallback buckets to fuse. :func:`sm3` below is a deprecation shim building
the equivalent single-group ``OptimizerSpec``.
"""

from __future__ import annotations

import warnings

from repro.optim.base import GradientTransformation


def sm3(lr=1e-3, beta1: float | None = 0.9, eps: float = 1e-30,
        bucket: bool = True) -> GradientTransformation:
    """Deprecated shim: SM3-II on the leaf-plan engine. Prefer
    ``build_optimizer(OptimizerSpec(family="sm3", ...))``."""
    from repro.optim.spec import OptimizerSpec, build_optimizer

    warnings.warn(
        "sm3(...) is deprecated; build via repro.optim.spec.OptimizerSpec "
        "(family='sm3') + build_optimizer", DeprecationWarning, stacklevel=2)
    hp = dict(lr=lr, beta1=beta1, eps=eps, bucket=bucket)
    return build_optimizer(OptimizerSpec(family="sm3", hyperparams=hp))
