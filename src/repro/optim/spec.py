"""OptimizerSpec: one declarative, partition-aware construction API.

The whole optimizer family is built from a single serializable dataclass
tree instead of six bespoke constructors::

    spec = OptimizerSpec(
        family="smmf",
        hyperparams={"lr": 1e-3, "decay_rate": -0.8, "blocks": 4},
        schedule={"kind": "warmup_cosine", "peak_lr": 1e-3,
                  "warmup_steps": 100, "total_steps": 10_000},
        partitions=(
            Partition(name="norms", match=r"norm|scale$|bias$", family="adam",
                      hyperparams={"lr": 3e-4}),
            Partition(name="frozen_base", match=r"^base(/|$)", freeze=True),
        ),
    )
    opt = build_optimizer(spec)           # one engine-backed transformation
    state = opt.init(params)

``partitions`` maps **label rules** to per-group overrides (like optax's
``multi_transform``): a path-regex (serializable), a programmatic
``predicate(path, leaf)``, or an explicit label pytree passed to
``build_optimizer(spec, labels=...)``. Each group may swap the optimizer
family, ``freeze`` its leaves (zero state, zero update), mask weight decay,
or override any hyperparam / engine knob (``blocks``, ``use_kernel``,
``fuse_dense``, ``bucket``). The first matching partition wins; unmatched
leaves belong to the spec's default group.

``build_optimizer`` lowers the spec onto the leaf-plan engine
(``repro.optim.engine``) with **group-aware planning**: every leaf's
:class:`~repro.core.plan.LeafPlan` carries its group label, buckets never
span groups, and fused dense rows stay per (group, dtype) — so one bucketed
update serves a mixed-family tree with the same launch accounting, sharding
constraints, and donation safety as a single-family one.

The update protocol is the widened extra-args form::

    update(grads, state, params, *, step=None, schedule=None, offload=None,
           **extras)

with ONE shared step counter in :class:`EngineState` (instead of a private
counter per family) — checkpoint-resume, donation, and every group's
schedule read the same step source; passing ``step=`` explicitly overrides
it (e.g. to re-line a restored state onto a trusted external counter).

``schedule``/``offload``/``telemetry`` are **execution-only** knobs (never
part of the spec, so :meth:`OptimizerSpec.spec_hash` and the state layout
are untouched). ``schedule="grad"`` re-emits the per-bucket updates in
reverse-mode gradient-availability order and chains them with
``lax.optimization_barrier`` links, so XLA's latency-hiding scheduler can
interleave each bucket's gather→update→scatter with the still-running
backward — bitwise-identical to the barrier order (the links are value
identities and every bucket's math is self-contained).
``offload="cold"`` routes quantized buckets' state through the host
tier (``repro.optim.offload``): each cold bucket's subtree is prefetched
host→device one schedule position ahead (double-buffered) and parked back
after its re-encode — one logical state, donation- and
checkpoint-transparent. ``telemetry=`` accepts a
:class:`repro.obs.jit.TelemetryCollector`: the update loop then records
per-bucket update-RMS, quant clip-saturation / requant error, and
transport round-trip error as f32 scalars the caller returns with its
step metrics — bitwise-identical updates either way, and mutable per
group via the ``telemetry`` hyperparam (default True, hash-excluded).

Specs round-trip through :meth:`OptimizerSpec.to_json` /
:meth:`OptimizerSpec.from_json`; :meth:`OptimizerSpec.spec_hash` is stored
in checkpoints and verified on restore (``repro.checkpoint.ckpt``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed import transport as _T
from repro.optim import families as F
from repro.optim import qstate
from repro.optim.base import (
    EngineState,
    GradientTransformation,
    Schedule,
    as_schedule,
    warmup_cosine,
)
from repro.optim.engine import LeafPlanEngine
from repro.utils.tree import tree_bytes

PyTree = Any

DEFAULT_GROUP = "default"
_NAME_RE = re.compile(r"^[A-Za-z0-9_.\-]+$")


# ---------------------------------------------------------------------------
# schedules (serializable)
# ---------------------------------------------------------------------------

def resolve_schedule(sched, hp: dict) -> Schedule:
    """Lower a serializable schedule spec to a ``step -> lr`` callable.

    ``None`` falls back to the group's constant ``lr`` hyperparam; a number
    is a constant; a dict selects a registered kind: ``{"kind": "constant",
    "value": v}`` or ``{"kind": "warmup_cosine", "peak_lr": ..,
    "warmup_steps": .., "total_steps": .., "min_ratio": 0.1}``. A callable
    passes through (programmatic use only — not serializable).
    """
    if sched is None:
        return as_schedule(hp.get("lr", 1e-3))
    if callable(sched):
        return sched
    if isinstance(sched, (int, float)):
        return as_schedule(float(sched))
    kind = sched.get("kind")
    if kind == "constant":
        return as_schedule(float(sched["value"]))
    if kind == "warmup_cosine":
        return warmup_cosine(
            float(sched["peak_lr"]), int(sched["warmup_steps"]),
            int(sched["total_steps"]), min_ratio=float(sched.get("min_ratio", 0.1)),
        )
    raise ValueError(f"unknown schedule kind: {sched!r}")


# ---------------------------------------------------------------------------
# the spec dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Partition:
    """One label rule + per-group overrides of an :class:`OptimizerSpec`.

    ``name`` labels the group and prefixes its state keys
    (``<name>/fac:...``), so it must stay stable across restarts.
    ``match`` is a path regex (``re.search`` over the '/'-joined leaf
    path); ``predicate`` a programmatic ``(path, leaf) -> bool`` override
    (not serializable). ``family=None`` inherits the spec's family;
    ``freeze=True`` gives the group zero state and zero updates (the
    LoRA-frozen-base case). ``hyperparams`` override the group family's
    defaults (including engine knobs); ``schedule`` overrides the spec
    schedule, and a partition that overrides ``lr`` without its own
    schedule gets that constant lr (the spec-level schedule does not shadow
    an explicit per-group lr). Weight-decay masking is expressed the same
    way: a partition with ``hyperparams={"weight_decay": 0.0}`` exempts its
    leaves.

    ``state_sharding`` overrides the mesh axes the group's bucket stacks
    shard over — an ordered axis-name preference chain replacing the
    default ``("pod", "data")`` (e.g. ``("model",)`` puts an expert group's
    moment stacks on the expert-parallel axis). Placement-only: it changes
    neither state keys nor shapes, so it is excluded from
    :meth:`OptimizerSpec.spec_hash` and re-shardable on restore. Lowered
    through both ``repro.distributed.rules.opt_state_shardings`` and the
    engine's in-update constraints (``docs/sharding.md``).
    """

    name: str
    match: str | None = None
    predicate: Callable[[str, Any], bool] | None = None
    family: str | None = None
    freeze: bool = False
    hyperparams: dict = dataclasses.field(default_factory=dict)
    schedule: dict | float | None = None
    state_sharding: tuple[str, ...] | None = None

    def __post_init__(self):
        if not _NAME_RE.match(self.name) or self.name in (DEFAULT_GROUP, "factors"):
            raise ValueError(
                f"partition name must match {_NAME_RE.pattern} and not be "
                f"{DEFAULT_GROUP!r} or 'factors', got {self.name!r}")
        if self.state_sharding is not None:
            axes = tuple(self.state_sharding)
            if isinstance(self.state_sharding, str) or not axes or \
                    len(set(axes)) != len(axes) or not all(
                        isinstance(a, str) and _NAME_RE.match(a) for a in axes):
                raise ValueError(
                    f"state_sharding must be a non-repeating tuple of mesh "
                    f"axis names, got {self.state_sharding!r}")
            object.__setattr__(self, "state_sharding", axes)

    def matches(self, path: str, leaf) -> bool:
        """True when this partition claims the leaf at ``path``. A partition
        with neither ``match`` nor ``predicate`` claims nothing by rule — it
        exists to be targeted via explicit ``labels=`` at build time."""
        if self.predicate is not None:
            return bool(self.predicate(path, leaf))
        return self.match is not None and re.search(self.match, path) is not None


@dataclasses.dataclass(frozen=True)
class OptimizerSpec:
    """Declarative optimizer construction spec (see module docstring).

    ``family`` + ``hyperparams`` configure the default group; ``schedule``
    the default learning-rate schedule; ``partitions`` the label-rule
    groups, tried in order (first match wins).
    """

    family: str = "smmf"
    hyperparams: dict = dataclasses.field(default_factory=dict)
    schedule: dict | float | None = None
    partitions: tuple[Partition, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "partitions", tuple(self.partitions))
        names = [p.name for p in self.partitions]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate partition names: {names}")

    # -- serialization -----------------------------------------------------

    def to_json(self, indent: int | None = None) -> str:
        """Serialize to JSON. Raises ValueError on non-serializable content
        (callable schedules/predicates/hyperparams are programmatic-only)."""
        def enc(o):
            raise ValueError(f"OptimizerSpec is not serializable: {o!r} "
                             "(callable predicates/schedules/hyperparams are "
                             "programmatic-only)")

        d = dataclasses.asdict(self)
        for p in d["partitions"]:
            if p.pop("predicate") is not None:
                raise ValueError("partitions with predicates are not "
                                 "serializable; use a match regex or labels")
        return json.dumps(d, indent=indent, sort_keys=True, default=enc)

    @staticmethod
    def from_json(text: str) -> "OptimizerSpec":
        """Inverse of :meth:`to_json` (``from_json(to_json(s)) == s``)."""
        d = json.loads(text)

        def detuple(v):
            if isinstance(v, list):
                return tuple(detuple(x) for x in v)
            return v

        def hp(d_):
            return {k: detuple(v) for k, v in d_.items()}

        parts = tuple(
            Partition(name=p["name"], match=p.get("match"),
                      family=p.get("family"), freeze=bool(p.get("freeze", False)),
                      hyperparams=hp(p.get("hyperparams", {})),
                      schedule=p.get("schedule"),
                      state_sharding=detuple(p.get("state_sharding")))
            for p in d.get("partitions", ())
        )
        return OptimizerSpec(family=d["family"], hyperparams=hp(d.get("hyperparams", {})),
                             schedule=d.get("schedule"), partitions=parts)

    def spec_hash(self) -> str:
        """Stable 16-hex digest of the **layout-relevant** spec — stored in
        checkpoint manifests and verified on restore.

        Execution-only knobs (``use_kernel``, ``kernel_block``,
        ``interpret``), the learning rate, the schedule and the per-group
        ``state_sharding`` placement override are excluded: they never
        change the state layout, so a checkpoint written with the fused TPU
        kernel resumes on CPU, a re-sharded restore is not refused, and an
        lr re-tune on resume is not refused. Everything that can change
        state keys/shapes or the family math structure (families,
        partitions, ``bucket``, ``fuse_dense``, ``blocks``,
        ``beta1``-presence, and the qstate storage mode ``quant`` — int8
        payloads+scales are a different checkpoint layout than f32) is
        covered.
        """
        # transport is execution-only too: it round-trips the *gradient*
        # through the wire format inside the step and carries zero state,
        # so toggling it never changes the checkpoint layout; telemetry is
        # pure read-side scalar reductions (repro.obs.jit) with no state
        # and no effect on the update math
        skip = ("use_kernel", "kernel_block", "interpret", "lr",
                "transport", "transport_flush_every", "telemetry")
        d = dataclasses.asdict(self)
        d.pop("schedule", None)

        def hp_form(hp: dict, family: str | None) -> dict:
            out = {k: v for k, v in hp.items() if k not in skip}
            # momentum-free SMMF changed its state layout (5 slots ->
            # (r_v, c_v)) in PR 5; the spec itself is unchanged, so the
            # hash must carry a layout version or a checkpoint written by
            # the old code would restore its r_m/c_m factors into the new
            # r_v/c_v slots (same shapes!) without any error
            if (family or self.family) == "smmf" and \
                    "beta1" in hp and hp["beta1"] is None:
                out["_smmf_momentum_free_layout"] = 2
            # the full-size Adafactor/CAME momentum slot became a
            # blockwise-scaled QTensor (was exact f32) — under quant, the
            # stored layout differs from older checkpoints, so version it
            if (family or self.family) in ("adafactor", "came", "came_conf") \
                    and hp.get("quant"):
                out["_factored_momentum_quant_layout"] = 1
            return out

        d["hyperparams"] = hp_form(d["hyperparams"], None)
        for p in d["partitions"]:
            p.pop("predicate", None)
            p.pop("schedule", None)
            p.pop("state_sharding", None)
            p["hyperparams"] = hp_form(p["hyperparams"], p.get("family"))

        def enc(o):
            raise ValueError(f"OptimizerSpec hash needs serializable "
                             f"layout-relevant content, got {o!r}")

        text = json.dumps(d, sort_keys=True, default=enc)
        return hashlib.sha256(text.encode()).hexdigest()[:16]

    def with_rule(self, rule: str) -> "OptimizerSpec":
        """Append one CLI partition rule (see :func:`parse_rule`)."""
        part = parse_rule(rule, index=len(self.partitions))
        return dataclasses.replace(self, partitions=self.partitions + (part,))


def parse_rule(rule: str, index: int = 0) -> Partition:
    """Parse an inline CLI rule ``PATTERN=FAMILY[,KEY=VALUE...]``.

    ``PATTERN`` is the path regex (must not contain '='); ``FAMILY`` a
    registered family name or the keyword ``freeze``; trailing ``KEY=VALUE``
    pairs become hyperparam overrides (values parsed as Python literals,
    falling back to strings). The group is named ``<FAMILY><index>``, e.g.
    ``--optim-rule 'norm|bias=adam,lr=3e-4'`` -> group ``adam0``.

    ``state_sharding`` is recognized as the :class:`Partition` placement
    field rather than a hyperparam — ``--optim-rule
    'moe/=smmf,state_sharding=("model",)'`` shards that group's bucket
    stacks over the model axis (a bare axis name is lifted to a 1-tuple).
    """
    pat, sep, rhs = rule.partition("=")
    if not sep or not pat or not rhs:
        raise ValueError(f"bad --optim-rule {rule!r}: want PATTERN=FAMILY[,K=V...]")
    # split on commas at bracket depth 0 only, so literal values like
    # kernel_block=(512,512) stay whole
    parts, depth, cur = [], 0, []
    for ch in rhs:
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
            continue
        depth += ch in "([{"
        depth -= ch in ")]}"
        cur.append(ch)
    parts.append("".join(cur))
    fam = parts[0].strip()
    hp: dict = {}
    for kv in parts[1:]:
        k, s, v = kv.partition("=")
        if not s:
            raise ValueError(f"bad override {kv!r} in --optim-rule {rule!r}")
        import ast

        try:
            hp[k.strip()] = ast.literal_eval(v.strip())
        except (ValueError, SyntaxError):
            hp[k.strip()] = v.strip()
    if fam == "freeze":
        if hp:
            raise ValueError(f"freeze rule {rule!r} takes no overrides")
        return Partition(name=f"freeze{index}", match=pat, freeze=True)
    F.get_family(fam)  # validate early: unknown family -> ValueError
    state_sharding = hp.pop("state_sharding", None)
    if isinstance(state_sharding, str):
        state_sharding = (state_sharding,)
    return Partition(name=f"{fam}{index}", match=pat, family=fam,
                     hyperparams=hp, state_sharding=state_sharding)


# ---------------------------------------------------------------------------
# lowering: spec -> groups -> engine-backed GradientTransformation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _Group:
    """A resolved partition: registry entry + merged hyperparams + schedule."""

    name: str                    # "" for the default group (no key prefix)
    label: str                   # user-facing name ("default" or partition name)
    entry: F.Family | None       # None iff frozen
    hp: dict
    lr_fn: Schedule | None
    freeze: bool = False
    state_axes: tuple[str, ...] | None = None  # state_sharding override


def _merge_hp(entry: F.Family, *layers: dict, strict: tuple[dict, ...] = ()) -> dict:
    """Merge hyperparam layers onto the family defaults. ``strict`` layers
    must only contain keys the family knows; other layers (inherited from a
    different base family) are filtered to known keys."""
    known = set(entry.defaults)
    for layer in strict:
        unknown = set(layer) - known
        if unknown:
            raise ValueError(
                f"unknown hyperparams for family {entry.name!r}: "
                f"{sorted(unknown)} (known: {sorted(known)})")
    out = dict(entry.defaults)
    for layer in layers:
        out.update({k: v for k, v in layer.items() if k in known})
    for layer in strict:
        out.update(layer)
    return out


def _check_quant(entry: F.Family, hp: dict) -> None:
    """Validate a group's ``quant`` hyperparam against the family's qstate
    capability (families without ``quant_slots`` — sm3 — also reject the
    key itself via their ``defaults`` schema)."""
    mode = hp.get("quant")
    if mode is None:
        return
    from repro.core.quant import check_mode

    check_mode(mode)
    if entry.quant_slots is None:
        raise ValueError(
            f"family {entry.name!r} has no quantizable state (quant={mode!r})")


def _check_transport(hp: dict) -> None:
    """Validate a group's gradient-transport hyperparams (every family
    accepts them — transport is engine-level, family-math-agnostic)."""
    from repro.distributed.transport import check_flush_every, check_mode

    mode = check_mode(hp.get("transport"))
    if mode is not None:
        check_flush_every(hp.get("transport_flush_every", 8))


def _resolve_groups(spec: OptimizerSpec) -> list[_Group]:
    """[default group] + one group per partition, hyperparams validated."""
    base = F.get_family(spec.family)
    base_hp = _merge_hp(base, strict=(spec.hyperparams,))
    if not base.fuse_dense_ok:
        base_hp["fuse_dense"] = False
    if base.validate:
        base.validate(base_hp)
    _check_quant(base, base_hp)
    _check_transport(base_hp)
    groups = [_Group("", DEFAULT_GROUP, base, base_hp,
                     resolve_schedule(spec.schedule, base_hp))]
    for p in spec.partitions:
        if p.freeze:
            groups.append(_Group(p.name, p.name, None, {}, None, freeze=True))
            continue
        entry = F.get_family(p.family) if p.family else base
        # inherit the spec-level hyperparams that the group's family knows,
        # then apply the partition's own overrides strictly
        hp = _merge_hp(entry, spec.hyperparams, strict=(p.hyperparams,))
        if not entry.fuse_dense_ok:
            hp["fuse_dense"] = False
        if entry.validate:
            entry.validate(hp)
        _check_quant(entry, hp)
        _check_transport(hp)
        # schedule precedence: the partition's own schedule wins; a partition
        # that overrides "lr" (without a schedule) means that lr — it must
        # NOT be shadowed by the spec-level schedule; otherwise inherit
        if p.schedule is not None:
            sched = p.schedule
        elif "lr" in p.hyperparams:
            sched = None  # resolve_schedule falls back to the group's lr
        else:
            sched = spec.schedule
        groups.append(_Group(p.name, p.name, entry, hp,
                             resolve_schedule(sched, hp),
                             state_axes=p.state_sharding))
    return groups


def _leaf_paths(params: PyTree) -> list[str]:
    """'/'-joined leaf paths in ``jax.tree.flatten`` leaf order."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return ["/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
            for path, _ in flat]


def _assign_groups(spec: OptimizerSpec, groups: list[_Group], params: PyTree,
                   labels: PyTree | None) -> list[int]:
    """Group index per flat leaf: explicit ``labels`` win, else the first
    matching partition, else the default group (index 0)."""
    leaves, treedef = jax.tree.flatten(params)
    if labels is not None:
        by_label = {g.label: i for i, g in enumerate(groups)}
        flat_labels = treedef.flatten_up_to(labels)
        out = []
        for lbl in flat_labels:
            if lbl not in by_label:
                raise ValueError(f"label {lbl!r} names no group "
                                 f"(have: {sorted(by_label)})")
            out.append(by_label[lbl])
        return out
    paths = _leaf_paths(params)
    out = []
    for path, leaf in zip(paths, leaves):
        gi = 0
        for i, part in enumerate(spec.partitions):
            if part.matches(path, leaf):
                gi = i + 1  # groups[0] is the default
                break
        out.append(gi)
    return out


def build_optimizer(spec: OptimizerSpec, params: PyTree | None = None,
                    labels: PyTree | None = None) -> GradientTransformation:
    """Lower an :class:`OptimizerSpec` to one engine-backed transformation.

    ``params`` is optional and only used to validate the spec's partition
    coverage eagerly (construction is otherwise shape-agnostic — the engine
    plans lazily per params tree, exactly like the legacy constructors).
    ``labels`` is an explicit label pytree (same structure as params, leaf
    values = group names) overriding the partition match rules.

    The result's ``update`` follows the widened protocol
    ``update(grads, state, params, *, step=None, **extras)`` and its
    ``plan(params)`` exposes the group-aware leaf-plan engine for
    launch/bucket introspection.
    """
    groups = _resolve_groups(spec)
    by_name = {g.name: g for g in groups}

    def _engine(params) -> LeafPlanEngine:
        assign = _assign_groups(spec, groups, params, labels)

        def plan_fn(i: int, shape: tuple[int, ...]):
            g = groups[assign[i]]
            if g.freeze:
                import math as _math

                numel = int(_math.prod(shape)) if shape else 1
                from repro.core.plan import LeafPlan

                return LeafPlan(i, shape, False, (numel,), group=g.name,
                                freeze=True)
            p = g.entry.make_plan_fn(g.hp)(i, shape)
            return dataclasses.replace(
                p, group=g.name,
                solo=not g.hp.get("bucket", True),
                fuse=(not p.factorized) and bool(g.hp.get("fuse_dense", False)),
                state_axes=g.state_axes,
                quant=g.hp.get("quant"),
                transport=_T.check_mode(g.hp.get("transport")),
                transport_flush_every=g.hp.get("transport_flush_every", 8),
            )

        return LeafPlanEngine(params, plan_fn)

    def plan(params) -> LeafPlanEngine:
        """Static group-aware leaf-plan engine for ``params``."""
        return _engine(params)

    if params is not None:
        _engine(params)  # eager validation of rules against a real tree

    def _group_of(bucket) -> _Group:
        return by_name[bucket.plans[0].group]

    def init(params):
        engine = _engine(params)
        factors = {}
        for bk in engine.buckets:
            g = _group_of(bk)
            raw = g.entry.init_bucket(bk, g.hp)
            if g.hp.get("quant"):
                raw = qstate.encode_init(
                    g.entry.quant_slots(bk, g.hp), bk, g.hp, raw)
            factors[bk.key] = raw
        return EngineState(jnp.zeros((), jnp.int32), factors)

    def update(grads, state, params, *, step=None, schedule=None,
               offload=None, telemetry=None, **extras):
        del extras  # forward-compat: callers may thread e.g. loss scales
        from repro.optim import offload as O

        engine = _engine(params)
        new_step = state.step + 1 if step is None else jnp.asarray(step, jnp.int32)
        t = new_step.astype(jnp.float32)

        flat_g = list(engine.leaves(grads))
        flat_p = engine.leaves(params)
        # grad-coupled ("adam" mode, paper Algo 6) weight decay, per group
        for p in engine.plans:
            g = by_name[p.group]
            if p.freeze or not g.hp.get("weight_decay"):
                continue
            if g.entry.wd_mode(g.hp) == "adam":
                flat_g[p.index] = (flat_g[p.index].astype(jnp.float32)
                                   + g.hp["weight_decay"]
                                   * flat_p[p.index].astype(jnp.float32))

        out_flat: list = [None] * len(flat_g)
        for p in engine.plans:
            if p.freeze:  # no state, zero update
                out_flat[p.index] = jnp.zeros(p.shape, jnp.float32)

        # dispatch order + interleave links (module docstring): under a
        # schedule the buckets are emitted in grad-availability order and
        # chained through lax.optimization_barrier — a value identity that
        # orders bucket i's update before bucket i+1's gather, giving the
        # latency-hiding scheduler an overlap-friendly serialization
        # instead of one flat all-at-the-end update block
        order = engine.schedule(schedule)
        chained = schedule is not None
        cold = O.cold_keys(engine, offload)
        token = t  # barrier-chain carrier (any tiny already-live scalar)

        # double-buffered host prefetch: emit the fetch for the cold bucket
        # at schedule position `pos` (one position AHEAD of the bucket
        # being updated, so the transfer overlaps the current bucket's math)
        fetched: dict = {}

        def _prefetch(pos: int) -> None:
            if pos < len(order):
                nxt = engine.buckets[order[pos]]
                if nxt.key in cold:
                    fetched[nxt.key] = O.fetch(state.factors[nxt.key])

        _prefetch(0)
        factors = {}
        for j, pos in enumerate(order):
            bk = engine.buckets[pos]
            g = _group_of(bk)
            ctx = F.UpdateCtx(step=new_step, t=t, hp=g.hp)
            # telemetry (repro.obs.jit): execution-only collector of scalar
            # reductions riding out with the step metrics; the per-group
            # "telemetry" hyperparam (spec_hash-excluded) can mute a group
            tel = telemetry if (telemetry is not None
                                and g.hp.get("telemetry", True)) else None
            st = fetched.pop(bk.key) if bk.key in cold \
                else state.factors[bk.key]
            _prefetch(j + 1)
            gm = engine.gather(flat_g, bk)
            if chained:
                gm, token = jax.lax.optimization_barrier((gm, token))
            # gradient transport (repro.distributed.transport): round-trip
            # the gathered gradient through the wire format — stateless,
            # seeded SR, so there is no EF buffer and nothing to checkpoint
            if bk.transport:
                gm = _T.compress_bucket(bk.transport, bk, gm, new_step,
                                        bk.transport_flush_every,
                                        telemetry=tel)
            # qstate codec (repro.optim.qstate): dequantize stored slots at
            # gather, run the family math in f32, re-quantize with
            # stochastic rounding at scatter (kernel_deq slots skip the
            # decode — the fused kernel dequantizes in-register)
            slots = None
            if g.hp.get("quant"):
                slots = g.entry.quant_slots(bk, g.hp)
                st = qstate.decode(slots, bk, g.hp, st)
            u, new_st = g.entry.update_bucket(ctx, bk, gm, st)
            if tel is not None:
                from repro.obs.jit import rms as _rms

                tel.record(f"optim/update_rms/{bk.key}", _rms(u))
            if slots is not None:
                new_st = qstate.encode(slots, bk, g.hp, new_st,
                                       qstate.update_key(new_step, bk),
                                       telemetry=tel)
            if bk.key in cold:
                new_st = O.park(new_st)
            factors[bk.key] = new_st
            if chained:
                u, token = jax.lax.optimization_barrier((u, token))
            engine.scatter(bk, -g.lr_fn(new_step) * u, out_flat)

        # decoupled ("adamw" mode, paper Algo 7) weight decay, per group
        for p in engine.plans:
            g = by_name[p.group]
            if p.freeze or not g.hp.get("weight_decay"):
                continue
            if g.entry.wd_mode(g.hp) == "adamw":
                out_flat[p.index] = (out_flat[p.index]
                                     - g.lr_fn(new_step) * g.hp["weight_decay"]
                                     * flat_p[p.index].astype(jnp.float32))
        return engine.unflatten(out_flat), EngineState(new_step, factors)

    return GradientTransformation(init, update, plan=plan, spec=spec)


# ---------------------------------------------------------------------------
# per-group accounting
# ---------------------------------------------------------------------------

def state_bytes_by_group(opt: GradientTransformation, params: PyTree) -> dict[str, int]:
    """Persistent optimizer-state bytes per partition group (frozen groups
    report 0 — the LoRA frozen-base memory win). Shape-only: works on
    abstract params, no allocation."""
    if opt.spec is None or opt.plan is None:
        raise ValueError("state_bytes_by_group needs a spec-built optimizer")
    engine = opt.plan(params)
    state = jax.eval_shape(opt.init, params)
    by_key = {bk.key: bk for bk in engine.buckets}
    labels = {p.group or DEFAULT_GROUP for p in engine.plans}
    out = {lbl: 0 for lbl in labels}
    for key, sub in state.factors.items():
        grp = by_key[key].plans[0].group or DEFAULT_GROUP
        out[grp] += tree_bytes(sub)
    return out
