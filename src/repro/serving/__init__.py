from repro.serving.engine import GenerationEngine, Request
from repro.serving.legacy import LegacyRequest, LegacySlotEngine
from repro.serving.pages import (
    RESERVED_PAGES,
    PageAllocator,
    PagedKV,
    gather_pages,
    init_paged_kv,
    pages_needed,
)
from repro.serving.sampling import SampleParams, sample_tokens

__all__ = [
    "GenerationEngine",
    "Request",
    "LegacyRequest",
    "LegacySlotEngine",
    "RESERVED_PAGES",
    "PageAllocator",
    "PagedKV",
    "gather_pages",
    "init_paged_kv",
    "pages_needed",
    "SampleParams",
    "sample_tokens",
]
