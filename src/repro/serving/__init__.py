from repro.serving.engine import GenerationEngine

__all__ = ["GenerationEngine"]
