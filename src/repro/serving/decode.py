"""Jittable paged prefill / decode steps for the serving engine.

Pure functions over (params, device arrays); the engine jits them once per
static shape bucket. Three orthogonal axes, all resolved at trace time:

* **family** — decoder-only (dense / MoE, RoPE positions) or enc-dec
  (learned positions, per-layer cross-attention to the slot's encoder
  states, which stay dense — they are written once at admission and read
  every step, so paging buys nothing there);
* **KV quantization** — ``kv_quant in ("int8", "fp8")`` stores page
  payloads through ``core.quant`` with one f32 scale per (token, head);
  the cache is write-once, so plain round-to-nearest is exact enough and
  no stochastic rounding key is threaded (unlike the optimizer's
  re-quantize-every-step loop);
* **attention path** — ``use_kernel`` routes decode through the
  ``flash_decode_paged`` Pallas kernel (scalar-prefetched block table,
  in-register dequant, no gathered copy); otherwise the XLA reference
  path gathers pages densely. Under a mesh with a divisible ``model``
  axis the kernel runs inside ``shard_map`` split over KV heads — the
  same heads-over-model placement ``rules.cache_shardings`` uses.

Decode threads the whole pool through the layer scan as a carry (the
PR 6 ``cache_as_carry`` pattern): each layer scatters its one new K/V
token in place instead of rewriting a full slice.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.quant import qmax, quantize
from repro.distributed.ctx import constrain
from repro.kernels.flash_decode import flash_decode_paged, flash_decode_paged_ref
from repro.models import encdec as E
from repro.models import layers as L
from repro.models import lm as LM
from repro.models.config import ModelConfig
from repro.serving.sampling import sample_tokens

PyTree = Any
_SCALE_FLOOR = 1e-30


def _quant_token(x, mode: str):
    """x (..., Hkv, D) f32-ish -> (payload, scale (..., Hkv) f32): one
    absmax scale per (token, head) — the page fills append-only, so each
    arriving token carries its own exact range."""
    sc = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / qmax(mode)
    sc = jnp.maximum(sc, _SCALE_FLOOR)
    return quantize(x, sc[..., None], mode), sc


def scatter_prefill(pools: dict, kv: dict, tbl, valid, page: int,
                    kv_quant: str | None) -> dict:
    """Scatter per-layer prefill K/V (L, B, S, Hkv, D) into the pools.

    Position ``s`` of row ``b`` lands on page ``tbl[b, s // page]`` at
    offset ``s % page``; positions past ``valid[b]`` are redirected to the
    reserved scratch page 0 (their payload is garbage and never attended).
    """
    kl, bsz, s, hkv, d = kv["k"].shape
    sidx = jnp.arange(s, dtype=jnp.int32)
    pid = jnp.take_along_axis(tbl, jnp.broadcast_to(sidx[None, :] // page,
                                                    (bsz, s)), axis=1)
    pid = jnp.where(sidx[None, :] < valid[:, None], pid, 0).reshape(-1)
    off = jnp.broadcast_to(sidx[None, :] % page, (bsz, s)).reshape(-1)
    out = dict(pools)
    for name in ("k", "v"):
        flat = kv[name].reshape(kl, bsz * s, hkv, d)
        if kv_quant:
            payload, sc = _quant_token(flat, kv_quant)
            out[name] = out[name].at[:, pid, off].set(payload)
            out[f"{name}_scale"] = out[f"{name}_scale"].at[:, pid, off].set(sc)
        else:
            out[name] = out[name].at[:, pid, off].set(flat.astype(out[name].dtype))
    return out


def _paged_attn(q, kp, vp, ks, vs, pos, tbl, *, use_kernel: bool, mesh):
    """One layer's paged decode attention. q (B, Hq, D); per-layer pools
    kp/vp (P, page, Hkv, D) (+ scales (P, page, Hkv) when quantized).
    Returns (B, Hq, D) f32."""
    if not use_kernel:
        return flash_decode_paged_ref(q, kp, vp, pos, tbl,
                                      k_scale=ks, v_scale=vs)
    hkv = kp.shape[2]
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    if mesh is None or msize <= 1 or hkv % msize or q.shape[1] % msize:
        return flash_decode_paged(q, kp, vp, pos, tbl,
                                  k_scale=ks, v_scale=vs)
    # heads-over-model shard_map: q's Hq axis is h-major (head = h*grp+g),
    # so an Hq/m chunk holds exactly Hkv/m complete GQA groups — each shard
    # runs the kernel on its own heads with zero collectives.
    from jax.experimental.shard_map import shard_map

    qspec = P(None, "model", None)
    pool = P(None, None, "model", None)
    scale = P(None, None, "model")
    if ks is not None:
        fn = shard_map(
            lambda q_, k_, v_, ks_, vs_, pos_, tbl_: flash_decode_paged(
                q_, k_, v_, pos_, tbl_, k_scale=ks_, v_scale=vs_),
            mesh=mesh,
            in_specs=(qspec, pool, pool, scale, scale, P(None), P(None, None)),
            out_specs=qspec, check_rep=False)
        return fn(q, kp, vp, ks, vs, pos, tbl)
    fn = shard_map(
        lambda q_, k_, v_, pos_, tbl_: flash_decode_paged(q_, k_, v_, pos_, tbl_),
        mesh=mesh,
        in_specs=(qspec, pool, pool, P(None), P(None, None)),
        out_specs=qspec, check_rep=False)
    return fn(q, kp, vp, pos, tbl)


def _append_token(pools_kv, scales, l, pid, off, token_kv, kv_quant):
    """Scatter one decode token (B, Hkv, D) into layer ``l`` of a pool."""
    if kv_quant:
        payload, sc = _quant_token(token_kv, kv_quant)
        return (pools_kv.at[l, pid, off].set(payload),
                scales.at[l, pid, off].set(sc))
    return pools_kv.at[l, pid, off].set(token_kv.astype(pools_kv.dtype)), scales


def paged_prefill(params, tokens, valid, tbl, pools, samp, frames=None, *,
                  cfg: ModelConfig, page: int, kv_quant: str | None):
    """Batched admission: model prefill + page scatter + first-token sample.

    tokens (B, S) right-padded; valid (B,); tbl (B, S/page); samp = the
    5-tuple of per-row sampling arrays (count = 0 for the first token).
    Returns (token (B,), logits (B, Vpad), pools, enc|None).
    """
    if cfg.family == "encdec":
        enc = E.encode(params, cfg, frames)
        logits, kv = E.encdec_prefill_batch(params, cfg, tokens, valid, enc)
    else:
        enc = None
        logits, kv = LM.lm_prefill_batch(params, cfg, tokens, valid)
    pools = scatter_prefill(pools, kv, tbl, valid, page, kv_quant)
    tok = sample_tokens(logits, *samp, vocab=cfg.vocab)
    return tok, logits, pools, enc


def paged_decode(params, token, counts, tbl, pools, samp, enc=None, *,
                 cfg: ModelConfig, page: int, kv_quant: str | None,
                 use_kernel: bool, mesh=None):
    """One decode step over every slot. token (B,) last sampled tokens;
    counts (B,) tokens already resident (the new token writes at index
    ``counts`` and attention covers ``<= counts``); tbl (B, npages_bucket).
    Returns (next token (B,), updated pools dict)."""
    pos = counts.astype(jnp.int32)
    positions = pos[:, None]
    pid = jnp.take_along_axis(tbl, (pos // page)[:, None], axis=1)[:, 0]
    off = pos % page

    x = jnp.take(params["embed"], token[:, None], axis=0)       # (B, 1, D)
    if cfg.family == "encdec":
        x = x + jnp.take(params["pos_embed"], positions, axis=0)
        blocks = params["dec_blocks"]
    else:
        blocks = params["blocks"]
    x = constrain(x, "residual")

    kc, vc = pools["k"], pools["v"]
    ks, vs = pools.get("k_scale"), pools.get("v_scale")
    attn = functools.partial(_paged_attn, use_kernel=use_kernel, mesh=mesh)

    def body(carry, scanned):
        h, kc, vc, ks, vs = carry
        p, l = scanned
        hn = L.norm(h, p["norm1"], cfg.norm)
        q, k1, v1 = L._qkv(p["attn"], hn, hn, cfg)
        if cfg.family != "encdec":
            q = L.rope(q, positions, cfg.rope_theta)
            k1 = L.rope(k1, positions, cfg.rope_theta)
        kc, ks = _append_token(kc, ks, l, pid, off, k1[:, 0], kv_quant)
        vc, vs = _append_token(vc, vs, l, pid, off, v1[:, 0], kv_quant)
        o = attn(q[:, 0], kc[l], vc[l],
                 ks[l] if ks is not None else None,
                 vs[l] if vs is not None else None, pos, tbl)
        out = jnp.einsum("bhk,hkd->bd", o.astype(h.dtype), p["attn"]["wo"])
        h = constrain(h + out[:, None], "residual")
        if cfg.family == "encdec":
            o, _ = L.attention(p["xattn"], L.norm(h, p["norm_x"], cfg.norm),
                               cfg, positions, kv_x=enc, use_rope=False)
            h = h + o
        hn2 = L.norm(h, p["norm2"], cfg.norm)
        if cfg.family == "moe":
            f, _ = L.moe_ffn(p["moe"], hn2, cfg)
        else:
            f = L.ffn(p["ffn"], hn2, cfg)
        return (h + f, kc, vc, ks, vs), None

    (x, kc, vc, ks, vs), _ = jax.lax.scan(
        body, (x, kc, vc, ks, vs),
        (blocks, jnp.arange(cfg.n_layers)))

    if cfg.family == "encdec":
        xn = L.norm(x, params["final_norm"], cfg.norm)
        logits = jnp.einsum("bsd,vd->bsv", xn, params["embed"]).astype(jnp.float32)
        logits = constrain(logits, "logits")
    else:
        logits = constrain(LM._head_logits(params, cfg, x), "logits")

    out = dict(pools)
    out["k"], out["v"] = kc, vc
    if ks is not None:
        out["k_scale"], out["v_scale"] = ks, vs
    tok = sample_tokens(logits[:, 0], *samp, vocab=cfg.vocab)
    return tok, out
