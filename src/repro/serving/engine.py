"""Continuous-batching serving engine on a paged, optionally quantized KV
cache.

What replaced the seed slot-batcher (kept as ``repro.serving.legacy``):

* **Paged memory** — one pooled cache of fixed-size pages + a slot→page
  block table (``repro.serving.pages``). A request holds exactly
  ``ceil((prompt + max_new) / page)`` pages for its lifetime; long and
  short sequences share the pool and nothing is padded to ``max_len``.
* **Batched prefill admission** — queued requests are admitted together
  under a token budget, right-padded to a shared pow2-bucketed length
  (pow2 batch rows too, so jit keys stay few), run through one batched
  prefill, and their K/V prefixes scattered straight into their pages.
* **Paged decode** — every step decodes all slots over the smallest pow2
  page-table bucket that covers the longest active row; the hot path is
  the ``flash_decode_paged`` Pallas kernel (``use_kernel=True``) with the
  block table scalar-prefetched into its index maps.
* **Sampling** — per-request temperature / top-k / top-p with a
  per-request seed (``repro.serving.sampling``); token ``t`` of a request
  draws from ``fold_in(PRNGKey(seed), t)`` regardless of slot or batch
  company. ``temperature=0`` (default) is exact greedy.
* **Quantized KV** — ``kv_quant="int8" | "fp8"`` stores pages through
  ``core.quant`` with per-(token, head) scales; attention dequantizes
  in-register on the kernel path.
* **Mesh decode** — pass ``mesh=`` to install the PR 4 ``rules``
  activation constraints in "decode" mode, place the pools heads-over-
  model, and run the kernel under ``shard_map``.

Admission policy (documented in docs/serving.md): FIFO, head-of-line
blocking — the queue head is admitted as soon as a slot AND its full page
allowance are free, then more requests join the same prefill batch until
the token budget or resources run out. Upfront full-lifetime page grants
mean an admitted request can never be starved mid-decode, so there is no
preemption machinery to get wrong.

``run()`` returns the requests that actually finished during the call —
the seed version returned a snapshot of the *queue* taken before the loop
(dropping anything admitted earlier or submitted mid-run); the regression
test pins the fix.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any

import jax
import numpy as np

from repro.models.config import ModelConfig
from repro.obs import EventLog, MetricsRegistry
from repro.serving import decode as D
from repro.serving.pages import (
    PageAllocator,
    PagedKV,
    init_paged_kv,
    pages_needed,
)
from repro.serving.sampling import SampleParams

PyTree = Any

SUPPORTED_FAMILIES = ("dense", "moe", "encdec")


def _pow2(n: int) -> int:
    return 1 << max(0, n - 1).bit_length()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    # sampling (defaults = exact greedy, matching the seed engine)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    # enc-dec: stub-frontend frames (T_enc, D); zeros when omitted
    frames: np.ndarray | None = None
    # lifecycle timestamps (time.perf_counter seconds, set by the engine):
    # submit -> first generated token -> retirement. TTFT/TPOT histograms
    # are derived from exactly these, so tests can cross-check.
    t_submit: float | None = None
    t_first: float | None = None
    t_done: float | None = None


class GenerationEngine:
    def __init__(self, params, cfg: ModelConfig, slots: int = 4,
                 max_len: int = 512, eos_id: int = -1, *, page: int = 16,
                 npages: int | None = None, kv_quant: str | None = None,
                 use_kernel: bool = False, prefill_budget: int = 4096,
                 mesh=None, registry: MetricsRegistry | None = None,
                 events: EventLog | None = None):
        if cfg.family not in SUPPORTED_FAMILIES:
            raise ValueError(
                f"paged serving supports {SUPPORTED_FAMILIES}, not "
                f"{cfg.family!r}; use repro.serving.legacy.LegacySlotEngine "
                "for recurrent-state families")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.page = page
        self.maxp = pages_needed(max_len, page)
        self.max_len = self.maxp * page
        self.eos_id = eos_id
        self.kv_quant = kv_quant
        self.use_kernel = use_kernel
        self.prefill_budget = max(1, prefill_budget)
        self.mesh = mesh

        npages = npages or (1 + slots * self.maxp)
        self.allocator = PageAllocator(npages)
        self.kv: PagedKV = init_paged_kv(cfg, npages, page, kv_quant)
        self.tbl = np.zeros((slots, self.maxp), np.int32)
        self.counts = np.zeros((slots,), np.int32)   # tokens resident per slot
        self.samp = SampleParams.zeros(slots)
        self.slot_req: list[Request | None] = [None] * slots
        self.slot_pages: list[list[int] | None] = [None] * slots
        self.queue: list[Request] = []
        self.stats = {"prefill_batches": 0, "prefill_tokens": 0,
                      "prefill_rows": 0, "decode_steps": 0,
                      "max_admit_tokens": 0, "deferred_admissions": 0}
        # per-engine registry/event-log by default (docs/observability.md):
        # spans wrap admission/prefill/decode phases, counters+gauges back
        # the metrics() snapshot; the default log is silent so library use
        # prints nothing new — launch/serve.py passes a JSONL-backed one
        self.registry = registry if registry is not None else MetricsRegistry()
        self.events = events if events is not None else \
            EventLog(tag="serve", echo=False, registry=self.registry)
        self._update_gauges()
        self._finished: list[Request] = []
        self._jits: dict[tuple, Any] = {}

        self.enc = None
        if cfg.family == "encdec":
            import jax.numpy as jnp
            self.enc = jnp.zeros((slots, cfg.encoder_seq, cfg.d_model),
                                 jnp.dtype(cfg.dtype))
        if mesh is not None:
            from repro.distributed import rules

            sh = rules.paged_cache_shardings(mesh, cfg, self.kv.tree())
            pools = {k: jax.device_put(v, sh[k])
                     for k, v in self.kv.tree().items()}
            self._set_pools(pools)
            if self.enc is not None:
                self.enc = jax.device_put(
                    self.enc, rules.paged_enc_sharding(mesh, cfg,
                                                       self.enc.shape))

    # -- jit plumbing -------------------------------------------------------

    def _ctx(self):
        if self.mesh is None:
            import contextlib

            return contextlib.nullcontext()
        from repro.distributed import rules
        from repro.distributed.ctx import sharding_ctx

        return sharding_ctx(rules.activation_rules(self.mesh, self.cfg, "decode"))

    def _prefill_fn(self, bp: int, sp: int):
        key = ("prefill", bp, sp)
        if key not in self._jits:
            fn = functools.partial(D.paged_prefill, cfg=self.cfg,
                                   page=self.page, kv_quant=self.kv_quant)
            self._jits[key] = jax.jit(fn)
        return self._jits[key]

    def _decode_fn(self, npb: int):
        key = ("decode", npb)
        if key not in self._jits:
            fn = functools.partial(D.paged_decode, cfg=self.cfg,
                                   page=self.page, kv_quant=self.kv_quant,
                                   use_kernel=self.use_kernel, mesh=self.mesh)
            self._jits[key] = jax.jit(fn)
        return self._jits[key]

    def _set_pools(self, pools: dict) -> None:
        self.kv.k, self.kv.v = pools["k"], pools["v"]
        if self.kv.quantized:
            self.kv.k_scale = pools["k_scale"]
            self.kv.v_scale = pools["v_scale"]

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> None:
        plen = len(req.prompt)
        if plen < 1:
            raise ValueError(f"request {req.rid}: empty prompt")
        if pages_needed(plen + req.max_new, self.page) > min(
                self.maxp, self.allocator.capacity):
            raise ValueError(
                f"request {req.rid}: prompt {plen} + max_new {req.max_new} "
                f"exceeds per-slot capacity {self.max_len} "
                f"(pool {self.allocator.capacity} pages of {self.page})")
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        self.registry.inc("serve/submitted")
        self.registry.set("serve/queue_depth", len(self.queue))

    def step(self) -> bool:
        """Admit what fits, then run one decode step. False = fully idle."""
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return False
        self._decode_step(active)
        return True

    def run(self) -> list[Request]:
        """Drive to completion; returns the requests that finished during
        this call (admitted-before-call and submitted-mid-run included)."""
        finished: list[Request] = []
        while self.step():
            finished.extend(self._finished)
            self._finished.clear()
        finished.extend(self._finished)
        self._finished.clear()
        return finished

    # -- admission ----------------------------------------------------------

    def _free_slots(self) -> list[int]:
        return [s for s in range(self.slots) if self.slot_req[s] is None]

    def _admit(self) -> None:
        import jax.numpy as jnp

        free = self._free_slots()
        admits: list[tuple[int, Request, list[int]]] = []
        tokens = 0
        while self.queue and free:
            req = self.queue[0]
            plen = len(req.prompt)
            if admits and tokens + plen > self.prefill_budget:
                break
            need = pages_needed(plen + req.max_new, self.page)
            pages = self.allocator.alloc(need)
            if pages is None:
                self.stats["deferred_admissions"] += 1
                self.registry.inc("serve/deferred_admissions")
                break   # FIFO head-of-line: wait for pages to free up
            self.queue.pop(0)
            admits.append((free.pop(0), req, pages))
            tokens += plen

        if not admits:
            return

        bp = _pow2(len(admits))
        sp = self.page * _pow2(pages_needed(
            max(len(r.prompt) for _, r, _ in admits), self.page))
        spp = sp // self.page
        tok_b = np.zeros((bp, sp), np.int32)
        valid = np.ones((bp,), np.int32)
        tbl_b = np.zeros((bp, spp), np.int32)
        samp = SampleParams.zeros(bp)
        frames = None
        if self.cfg.family == "encdec":
            frames = np.zeros((bp, self.cfg.encoder_seq, self.cfg.d_model),
                              np.float32)
        for i, (slot, req, pages) in enumerate(admits):
            plen = len(req.prompt)
            tok_b[i, :plen] = np.asarray(req.prompt, np.int32)
            valid[i] = plen
            row = np.zeros((self.maxp,), np.int32)
            row[: len(pages)] = pages
            self.tbl[slot] = row
            tbl_b[i] = row[:spp]
            samp.set_slot(i, temperature=req.temperature, top_k=req.top_k,
                          top_p=req.top_p, seed=req.seed, count=0)
            if frames is not None and req.frames is not None:
                frames[i] = np.asarray(req.frames, np.float32)

        with self.events.span("serve/prefill", rows=len(admits),
                              tokens=tokens), self._ctx():
            tok, _logits, pools, enc = self._prefill_fn(bp, sp)(
                self.params, jnp.asarray(tok_b), jnp.asarray(valid),
                jnp.asarray(tbl_b), self.kv.tree(), samp.arrays(),
                jnp.asarray(frames) if frames is not None else None)
            self._set_pools(pools)
            tok_h = np.asarray(jax.device_get(tok))
        if enc is not None:
            rows = jnp.asarray([slot for slot, _, _ in admits])
            take = jnp.arange(len(admits))
            self.enc = self.enc.at[rows].set(enc[take].astype(self.enc.dtype))

        self.stats["prefill_batches"] += 1
        self.stats["prefill_tokens"] += tokens
        self.stats["prefill_rows"] += len(admits)
        self.stats["max_admit_tokens"] = max(self.stats["max_admit_tokens"],
                                             tokens)
        self.registry.inc("serve/admitted", len(admits))
        self.registry.inc("serve/prefill_tokens", tokens)
        t_first = time.perf_counter()
        for i, (slot, req, pages) in enumerate(admits):
            first = int(tok_h[i])
            req.out.append(first)
            req.t_first = t_first
            if req.t_submit is not None:
                self.registry.observe("serve/ttft_ms",
                                      (t_first - req.t_submit) * 1e3)
            self.registry.inc("serve/tokens_out")
            self.counts[slot] = len(req.prompt)
            self.samp.set_slot(slot, temperature=req.temperature,
                               top_k=req.top_k, top_p=req.top_p,
                               seed=req.seed, count=1)
            self.slot_req[slot] = req
            self.slot_pages[slot] = pages
            if first == self.eos_id or len(req.out) >= req.max_new:
                self._retire(slot)
        self._update_gauges()

    # -- decode -------------------------------------------------------------

    def _decode_step(self, active: list[int]) -> None:
        import jax.numpy as jnp

        npb = min(self.maxp, _pow2(max(
            pages_needed(int(self.counts[s]) + 1, self.page) for s in active)))
        toks = np.zeros((self.slots,), np.int32)
        for s in active:
            toks[s] = self.slot_req[s].out[-1]
        with self.events.span("serve/decode", rows=len(active)), self._ctx():
            tok, pools = self._decode_fn(npb)(
                self.params, jnp.asarray(toks), jnp.asarray(self.counts),
                jnp.asarray(self.tbl[:, :npb]), self.kv.tree(),
                self.samp.arrays(), self.enc)
            self._set_pools(pools)
            tok_h = np.asarray(jax.device_get(tok))
        self.stats["decode_steps"] += 1
        self.registry.inc("serve/tokens_out", len(active))
        for s in active:
            req = self.slot_req[s]
            t = int(tok_h[s])
            req.out.append(t)
            self.counts[s] += 1
            self.samp.count[s] += 1
            if t == self.eos_id or len(req.out) >= req.max_new:
                self._retire(s)

    def _retire(self, slot: int) -> None:
        req = self.slot_req[slot]
        self.allocator.free(self.slot_pages[slot])
        self.slot_pages[slot] = None
        self.slot_req[slot] = None
        self.tbl[slot] = 0
        self.counts[slot] = 0
        self.samp.set_slot(slot)
        req.done = True
        req.t_done = time.perf_counter()
        self.registry.inc("serve/finished")
        if req.t_first is not None and len(req.out) > 1:
            # time-per-output-token over the decode phase (tokens after the
            # prefill-produced first one)
            tpot_ms = (req.t_done - req.t_first) * 1e3 / (len(req.out) - 1)
            self.registry.observe("serve/tpot_ms", tpot_ms)
        self.events.event("serve/retire", rid=req.rid, tokens=len(req.out))
        self._update_gauges()
        self._finished.append(req)

    # -- metrics ------------------------------------------------------------

    def _update_gauges(self) -> None:
        self.registry.set("serve/queue_depth", len(self.queue))
        self.registry.set(
            "serve/page_pool_used_frac",
            1.0 - self.allocator.available / self.allocator.capacity)
        self.registry.set(
            "serve/active_slots",
            sum(1 for r in self.slot_req if r is not None))

    def metrics(self) -> dict:
        """Live metrics snapshot (plain JSON, ``docs/observability.md``):
        the registry's counters / gauges / histograms (queue depth,
        page-pool utilization, admissions/deferrals, TTFT/TPOT) plus the
        legacy ``stats`` dict and a derived ``tokens_per_sec`` over the
        engine's busy time (prefill + decode span durations)."""
        self._update_gauges()
        snap = self.registry.snapshot()
        busy_ms = sum(
            h["sum"] for name, h in snap["histograms"].items()
            if name in ("serve/prefill_ms", "serve/decode_ms"))
        tokens = snap["counters"].get("serve/tokens_out", 0.0)
        snap["stats"] = dict(self.stats)
        snap["tokens_per_sec"] = tokens / (busy_ms / 1e3) if busy_ms else 0.0
        return snap
