"""The seed slot-batcher, kept verbatim as the serving bench baseline.

This is the engine the paged rebuild (``repro.serving.engine``) replaces:
one-at-a-time prefill admission (a fresh jit per distinct prompt length),
every cache padded to ``max_len``, greedy-only host argmax. It exists so
``benchmarks/serve_bench.py`` can price the rebuild against the exact seed
behavior on the same trace — do not grow features here.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_cache, lm_decode_step, lm_prefill
from repro.models.config import ModelConfig

PyTree = Any


@dataclasses.dataclass
class LegacyRequest:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class LegacySlotEngine:
    """Fixed-capacity decode batch; finished sequences free their slot and
    queued requests prefill into it one at a time."""

    def __init__(self, params, cfg: ModelConfig, slots: int = 4,
                 max_len: int = 512, eos_id: int = -1):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = init_cache(cfg, slots, max_len)
        self.slot_req: list[LegacyRequest | None] = [None] * slots
        self.queue: list[LegacyRequest] = []
        self._decode = jax.jit(lambda p, t, c: lm_decode_step(p, cfg, t, c))
        self._prefill = jax.jit(lambda p, t: lm_prefill(p, cfg, t))

    def submit(self, req: LegacyRequest):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                logits, pcache = self._prefill(self.params, req.prompt[None, :])
                tok = int(jax.device_get(jnp.argmax(logits[0, -1, : self.cfg.vocab])))
                req.out.append(tok)
                self._install(s, pcache, len(req.prompt))
                self.slot_req[s] = req

    def _install(self, slot: int, pcache, plen: int):
        new = {}
        for key in self.cache:
            if key == "pos":
                new[key] = self.cache[key].at[slot].set(plen)
            elif isinstance(self.cache[key], dict):
                sub = {}
                for k2, dst in self.cache[key].items():
                    src = pcache[key][k2]
                    if dst.ndim == 5:  # (L, 1, S_p, H, D) -> pad to S_max
                        pad = dst.shape[2] - src.shape[2]
                        srcp = jnp.pad(src, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                        sub[k2] = dst.at[:, slot].set(srcp[:, 0])
                    else:
                        sub[k2] = dst.at[:, slot].set(src[:, 0])
                new[key] = sub
            else:
                new[key] = self.cache[key]
        self.cache = new

    def step(self):
        self._admit()
        active = [s for s in range(self.slots) if self.slot_req[s] is not None]
        if not active:
            return False
        toks = np.zeros((self.slots, 1), np.int32)
        for s in active:
            toks[s, 0] = self.slot_req[s].out[-1]
        logits, self.cache = self._decode(self.params, jnp.asarray(toks), self.cache)
        nxt = jax.device_get(jnp.argmax(logits[:, 0, : self.cfg.vocab], axis=-1))
        for s in active:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.out.append(tok)
            if tok == self.eos_id or len(req.out) >= req.max_new:
                req.done = True
                self.slot_req[s] = None
        return True
