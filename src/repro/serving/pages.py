"""Paged KV-cache bookkeeping: the page allocator and the pooled arrays.

The serving engine's KV cache is a **pool of fixed-size pages** shared by
every slot — long and short sequences co-exist without anyone paying
``max_len`` padding. Layout:

* payload pools ``k`` / ``v``: ``(L, P, page, Hkv, D)`` — page ``p`` of
  layer ``l`` holds ``page`` consecutive tokens of exactly one sequence
  (or scratch). Payload dtype is the model dtype, or the 1-byte
  ``core.quant`` payload dtype under ``kv_quant``;
* scale pools ``k_scale`` / ``v_scale``: ``(L, P, page, Hkv)`` f32,
  present only under quantization — one scale per **(token, head)**,
  because pages fill append-only (a single per-page scalar would have to
  re-quantize every resident token when a new absmax arrives; per-token
  scales make the write-once append exact and cheap);
* a host-side **block table** ``(slots, max_pages_per_slot)`` int32 mapping
  each slot's j-th logical page to a pool page id, zero-padded.

**Page 0 is reserved scratch**: the allocator never hands it out, so a
zero-padded table row is always safe to address — dummy prefill rows,
inactive decode slots, and positions past a row's valid length all land on
(or read) page 0 and are masked out by ``pos`` downstream.

:class:`PageAllocator` is deliberately plain host Python (allocation
happens once per request admission/retirement, never on the hot path) with
invariants the hypothesis suite in ``tests/test_serving.py`` hammers: no
page is ever double-owned, freeing returns exactly what was allocated, and
the reserved page can neither be allocated nor freed.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.core.quant import payload_dtype
from repro.models.config import ModelConfig

PyTree = Any

RESERVED_PAGES = 1  # page 0: scratch target for padded / inactive rows


class PageAllocator:
    """Free-list allocator over pool pages ``[RESERVED_PAGES, npages)``.

    ``alloc(n)`` returns ``n`` distinct page ids or ``None`` when fewer
    than ``n`` are free (the engine defers admission — never a partial
    grant). ``free(pages)`` returns them; freeing a page that is not
    currently allocated (double-free, foreign id, the reserved page)
    raises ``ValueError``.
    """

    def __init__(self, npages: int):
        if npages <= RESERVED_PAGES:
            raise ValueError(f"need > {RESERVED_PAGES} pages, got {npages}")
        self.npages = npages
        self._free: list[int] = list(range(npages - 1, RESERVED_PAGES - 1, -1))
        self._allocated: set[int] = set()

    @property
    def capacity(self) -> int:
        return self.npages - RESERVED_PAGES

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> frozenset[int]:
        return frozenset(self._allocated)

    def alloc(self, n: int) -> list[int] | None:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        return pages

    def free(self, pages) -> None:
        pages = list(pages)
        bad = [p for p in pages if p not in self._allocated]
        if bad or len(set(pages)) != len(pages):
            raise ValueError(f"free of unallocated/duplicate pages: {pages}")
        for p in pages:
            self._allocated.remove(p)
            self._free.append(p)

    def check_invariants(self) -> None:
        """Every page is exactly one of {reserved, free, allocated}."""
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        assert not (free & self._allocated), "page both free and allocated"
        assert free | self._allocated == set(range(RESERVED_PAGES, self.npages))
        assert all(p >= RESERVED_PAGES for p in free | self._allocated)


@dataclasses.dataclass
class PagedKV:
    """Device-side pooled KV cache (+ per-token scales under quantization)."""

    k: jnp.ndarray                       # (L, P, page, Hkv, D) payload
    v: jnp.ndarray
    k_scale: jnp.ndarray | None = None   # (L, P, page, Hkv) f32 when quantized
    v_scale: jnp.ndarray | None = None

    @property
    def page(self) -> int:
        return self.k.shape[2]

    @property
    def npages(self) -> int:
        return self.k.shape[1]

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None

    def tree(self) -> dict:
        out = {"k": self.k, "v": self.v}
        if self.quantized:
            out["k_scale"] = self.k_scale
            out["v_scale"] = self.v_scale
        return out


def init_paged_kv(cfg: ModelConfig, npages: int, page: int,
                  kv_quant: str | None = None) -> PagedKV:
    """Zeroed pools for ``cfg.n_layers`` decoder layers."""
    shape = (cfg.n_layers, npages, page, cfg.kv_heads, cfg.hd)
    dt = payload_dtype(kv_quant) if kv_quant else jnp.dtype(cfg.dtype)
    kv = PagedKV(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt))
    if kv_quant:
        sc = jnp.ones(shape[:-1], jnp.float32)
        kv.k_scale, kv.v_scale = sc, sc
    return kv


def pages_needed(tokens: int, page: int) -> int:
    return -(-tokens // page)


def gather_pages(pool: jnp.ndarray, tbl: jnp.ndarray,
                 scale: jnp.ndarray | None = None) -> jnp.ndarray:
    """Reassemble a dense (B, npages*page, Hkv, D) cache from per-layer
    pool (P, page, Hkv, D) + table (B, npages); dequantizes when ``scale``
    (P, page, Hkv) is given. Test/oracle utility — the kernel path never
    materializes this."""
    g = pool[tbl]                        # (B, npages, page, Hkv, D)
    if scale is not None:
        g = g.astype(jnp.float32) * scale[tbl][..., None]
    b, n, p, h, d = g.shape
    return g.reshape(b, n * p, h, d)
