"""Per-request seeded sampling: greedy / temperature / top-k / top-p.

Every request carries its own ``(seed, token index)`` RNG state; the key
for token ``t`` of a request is ``fold_in(PRNGKey(seed), t)``, computed
*inside* the jitted step via vmap. Consequences the test suite locks down:

* the stream is a pure function of ``(seed, t)`` — the same request
  produces the same tokens whether it runs solo or packed next to others
  (no cross-slot RNG bleed: no batch-level key is ever split by position);
* jit / no-jit and any batch padding produce identical tokens (threefry
  is deterministic and each row's key is derived from row data only);
* ``temperature == 0`` short-circuits to exact ``argmax`` — bitwise the
  greedy reference, no RNG draw involved.

Filters compose OpenAI-style: logits / temperature → top-k cut → top-p
(nucleus) cut over the renormalized distribution → Gumbel-argmax draw.
``top_k <= 0`` and ``top_p >= 1`` disable the respective filter.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

NEG = -1e30


@dataclasses.dataclass
class SampleParams:
    """Host-side per-slot sampling state, mirrored to device each step."""

    temperature: np.ndarray   # (slots,) f32; 0 = greedy
    top_k: np.ndarray         # (slots,) i32; 0 = off
    top_p: np.ndarray         # (slots,) f32; 1 = off
    seed: np.ndarray          # (slots,) u32
    count: np.ndarray         # (slots,) i32: tokens sampled so far

    @classmethod
    def zeros(cls, slots: int) -> "SampleParams":
        return cls(
            temperature=np.zeros((slots,), np.float32),
            top_k=np.zeros((slots,), np.int32),
            top_p=np.ones((slots,), np.float32),
            seed=np.zeros((slots,), np.uint32),
            count=np.zeros((slots,), np.int32),
        )

    def set_slot(self, s: int, *, temperature=0.0, top_k=0, top_p=1.0,
                 seed=0, count=0) -> None:
        self.temperature[s] = temperature
        self.top_k[s] = top_k
        self.top_p[s] = top_p
        self.seed[s] = seed
        self.count[s] = count

    def arrays(self) -> tuple:
        return (jnp.asarray(self.temperature), jnp.asarray(self.top_k),
                jnp.asarray(self.top_p), jnp.asarray(self.seed),
                jnp.asarray(self.count))


def _sample_row(logits, temperature, top_k, top_p, seed, count):
    """One row: logits (V,) f32 (already vocab-masked) -> token i32."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits).astype(jnp.int32)

    t = jnp.maximum(temperature, 1e-6)
    l = logits / t
    # top-k: threshold at the k-th largest value (k<=0 keeps everything)
    desc = jnp.sort(l)[::-1]
    kth = desc[jnp.clip(top_k - 1, 0, v - 1)]
    l = jnp.where((top_k > 0) & (l < kth), NEG, l)
    # top-p: keep the smallest prefix of the sorted distribution whose mass
    # reaches p (the token crossing the boundary is kept)
    probs = jax.nn.softmax(l)
    sp = jnp.sort(probs)[::-1]
    cum = jnp.cumsum(sp)
    kept = jnp.where(cum - sp < top_p, sp, jnp.inf)
    thresh = jnp.min(kept)            # smallest kept probability
    l = jnp.where(probs >= thresh, l, NEG)

    key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
    g = jax.random.gumbel(key, (v,), jnp.float32)
    sampled = jnp.argmax(l + g).astype(jnp.int32)
    return jnp.where(temperature <= 0.0, greedy, sampled)


def sample_tokens(logits, temperature, top_k, top_p, seed, count,
                  vocab: int):
    """Batched sampler. logits (B, Vpad) f32; per-row parameter vectors
    (B,). Columns ``>= vocab`` are masked before any filter. Returns (B,)
    int32 tokens."""
    vp = logits.shape[-1]
    if vp != vocab:
        col = jnp.arange(vp)
        logits = jnp.where(col[None, :] < vocab, logits, NEG)
    return jax.vmap(_sample_row)(
        logits, temperature.astype(jnp.float32), top_k.astype(jnp.int32),
        top_p.astype(jnp.float32), seed.astype(jnp.uint32),
        count.astype(jnp.int32))
