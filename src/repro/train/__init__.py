from repro.train.loop import TrainLoop, TrainLoopConfig

__all__ = ["TrainLoop", "TrainLoopConfig"]
