"""Fault-tolerant training loop.

Production behaviours implemented (and simulated/tested on CPU):

* periodic **atomic checkpoints** + auto-resume from the latest one (the
  data stream is a pure function of step, so resume is exact);
* **preemption simulation**: `crash_at_step` kills the loop mid-run in
  tests; the next TrainLoop picks up from the checkpoint;
* **straggler/hang mitigation**: per-step wall-time EWMA; steps slower
  than ``straggler_factor``x the EWMA are logged and counted (on real
  multi-host pods this signal feeds the coordinator's slow-host eviction).
  The first executed step of a process includes jit compilation, so it is
  excluded from both the EWMA seed and the straggler check — seeding from
  it would inflate the baseline by the compile time and mask every early
  straggler;
* **NaN/divergence guard**: non-finite loss skips the update (params and
  optimizer state are kept from the previous step) and is counted —
  the SMMF paper's loss-spike discussion (Sec. 6) motivates this guard.

Observability (``docs/observability.md``): each loop owns a
:class:`repro.obs.MetricsRegistry` (pass ``registry=`` to share one) —
straggler / NaN-skip counts live there as ``train/straggler_steps`` /
``train/nan_skips`` counters (the legacy ``straggler_steps`` /
``skipped_nan_steps`` attributes remain as read-through properties), phase
timings (``train/data``, ``train/step``, ``train/checkpoint``) are
recorded as spans through an :class:`repro.obs.EventLog`, and any in-jit
telemetry the step returns under ``metrics["telemetry"]`` (the
``make_train_step(telemetry=True)`` knob) is folded into the registry as
gauges after the step's loss fetch. Status lines are structured events
echoed to stdout in the familiar ``[trainloop] ...`` form.

Host-offload tier (``repro.optim.offload``): the loop is placement-agnostic
— cold optimizer-state buckets parked on host memory flow through
checkpoint save (host numpy either way) and the step unchanged. The one
placement-sensitive moment is **resume**: ``restore`` re-materializes
state on the default device memory, so a caller running ``--offload``
passes ``place_state`` (applied to the restored opt state) to re-park the
cold buckets before the first step.

Donation contract: the loop always adopts whatever (params, opt_state) the
step function returns and never touches the pre-call buffers again, so
``step_fn`` may be jitted with ``donate_argnums=(0, 1)`` (or be an AOT
``Compiled`` with donated inputs) — the old buffers are dead the moment the
call returns. The NaN guard therefore lives *inside* the step
(``repro.launch.steps.make_train_step`` selects old-vs-new state in-jit);
a step_fn without an in-step guard still gets its skips counted here, but
must itself return the untouched state on a bad step.
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import latest_step, restore, save
from repro.obs import EventLog, MetricsRegistry

PyTree = Any


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    crash_at_step: int | None = None  # fault-injection for tests
    keep_last: int = 3
    # OptimizerSpec.spec_hash() of the optimizer that owns opt_state: stored
    # in every checkpoint manifest and verified on resume, so a restart
    # under an edited spec (different state layout) fails loudly
    spec_hash: str | None = None


class TrainLoop:
    def __init__(
        self,
        step_fn: Callable,            # (params, opt_state, batch) -> (params, opt_state, metrics)
        params: PyTree,
        opt_state: PyTree,
        stream,                        # .batch(step) -> dict
        cfg: TrainLoopConfig,
        shardings: tuple | None = None,
        place_state: Callable | None = None,  # opt_state -> opt_state, post-restore
        registry: MetricsRegistry | None = None,
        events: EventLog | None = None,
    ):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.stream = stream
        self.cfg = cfg
        self.shardings = shardings
        self.place_state = place_state
        self.start_step = 0
        self.history: list[dict] = []
        # per-loop registry by default: resume tests run several loops in
        # one process, and their counters must not bleed into each other
        self.registry = registry if registry is not None else MetricsRegistry()
        self.events = events if events is not None else \
            EventLog(tag="trainloop", registry=self.registry)
        self._maybe_resume()

    # legacy counter surface (checkpoint extras, tests, launcher summary)
    @property
    def straggler_steps(self) -> int:
        return int(self.registry.counter("train/straggler_steps"))

    @property
    def skipped_nan_steps(self) -> int:
        return int(self.registry.counter("train/nan_skips"))

    # -- fault tolerance ----------------------------------------------------
    def _maybe_resume(self):
        last = latest_step(self.cfg.ckpt_dir)
        if last is None:
            return
        state = {"params": self.params, "opt": self.opt_state}
        sh = None
        if self.shardings is not None:
            sh = {"params": self.shardings[0], "opt": self.shardings[1]}
        state, manifest = restore(self.cfg.ckpt_dir, state, step=last, shardings=sh,
                                  spec_hash=self.cfg.spec_hash)
        self.params, self.opt_state = state["params"], state["opt"]
        if self.place_state is not None:
            # re-park offloaded (cold) state on its memory tier: restore
            # materialized everything on default device memory
            self.opt_state = self.place_state(self.opt_state)
        self.start_step = manifest["step"]
        self.events.event("resume", f"resumed from step {self.start_step}",
                          step=self.start_step)

    def _checkpoint(self, step: int):
        save(self.cfg.ckpt_dir, step, {"params": self.params, "opt": self.opt_state},
             extra={"stragglers": self.straggler_steps, "nan_skips": self.skipped_nan_steps},
             spec_hash=self.cfg.spec_hash)
        # retention
        steps = sorted(
            int(p.name.split("_")[1]) for p in Path(self.cfg.ckpt_dir).glob("step_*")
        )
        for s in steps[: -self.cfg.keep_last]:
            import shutil

            shutil.rmtree(Path(self.cfg.ckpt_dir) / f"step_{s:010d}", ignore_errors=True)

    def _absorb_telemetry(self, metrics) -> None:
        """Fold the step's in-jit telemetry scalars (already on host — the
        loss fetch synced the step) into the registry as gauges."""
        tel = metrics.get("telemetry") if isinstance(metrics, dict) else None
        if not tel:
            return
        host = jax.device_get(tel)
        for name, v in host.items():
            self.registry.set(f"tel/{name}", float(v))
        # the trip indicator also accumulates across the run, on top of the
        # last-value gauge (a spike is visible either way)
        if "train/nan_guard_trip" in host:
            self.registry.inc("train/nan_guard_trips",
                              float(host["train/nan_guard_trip"]))

    # -- main ---------------------------------------------------------------
    def run(self) -> dict:
        ewma = None
        first_timed = True
        step = self.start_step
        while step < self.cfg.total_steps:
            if self.cfg.crash_at_step is not None and step == self.cfg.crash_at_step:
                raise RuntimeError(f"injected crash at step {step}")
            with self.events.span("train/data", step=step):
                batch = self.stream.batch(step)
            t0 = time.time()
            with self.events.span("train/step", step=step) as sp:
                new_params, new_opt, metrics = self.step_fn(self.params, self.opt_state, batch)
                loss = float(jax.device_get(metrics["loss"]))
                sp["loss"] = loss
            dt = time.time() - t0

            # donation contract: the pre-call buffers may have been donated,
            # so ALWAYS adopt the returned state — the step's in-jit NaN
            # guard already selected old-vs-new (see module docstring)
            self.params, self.opt_state = new_params, new_opt
            self._absorb_telemetry(metrics)
            if not np.isfinite(loss):
                # divergence guard tripped in-step (Sec. 6 loss spikes)
                self.registry.inc("train/nan_skips")
                self.events.event(
                    "nan_skip", f"step {step}: non-finite loss, update skipped",
                    step=step)

            if first_timed:
                # first executed step carries the jit compile: seeding the
                # EWMA from it would mask every early straggler
                first_timed = False
            else:
                if ewma is not None and dt > self.cfg.straggler_factor * ewma:
                    self.registry.inc("train/straggler_steps")
                    self.events.event(
                        "straggler",
                        f"step {step}: straggler ({dt:.2f}s vs ewma {ewma:.2f}s)",
                        step=step, sec=dt, ewma=ewma)
                ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            self.registry.observe("train/step_sec", dt)
            self.registry.set("train/loss", loss)

            step += 1
            if step % self.cfg.log_every == 0:
                self.history.append({"step": step, "loss": loss, "sec": dt})
                self.events.event(
                    "log", f"step {step} loss {loss:.4f} ({dt:.2f}s)",
                    step=step, loss=loss, sec=dt)
            if step % self.cfg.ckpt_every == 0 or step == self.cfg.total_steps:
                with self.events.span("train/checkpoint", step=step):
                    self._checkpoint(step)
        return {
            "final_step": step,
            "history": self.history,
            "stragglers": self.straggler_steps,
            "nan_skips": self.skipped_nan_steps,
        }
