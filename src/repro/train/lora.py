"""LoRA fine-tuning (Hu et al. 2021) — the paper's LLaMA-7b setup (Table 4,
Appendix K): freeze base weights, train rank-r adapters with SMMF, whose
square-matricized factorization applies to the adapter matrices like any
other tensor (A (d, r) and B (r, k) square-matricize to near-square).

Functional API matching the rest of the framework:

  lora_init(key, params, targets, rank)   -> adapters pytree
  lora_merge(params, adapters, scale)     -> effective params (W + s*A@B)
  lora_train_step(...)                    -> grads flow ONLY to adapters
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any

DEFAULT_TARGETS = r"(attn/w[qkvo]|ffn/w[igo])$"


def _match(path: str, targets: str) -> bool:
    return re.search(targets, path) is not None


def lora_init(key, params: PyTree, targets: str = DEFAULT_TARGETS, rank: int = 8) -> dict:
    """Adapters as a flat {path: {"a", "b"}} dict (checkpoint-friendly).

    One (A, B) pair per matching >=2-D leaf. A ~ N(0, 1/r), B = 0 (so the
    initial adapted model equals the base model). Stacked (L, ...) leaves
    get stacked adapters; >2-D leaves adapt their last two axes."""
    from repro.utils.tree import tree_map_with_path

    adapters: dict = {}

    def _mk(path, leaf):
        if _match(path, targets) and leaf.ndim >= 2:
            *lead, n, m = leaf.shape
            k1 = jax.random.fold_in(key, len(adapters))
            adapters[path] = {
                "a": jax.random.normal(k1, (*lead, n, rank), jnp.float32) / rank,
                "b": jnp.zeros((*lead, rank, m), jnp.float32),
            }
        return leaf

    tree_map_with_path(_mk, params)
    return adapters


def lora_merge(params: PyTree, adapters: dict, scale: float = 1.0) -> PyTree:
    """Effective weights W + scale * (A @ B) on adapted leaves."""
    from repro.utils.tree import tree_map_with_path

    def _one(path, w):
        ad = adapters.get(path)
        if ad is None:
            return w
        delta = jnp.einsum("...nr,...rm->...nm", ad["a"], ad["b"]) * scale
        return (w.astype(jnp.float32) + delta).astype(w.dtype)

    return tree_map_with_path(_one, params)


def make_lora_train_step(cfg, opt, loss_fn, scale: float = 1.0):
    """(base_params, adapters, opt_state, batch) -> (adapters, opt_state, metrics).

    Gradients are taken w.r.t. the adapters only; the optimizer state covers
    only adapter tensors — with SMMF on top, fine-tuning state is doubly
    small (the paper reports 3.9 MiB for LLaMA-7b vs Adam's 153 MiB).
    """

    def step(base_params, adapters, opt_state, batch):
        def compute(ad):
            merged = lora_merge(base_params, ad, scale)
            loss, metrics = loss_fn(merged, cfg, batch)
            return loss, metrics

        (_, metrics), grads = jax.value_and_grad(compute, has_aux=True)(adapters)
        updates, opt_state = opt.update(grads, opt_state, adapters)
        from repro.optim.base import apply_updates

        adapters = apply_updates(adapters, updates)
        return adapters, opt_state, metrics

    return step
