from repro.utils.tree import tree_bytes, tree_count, tree_map_with_path

__all__ = ["tree_bytes", "tree_count", "tree_map_with_path"]
