"""Small pytree helpers used across the framework."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of scalar elements in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays (or ShapeDtypeStructs)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def tree_map_with_path(fn, tree):
    """jax.tree_util.tree_map_with_path with '/'-joined string paths."""

    def _fn(path, leaf):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        return fn(name, leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)
