"""Subprocess child probing the XLA concatenate-partitioning miscompile.

Re-runs the multi-axis parity child (tests/_multiaxis_child.py — 8 forced
host devices, a (pod 2, data 2, model 2) mesh, and a spec with a
``state_sharding=("model",)`` override group) with ONLY the
"opt_update_row" boundary pin dropped (``perf_flags(no_opt_boundary=True)``
— the smmf_* state constraints stay). On XLA versions carrying the
concatenate-partitioning bug the override group's moments come out scaled
by the replication factor and the parity assertions fire; on fixed XLA the
fully-sharded path is correct without the pin.

Prints exactly one verdict line:

* ``CONCAT MISCOMPILE REPRODUCED`` — parity failed without the pin; the
  guard in ``repro.distributed.rules`` is still needed.
* ``CONCAT MISCOMPILE ABSENT`` — the unpinned path is already correct;
  the version gate (``rules._CONCAT_MISCOMPILE_LAST_BAD``) should be
  retired for this jaxlib.

tests/test_multiaxis_sharding.py asserts the verdict agrees with
``rules.xla_concat_miscompile_present()``, so this child is the regression
test that *flips* when a jaxlib upgrade fixes the bug: the version gate
must be retired in the same change, or the test fails loudly.
"""

import _multiaxis_child  # noqa: F401  (sets XLA_FLAGS before importing jax)

from repro.models.perf import perf_flags


def main() -> None:
    try:
        with perf_flags(no_opt_boundary=True):
            _multiaxis_child.main()
    except AssertionError:
        print("CONCAT MISCOMPILE REPRODUCED")
    else:
        print("CONCAT MISCOMPILE ABSENT")


if __name__ == "__main__":
    main()
