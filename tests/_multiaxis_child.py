"""Subprocess child for the multi-axis / per-group-override parity test.

Runs under the emulated-mesh harness (tests/conftest.py) on 8 forced host
devices arranged as a (pod 2, data 2, model 2) mesh — the smallest mesh
exercising every leg of the multi-axis stack policy:

* default-group SMMF buckets whose stack divides pod*data -> stacked over
  ``("pod", "data")`` (4-way);
* an "experts" partition with ``state_sharding=("model",)`` -> its stacks
  ride the model axis instead (and its minor dims drop "model");
* an adam partition -> fused dense row on the (pod, data) element chain.

Asserts the placements actually distribute, then 3 update steps of
sharded-vs-replicated parity to float32 resolution (tight allclose — XLA
fuses the two programs differently, so exact bit-equality is not
attainable even for the override group's fully-local per-entry math).
This child is also the lock on the XLA concatenate-partitioning
miscompile: without the engine's "opt_update_row" boundary pins the
override group's moments come out scaled by the replication factor.
Prints "MULTIAXIS PARITY OK" on success.
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.distributed import rules  # noqa: E402
from repro.distributed.ctx import sharding_ctx  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim.base import apply_updates  # noqa: E402
from repro.optim.spec import OptimizerSpec, Partition, build_optimizer  # noqa: E402

SHAPES = {
    # default smmf: one bucket, stack K*B = 4 -> ("pod", "data") (4-way)
    "wq": (32, 64), "wk": (32, 64), "wv": (32, 64), "wo": (32, 64),
    # experts: one bucket, stack 4, override -> ("model",) (2-way)
    "experts/w0": (16, 32), "experts/w1": (16, 32),
    "experts/w2": (16, 32), "experts/w3": (16, 32),
    # adam group: fused dense flat row
    "b1": (64,), "b2": (64,),
}

SPEC = OptimizerSpec(
    family="smmf",
    hyperparams={"lr": 1e-2, "decay_rate": -0.8},
    partitions=(
        Partition(name="experts", match=r"^experts/",
                  state_sharding=("model",)),
        Partition(name="norms", match=r"^b\d$", family="adam",
                  hyperparams={"lr": 1e-2}),
    ),
)


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in SHAPES.items()}


def _n_shards(arr) -> int:
    return len({str(s.index) for s in arr.addressable_shards})


def main() -> None:
    assert jax.device_count() >= 8, jax.device_count()
    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 2, 2),
                ("pod", "data", "model"))
    cfg = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2, dtype="float32")
    opt = build_optimizer(SPEC)
    params = _tree(0)
    state = opt.init(params)

    psh = rules.param_shardings(mesh, None, params)
    osh = rules.opt_state_shardings(mesh, None, params, opt)
    rule = rules.activation_rules(mesh, cfg, "train")

    params_s = jax.device_put(params, psh)
    state_s = jax.device_put(state, osh)

    # default-group stack (K*B = 4) rides (pod, data): 4 distinct shards
    r_m = state_s.factors["fac:1x64x32"][0]
    assert _n_shards(r_m) == 4, f"default stack not (pod,data)-sharded: {_n_shards(r_m)}"
    # override group's stack rides the model axis: 2 distinct shards, and
    # its column factors must NOT also carry model (axis never reused)
    ex_rm = state_s.factors["experts/fac:1x32x16"][0]
    assert _n_shards(ex_rm) == 2, f"override stack not model-sharded: {_n_shards(ex_rm)}"
    ex_cm = state_s.factors["experts/fac:1x32x16"][1]
    assert _n_shards(ex_cm) == 2, f"override cols wrong: {_n_shards(ex_cm)}"

    def upd_with_constraints(g, s, p):
        with sharding_ctx(rule):
            return opt.update(g, s, p)

    upd_s = jax.jit(upd_with_constraints, in_shardings=(psh, osh, psh),
                    out_shardings=(psh, osh))
    upd_r = jax.jit(opt.update)

    for step in range(3):
        grads = _tree(100 + step)
        u_r, state = upd_r(grads, state, params)
        u_s, state_s = upd_s(jax.device_put(grads, psh), state_s, params_s)
        params = apply_updates(params, u_r)
        params_s = apply_updates(params_s, u_s)
        for k in params:
            # all groups agree to float32 resolution: the override group's
            # math is fully local per stack entry (fusion differences
            # only), the rest reorders cross-shard reductions — a few ulps
            # accumulate over steps either way
            np.testing.assert_allclose(
                np.asarray(params[k]), np.asarray(params_s[k]),
                rtol=1e-6, atol=1e-7, err_msg=f"step {step} {k}")
        for i, (a, b) in enumerate(zip(jax.tree.leaves(state),
                                       jax.tree.leaves(state_s))):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7,
                err_msg=f"step {step} state leaf {i}")
    print("MULTIAXIS PARITY OK")


if __name__ == "__main__":
    main()
