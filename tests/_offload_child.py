"""Subprocess child: offloaded-state checkpoint roundtrip across a mesh change.

Runs under the emulated-mesh harness (8 forced host devices). A quantized
SMMF group (cold — its state parks on the offload tier) plus a plain adam
partition (hot — device-resident) train one step on a 2-device mesh with
``rules.opt_state_shardings(..., offload="cold")`` placement, checkpoint,
then **restore onto a 4-device mesh** with freshly computed offload-aware
shardings and train a second step. The full trajectory must match a
replicated no-offload reference run to float32 resolution, proving the
offload tier is checkpoint-transparent (one logical state) *and* elastic.

On the CPU backend the host memory kind is structural (identity placement
— ``offload.supported()`` False), so what this child locks down is the
placement/restore plumbing and the scheduled round-trip program shape; the
memory-kind transfers themselves are exercised wherever a real host tier
exists. Prints "OFFLOAD ELASTIC ROUNDTRIP OK" on success.
"""

import os
import tempfile

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.checkpoint import restore, save  # noqa: E402
from repro.distributed import rules  # noqa: E402
from repro.optim import offload  # noqa: E402
from repro.optim.base import apply_updates  # noqa: E402
from repro.optim.spec import OptimizerSpec, Partition, build_optimizer  # noqa: E402

SHAPES = {
    # default smmf+int8 group: one factored bucket (stack 4) -> cold
    "wq": (32, 64), "wk": (32, 64), "wv": (32, 64), "wo": (32, 64),
    # adam partition without quant: fused dense flat row -> stays hot
    "b1": (64,), "b2": (64,),
}

SPEC = OptimizerSpec(
    family="smmf",
    hyperparams={"lr": 1e-2, "decay_rate": -0.8, "quant": "int8"},
    partitions=(
        # quant=None override: partitions inherit the spec-level quant, and
        # this child needs a hot (device-resident) bucket next to the cold one
        Partition(name="norms", match=r"^b\d$", family="adam",
                  hyperparams={"lr": 1e-2, "quant": None}),
    ),
)


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in SHAPES.items()}


def _n_shards(arr) -> int:
    return len({str(s.index) for s in arr.addressable_shards})


def main() -> None:
    assert jax.device_count() >= 8, jax.device_count()
    opt = build_optimizer(SPEC)
    params = _tree(0)
    engine = opt.plan(params)
    cold = offload.cold_keys(engine, "cold")
    assert cold, "expected the quantized smmf bucket to be cold"
    assert any(bk.key not in cold for bk in engine.buckets), \
        "expected the adam bucket to stay hot"
    spec_hash = SPEC.spec_hash()

    # replicated no-offload reference trajectory (2 steps)
    ref_params, ref_state = dict(params), opt.init(params)
    upd_ref = jax.jit(opt.update)
    for step in range(2):
        u, ref_state = upd_ref(_tree(100 + step), ref_state, ref_params)
        ref_params = apply_updates(ref_params, u)

    def sharded_step(params_s, state_s, psh, osh, step):
        upd = jax.jit(
            lambda g, s, p: opt.update(g, s, p, schedule="grad", offload="cold"),
            in_shardings=(psh, osh, psh), out_shardings=(psh, osh))
        u, state_s = upd(jax.device_put(_tree(100 + step), psh), state_s, params_s)
        return apply_updates(params_s, u), state_s

    def placements(mesh):
        psh = rules.param_shardings(mesh, None, params)
        osh = rules.opt_state_shardings(mesh, None, params, opt, offload="cold")
        return psh, osh

    # step 0 on the 2-device mesh, offloaded placement, then checkpoint
    mesh2 = Mesh(np.asarray(jax.devices()[:2]).reshape(2), ("data",))
    psh2, osh2 = placements(mesh2)
    params_s = jax.device_put(params, psh2)
    state_s = jax.device_put(offload.place_host(opt.init(params), engine, "cold"),
                             osh2)
    params_s, state_s = sharded_step(params_s, state_s, psh2, osh2, 0)

    ckpt_dir = tempfile.mkdtemp(prefix="offload_ckpt_")
    save(ckpt_dir, 1, {"params": params_s, "opt": state_s}, spec_hash=spec_hash)

    # elastic restore on a 4-device mesh with offload-aware shardings
    mesh4 = Mesh(np.asarray(jax.devices()[:4]).reshape(4), ("data",))
    psh4, osh4 = placements(mesh4)
    like = {"params": params, "opt": jax.eval_shape(opt.init, params)}
    state, manifest = restore(ckpt_dir, like, step=1,
                              shardings={"params": psh4, "opt": osh4},
                              spec_hash=spec_hash)
    assert manifest["step"] == 1
    params_s, state_s = state["params"], state["opt"]
    # the cold bucket's stacked payload really re-sharded onto 4 devices
    (ck,) = cold
    payload = jax.tree.leaves(state_s.factors[ck])[0]
    assert _n_shards(payload) == 4, f"payload not 4-way after restore: {_n_shards(payload)}"

    # step 1 from the restored state; full trajectory must match reference
    params_s, state_s = sharded_step(params_s, state_s, psh4, osh4, 1)
    for k in ref_params:
        np.testing.assert_allclose(
            np.asarray(ref_params[k]), np.asarray(params_s[k]),
            rtol=1e-6, atol=1e-7, err_msg=f"param {k}")
    for i, (a, b) in enumerate(zip(jax.tree.leaves(ref_state),
                                   jax.tree.leaves(state_s))):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7,
            err_msg=f"state leaf {i}")
    print("OFFLOAD ELASTIC ROUNDTRIP OK")


if __name__ == "__main__":
    main()
