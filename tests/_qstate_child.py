"""Subprocess child for the quantized-state (qstate) multi-device tests.

Runs under the session-scoped emulated-mesh harness (tests/conftest.py).
Covers, on a real 4-device "data" mesh:

* sharded-vs-replicated parity of a quantized (int8) SMMF update — the
  payloads AND scale rows are stack-sharded per ``rules.opt_state_shardings``
  and the sharded trajectory matches the single-device one to within ONE
  quantizer code (the SR stream is deterministic per (step, bucket, slot),
  but sharded f32 reduction order can nudge a value across a rounding
  boundary — never further than one code);
* a checkpoint written from the 2-way mesh restores onto the 4-way mesh
  (mesh-elastic re-sharding of int8 payloads + scales) with bit-identical
  contents.

Prints "QSTATE PARITY OK" / "QSTATE ELASTIC OK" on success.
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()

import tempfile  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.checkpoint import ckpt  # noqa: E402
from repro.distributed import rules  # noqa: E402
from repro.distributed.ctx import sharding_ctx  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim.base import apply_updates  # noqa: E402
from repro.optim.spec import OptimizerSpec, build_optimizer  # noqa: E402

# four same-geometry 2-D leaves -> one factored bucket with stack 4
# (divisible by the 4-way data axis -> stack-sharded payloads + scales);
# two 1-D leaves + a scalar -> the fused dense path with segment scales
SHAPES = {
    "wq": (32, 64), "wk": (32, 64), "wv": (32, 64), "wo": (32, 64),
    "b1": (64,), "b2": (64,),
    "s": (),
}

SPEC = OptimizerSpec(family="smmf", hyperparams={
    "lr": 1e-2, "decay_rate": -0.8, "quant": "int8"})


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in SHAPES.items()}


def _assert_bitwise(a_tree, b_tree, msg):
    for i, (a, b) in enumerate(zip(jax.tree.leaves(a_tree),
                                   jax.tree.leaves(b_tree))):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype, f"{msg}: leaf {i} dtype {a.dtype}!={b.dtype}"
        np.testing.assert_array_equal(a, b, err_msg=f"{msg}: leaf {i}")


def _assert_one_code(a_tree, b_tree, msg):
    """Quantized-state parity: int8 payloads within ONE code of each other,
    everything else (scales, signs, the step scalar) numerically close."""
    for i, (a, b) in enumerate(zip(jax.tree.leaves(a_tree),
                                   jax.tree.leaves(b_tree))):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype, f"{msg}: leaf {i} dtype {a.dtype}!={b.dtype}"
        if a.dtype == np.int8:
            d = np.abs(a.astype(np.int16) - b.astype(np.int16))
            assert int(d.max(initial=0)) <= 1, \
                f"{msg}: leaf {i} payloads differ by {int(d.max())} codes"
        elif a.dtype == np.uint8:
            np.testing.assert_array_equal(a, b, err_msg=f"{msg}: leaf {i}")
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-9,
                                       err_msg=f"{msg}: leaf {i}")


def parity() -> None:
    """Sharded-vs-replicated bitwise parity of the quantized trajectory."""
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("data",))
    cfg = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2,
                      dtype="float32")
    opt = build_optimizer(SPEC)
    params = _tree(0)
    state = opt.init(params)

    psh = rules.param_shardings(mesh, None, params)
    osh = rules.opt_state_shardings(mesh, None, params, opt)
    rule = rules.activation_rules(mesh, cfg, "train")

    params_s = jax.device_put(params, psh)
    state_s = jax.device_put(state, osh)

    # the factored bucket's int8 payload AND its scale rows must actually
    # be distributed over the 4-way stack axis
    qt = state_s.factors["fac:1x64x32"][0]
    assert str(qt.q.dtype) == "int8", qt.q.dtype
    for name, arr in (("payload", qt.q), ("scale", qt.scale)):
        n_shards = len({str(s.index) for s in arr.addressable_shards})
        assert n_shards == 4, f"quantized {name} not stack-sharded: {n_shards}"

    def upd_with_constraints(g, s, p):
        with sharding_ctx(rule):
            return opt.update(g, s, p)

    upd_s = jax.jit(upd_with_constraints, in_shardings=(psh, osh, psh),
                    out_shardings=(psh, osh))
    upd_r = jax.jit(opt.update)

    for step in range(3):
        grads = _tree(100 + step)
        u_r, state = upd_r(grads, state, params)
        u_s, state_s = upd_s(jax.device_put(grads, psh), state_s, params_s)
        params = apply_updates(params, u_r)
        params_s = apply_updates(params_s, u_s)
        for k in params:
            np.testing.assert_allclose(
                np.asarray(params[k]), np.asarray(params_s[k]),
                rtol=1e-6, atol=1e-7, err_msg=f"step {step} leaf {k}")
        # shared SR stream -> payloads agree to within one quantizer code
        _assert_one_code(state, state_s, f"step {step} quantized state")
    print("QSTATE PARITY OK")


def elastic() -> None:
    """int8+scales checkpoint round-trip across a mesh-size change."""
    opt = build_optimizer(SPEC)
    params = _tree(1)
    mesh2 = Mesh(np.asarray(jax.devices()[:2]), ("data",))
    mesh4 = Mesh(np.asarray(jax.devices()[:4]), ("data",))
    osh2 = rules.opt_state_shardings(mesh2, None, params, opt)
    osh4 = rules.opt_state_shardings(mesh4, None, params, opt)

    state = jax.device_put(opt.init(params), osh2)
    u, state = jax.jit(opt.update)(_tree(2), state, params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state, spec_hash=SPEC.spec_hash())
        like = jax.eval_shape(lambda: state)
        restored, manifest = ckpt.restore(d, like, shardings=osh4,
                                          spec_hash=SPEC.spec_hash())
    assert manifest["spec_hash"] == SPEC.spec_hash()
    _assert_bitwise(state, restored, "elastic restore")
    # and the restored payloads really live on the 4-way layout
    qt = restored.factors["fac:1x64x32"][0]
    n_shards = len({str(s.index) for s in qt.q.addressable_shards})
    assert n_shards == 4, f"restored payload not re-sharded: {n_shards}"
    print("QSTATE ELASTIC OK")


if __name__ == "__main__":
    assert jax.device_count() >= 4, jax.device_count()
    parity()
    elastic()
