"""Subprocess child for the multi-device serving tests.

Runs under the session-scoped emulated-mesh harness (tests/conftest.py).
Covers, on a real (data 2, model 2) mesh:

* sharded-vs-replicated decode parity — the paged engine under
  ``mesh=`` (pools placed heads-over-"model" by
  ``rules.paged_cache_shardings``, decode constrained by the PR 4
  ``activation_rules(mode="decode")``, the flash_decode_paged kernel run
  inside ``shard_map`` over KV heads) produces the exact token streams of
  the single-device run, greedy and kernel+int8 alike;
* pool placement — the payload pools really are distributed over the
  "model" axis (distinct addressable shard indices), not silently
  replicated;
* page-table consistency — the block table is host state, identical no
  matter which device asks: every admitted slot's pages are distinct,
  non-reserved, and the allocator invariants hold mid-flight on the mesh
  engine exactly as they do single-device.

Prints "SERVING MESH PARITY OK" / "SERVING MESH TABLE OK" on success.
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.models import ModelConfig, init_lm  # noqa: E402
from repro.serving import GenerationEngine, Request  # noqa: E402

CFG = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2,
                  dtype="float32")


def _requests():
    rng = np.random.default_rng(7)
    return [Request(rid=i, prompt=rng.integers(0, CFG.vocab, size=3 + 2 * i)
                    .astype(np.int32), max_new=6) for i in range(5)]


def _run(params, mesh, **kw):
    eng = GenerationEngine(params, CFG, slots=2, max_len=64, mesh=mesh, **kw)
    reqs = _requests()
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    return eng, [r.out for r in reqs]


def parity() -> None:
    mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    params = init_lm(jax.random.PRNGKey(0), CFG)

    _, base = _run(params, None)
    for kw in ({}, {"use_kernel": True}, {"use_kernel": True, "kv_quant": "int8"}):
        eng, toks = _run(params, mesh, **kw)
        assert toks == base, f"mesh decode diverged under {kw}: {toks} != {base}"
        # pools must actually live heads-over-model, not be replicated
        n_shards = len({str(s.index) for s in eng.kv.k.addressable_shards})
        assert n_shards >= 2, f"pool not sharded under {kw}: {n_shards}"
    print("SERVING MESH PARITY OK")


def table() -> None:
    mesh = Mesh(np.asarray(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
    params = init_lm(jax.random.PRNGKey(1), CFG)
    eng = GenerationEngine(params, CFG, slots=2, max_len=64, mesh=mesh,
                           use_kernel=True)
    for r in _requests():
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 200
        # the table is host state: one row per slot, pages distinct and
        # never the reserved scratch page, matching the allocator's books
        live = []
        for s in range(eng.slots):
            if eng.slot_req[s] is None:
                assert not eng.tbl[s].any(), f"idle slot {s} holds pages"
                continue
            used = eng.tbl[s][: -(-int(eng.counts[s]) // eng.page)]
            assert (used > 0).all(), f"slot {s} maps the reserved page"
            live.extend(int(p) for p in used)
        assert len(live) == len(set(live)), "page double-mapped across slots"
        assert set(live) <= eng.allocator.allocated
        eng.allocator.check_invariants()
    assert eng.allocator.available == eng.allocator.capacity
    print("SERVING MESH TABLE OK")


if __name__ == "__main__":
    assert jax.device_count() >= 4, jax.device_count()
    parity()
    table()
