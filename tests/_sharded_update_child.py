"""Subprocess child for the sharded-vs-replicated update parity test.

Runs under the session-scoped emulated-mesh harness (tests/conftest.py),
which forces the host-platform device count via XLA_FLAGS before spawning;
when launched by hand it forces 4 devices itself. The data mesh is built
from an explicit 4-device slice, so the same child works on the harness's
8-device platform. Prints "PARITY OK" on success (the parent test asserts
on it); any mismatch raises and the parent sees the traceback.
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.distributed import rules  # noqa: E402
from repro.distributed.ctx import sharding_ctx  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim.base import apply_updates  # noqa: E402
from repro.optim.spec import OptimizerSpec, build_optimizer  # noqa: E402

# four same-geometry 2-D leaves -> one bucket with stack K*B = 4, divisible
# by the 4-way data axis (stack-sharded path); two 1-D leaves -> K*B = 2
# bucket (fallback row/col path); a scalar -> fused dense path
SHAPES = {
    "wq": (32, 64), "wk": (32, 64), "wv": (32, 64), "wo": (32, 64),
    "b1": (64,), "b2": (64,),
    "s": (),
}


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in SHAPES.items()}


def main() -> None:
    assert jax.device_count() >= 4, jax.device_count()
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("data",))
    cfg = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2, dtype="float32")
    opt = build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-2, "decay_rate": -0.8}))
    params = _tree(0)
    state = opt.init(params)

    psh = rules.param_shardings(mesh, None, params)
    osh = rules.opt_state_shardings(mesh, None, params, opt)
    rule = rules.activation_rules(mesh, cfg, "train")

    params_s = jax.device_put(params, psh)
    state_s = jax.device_put(state, osh)

    def upd_with_constraints(g, s, p):
        # the sharding context must be active while *tracing* (first call)
        with sharding_ctx(rule):
            return opt.update(g, s, p)

    upd_s = jax.jit(upd_with_constraints, in_shardings=(psh, osh, psh),
                    out_shardings=(psh, osh))
    upd_r = jax.jit(opt.update)

    # the big factored bucket's state must actually be distributed
    fac = state_s.factors["fac:1x64x32"]
    n_shards = len({str(s.index) for s in fac[0].addressable_shards})
    assert n_shards == 4, f"stacked r_m not stack-sharded: {n_shards} shards"

    for step in range(3):
        grads = _tree(100 + step)
        u_r, state = upd_r(grads, state, params)
        u_s, state_s = upd_s(jax.device_put(grads, psh), state_s, params_s)
        params = apply_updates(params, u_r)
        params_s = apply_updates(params_s, u_s)
        for k in params:
            np.testing.assert_allclose(
                np.asarray(params[k]), np.asarray(params_s[k]),
                rtol=1e-6, atol=1e-7, err_msg=f"step {step} leaf {k}")
        for i, (a, b) in enumerate(zip(jax.tree.leaves(state),
                                       jax.tree.leaves(state_s))):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7,
                err_msg=f"step {step} state leaf {i}")
    print("PARITY OK")


if __name__ == "__main__":
    main()
