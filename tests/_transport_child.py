"""Subprocess child for the gradient-transport multi-device tests.

Runs under the session-scoped emulated-mesh harness (tests/conftest.py).
On a real 4-device "data" mesh, for BOTH transport modes (int8, rank1):

* the compressed gradient delivered inside the sharded update is the same
  one the replicated update sees — the SR stream is a pure function of
  ``(step, bucket-crc, slot)``, so every replica rounds identically and
  the sharded-vs-replicated parameter trajectories track each other;
* training *converges* the same way: after N steps on a fixed quadratic,
  the sharded and replicated losses match tightly and both beat the
  starting loss by a wide margin (transport compression does not break
  optimization, distributed or not).

Prints "TRANSPORT PARITY OK <mode>" per mode on success.
"""

import os

if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=4"
    ).strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.distributed import rules  # noqa: E402
from repro.distributed.ctx import sharding_ctx  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim.base import apply_updates  # noqa: E402
from repro.optim.spec import OptimizerSpec, build_optimizer  # noqa: E402

# four same-geometry 2-D leaves -> one factored bucket with stack 4
# (stack-sharded over the 4-way data axis); biases + scalar -> the fused
# dense path (segment int8 scales / one flat rank1 row)
SHAPES = {
    "wq": (32, 64), "wk": (32, 64), "wv": (32, 64), "wo": (32, 64),
    "b1": (64,), "b2": (64,),
    "s": (),
}

STEPS = 15


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in SHAPES.items()}


TARGET = _tree(7)


def loss_fn(p):
    """Fixed quadratic: every leaf pulled toward a frozen random target."""
    return sum(jnp.sum((p[k] - TARGET[k]) ** 2) for k in SHAPES) / len(SHAPES)


def parity(mode: str) -> None:
    spec = OptimizerSpec(family="smmf", hyperparams={
        "lr": 1e-1, "decay_rate": -0.8,
        "transport": mode, "transport_flush_every": 4})
    opt = build_optimizer(spec)
    mesh = Mesh(np.asarray(jax.devices()[:4]), ("data",))
    cfg = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2,
                      dtype="float32")

    params = _tree(0)
    loss0 = float(loss_fn(params))
    state = opt.init(params)

    psh = rules.param_shardings(mesh, None, params)
    osh = rules.opt_state_shardings(mesh, None, params, opt)
    rule = rules.activation_rules(mesh, cfg, "train")

    params_s = jax.device_put(params, psh)
    state_s = jax.device_put(state, osh)

    def step_r(p, s):
        g = jax.grad(loss_fn)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    def step_s(p, s):
        g = jax.grad(loss_fn)(p)
        with sharding_ctx(rule):
            u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    step_r = jax.jit(step_r)
    step_s = jax.jit(step_s, in_shardings=(psh, osh),
                     out_shardings=(psh, osh))

    for step in range(STEPS):
        params, state = step_r(params, state)
        params_s, state_s = step_s(params_s, state_s)
        for k in params:
            np.testing.assert_allclose(
                np.asarray(params[k]), np.asarray(params_s[k]),
                rtol=1e-5, atol=1e-6,
                err_msg=f"{mode} step {step} leaf {k}")

    lr, ls = float(loss_fn(params)), float(loss_fn(params_s))
    assert abs(lr - ls) <= 1e-5 * max(abs(lr), 1e-8), (mode, lr, ls)
    assert lr < 0.7 * loss0, f"{mode}: no convergence ({loss0} -> {lr})"
    print(f"TRANSPORT PARITY OK {mode} (loss {loss0:.4f} -> {lr:.4f})")


if __name__ == "__main__":
    assert jax.device_count() >= 4, jax.device_count()
    parity("int8")
    parity("rank1")
