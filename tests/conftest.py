"""Shared pytest fixtures: the emulated multi-device mesh harness.

Real-mesh tests (sharded-vs-replicated parity, multi-axis placement) need a
process whose XLA *host platform* is forced to N devices — the
``--xla_force_host_platform_device_count`` flag is read at first jax
import, so it cannot be flipped inside the already-running test process.
The :class:`MeshHarness` below is the single place that spawns such
children: a **session-scoped** fixture with a result cache, so every test
asserting on the same child's output shares one spawn instead of paying
per-test subprocess boilerplate (the pre-PR-4 pattern).

Markers (registered here; see pytest.ini):

* ``multidevice`` — tests that spawn emulated-mesh children; the CI
  ``multi-device`` job runs exactly these.
* ``slow`` — the full dryrun compile-smoke matrix and other multi-minute
  tests; **deselected by default**, opt in with ``--runslow``.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

TESTS = Path(__file__).resolve().parent
SRC = TESTS.parent / "src"


def spec_opt(family: str, lr: float = 1e-3, **hp):
    """Spec-built twin of the deprecated per-family constructors.

    Tier-1 turns the ``repro.optim`` shim DeprecationWarnings into errors
    (pytest.ini), so tests that merely *use* an optimizer — rather than
    testing the legacy surface itself — build through the OptimizerSpec
    API via this one shared helper (``from conftest import spec_opt``).
    """
    from repro.optim.spec import OptimizerSpec, build_optimizer

    return build_optimizer(
        OptimizerSpec(family=family, hyperparams={"lr": lr, **hp}))

# Default emulated device count: 8 = (pod 2) x (data 2) x (model 2), the
# smallest mesh that exercises every axis of the multi-axis stack policy.
MESH_DEVICES = 8


class MeshHarness:
    """Run helper scripts under an emulated N-device host platform.

    ``run("child.py", "arg")`` spawns ``tests/child.py`` (or an absolute
    path) once per distinct ``(script, args, devices)`` key with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and
    ``PYTHONPATH=src`` set, and caches the ``CompletedProcess`` for the
    rest of the session — tests assert on the cached stdout/returncode.
    """

    def __init__(self, devices: int = MESH_DEVICES):
        self.devices = devices
        self._cache: dict[tuple, subprocess.CompletedProcess] = {}

    def run(self, script: str, *args: str, devices: int | None = None,
            timeout: int = 900) -> subprocess.CompletedProcess:
        devices = devices or self.devices
        key = (script, args, devices)
        if key not in self._cache:
            path = Path(script)
            if not path.is_absolute():
                path = TESTS / script
            env = dict(os.environ)
            env["PYTHONPATH"] = f"{SRC}{os.pathsep}" + env.get("PYTHONPATH", "")
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "")
                + f" --xla_force_host_platform_device_count={devices}"
            ).strip()
            self._cache[key] = subprocess.run(
                [sys.executable, str(path), *args],
                capture_output=True, text=True, env=env, timeout=timeout,
            )
        return self._cache[key]


@pytest.fixture(scope="session")
def emulated_mesh() -> MeshHarness:
    """Session-scoped emulated-mesh subprocess harness (module docstring)."""
    return MeshHarness()


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run tests marked slow (dryrun compile matrix)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow test: pass --runslow to run")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
