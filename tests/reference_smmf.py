"""Direct NumPy port of the paper's reference PyTorch SMMF (Appendix M).

Used as the faithfulness oracle: the JAX implementation must produce the
same parameter trajectories.
"""

from __future__ import annotations

import numpy as np


def get_effective_shape(numel: int) -> tuple[int, int]:
    sqrt_num = int(numel ** 0.5) ** 2
    if numel == sqrt_num:
        s = int(numel ** 0.5)
        return (s, s)
    for i in reversed(range(1, int(numel ** 0.5) + 1)):
        if numel % i == 0:
            return (numel // i, i)
    return (numel, 1)


def _nnmf(matrix: np.ndarray):
    r = matrix.sum(axis=1)
    c = matrix.sum(axis=0)
    if matrix.shape[0] < matrix.shape[1]:
        s = r.sum()
        if s != 0:
            r = r / s
    else:
        s = c.sum()
        if s != 0:
            c = c / s
    return r, c


def _unnmf(rc) -> np.ndarray:
    return np.outer(rc[0], rc[1])


class RefSMMF:
    """Reference optimizer (paper Appendix M), NumPy, eager per-tensor."""

    def __init__(self, shapes: dict, lr=1e-3, beta=0.9, eps=1e-8,
                 weight_decay=0.0, decay_rate=-0.5, growth_rate=0.999,
                 vector_reshape=True, weight_decay_mode="adamw"):
        self.lr, self.beta, self.eps = lr, beta, eps
        self.wd, self.gamma, self.lam = weight_decay, decay_rate, growth_rate
        self.vector_reshape = vector_reshape
        self.mode = weight_decay_mode
        self.state: dict = {}
        for name, shape in shapes.items():
            squeezed = [s for s in shape if s != 1]
            dimension = len(squeezed)
            fact = not (dimension == 1 and not self.vector_reshape)
            numel = int(np.prod(shape)) if shape else 1
            st = {"step": 1, "fact": fact}
            if fact:
                eff = get_effective_shape(numel)
                st["eff"] = eff
                st["rm"] = np.zeros(eff[0])
                st["cm"] = np.zeros(eff[1])
                st["sign"] = np.zeros(eff, dtype=bool)
                st["rv"] = np.zeros(eff[0])
                st["cv"] = np.zeros(eff[1])
            else:
                st["m"] = np.zeros(shape)
                st["v"] = np.zeros(shape)
            self.state[name] = st

    def step(self, params: dict, grads: dict) -> dict:
        out = {}
        for name, p in params.items():
            g = grads[name].astype(np.float64).astype(np.float32)
            st = self.state[name]
            if self.wd and self.mode == "adam":
                g = g + self.wd * p
            elif self.wd and self.mode == "adamw":
                p = p * (1 - self.lr * self.wd)
            t = st["step"]
            beta_m = self.beta * self.lam ** (t - 1.0)
            beta_v = 1.0 - t ** self.gamma
            if st["fact"]:
                gm = g.reshape(st["eff"])
                m = _unnmf((st["rm"], st["cm"]))
                m = np.where(st["sign"], m, -m)
                v = _unnmf((st["rv"], st["cv"]))
                m = beta_m * m + (1 - beta_m) * gm
                v = beta_v * v + (1 - beta_v) * gm * gm
                st["sign"] = m >= 0
                st["rm"], st["cm"] = _nnmf(np.abs(m))
                st["rv"], st["cv"] = _nnmf(v)
                upd = (m / (np.sqrt(v) + self.eps)).reshape(p.shape)
            else:
                st["m"] = beta_m * st["m"] + (1 - beta_m) * g
                st["v"] = beta_v * st["v"] + (1 - beta_v) * g * g
                upd = st["m"] / (np.sqrt(st["v"]) + self.eps)
            st["step"] += 1
            out[name] = p - self.lr * upd
        return out
