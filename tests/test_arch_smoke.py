"""Per-architecture smoke tests: reduced same-family config, one train step
and one decode step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, PAPER_IDS, get_config, smoke_config
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.launch import specs as S
from conftest import spec_opt


def smmf(lr=1e-3, **hp):
    # spec-built (shim DeprecationWarnings are errors in tier-1)
    return spec_opt("smmf", lr, **hp)

from repro.models import init_cache, init_encdec, init_encdec_cache, init_lm, vocab_padded
from repro.models.config import SHAPES

KEY = jax.random.PRNGKey(0)
B, SEQ = 2, 32


def _init(cfg):
    init = init_encdec if cfg.family == "encdec" else init_lm
    return init(KEY, cfg)


def _batch(cfg):
    toks = jax.random.randint(KEY, (B, SEQ), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(KEY, (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS + PAPER_IDS)
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    params = _init(cfg)
    opt = smmf(1e-3, decay_rate=-0.8)
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    p2, o2, metrics = step(params, opt_state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    # params changed, shapes preserved
    changed = jax.tree.map(lambda a, b: a.shape == b.shape, params, p2)
    assert all(jax.tree.leaves(changed))
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))]
    assert max(diffs) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = _init(cfg)
    step = jax.jit(make_decode_step(cfg))
    if cfg.family == "encdec":
        cache = init_encdec_cache(cfg, B, SEQ)
        from repro.models import encode
        enc = encode(params, cfg, jax.random.normal(KEY, (B, cfg.encoder_seq, cfg.d_model)))
        batch = {"token": jnp.zeros((B, 1), jnp.int32), "enc": enc}
        tok, cache = step(params, batch, cache)
    else:
        cache = init_cache(cfg, B, SEQ)
        batch = {"token": jnp.zeros((B, 1), jnp.int32)}
        tok, cache = step(params, batch, cache)
    assert tok.shape == (B,)
    assert int(jnp.max(tok)) < vocab_padded(cfg)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_exactness(arch):
    """The FULL configs match the assignment (never instantiated here)."""
    cfg = get_config(arch)
    expected = {
        "grok_1_314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek_moe_16b": (28, 2048, 16, 16, 1408, 102400),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen1_5_4b": (40, 2560, 20, 20, 6912, 151936),
        "nemotron_4_15b": (32, 6144, 48, 8, 24576, 256000),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "whisper_base": (6, 512, 8, 8, 2048, 51865),
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads if cfg.n_heads else 0,
           cfg.d_ff, cfg.vocab)
    assert got == expected
    # extra structural features
    if arch == "grok_1_314b":
        assert (cfg.n_experts, cfg.top_k) == (8, 2)
    if arch == "deepseek_moe_16b":
        assert (cfg.n_experts, cfg.top_k, cfg.n_shared_experts) == (64, 6, 2)
    if arch == "qwen1_5_4b":
        assert cfg.qkv_bias
    if arch == "nemotron_4_15b":
        assert cfg.activation == "sq_relu" and not cfg.gated_mlp
    if arch == "recurrentgemma_2b":
        assert cfg.attn_window == 2048 and cfg.rglru_ratio == 2
    if arch == "whisper_base":
        assert cfg.encoder_layers == 6 and cfg.encoder_seq == 1500
    if arch == "llava_next_34b":
        assert cfg.n_patches > 0
    if arch == "mamba2_370m":
        assert cfg.ssm_state == 128


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_input_specs_are_abstract(arch):
    """input_specs never allocates: every leaf is a ShapeDtypeStruct."""
    cfg = get_config(arch)
    for shape in SHAPES.values():
        from repro.configs import cell_status
        if cell_status(cfg, shape) != "run":
            continue
        spec = S.input_specs(cfg, shape)
        for leaf in jax.tree.leaves(spec):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
    psds = S.params_specs(cfg)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in jax.tree.leaves(psds))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(psds))
    assert n > 0.5 * cfg.param_count()  # sanity vs analytic count
