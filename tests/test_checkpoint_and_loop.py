"""Checkpointing + fault-tolerant training loop tests."""

import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.data import SyntheticLMStream
from repro.launch.steps import make_train_step
from repro.models import init_lm
from repro.models.config import ModelConfig
from repro.train import TrainLoop, TrainLoopConfig

from conftest import spec_opt


def smmf(lr=1e-3, **hp):
    # spec-built (shim DeprecationWarnings are errors in tier-1)
    return spec_opt("smmf", lr, **hp)


CFG = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2, dtype="float32")


def _setup():
    params = init_lm(jax.random.PRNGKey(0), CFG)
    opt = smmf(1e-3, decay_rate=-0.8)
    return params, opt, opt.init(params)


def test_save_restore_roundtrip(tmp_path):
    params, opt, opt_state = _setup()
    save(tmp_path, 7, {"params": params, "opt": opt_state}, extra={"note": "x"})
    assert latest_step(tmp_path) == 7
    got, manifest = restore(tmp_path, {"params": params, "opt": opt_state})
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(got["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_tmp_pruned(tmp_path):
    params, opt, opt_state = _setup()
    # a stale tmp dir from a "preempted" writer
    (tmp_path / "tmp.99.1234").mkdir(parents=True)
    save(tmp_path, 1, {"params": params})
    assert not list(tmp_path.glob("tmp.*"))
    assert latest_step(tmp_path) == 1


def test_restore_shape_mismatch_raises(tmp_path):
    params, opt, opt_state = _setup()
    save(tmp_path, 1, {"p": jnp.zeros((4, 4))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(tmp_path, {"p": jnp.zeros((5, 4))})


def test_crash_resume_exact(tmp_path):
    """Train 20 steps with a crash at 12; resume must match an uninterrupted
    run exactly (data stream is a pure function of step)."""
    def run(crash_at, ckpt_dir):
        params, opt, opt_state = _setup()
        stream = SyntheticLMStream(CFG, 4, 16, seed=1)
        step_fn = jax.jit(make_train_step(CFG, opt))
        loop = TrainLoop(step_fn, params, opt_state, stream,
                         TrainLoopConfig(total_steps=20, ckpt_every=5,
                                         ckpt_dir=str(ckpt_dir), log_every=100,
                                         crash_at_step=crash_at))
        return loop

    clean = run(None, tmp_path / "clean").run()
    crash_dir = tmp_path / "crash"
    with pytest.raises(RuntimeError, match="injected crash"):
        run(12, crash_dir).run()
    resumed_loop = run(None, crash_dir)
    assert resumed_loop.start_step == 10  # last ckpt before the crash
    resumed = resumed_loop.run()
    # final params identical between clean and crashed+resumed runs
    a, _ = restore(tmp_path / "clean", {"params": resumed_loop.params, "opt": resumed_loop.opt_state})
    for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(resumed_loop.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-7)


def test_nan_guard_skips_update(tmp_path):
    params, opt, opt_state = _setup()

    calls = {"n": 0}

    def bad_step(p, o, b):
        calls["n"] += 1
        loss = jnp.float32(np.nan) if calls["n"] == 2 else jnp.float32(1.0)
        return p, o, {"loss": loss}

    stream = SyntheticLMStream(CFG, 4, 16)
    loop = TrainLoop(bad_step, params, opt_state, stream,
                     TrainLoopConfig(total_steps=3, ckpt_every=100,
                                     ckpt_dir=str(tmp_path / "nan_ckpt"), log_every=100))
    out = loop.run()
    assert out["nan_skips"] == 1


def test_elastic_restore_with_shardings(tmp_path):
    """Restore re-shards onto explicitly provided (1-device) shardings."""
    params, opt, opt_state = _setup()
    save(tmp_path, 3, {"params": params})
    sh = jax.tree.map(lambda _: jax.devices()[0], params)  # device placement
    got, _ = restore(tmp_path, {"params": params}, shardings={"params": sh})
    for a, b in zip(jax.tree.leaves(got["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_determinism_and_host_slicing():
    s1 = SyntheticLMStream(CFG, 8, 16, seed=5, host_id=0, num_hosts=2)
    s2 = SyntheticLMStream(CFG, 8, 16, seed=5, host_id=0, num_hosts=2)
    s3 = SyntheticLMStream(CFG, 8, 16, seed=5, host_id=1, num_hosts=2)
    b1, b2, b3 = s1.batch(3), s2.batch(3), s3.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # deterministic
    assert not np.array_equal(b1["tokens"], b3["tokens"])      # host-sliced
    assert b1["tokens"].shape == (4, 16)                       # local batch
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
