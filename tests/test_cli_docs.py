"""argparse ↔ docs/cli.md parity: no launcher flag may land undocumented.

Each launcher exposes ``build_parser()``; this test diffs the parser's
option strings against the ``--flag`` tokens in the matching section of
docs/cli.md, in both directions (undocumented flag = failure, stale doc row
= failure).
"""

import re
from pathlib import Path

import pytest

from repro.launch.dryrun import build_parser as dryrun_parser
from repro.launch.serve import build_parser as serve_parser
from repro.launch.train import build_parser as train_parser

CLI_MD = Path(__file__).resolve().parents[1] / "docs" / "cli.md"

SECTIONS = {
    "repro.launch.train": train_parser,
    "repro.launch.dryrun": dryrun_parser,
    "repro.launch.serve": serve_parser,
}


def _doc_sections() -> dict[str, str]:
    """Split docs/cli.md into module-named '## ...' sections."""
    text = CLI_MD.read_text()
    out = {}
    for name in SECTIONS:
        m = re.search(rf"^## .*{re.escape(name)}.*?$(.*?)(?=^## |\Z)",
                      text, re.M | re.S)
        assert m, f"docs/cli.md has no section for {name}"
        out[name] = m.group(1)
    return out


def _parser_flags(parser) -> set[str]:
    """All --long option strings of a parser (minus argparse's --help)."""
    flags = set()
    for action in parser._actions:
        flags.update(s for s in action.option_strings if s.startswith("--"))
    flags.discard("--help")
    return flags


@pytest.mark.parametrize("name", sorted(SECTIONS))
def test_cli_docs_parity(name):
    section = _doc_sections()[name]
    documented = set(re.findall(r"`(--[a-z][a-z0-9-]*)`", section))
    actual = _parser_flags(SECTIONS[name]())
    undocumented = actual - documented
    stale = documented - actual
    assert not undocumented, (
        f"{name}: flags missing from docs/cli.md: {sorted(undocumented)}")
    assert not stale, (
        f"{name}: docs/cli.md documents non-existent flags: {sorted(stale)}")
