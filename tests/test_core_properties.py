"""Hypothesis property tests for the paper's core algorithms."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.matricize import effective_shape, square_matricize, unmatricize
from repro.core.nnmf import nnmf_compress, nnmf_decompress
from repro.core.signpack import np_pack_signs, pack_signs, packed_width, unpack_signs


# --------------------------------------------------------------------------
# square-matricization (Algorithm 2 / Theorems 3.1-3.2)
# --------------------------------------------------------------------------

@given(st.integers(min_value=1, max_value=200_000))
@settings(max_examples=300, deadline=None)
def test_effective_shape_invariants(n):
    a, b = effective_shape(n)
    assert a * b == n
    assert a >= b >= 1
    # b is the largest divisor <= sqrt(n) -> |a-b| minimal over factor pairs
    for cand in range(b + 1, int(np.sqrt(n)) + 1):
        assert n % cand != 0 or cand == b


@given(st.integers(min_value=1, max_value=5000))
@settings(max_examples=100, deadline=None)
def test_effective_shape_minimizes_sum(n):
    """argmin |a-b| == argmin a+b over factor pairs (Theorem 3.2)."""
    a, b = effective_shape(n)
    best_sum = min(d + n // d for d in range(1, int(np.sqrt(n)) + 1) if n % d == 0)
    assert a + b == best_sum


@given(
    st.lists(st.integers(min_value=1, max_value=12), min_size=1, max_size=4)
)
@settings(max_examples=100, deadline=None)
def test_matricize_roundtrip(dims):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(dims), jnp.float32)
    m = square_matricize(x)
    assert m.ndim == 2 and m.size == x.size
    back = unmatricize(m, tuple(dims))
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


# --------------------------------------------------------------------------
# NNMF (Algorithm 4/5, Lemma E.7, Theorem I.1)
# --------------------------------------------------------------------------

@given(st.integers(2, 40), st.integers(2, 40), st.integers(0, 10_000))
@settings(max_examples=60, deadline=None)
def test_nnmf_error_sums_to_zero(n, m, seed):
    """Lemma E.7: the decompression error matrix sums to zero."""
    rng = np.random.default_rng(seed)
    mat = jnp.asarray(np.abs(rng.standard_normal((n, m))) + 1e-3, jnp.float32)
    r, c = nnmf_compress(mat)
    rec = nnmf_decompress(r, c)
    err = np.asarray(rec - mat, np.float64)
    assert abs(err.sum()) < 1e-2 * np.asarray(mat).sum()


@given(st.integers(2, 30), st.integers(2, 30), st.integers(0, 1000))
@settings(max_examples=50, deadline=None)
def test_nnmf_exact_on_rank1(n, m, seed):
    rng = np.random.default_rng(seed)
    r0 = np.abs(rng.standard_normal(n)) + 0.1
    c0 = np.abs(rng.standard_normal(m)) + 0.1
    mat = jnp.asarray(np.outer(r0, c0), jnp.float32)
    r, c = nnmf_compress(mat)
    rec = np.asarray(nnmf_decompress(r, c))
    np.testing.assert_allclose(rec, np.asarray(mat), rtol=2e-4)


def test_nnmf_zero_matrix():
    """Theorem I.1 edge: the all-zero matrix factorizes to zeros (no NaN)."""
    mat = jnp.zeros((5, 7))
    r, c = nnmf_compress(mat)
    assert np.all(np.isfinite(np.asarray(r))) and np.all(np.isfinite(np.asarray(c)))
    np.testing.assert_array_equal(np.asarray(nnmf_decompress(r, c)), 0.0)


# --------------------------------------------------------------------------
# sign bit-packing
# --------------------------------------------------------------------------

@given(st.integers(1, 40), st.integers(1, 70), st.integers(0, 10_000))
@settings(max_examples=80, deadline=None)
def test_signpack_roundtrip(n, m, seed):
    rng = np.random.default_rng(seed)
    signs = rng.random((n, m)) < 0.5
    packed = pack_signs(jnp.asarray(signs))
    assert packed.shape == (n, packed_width(m))
    assert packed.dtype == jnp.uint8
    un = np.asarray(unpack_signs(packed, m))
    np.testing.assert_array_equal(un, np.where(signs, 1.0, -1.0))
    # numpy twin used by checkpoint tooling agrees
    np.testing.assert_array_equal(np_pack_signs(signs), np.asarray(packed))


@given(st.integers(1, 30), st.integers(1, 60))
@settings(max_examples=40, deadline=None)
def test_signpack_is_32x_smaller_than_f32(n, m):
    from repro.core.signpack import sign_bytes

    assert sign_bytes((n, m)) <= (n * m * 4) / 8 / 4 + n  # ~1/32 + row padding
