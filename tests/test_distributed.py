"""Sharding rules, specs, serving engine, HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed import rules
from repro.launch import specs as S
from repro.models.config import SHAPES

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_fit_spec_divisibility():
    sp = rules.fit_spec(MESH, (32, 48), ("data", "model"))
    assert sp == P("data", "model")
    sp = rules.fit_spec(MESH, (20, 48), ("data", "model"))  # 20 % 16 != 0
    assert sp == P(None, "model")
    sp = rules.fit_spec(MESH3, (128, 4), (("pod", "data"), "model"))
    assert sp == P(("pod", "data"), None)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["pod", "multipod"])
def test_param_shardings_cover_all_archs(arch, mesh):
    """Every param leaf gets a legal sharding (dims divisible per axis)."""
    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    shardings = rules.param_shardings(mesh, cfg, psds)
    for leaf, sh in zip(jax.tree.leaves(psds), jax.tree.leaves(shardings)):
        spec = sh.spec
        assert len(spec) <= len(leaf.shape)
        for dim, want in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if want is None:
                continue
            size = rules._axsize(mesh, want)
            assert dim % size == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["grok_1_314b", "yi_6b", "mamba2_370m"])
def test_opt_state_shardings(arch):
    from repro.core.smmf import smmf

    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    opt = smmf(1e-3)
    sh = rules.opt_state_shardings(MESH, cfg, psds, opt)
    state_sds = jax.eval_shape(opt.init, psds)
    for leaf, s in zip(jax.tree.leaves(state_sds), jax.tree.leaves(sh)):
        for dim, want in zip(leaf.shape, tuple(s.spec) + (None,) * 8):
            if want is None:
                continue
            assert dim % rules._axsize(MESH, want) == 0, (arch, leaf.shape, s.spec)


def test_activation_rules_modes():
    cfg = get_config("yi_6b")
    for mode in ("train", "prefill", "decode"):
        rule = rules.activation_rules(MESH, cfg, mode)
        res = rule("residual", (256, 4096, 4096))
        assert res is not None
        got = rule("flash_q", (16, 16, 256, 4, 8, 128))
        if mode != "decode":
            # yi: kv=4 indivisible, heads=32 divisible -> defer to GSPMD
            assert got is None


def test_cell_matrix_counts():
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2] == "run"]
    skipped = [c for c in cells if c[2] != "run"]
    assert len(runnable) == 32
    assert all(c[1] == "long_500k" for c in skipped)
    assert {c[0] for c in skipped} == {
        "grok_1_314b", "deepseek_moe_16b", "yi_6b", "deepseek_7b",
        "qwen1_5_4b", "nemotron_4_15b", "whisper_base", "llava_next_34b",
    }


def test_hloanalysis_scan_tripcount():
    from repro.launch.hloanalysis import analyze_text

    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    res = analyze_text(c.as_text())
    expect = 5 * 2 * 64 ** 3
    assert expect <= res.flops <= 1.2 * expect


def test_hloanalysis_collectives():
    from repro.launch.hloanalysis import analyze_text

    mesh = jax.make_mesh((1,), ("d",))
    # trivially: unsharded single-device program has zero collectives
    f = jax.jit(lambda x: x @ x)
    c = f.lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    res = analyze_text(c.as_text())
    assert sum(res.coll.values()) == 0


def test_serving_engine_generates():
    from repro.models import ModelConfig, init_lm
    from repro.serving import GenerationEngine
    from repro.serving.engine import Request

    cfg = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32) % 64, max_new=6)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    while eng.step():
        pass
    for r in reqs:
        assert r.done and len(r.out) == 6
        assert all(0 <= t < 64 for t in r.out)


def test_mesh_construction_shapes():
    # run in-process only when enough devices were forced; else assert raises
    import repro.launch.mesh as M

    if jax.device_count() >= 512:
        mesh = M.make_production_mesh(multi_pod=True)
        assert mesh.shape == {"pod": 2, "data": 16, "model": 16}
    else:
        with pytest.raises(Exception):
            M.make_production_mesh()
