"""Sharding rules, specs, serving engine, HLO analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed import rules
from repro.launch import specs as S
from repro.models.config import SHAPES, ModelConfig
from conftest import spec_opt


def smmf(lr=1e-3, **hp):
    # spec-built twin of the deprecated constructor (shim warnings are
    # errors in tier-1; these tests exercise sharding, not the shims)
    return spec_opt("smmf", lr, **hp)

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_fit_spec_divisibility():
    sp = rules.fit_spec(MESH, (32, 48), ("data", "model"))
    assert sp == P("data", "model")
    sp = rules.fit_spec(MESH, (20, 48), ("data", "model"))  # 20 % 16 != 0
    assert sp == P(None, "model")
    sp = rules.fit_spec(MESH3, (128, 4), (("pod", "data"), "model"))
    assert sp == P(("pod", "data"), None)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [MESH, MESH3], ids=["pod", "multipod"])
def test_param_shardings_cover_all_archs(arch, mesh):
    """Every param leaf gets a legal sharding (dims divisible per axis)."""
    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    shardings = rules.param_shardings(mesh, cfg, psds)
    for leaf, sh in zip(jax.tree.leaves(psds), jax.tree.leaves(shardings)):
        spec = sh.spec
        assert len(spec) <= len(leaf.shape)
        for dim, want in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if want is None:
                continue
            size = rules._axsize(mesh, want)
            assert dim % size == 0, (arch, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["grok_1_314b", "yi_6b", "mamba2_370m"])
def test_opt_state_shardings(arch):
    cfg = get_config(arch)
    psds = S.params_specs(cfg)
    opt = smmf(1e-3)
    sh = rules.opt_state_shardings(MESH, cfg, psds, opt)
    state_sds = jax.eval_shape(opt.init, psds)
    for leaf, s in zip(jax.tree.leaves(state_sds), jax.tree.leaves(sh)):
        for dim, want in zip(leaf.shape, tuple(s.spec) + (None,) * 8):
            if want is None:
                continue
            assert dim % rules._axsize(MESH, want) == 0, (arch, leaf.shape, s.spec)


def test_activation_rules_modes():
    cfg = get_config("yi_6b")
    for mode in ("train", "prefill", "decode"):
        rule = rules.activation_rules(MESH, cfg, mode)
        res = rule("residual", (256, 4096, 4096))
        assert res is not None
        got = rule("flash_q", (16, 16, 256, 4, 8, 128))
        if mode != "decode":
            # yi: kv=4 indivisible, heads=32 divisible -> defer to GSPMD
            assert got is None


def test_cell_matrix_counts():
    from repro.configs import all_cells

    cells = all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2] == "run"]
    skipped = [c for c in cells if c[2] != "run"]
    assert len(runnable) == 32
    assert all(c[1] == "long_500k" for c in skipped)
    assert {c[0] for c in skipped} == {
        "grok_1_314b", "deepseek_moe_16b", "yi_6b", "deepseek_7b",
        "qwen1_5_4b", "nemotron_4_15b", "whisper_base", "llava_next_34b",
    }


def test_hloanalysis_scan_tripcount():
    from repro.launch.hloanalysis import analyze_text

    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    res = analyze_text(c.as_text())
    expect = 5 * 2 * 64 ** 3
    assert expect <= res.flops <= 1.2 * expect


def test_hloanalysis_collectives():
    from repro.launch.hloanalysis import analyze_text

    mesh = jax.make_mesh((1,), ("d",))
    # trivially: unsharded single-device program has zero collectives
    f = jax.jit(lambda x: x @ x)
    c = f.lower(jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    res = analyze_text(c.as_text())
    assert sum(res.coll.values()) == 0


def test_serving_engine_generates():
    from repro.models import ModelConfig, init_lm
    from repro.serving import GenerationEngine
    from repro.serving.engine import Request

    cfg = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    eng = GenerationEngine(params, cfg, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32) % 64, max_new=6)
            for i in range(5)]
    for r in reqs:
        eng.submit(r)
    while eng.step():
        pass
    for r in reqs:
        assert r.done and len(r.out) == 6
        assert all(0 <= t < 64 for t in r.out)


# ---------------------------------------------------------------------------
# sharded bucket stacks + donation (PR 2)
# ---------------------------------------------------------------------------

def test_sharded_bucket_bytes_shrink_linearly():
    """Per-device optimizer-state bytes shrink ~linearly with the fsdp axis
    (acceptance: <= 30% of replicated on a 4-way AbstractMesh for
    smmf/transformer_base — the benchmarks/opt_memory_sharded.py metric)."""
    cfg = get_config("transformer_base")
    psds = S.params_specs(cfg)
    opt = smmf(1e-3, decay_rate=-0.8)
    state_sds = jax.eval_shape(opt.init, psds)

    def per_dev(ways):
        mesh = AbstractMesh((("data", ways),))
        sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
        return rules.sharded_state_bytes(sh, state_sds)

    base = per_dev(1)
    from repro.utils.tree import tree_bytes

    assert base == tree_bytes(state_sds)  # 1-way == replicated total
    assert per_dev(2) <= 0.55 * base
    assert per_dev(4) <= 0.30 * base     # PR-2 acceptance criterion
    assert per_dev(8) <= 0.20 * base


@pytest.mark.multidevice
def test_sharded_vs_replicated_update_parity(emulated_mesh):
    """On a real (forced-host) multi-device mesh, the stack-sharded update
    is numerically identical to the replicated one and the bucket stack is
    actually distributed. Runs on the session-scoped emulated-mesh harness
    (tests/conftest.py): the forced device count is read at first jax
    import, and the child's result is cached for the whole session."""
    out = emulated_mesh.run("_sharded_update_child.py")
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    assert "PARITY OK" in out.stdout


def test_donation_with_grad_accum():
    """Donating params+opt state through the jitted step leaves no
    aliased-buffer errors under gradient accumulation, the jax.stages
    args_info marks them donated, and the executable aliases the bytes."""
    from repro.data import SyntheticLMStream
    from repro.launch.steps import assert_donation, make_train_step
    from repro.models import init_lm

    cfg = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2, dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    opt = smmf(1e-3, decay_rate=-0.8)
    opt_state = opt.init(params)
    stream = SyntheticLMStream(cfg, 4, 16, seed=0)

    step_fn = jax.jit(make_train_step(cfg, opt, grad_accum=2), donate_argnums=(0, 1))
    lowered = step_fn.lower(params, opt_state, stream.batch(0))
    compiled = lowered.compile()
    rep = assert_donation(lowered, compiled)
    assert rep["donated_args"] > 0 and rep["alias_bytes"] > 0

    # consecutive steps re-donating the returned buffers: no
    # "Array has been deleted" / aliasing errors, finite results
    for step in range(3):
        params, opt_state, metrics = compiled(params, opt_state, stream.batch(step))
    assert np.isfinite(float(metrics["loss"]))
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(params))


def test_mesh_construction_shapes():
    # run in-process only when enough devices were forced; else assert raises
    import repro.launch.mesh as M

    if jax.device_count() >= 512:
        mesh = M.make_production_mesh(multi_pod=True)
        assert mesh.shape == {"pod": 2, "data": 16, "model": 16}
    else:
        with pytest.raises(Exception):
            M.make_production_mesh()
