"""Leaf-plan update engine: bucketing, blockwise-kernel, and launch counts.

Covers the engine refactor's acceptance criteria:

* bucketed updates are bit-compatible with the per-leaf baseline and track
  the paper's reference trajectories on a mixed pytree;
* ``use_kernel=True`` composes with ``blocks>1`` (no silent fallback) and
  matches the unfused blockwise path;
* bucketing collapses per-step update launches by >= 5x on a
  transformer-shaped param set.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.plan import build_buckets, smmf_planner
from repro.kernels.smmf_update import ops as kops
from repro.optim.base import apply_updates

# spec-built twins of the legacy constructors (shared helper: conftest)
from conftest import spec_opt


def smmf(lr=1e-3, **hp):
    return spec_opt("smmf", lr, **hp)


def adafactor(lr=1e-3, **hp):
    return spec_opt("adafactor", lr, **hp)


def came(lr=1e-3, **hp):
    return spec_opt("came", lr, **hp)


def sm3(lr=1e-3, **hp):
    return spec_opt("sm3", lr, **hp)

from repro.utils.tree import tree_bytes

from reference_smmf import RefSMMF

# mixed pytree: bias / conv / embedding / scalar shapes, with repeated
# geometries so bucketing actually groups leaves
SHAPES = {
    "wq": (48, 96),
    "wk": (48, 96),
    "wv": (48, 96),
    "bias_q": (96,),
    "bias_k": (96,),
    "conv": (3, 3, 8, 16),
    "embed": (128, 24),
    "scalar": (),
}


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {k: rng.standard_normal(s).astype(np.float32) for k, s in SHAPES.items()}


def _run(opt, steps=6, seed0=50):
    params = jax.tree.map(jnp.asarray, _tree(0))
    state = opt.init(params)
    for s in range(steps):
        grads = jax.tree.map(jnp.asarray, _tree(seed0 + s))
        u, state = opt.update(grads, state, params)
        params = apply_updates(params, u)
    return params


# ---------------------------------------------------------------------------
# plan / bucket invariants
# ---------------------------------------------------------------------------

def test_plans_and_buckets():
    plan_fn = smmf_planner(blocks=1)
    flat = [jnp.zeros(s) for s in SHAPES.values()]
    plans = [plan_fn(i, tuple(p.shape)) for i, p in enumerate(flat)]
    # same-geometry leaves share a bucket; per-leaf mode never groups
    buckets = build_buckets(plans, bucket=True)
    nobuckets = build_buckets(plans, bucket=False)
    assert len(buckets) < len(plans)
    assert len(nobuckets) == len(plans)
    assert sum(b.size for b in buckets) == len(plans)
    by_key = {b.key: b for b in buckets}
    assert by_key["fac:1x72x64"].size == 3          # the three 48x96 leaves
    assert by_key["dense:1"].size == 1              # scalar fallback
    # blockwise geometry divides the row axis
    p = smmf_planner(blocks=4)(0, (64, 64))
    assert p.geometry == (4, 16, 64)


def test_engine_state_bytes_matches_actual():
    from repro.core.plan import smmf_state_bytes

    params = jax.tree.map(jnp.asarray, _tree(0))
    opt = smmf(1e-3)
    eng = opt.plan(params)
    state = jax.eval_shape(opt.init, params)
    assert smmf_state_bytes(eng.plans) == tree_bytes(state.factors)


# ---------------------------------------------------------------------------
# bucketed-update parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("blocks", [1, 4])
def test_bucketed_matches_per_leaf(blocks):
    """bucket=True must be numerically identical to the per-leaf baseline."""
    a = _run(smmf(1e-2, decay_rate=-0.8, blocks=blocks, bucket=True))
    b = _run(smmf(1e-2, decay_rate=-0.8, blocks=blocks, bucket=False))
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=k)


def test_bucketed_matches_paper_reference():
    """Bucketed engine tracks the paper's reference trajectories on the
    mixed pytree (bias / conv / embedding / scalar)."""
    params_np = _tree(0)
    ref = RefSMMF({k: v.shape for k, v in params_np.items()}, lr=1e-2, decay_rate=-0.5)
    opt = smmf(lr=1e-2, decay_rate=-0.5)
    params = jax.tree.map(jnp.asarray, params_np)
    state = opt.init(params)
    for step in range(6):
        grads_np = _tree(step + 200)
        u, state = opt.update(jax.tree.map(jnp.asarray, grads_np), state, params)
        params = apply_updates(params, u)
        params_np = ref.step(params_np, grads_np)
        for k in params_np:
            np.testing.assert_allclose(np.asarray(params[k]), params_np[k],
                                       rtol=3e-5, atol=3e-6, err_msg=f"step {step} leaf {k}")


@pytest.mark.parametrize("name,mk", [
    ("adafactor", lambda b: adafactor(1e-2, bucket=b)),
    ("came", lambda b: came(1e-2, bucket=b)),
    ("sm3", lambda b: sm3(1e-2, bucket=b)),
])
def test_baseline_optimizers_bucket_parity(name, mk):
    a = _run(mk(True))
    b = _run(mk(False))
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=f"{name} {k}")


# ---------------------------------------------------------------------------
# blockwise kernel path (use_kernel x blocks>1)
# ---------------------------------------------------------------------------

def test_kernel_composes_with_blocks():
    """use_kernel + blocks=4 takes the fused path (no silent fallback) and
    matches the unfused blockwise update within 1e-5."""
    before = kops.KERNEL_LAUNCHES
    a = _run(smmf(1e-2, decay_rate=-0.8, blocks=4, use_kernel=True))
    assert kops.KERNEL_LAUNCHES > before, "kernel path silently skipped"
    b = _run(smmf(1e-2, decay_rate=-0.8, blocks=4, use_kernel=False))
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-5, atol=1e-5, err_msg=k)


def test_batched_kernel_matches_ref_stack():
    """The batched kernel on a (B, n, m) stack equals B single-matrix
    reference calls."""
    from repro.core.signpack import pack_signs
    from repro.kernels.smmf_update import smmf_update_ref

    rng = np.random.default_rng(7)
    B, n, m = 3, 96, 72
    g = jnp.asarray(rng.standard_normal((B, n, m)), jnp.float32)
    r_m = jnp.abs(jnp.asarray(rng.standard_normal((B, n)), jnp.float32))
    c_m = jnp.abs(jnp.asarray(rng.standard_normal((B, m)), jnp.float32))
    r_v = jnp.abs(jnp.asarray(rng.standard_normal((B, n)), jnp.float32))
    c_v = jnp.abs(jnp.asarray(rng.standard_normal((B, m)), jnp.float32))
    sign = jnp.stack([pack_signs(jnp.asarray(rng.standard_normal((n, m)) >= 0))
                      for _ in range(B)])
    kw = dict(beta1_t=0.85, beta2_t=0.97, eps=1e-8)
    out = kops.smmf_update_batched(g, r_m, c_m, sign, r_v, c_v, **kw)
    for b in range(B):
        ref = smmf_update_ref(g[b], r_m[b], c_m[b], sign[b], r_v[b], c_v[b], **kw)
        names = ["u", "r_m", "c_m", "sign", "r_v", "c_v"]
        for name, got, want in zip(names, [o[b] for o in out], ref):
            if name == "sign":
                np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            else:
                np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                           rtol=3e-6, atol=3e-6, err_msg=f"b={b} {name}")


# ---------------------------------------------------------------------------
# launch accounting (acceptance: >= 5x fewer launches than per-leaf)
# ---------------------------------------------------------------------------

def _transformer_params(d=256, layers=4):
    rng = np.random.default_rng(0)
    p = {}
    for i in range(layers):
        p[f"attn{i}"] = jnp.asarray(rng.standard_normal((d, d)), jnp.float32)
        p[f"ffn{i}"] = jnp.asarray(rng.standard_normal((d, 4 * d)), jnp.float32)
        p[f"out{i}"] = jnp.asarray(rng.standard_normal((4 * d, d)), jnp.float32)
        p[f"bias{i}"] = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
        p[f"scale{i}"] = jnp.asarray(rng.standard_normal((d,)), jnp.float32)
    return p


def test_bucketing_collapses_launches_5x():
    params = _transformer_params()
    bucketed = smmf(1e-3).plan(params).stats()
    per_leaf = smmf(1e-3, bucket=False).plan(params).stats()
    assert per_leaf["update_launches"] == len(jax.tree.leaves(params))
    assert bucketed["update_launches"] * 5 <= per_leaf["update_launches"]


def test_kernel_plan_covers_all_factored_buckets():
    params = _transformer_params()
    stats = smmf(1e-3, use_kernel=True, blocks=4).plan(params).stats()
    assert stats["kernel_buckets"] == stats["factored_buckets"] > 0


# ---------------------------------------------------------------------------
# fused dense fallback: one concatenated launch per dtype
# ---------------------------------------------------------------------------

# fallback-heavy tree (vector_reshape=False keeps 1-D leaves dense): four
# dense leaves with three distinct element counts, plus factored matrices
FB_SHAPES = {
    "w1": (24, 32), "w2": (24, 32),
    "b1": (48,), "b2": (48,), "b3": (80,),
    "scalar": (),
}


def _fb_tree(seed):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s).astype(np.float32))
            for k, s in FB_SHAPES.items()}


def _run_fb(opt, steps=5, seed0=300):
    params = _fb_tree(0)
    state = opt.init(params)
    for s in range(steps):
        u, state = opt.update(_fb_tree(seed0 + s), state, params)
        params = apply_updates(params, u)
    return params


def test_fused_dense_counts_as_one_launch():
    """stats() launch accounting: the fused dense-fallback launch counts as
    1 (not one per distinct element count) so the benchmarks' launches
    column stays truthful; fuse_dense=False recovers per-geometry buckets."""
    params = _fb_tree(0)
    fused = smmf(1e-3, vector_reshape=False).plan(params).stats()
    assert fused["dense_buckets"] == 1
    assert fused["fused_dense_leaves"] == 4
    assert fused["update_launches"] == fused["factored_buckets"] + 1
    unfused = smmf(1e-3, vector_reshape=False, fuse_dense=False).plan(params).stats()
    assert unfused["dense_buckets"] == 3          # one per distinct numel
    assert unfused["fused_dense_leaves"] == 0
    # per-leaf baseline never fuses
    nobucket = smmf(1e-3, vector_reshape=False, bucket=False).plan(params).stats()
    assert nobucket["update_launches"] == len(FB_SHAPES)


def test_fused_dense_groups_by_dtype():
    """Mixed-dtype dense leaves dispatch one fused launch per dtype."""
    params = {"a": jnp.zeros((6,), jnp.float32),
              "b": jnp.zeros((10,), jnp.bfloat16),
              "c": jnp.zeros((10,), jnp.float32)}
    stats = smmf(1e-3, vector_reshape=False).plan(params).stats()
    assert stats["dense_buckets"] == 2
    assert stats["fused_dense_leaves"] == 3


def test_fused_dense_matches_unfused_and_per_leaf():
    """Fusing the dense fallback is a pure dispatch change: results are
    identical to per-geometry buckets and the per-leaf baseline."""
    a = _run_fb(smmf(1e-2, decay_rate=-0.8, vector_reshape=False))
    b = _run_fb(smmf(1e-2, decay_rate=-0.8, vector_reshape=False, fuse_dense=False))
    c = _run_fb(smmf(1e-2, decay_rate=-0.8, vector_reshape=False, bucket=False))
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=f"fused-vs-unfused {k}")
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(c[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=f"fused-vs-perleaf {k}")
