"""Flash-decode Pallas kernel vs jnp oracle (shape/dtype/pos sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode, flash_decode_ref

SWEEP = [
    # (B, S, Hq, Hkv, D, block_s)
    (2, 128, 8, 2, 32, 64),
    (3, 1000, 4, 4, 64, 256),
    (1, 64, 16, 1, 128, 64),
    (2, 513, 6, 3, 16, 128),
    (4, 2048, 2, 2, 64, 512),
]


def _mk(b, s, hq, hkv, d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    pos = jnp.asarray(rng.integers(0, s, size=(b,)), jnp.int32)
    return q, k, v, pos


@pytest.mark.parametrize("b,s,hq,hkv,d,bs", SWEEP)
def test_flash_decode_matches_ref(b, s, hq, hkv, d, bs):
    q, k, v, pos = _mk(b, s, hq, hkv, d, seed=s + hq)
    ref = flash_decode_ref(q, k, v, pos)
    out = flash_decode(q, k, v, pos, block_s=bs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_flash_decode_bf16_cache():
    q, k, v, pos = _mk(2, 256, 8, 2, 64, seed=7)
    kb, vb = k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    ref = flash_decode_ref(q, kb, vb, pos)
    out = flash_decode(q, kb, vb, pos, block_s=128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-2)


def test_flash_decode_edge_positions():
    """pos = 0 (single valid key) and pos = S-1 (full cache)."""
    q, k, v, _ = _mk(2, 128, 4, 2, 32, seed=3)
    for p in (0, 127):
        pos = jnp.full((2,), p, jnp.int32)
        ref = flash_decode_ref(q, k, v, pos)
        out = flash_decode(q, k, v, pos, block_s=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)
