"""Pallas fused SMMF kernel vs the pure-jnp oracle (shape/dtype sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.signpack import pack_signs
from repro.core.smmf import smmf
from repro.kernels.smmf_update import smmf_update, smmf_update_ref
from repro.optim.base import apply_updates

# These tests deliberately exercise the deprecated legacy-constructor
# surface (shim parity / reference trajectories); tier-1 errors on shim
# DeprecationWarnings everywhere else (pytest.ini).
pytestmark = pytest.mark.filterwarnings(
    "ignore:.*is deprecated. build via repro.optim.spec.OptimizerSpec.*:DeprecationWarning")

SWEEP = [
    (8, 8), (64, 48), (128, 128), (300, 280), (517, 999),
    (1, 7), (2048, 96), (33, 1024),
]


def _mk(n, m, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal((n, m)), dtype)
    m0 = rng.standard_normal((n, m))
    r_m = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    c_m = jnp.abs(jnp.asarray(rng.standard_normal(m), jnp.float32))
    r_v = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    c_v = jnp.abs(jnp.asarray(rng.standard_normal(m), jnp.float32))
    sign = pack_signs(jnp.asarray(m0 >= 0))
    return g, r_m, c_m, sign, r_v, c_v


@pytest.mark.parametrize("n,m", SWEEP)
def test_kernel_matches_ref(n, m):
    ops = _mk(n, m, seed=n * 1000 + m)
    kw = dict(beta1_t=0.85, beta2_t=0.97, eps=1e-8)
    ref = smmf_update_ref(*ops, **kw)
    out = smmf_update(*ops, **kw)
    names = ["u", "r_m", "c_m", "sign", "r_v", "c_v"]
    for name, a, b in zip(names, out, ref):
        if name == "sign":
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-6, atol=3e-6, err_msg=f"{n}x{m} {name}")


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    ops = list(_mk(96, 160, seed=5))
    ops[0] = ops[0].astype(dtype)
    kw = dict(beta1_t=0.9, beta2_t=0.5, eps=1e-8)
    ref = smmf_update_ref(*ops, **kw)
    out = smmf_update(*ops, **kw)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]), rtol=tol, atol=tol)


@pytest.mark.parametrize("block", [(8, 128), (16, 256), (256, 512)])
def test_kernel_block_shapes(block):
    ops = _mk(200, 333, seed=9)
    kw = dict(beta1_t=0.8, beta2_t=0.9, eps=1e-8)
    ref = smmf_update_ref(*ops, **kw)
    out = smmf_update(*ops, **kw, block=block)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]), rtol=3e-6, atol=3e-6)
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(ref[3]))


def test_kernel_beta_extremes():
    ops = _mk(64, 64, seed=3)
    for b1, b2 in [(0.0, 0.0), (1.0, 1.0), (0.999, 1e-4)]:
        ref = smmf_update_ref(*ops, beta1_t=b1, beta2_t=b2, eps=1e-8)
        out = smmf_update(*ops, beta1_t=b1, beta2_t=b2, eps=1e-8)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                                   rtol=3e-6, atol=3e-6)


def test_optimizer_kernel_path_matches_jnp_path():
    """smmf(use_kernel=True) must produce identical trajectories."""
    rng = np.random.default_rng(0)
    p0 = {"w": jnp.asarray(rng.standard_normal((96, 64)), jnp.float32)}
    o1, o2 = smmf(1e-2), smmf(1e-2, use_kernel=True)
    s1, s2 = o1.init(p0), o2.init(p0)
    p1 = p2 = p0
    for i in range(5):
        g = {"w": jnp.asarray(rng.standard_normal((96, 64)), jnp.float32)}
        u1, s1 = o1.update(g, s1, p1)
        u2, s2 = o2.update(g, s2, p2)
        p1 = apply_updates(p1, u1)
        p2 = apply_updates(p2, u2)
        np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]),
                                   rtol=2e-6, atol=2e-6)
