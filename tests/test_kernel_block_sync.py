"""The default fused-kernel tile is ONE constant, not four literals.

``DEFAULT_KERNEL_BLOCK`` is defined once in ``repro.core.plan`` (imports
only stdlib + jax, so every consumer can reach it cycle-free) and
re-exported by the optimizer surface (``repro.optim.families``,
``repro.optim.engine``), the legacy core module (``repro.core.smmf``),
and the kernel itself (``repro.kernels.smmf_update.kernel.DEFAULT_BLOCK``).
Before the hoist these were four separate ``(256, 512)`` literals that
could silently drift apart — a kernel compiled for one tile while the
plan priced another.
"""


def test_default_kernel_block_single_source():
    import importlib

    from repro.core import plan
    from repro.kernels.smmf_update import kernel
    from repro.optim import engine, families

    # repro.core re-exports the smmf *constructor* under the module's name,
    # so reach the module itself through importlib
    core_smmf = importlib.import_module("repro.core.smmf")
    assert plan.DEFAULT_KERNEL_BLOCK == (256, 512)
    assert families.DEFAULT_KERNEL_BLOCK is plan.DEFAULT_KERNEL_BLOCK
    assert engine.DEFAULT_KERNEL_BLOCK is plan.DEFAULT_KERNEL_BLOCK
    assert core_smmf.DEFAULT_KERNEL_BLOCK is plan.DEFAULT_KERNEL_BLOCK
    assert kernel.DEFAULT_BLOCK is plan.DEFAULT_KERNEL_BLOCK


def test_no_stray_kernel_block_literals():
    """No source file under src/ re-declares the tile as its own literal
    assignment — consumers must import it."""
    import re
    from pathlib import Path

    src = Path(__file__).resolve().parents[1] / "src"
    decl = re.compile(r"^(DEFAULT_KERNEL_BLOCK|DEFAULT_BLOCK)\s*=\s*\(",
                      re.MULTILINE)
    offenders = [
        p for p in src.rglob("*.py")
        if decl.search(p.read_text()) and p.name != "plan.py"
    ]
    assert not offenders, f"tile literal re-declared in {offenders}"
