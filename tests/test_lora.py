"""LoRA fine-tuning with SMMF (the paper's LLaMA-7b Table-4 setup, scaled)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import init_lm, lm_loss
from repro.models.config import ModelConfig
from conftest import spec_opt


def smmf(lr=1e-3, **hp):
    # spec-built (shim DeprecationWarnings are errors in tier-1)
    return spec_opt("smmf", lr, **hp)


def adam(lr=1e-3, **hp):
    return spec_opt("adam", lr, **hp)
from repro.train.lora import lora_init, lora_merge, make_lora_train_step
from repro.utils.tree import tree_bytes

CFG = ModelConfig("t", "dense", 2, 64, 4, 128, 128, n_kv_heads=2, dtype="float32")
KEY = jax.random.PRNGKey(0)


def test_lora_init_targets_attn_and_ffn():
    params = init_lm(KEY, CFG)
    ad = lora_init(KEY, params, rank=4)
    assert len(ad) == 7  # wq wk wv wo wi wg wo(ffn)
    for path, pair in ad.items():
        assert pair["a"].shape[-1] == 4 and pair["b"].shape[-2] == 4
        # B = 0 -> merge is an identity at init
    merged = lora_merge(params, ad)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_lora_training_moves_loss_with_frozen_base():
    params = init_lm(KEY, CFG)
    ad = lora_init(KEY, params, rank=4)
    opt = smmf(5e-2, decay_rate=-0.8)
    opt_state = opt.init(ad)
    step = jax.jit(make_lora_train_step(CFG, opt, lm_loss))
    toks = jax.random.randint(KEY, (4, 32), 0, 128)
    batch = {"tokens": toks, "labels": toks}
    base_copy = jax.tree.map(lambda x: x, params)
    losses = []
    for _ in range(30):
        ad, opt_state, m = step(params, ad, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2  # adapters learn
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(base_copy)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))  # base frozen


def test_lora_smmf_state_smaller_than_adam_full():
    """The paper's Table-4 effect: adapter-only SMMF state is tiny vs
    full-model Adam state."""
    params = init_lm(KEY, CFG)
    ad = lora_init(KEY, params, rank=4)
    smmf_lora = tree_bytes(jax.eval_shape(smmf(1e-3).init, ad))
    adam_full = tree_bytes(jax.eval_shape(adam(1e-3).init, params))
    assert smmf_lora < adam_full / 30
