"""Model-family correctness: decode == full-sequence logits, flash == naive."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    encdec_decode_step,
    encdec_logits,
    encode,
    init_cache,
    init_encdec,
    init_encdec_cache,
    init_lm,
    lm_decode_step,
    lm_logits,
    lm_loss,
    lm_prefill,
)

KEY = jax.random.PRNGKey(0)
B, S = 2, 48


def _toks(vocab=100):
    return jax.random.randint(KEY, (B, S), 0, vocab)


def _decode_parity(cfg, p, toks, rtol=1e-3):
    full, _ = jax.jit(lambda p, t: lm_logits(p, cfg, t))(p, toks)
    c = init_cache(cfg, B, S)
    step = jax.jit(lambda p, t, c: lm_decode_step(p, cfg, t, c))
    for i in range(S):
        lg, c = step(p, toks[:, i : i + 1], c)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0, : cfg.vocab]), np.asarray(full[:, -1, : cfg.vocab]),
        rtol=rtol, atol=rtol,
    )


def test_dense_gqa_decode_parity():
    cfg = ModelConfig("t", "dense", 2, 64, 4, 128, 100, n_kv_heads=2, dtype="float32")
    _decode_parity(cfg, init_lm(KEY, cfg), _toks())


def test_qkv_bias_decode_parity():
    cfg = ModelConfig("t", "dense", 2, 64, 4, 96, 100, n_kv_heads=4, qkv_bias=True, dtype="float32")
    _decode_parity(cfg, init_lm(KEY, cfg), _toks())


def test_sq_relu_nongated():
    cfg = ModelConfig("t", "dense", 2, 64, 4, 128, 100, n_kv_heads=2,
                      activation="sq_relu", gated_mlp=False, norm="layernorm", dtype="float32")
    p = init_lm(KEY, cfg)
    loss, m = jax.jit(lambda p, b: lm_loss(p, cfg, b))(p, {"tokens": _toks(), "labels": _toks()})
    assert np.isfinite(float(loss))
    _decode_parity(cfg, p, _toks())


def test_moe_decode_parity_and_aux():
    cfg = ModelConfig("t", "moe", 2, 64, 4, 48, 100, n_kv_heads=4, n_experts=4,
                      top_k=2, n_shared_experts=1, moe_d_ff=48, dtype="float32")
    p = init_lm(KEY, cfg)
    loss, m = jax.jit(lambda p, b: lm_loss(p, cfg, b))(p, {"tokens": _toks(), "labels": _toks()})
    assert float(m["aux"]) > 0
    _decode_parity(cfg, p, _toks(), rtol=2e-3)


def test_ssm_decode_parity():
    cfg = ModelConfig("t", "ssm", 2, 64, 0, 0, 100, ssm_state=16, ssm_headdim=16,
                      ssm_expand=2, ssm_chunk=16, dtype="float32")
    _decode_parity(cfg, init_lm(KEY, cfg), _toks(), rtol=2e-3)


def test_hybrid_decode_parity():
    cfg = ModelConfig("t", "hybrid", 5, 64, 4, 128, 100, n_kv_heads=1,
                      attn_window=16, rglru_ratio=2, lru_width=64, dtype="float32")
    _decode_parity(cfg, init_lm(KEY, cfg), _toks(), rtol=2e-3)


def test_vlm_prefix_loss_shapes():
    cfg = ModelConfig("t", "vlm", 2, 64, 4, 128, 100, n_kv_heads=2, n_patches=8, dtype="float32")
    p = init_lm(KEY, cfg)
    pe = jax.random.normal(KEY, (B, 8, 64))
    logits, _ = jax.jit(lambda p, t, e: lm_logits(p, cfg, t, e))(p, _toks(), pe)
    assert logits.shape[1] == S + 8
    loss, _ = lm_loss(p, cfg, {"tokens": _toks(), "labels": _toks(), "prefix_embeds": pe})
    assert np.isfinite(float(loss))


def test_encdec_decode_parity():
    cfg = ModelConfig("t", "encdec", 2, 64, 4, 128, 100, n_kv_heads=4,
                      encoder_layers=2, encoder_seq=24, norm="layernorm",
                      gated_mlp=False, activation="gelu", tie_embeddings=True, dtype="float32")
    p = init_encdec(KEY, cfg)
    toks = _toks()
    frames = jax.random.normal(KEY, (B, 24, 64))
    enc = jax.jit(lambda p, f: encode(p, cfg, f))(p, frames)
    full = jax.jit(lambda p, t, f: encdec_logits(p, cfg, t, f))(p, toks, frames)
    c = init_encdec_cache(cfg, B, S)
    step = jax.jit(lambda p, t, c, e: encdec_decode_step(p, cfg, t, c, e))
    for i in range(S):
        lg, c = step(p, toks[:, i : i + 1], c, enc)
    np.testing.assert_allclose(np.asarray(lg[:, 0, :100]), np.asarray(full[:, -1, :100]),
                               rtol=1e-3, atol=1e-3)


def test_prefill_then_decode_continues_correctly():
    cfg = ModelConfig("t", "dense", 2, 64, 4, 128, 100, n_kv_heads=2, dtype="float32")
    p = init_lm(KEY, cfg)
    toks = _toks()
    # full-sequence logits for positions S and S+1 given greedy continuation
    logits_p, cache = jax.jit(lambda p, t: lm_prefill(p, cfg, t))(p, toks)
    # prefill cache has length S; extend comparison via decode of next token
    nxt = jnp.argmax(logits_p[:, -1, :100], -1).astype(jnp.int32)[:, None]
    ext = jnp.concatenate([toks, nxt], axis=1)
    full, _ = jax.jit(lambda p, t: lm_logits(p, cfg, t))(p, ext)
    # decode step over a cache grown to S+1
    c2 = init_cache(cfg, B, S + 1)
    step = jax.jit(lambda p, t, c: lm_decode_step(p, cfg, t, c))
    c = c2
    for i in range(S + 1):
        lg, c = step(p, ext[:, i : i + 1], c)
    np.testing.assert_allclose(np.asarray(lg[:, 0, :100]), np.asarray(full[:, -1, :100]),
                               rtol=1e-3, atol=1e-3)


def test_flash_vs_naive_attention():
    from repro.models.flash import flash_attention
    from repro.models.layers import gqa_combine, gqa_scores

    key = jax.random.PRNGKey(1)
    b, s, hq, hkv, d = 2, 512, 8, 2, 32
    q = jax.random.normal(key, (b, s, hq, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, d))
    for window in (0, 64):
        sc = gqa_scores(q, k)
        qp = jnp.arange(s)[:, None]
        kp = jnp.arange(s)[None, :]
        mask = kp <= qp
        if window:
            mask = mask & (kp > qp - window)
        sc = jnp.where(mask[None, None], sc, -1e30)
        ref = gqa_combine(jax.nn.softmax(sc, -1), v)
        out = flash_attention(q, k, v, causal=True, window=window, block_q=64, block_kv=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_cnn_forward_and_loss():
    from repro.models import cnn_loss, init_cnn

    p = init_cnn(KEY, num_classes=10, width=8, depth=2)
    batch = {
        "images": jax.random.normal(KEY, (4, 32, 32, 3)),
        "labels": jnp.asarray([0, 1, 2, 3]),
    }
    loss, m = jax.jit(cnn_loss)(p, batch)
    assert np.isfinite(float(loss))
    # conv kernels are rank-4: the paper's high-rank momentum case
    assert p["conv0a"]["w"].ndim == 4
