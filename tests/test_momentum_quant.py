"""Full-size momentum on blockwise sub-row scales (Adafactor/CAME int8).

Pre-blockwise-scales, the momentum slot was the one remaining full-size
f32 slot in quantized Adafactor/CAME (a per-stack-row absmax scale is too
coarse for a full matrix: one outlier washes out its entire row). With
``SlotSpec.block`` the slot stores as 1-byte payloads + one f32 absmax
scale per 128-element sub-row block — which is what moves fully-quantized
Adafactor/CAME to ~28% of f32 per device (asserted analytically in
``benchmarks/memory_table.py`` and gated by ``tools/bench_compare.py``;
the numerics half lives here).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import spec_opt
from repro.core import quant as Q
from repro.optim.base import apply_updates
from repro.optim.families import MOMENTUM_QUANT_BLOCK
from repro.optim.qstate import QTensor, SlotSpec, _quantize_slot, dequantize_slot


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w1": jnp.asarray(rng.standard_normal((48, 96)), jnp.float32),
            "w2": jnp.asarray(rng.standard_normal((48, 96)), jnp.float32)}


def _run_steps(opt, params, steps=5, seed0=60):
    state = opt.init(params)

    @jax.jit
    def step(p, s, g):
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    for t in range(steps):
        rng = np.random.default_rng(seed0 + t)
        grads = jax.tree.map(
            lambda x: jnp.asarray(rng.standard_normal(x.shape) * 1e-2,
                                  jnp.float32), params)
        params, state = step(params, state, grads)
    return params, state


# ---------------------------------------------------------------------------
# codec: the block path in isolation
# ---------------------------------------------------------------------------

def test_block_slot_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 64, 256)), jnp.float32)
    slot = SlotSpec(True, block=64)
    # the block path never touches the bucket (segments are the fused-row
    # layout), so the codec is testable in isolation
    qt = _quantize_slot(x, None, slot, "int8", key=jax.random.PRNGKey(0))
    assert isinstance(qt, QTensor) and qt.q.dtype == jnp.int8
    # compact scales: one per (leading dims, 64-wide block), not per element
    assert qt.scale.shape == (4, 64, 4)
    back = dequantize_slot(qt, None, slot, "int8")
    # stochastic rounding is zero-mean; per-element error <= one block lsb
    lsb = np.repeat(np.asarray(qt.scale), 64, axis=-1)
    assert np.all(np.abs(np.asarray(back - x)) <= lsb + 1e-7)


def test_block_scale_localizes_outliers():
    """One huge element must not wash out the far blocks of its row —
    the property a per-row scale lacks and the reason momentum needs the
    block layout."""
    x = np.full((1, 1, 256), 1e-3, np.float32)
    x[0, 0, 0] = 100.0
    slot = SlotSpec(True, block=64)
    qt = _quantize_slot(jnp.asarray(x), None, slot, "int8")
    back = np.asarray(dequantize_slot(qt, None, slot, "int8"))
    # far blocks keep small-magnitude resolution
    np.testing.assert_allclose(back[0, 0, 64:], x[0, 0, 64:], rtol=0.02)


# ---------------------------------------------------------------------------
# families: the momentum slot actually quantizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam", ["adafactor", "came"])
def test_momentum_slot_stores_one_byte_payload(fam):
    opt = spec_opt(fam, 1e-2, quant="int8")
    params = _params()
    _, state = _run_steps(opt, params)
    # the factored bucket's slot 0 is the full-size momentum: it must be a
    # QTensor with 1-byte payload and *compact* blockwise scales
    mom = [bkstate[0] for key, bkstate in state.factors.items()
           if key.startswith("fac:")]
    assert mom, list(state.factors)
    for qt in mom:
        assert isinstance(qt, QTensor)
        assert qt.q.dtype.itemsize == 1
        assert qt.scale.shape[-1] == Q.block_count(qt.q.shape[-1],
                                                   MOMENTUM_QUANT_BLOCK)
        assert qt.scale.size < qt.q.size / 16  # scales stay overhead-sized


@pytest.mark.parametrize("fam", ["adafactor", "came"])
def test_quantized_momentum_tracks_f32_trajectory(fam):
    params = _params()
    p32, _ = _run_steps(spec_opt(fam, 1e-2), params)
    pq, _ = _run_steps(spec_opt(fam, 1e-2, quant="int8"), params)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(pq)):
        a, b = np.asarray(a), np.asarray(b)
        # lr 1e-2 x 5 steps moves params ~5e-2; 8-bit drift must stay a
        # modest fraction of that motion (CAME quantizes five slots —
        # momentum + four companded vectors — so the bound is a bit wider
        # than the smmf drift test in test_qstate.py)
        assert np.max(np.abs(a - b)) < 2e-2, np.max(np.abs(a - b))


def test_adapprox_momentum_block_quant():
    """Adapprox shares the same blockwise momentum layout on its full-size
    m slot (rank-k factors ride per-column scales instead)."""
    opt = spec_opt("adapprox", 1e-2, rank=2, quant="int8")
    _, state = _run_steps(opt, _params())
    for key, bkstate in state.factors.items():
        if "fac:" in key:
            m = bkstate[0]
            assert isinstance(m, QTensor) and m.q.ndim == 3
            assert m.scale.shape[-1] == Q.block_count(
                m.q.shape[-1], MOMENTUM_QUANT_BLOCK)
            r_v = bkstate[1]
            assert isinstance(r_v, QTensor)
            # per-(stack row, factor column) scales on the rank-k factors
            assert r_v.scale.shape[-1] == r_v.q.shape[-1] == 2
