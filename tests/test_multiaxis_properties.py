"""Hypothesis fuzz properties for the multi-axis stack policy.

Fuzz twins of the deterministic tests in ``test_multiaxis_sharding.py``
(own module: a module-level importorskip must not skip those). Runs where
hypothesis is installed — CI installs requirements-dev.txt.
"""

import math

import pytest
from jax.sharding import AbstractMesh

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.plan import (  # noqa: E402
    DEFAULT_STACK_AXES,
    LeafPlan,
    bucket_partition_wants,
    build_buckets,
    stack_axes,
)
from repro.distributed import rules  # noqa: E402

KINDS = ("matrix", "rows", "cols", "sign", "dense")


def _shape_for(kind: str, leading: int) -> tuple[int, ...]:
    return {
        "matrix": (leading, 64, 128),
        "rows": (leading, 64),
        "cols": (leading, 128),
        "sign": (leading * 64, 16),
        "dense": (leading, 4096),
    }[kind]


sizes_st = st.fixed_dictionaries({
    "pod": st.sampled_from([1, 2, 4]),
    "data": st.sampled_from([1, 2, 4, 8, 16]),
    "model": st.sampled_from([1, 2, 8, 16]),
})
leading_st = st.integers(min_value=1, max_value=64)
over_st = st.sampled_from([None, ("model",), ("data",), ("model", "data"),
                           ("pod", "data")])


@given(sizes_st, leading_st, st.sampled_from(KINDS), over_st)
@settings(max_examples=200, deadline=None)
def test_fuzz_wants_fit_and_never_reuse(sizes, leading, kind, over):
    """Every want tuple uses each mesh axis at most once, and every kept
    axis divides its dim after fit_spec."""
    shape = _shape_for(kind, leading)
    wants = bucket_partition_wants(kind, shape, sizes, stack_over=over)
    flat = []
    for w in wants:
        if w is not None:
            flat.extend(w if isinstance(w, tuple) else (w,))
    assert len(flat) == len(set(flat))
    axes = tuple((a, s) for a, s in sizes.items() if s > 1)
    if axes:
        mesh = AbstractMesh(axes)
        spec = rules.fit_spec(mesh, shape, wants)
        for dim, want in zip(shape, tuple(spec) + (None,) * 4):
            if want is not None:
                assert dim % rules._axsize(mesh, want) == 0


@given(sizes_st, leading_st)
@settings(max_examples=200, deadline=None)
def test_fuzz_stack_assignment_divides_and_falls_back(sizes, leading):
    """A stack assignment always divides the stack; None (replicated
    fallback) only when no preferred axis fits alone either."""
    st_ = stack_axes(leading, sizes)
    if st_ is None:
        for a in DEFAULT_STACK_AXES:
            assert sizes.get(a, 0) <= 1 or leading % sizes[a] != 0
    else:
        assert leading % math.prod(sizes[a] for a in st_) == 0


@given(sizes_st, leading_st, st.sampled_from(KINDS))
@settings(max_examples=200, deadline=None)
def test_fuzz_single_axis_mesh_identical_to_pr3(sizes, leading, kind):
    """Without a pod axis the policy equals the PR 3 single-axis rules."""
    sizes = dict(sizes, pod=1)
    shape = _shape_for(kind, leading)
    got = bucket_partition_wants(kind, shape, sizes)
    data = sizes["data"]
    stacked = data > 1 and shape[0] % data == 0
    ref = {
        "sign": ("data", "model"),
        "dense": (None, "data"),
        "matrix": ("data", None, "model") if stacked else (None, "data", "model"),
        "rows": ("data", None) if stacked else (None, "data"),
        "cols": ("data", "model") if stacked else (None, "model"),
    }[kind]
    assert got == ref


@given(st.lists(st.sampled_from(["", "g1", "g2", "g3"]), min_size=1,
                max_size=24))
@settings(max_examples=100, deadline=None)
def test_fuzz_buckets_never_span_groups(groups):
    plans = [LeafPlan(i, (4, 4), True, (1, 4, 4), group=g)
             for i, g in enumerate(groups)]
    for bk in build_buckets(plans):
        assert len({p.group for p in bk.plans}) == 1
