"""Multi-axis group sharding: policy properties + real-mesh parity.

Covers the PR-4 tentpole:

* :func:`repro.core.plan.stack_axes` / ``bucket_partition_wants`` over
  ``(pod, data, model)`` axis combos — divisibility, axis-never-reused,
  replicated fallback, single-axis (no-pod) bitwise identity with the PR 3
  policy, ``state_sharding`` override routing (deterministic parametrized
  versions always run; hypothesis fuzz versions run when hypothesis is
  installed);
* ``build_buckets`` never spans partition groups;
* per-group ``state_sharding`` lowering through
  ``rules.opt_state_shardings``;
* sharded-vs-replicated parity for a mixed per-group-override spec on the
  8-device emulated mesh (subprocess via the session harness; the
  stack-only override group agrees to float32 resolution — it also locks
  down the XLA concatenate-partitioning miscompile the update-boundary
  pins guard against);
* the 4-way-fsdp per-device memory number against the PR 2 baseline
  (25.4% of replicated).
"""

import itertools

import jax
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.core.plan import (
    DEFAULT_STACK_AXES,
    LeafPlan,
    bucket_partition_wants,
    build_buckets,
    stack_axes,
)
from repro.distributed import rules
from repro.launch import specs as S
from repro.optim.spec import OptimizerSpec, Partition, build_optimizer

KINDS = ("matrix", "rows", "cols", "sign", "dense")


def _shape_for(kind: str, leading: int) -> tuple[int, ...]:
    return {
        "matrix": (leading, 64, 128),
        "rows": (leading, 64),
        "cols": (leading, 128),
        "sign": (leading * 64, 16),
        "dense": (leading, 4096),
    }[kind]


def _flat_axes(wants) -> list[str]:
    out = []
    for w in wants:
        if w is None:
            continue
        out.extend(w if isinstance(w, tuple) else (w,))
    return out


# ---------------------------------------------------------------------------
# deterministic policy properties (always run)
# ---------------------------------------------------------------------------

SIZE_GRID = list(itertools.product((1, 2), (1, 2, 4, 16), (1, 2, 16)))


@pytest.mark.parametrize("pod,data,model", SIZE_GRID)
@pytest.mark.parametrize("leading", [1, 2, 3, 4, 6, 16, 32, 48])
def test_stack_axes_divisibility_and_maximality(pod, data, model, leading):
    """The chosen subset exists, divides the stack, and no larger ordered
    subset of the preference chain would also divide it."""
    sizes = {"pod": pod, "data": data, "model": model}
    st_ = stack_axes(leading, sizes)
    ways = lambda combo: 1 if not combo else \
        __import__("math").prod(sizes[a] for a in combo)
    if st_ is not None:
        assert all(sizes[a] > 1 for a in st_)
        assert leading % ways(st_) == 0
    # maximality: every ordered subset of (pod, data) that divides is no
    # bigger than the chosen one
    best = 0
    for mask in range(1, 4):
        combo = tuple(a for i, a in enumerate(DEFAULT_STACK_AXES) if mask >> i & 1)
        if all(sizes[a] > 1 for a in combo) and leading % ways(combo) == 0:
            best = max(best, ways(combo))
    assert ways(st_) == (best or 1)


@pytest.mark.parametrize("pod,data,model", SIZE_GRID)
@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("leading", [1, 2, 3, 4, 16, 32])
def test_wants_never_reuse_an_axis_and_fit(pod, data, model, kind, leading):
    """No mesh axis appears twice in a want tuple, and fit_spec accepts the
    wants on the corresponding AbstractMesh (every kept axis divides)."""
    sizes = {"pod": pod, "data": data, "model": model}
    shape = _shape_for(kind, leading)
    wants = bucket_partition_wants(kind, shape, sizes)
    flat = _flat_axes(wants)
    assert len(flat) == len(set(flat)), (kind, shape, wants)
    mesh = AbstractMesh(tuple((a, s) for a, s in sizes.items() if s > 1))
    spec = rules.fit_spec(mesh, shape, wants)
    for dim, want in zip(shape, tuple(spec) + (None,) * 4):
        if want is not None:
            assert dim % rules._axsize(mesh, want) == 0


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("leading", [1, 2, 3, 4, 16, 32])
@pytest.mark.parametrize("data,model", [(1, 1), (2, 2), (16, 16), (4, 1)])
def test_single_axis_mesh_identical_to_pr3_policy(kind, leading, data, model):
    """On meshes without a pod axis the multi-axis policy is bitwise
    identical to the PR 3 single-axis rules (the acceptance criterion)."""
    sizes = {"data": data, "model": model}
    shape = _shape_for(kind, leading)
    got = bucket_partition_wants(kind, shape, sizes)
    # PR 3 reference policy
    stacked = data > 1 and shape[0] % data == 0
    ref = {
        "sign": ("data", "model"),
        "dense": (None, "data"),
        "matrix": ("data", None, "model") if stacked else (None, "data", "model"),
        "rows": ("data", None) if stacked else (None, "data"),
        "cols": ("data", "model") if stacked else (None, "model"),
    }[kind]
    assert got == ref, (kind, shape, sizes, got, ref)


@pytest.mark.parametrize("pod,data", [(1, 4), (2, 2), (2, 8)])
def test_pod_data_split_when_divisible(pod, data):
    """A stack divisible by pod*data carries both axes (in mesh order)."""
    sizes = {"pod": pod, "data": data, "model": 2}
    leading = pod * data * 3
    wants = bucket_partition_wants("matrix", (leading, 64, 128), sizes)
    expect = ("pod", "data") if pod > 1 else "data"
    assert wants[0] == expect


def test_state_sharding_override_routes_stack_and_drops_minor_model():
    """stack_over=("model",) puts the stack on model and frees the minor
    dims of cols/sign from model (axis never reused); indivisible override
    falls back to the replicated-stack rules."""
    sizes = {"pod": 2, "data": 4, "model": 8}
    over = ("model",)
    assert bucket_partition_wants("matrix", (16, 64, 128), sizes, stack_over=over) \
        == ("model", None, None)
    assert bucket_partition_wants("cols", (16, 128), sizes, stack_over=over) \
        == ("model", None)
    assert bucket_partition_wants("rows", (16, 64), sizes, stack_over=over) \
        == ("model", None)
    assert bucket_partition_wants("sign", (16 * 64, 16), sizes, stack_over=over) \
        == ("model", None)
    # indivisible by the override -> replicated-stack fallback, model free
    assert bucket_partition_wants("matrix", (3, 64, 128), sizes, stack_over=over) \
        == (None, "data", "model")
    assert bucket_partition_wants("cols", (3, 128), sizes, stack_over=over) \
        == (None, "model")


def test_buckets_never_span_groups():
    """Same-geometry leaves in different groups land in different buckets
    (deterministic mirror of the hypothesis fuzz below)."""
    groups = ["", "a", "b", "", "a", "b", "", ""]
    plans = [LeafPlan(i, (8, 8), True, (1, 8, 8), group=g)
             for i, g in enumerate(groups)]
    buckets = build_buckets(plans)
    for bk in buckets:
        assert len({p.group for p in bk.plans}) == 1
    assert len(buckets) == 3  # one per group


# (hypothesis fuzz versions of these properties live in
# tests/test_multiaxis_properties.py — a module-level importorskip would
# skip this whole file on hosts without hypothesis)


# ---------------------------------------------------------------------------
# lowering + real-mesh parity + memory regression
# ---------------------------------------------------------------------------

def test_opt_state_shardings_lower_state_sharding_override():
    """A partition's state_sharding override reaches the state placement:
    the override group's stacks ride "model", the default group's ride the
    (pod, data) chain — shape-only, AbstractMesh."""
    mesh = AbstractMesh((("pod", 2), ("data", 2), ("model", 2)))
    spec = OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3},
        partitions=(Partition(name="experts", match=r"^ex_",
                              state_sharding=("model",)),),
    )
    opt = build_optimizer(spec)
    params = {f"w{i}": jax.ShapeDtypeStruct((32, 64), jax.numpy.float32)
              for i in range(4)}
    params.update({f"ex_{i}": jax.ShapeDtypeStruct((16, 32), jax.numpy.float32)
                   for i in range(4)})
    sh = rules.opt_state_shardings(mesh, None, params, opt)
    # default bucket (stack 4): (pod, data) on the stack axis
    fac = sh.factors["fac:1x64x32"]
    assert tuple(fac[0].spec) == (("pod", "data"), None)        # r_m
    assert tuple(fac[1].spec) == (("pod", "data"), "model")     # c_m
    # override bucket (stack 4): model on the stack, minor dims free it
    ex = sh.factors["experts/fac:1x32x16"]
    assert tuple(ex[0].spec) == ("model", None)                 # r_m
    assert tuple(ex[1].spec) == ("model", None)                 # c_m
    assert tuple(ex[2].spec) == ("model", None)                 # sign


def test_state_sharding_roundtrip_and_hash_excluded():
    """state_sharding serializes through JSON and never moves the spec hash
    (placement-only: a re-sharded restore must not be refused)."""
    spec = OptimizerSpec(
        family="smmf",
        partitions=(Partition(name="experts", match="ex", family="smmf",
                              state_sharding=("model", "data")),),
    )
    back = OptimizerSpec.from_json(spec.to_json())
    assert back == spec
    assert back.partitions[0].state_sharding == ("model", "data")
    bare = OptimizerSpec(
        family="smmf",
        partitions=(Partition(name="experts", match="ex", family="smmf"),))
    assert spec.spec_hash() == bare.spec_hash()
    with pytest.raises(ValueError):
        Partition(name="bad", match="x", state_sharding=("model", "model"))
    with pytest.raises(ValueError):
        Partition(name="bad", match="x", state_sharding="model")


def test_parse_rule_state_sharding():
    from repro.optim.spec import parse_rule

    part = parse_rule("moe/=smmf,state_sharding=('model',)")
    assert part.state_sharding == ("model",)
    part = parse_rule("moe/=smmf,state_sharding=model")
    assert part.state_sharding == ("model",)
    assert "state_sharding" not in part.hyperparams


@pytest.mark.multidevice
def test_concat_miscompile_probe_agrees_with_version_gate(emulated_mesh):
    """Empirical probe vs. the version gate behind the "opt_update_row"
    boundary pins: rerun the parity child with ONLY the pin dropped
    (``no_opt_boundary``) and require the observed behavior to match
    ``rules.xla_concat_miscompile_present()``. This is the test that FLIPS
    when a jaxlib upgrade fixes the concatenate-partitioning bug — at that
    point ``rules._CONCAT_MISCOMPILE_LAST_BAD`` must be retired (which also
    re-enables fully-sharded override transport, priced at 0 by
    ``boundary_transport_bytes``) or this fails loudly."""
    out = emulated_mesh.run("_concat_probe_child.py")
    assert out.returncode == 0, f"probe crashed:\n{out.stdout}\n{out.stderr}"
    if rules.xla_concat_miscompile_present():
        assert "CONCAT MISCOMPILE REPRODUCED" in out.stdout, (
            "version gate says the XLA concatenate miscompile is present "
            f"(jaxlib <= {rules._CONCAT_MISCOMPILE_LAST_BAD}) but the "
            "unpinned path is correct — retire the gate:\n" + out.stdout)
    else:
        assert "CONCAT MISCOMPILE ABSENT" in out.stdout, (
            "version gate says this jaxlib is fixed but the miscompile "
            "still reproduces — raise _CONCAT_MISCOMPILE_LAST_BAD:\n"
            + out.stdout)


@pytest.mark.multidevice
def test_multiaxis_sharded_vs_replicated_parity(emulated_mesh):
    """Mixed per-group-override spec on the real 8-device emulated mesh:
    placements distribute as planned and the sharded update trajectory
    matches the replicated one. Also the lock on the XLA
    concatenate-partitioning miscompile: without the engine's
    update-boundary pins the override group's moments come out scaled by
    the replication factor."""
    out = emulated_mesh.run("_multiaxis_child.py")
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    assert "MULTIAXIS PARITY OK" in out.stdout


def test_4way_fsdp_memory_does_not_regress_pr2_baseline():
    """smmf/transformer_base on a 4-way fsdp AbstractMesh: per-device state
    must stay at the PR 2 measured baseline (25.4% of replicated)."""
    from repro.configs import get_config
    from repro.utils.tree import tree_bytes

    cfg = get_config("transformer_base")
    psds = S.params_specs(cfg)
    opt = build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3, "decay_rate": -0.8}))
    state_sds = jax.eval_shape(opt.init, psds)

    def per_dev(axes):
        mesh = AbstractMesh(axes)
        sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
        return rules.sharded_state_bytes(sh, state_sds)

    base = per_dev((("data", 1),))
    assert base == tree_bytes(state_sds)
    frac4 = per_dev((("data", 4),)) / base
    assert frac4 <= 0.254 + 1e-3, f"4-way regressed: {frac4:.1%} > 25.4%"
    # the pod axis must help, not hurt: 2x4 <= 1x4
    frac24 = per_dev((("pod", 2), ("data", 4))) / base
    assert frac24 <= frac4
