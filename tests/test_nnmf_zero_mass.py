"""Zero-mass factorization regression: an all-zero momentum matrix must
never evaluate 0/0.

The rank-1 compress normalizes one factor by the total mass; before the
guard, an all-zero matrix (step-1 state, frozen groups, a parameter that
saw no gradient) evaluated ``0 / 0`` in the discarded ``where`` branch and
tripped ``jax_debug_nans``. The guard lives in four places that each
duplicate the Algorithm-4 normalization: ``core/nnmf.nnmf_compress``, the
batched ``_compress`` in ``optim/families``, the fused-kernel reference
ops (``kernels/smmf_update/ops``), and the rank-1 gradient-transport
sketch (``distributed/transport``). Each is exercised here under
``jax_debug_nans`` so a regression fails loudly.
"""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import spec_opt
from repro.core.nnmf import (
    nnmf_compress,
    nnmf_compress_k,
    nnmf_decompress,
    nnmf_decompress_k,
)
from repro.optim.base import apply_updates


@contextlib.contextmanager
def debug_nans():
    jax.config.update("jax_debug_nans", True)
    try:
        yield
    finally:
        jax.config.update("jax_debug_nans", False)


def test_compress_all_zero_matrix_is_nan_free():
    with debug_nans():
        r, c = jax.jit(nnmf_compress)(jnp.zeros((6, 10)))
    assert np.all(np.isfinite(r)) and np.all(np.isfinite(c))
    np.testing.assert_array_equal(np.asarray(nnmf_decompress(r, c)), 0.0)


@pytest.mark.parametrize("rank", [1, 3])
def test_compress_k_all_zero_stack_is_nan_free(rank):
    with debug_nans():
        r, c = jax.jit(lambda m: nnmf_compress_k(m, rank))(jnp.zeros((2, 6, 10)))
    assert np.all(np.isfinite(r)) and np.all(np.isfinite(c))
    np.testing.assert_array_equal(np.asarray(nnmf_decompress_k(r, c)), 0.0)


def test_compress_zero_rows_in_nonzero_stack():
    """Mixed stack: one all-zero slice beside a live one — the batched
    guard must be per-slice, not global."""
    mat = jnp.stack([jnp.zeros((6, 10)),
                     jnp.abs(jnp.asarray(
                         np.random.default_rng(0).standard_normal((6, 10)),
                         jnp.float32))])
    with debug_nans():
        r, c = jax.jit(lambda m: nnmf_compress_k(m, 1))(mat)
    rec = np.asarray(nnmf_decompress_k(r, c))
    assert np.all(np.isfinite(rec))
    np.testing.assert_array_equal(rec[0], 0.0)
    assert np.abs(rec[1]).max() > 0


def _zero_grad_steps(opt, params, steps=2):
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(p, s):
        u, s = opt.update(zeros, s, p)
        return apply_updates(p, u), s

    for _ in range(steps):
        params, state = step(params, state)
    return params


def _params():
    rng = np.random.default_rng(0)
    return {"w": jnp.asarray(rng.standard_normal((48, 96)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((96,)), jnp.float32)}


@pytest.mark.parametrize("hp", [
    {},                                          # batched _compress (families)
    {"beta1": None},                             # momentum-free factors only
    {"use_kernel": True, "interpret": True},     # fused-kernel reference ops
    {"transport": "rank1"},                      # transport magnitude sketch
], ids=["families", "momentum_free", "kernel_interpret", "transport_rank1"])
def test_smmf_zero_gradient_step_is_nan_free(hp):
    """A full zero-gradient optimizer step (the state starts all-zero, the
    gradient contributes nothing) through each normalization site."""
    opt = spec_opt("smmf", 1e-3, decay_rate=-0.8, **hp)
    with debug_nans():
        params = _zero_grad_steps(opt, _params())
    for leaf in jax.tree.leaves(params):
        assert np.all(np.isfinite(np.asarray(leaf)))


@pytest.mark.parametrize("fam,hp", [
    ("adapprox", {"decay_rate": -0.8, "rank": 2}),
    ("hfac", {}),
], ids=["adapprox", "hfac"])
def test_zoo_zero_gradient_step_is_nan_free(fam, hp):
    opt = spec_opt(fam, 1e-3, **hp)
    with debug_nans():
        params = _zero_grad_steps(opt, _params())
    for leaf in jax.tree.leaves(params):
        assert np.all(np.isfinite(np.asarray(leaf)))
