"""Host-side observability units: registry, event log, exporters.

* :class:`repro.obs.registry.Histogram`: bucket assignment (upper-
  inclusive edges, overflow bucket), exact count/sum/min/max sidecars,
  monotone quantiles, validation of degenerate boundaries;
* :class:`repro.obs.registry.MetricsRegistry`: counter monotonicity (a
  negative increment raises), gauge last-write-wins, snapshot shape and
  key order, merge semantics (counters add, histograms fold bucket-for-
  bucket, boundary mismatch raises), thread safety under concurrent
  writers;
* :class:`repro.obs.trace.EventLog`: ring + JSONL parity, span records
  carry ``dur_ms`` and feed ``<name>_ms`` histograms, annotation dict
  folds into the record, the Null log stays silent and registry-free;
* :mod:`repro.obs.export`: span -> Chrome ``"X"`` slice / event -> ``"i"``
  instant mapping with microsecond timestamps, JSONL round-trip.
"""

import json
import math
import threading

import pytest

from repro.obs import (
    DEFAULT_BUCKETS,
    EventLog,
    Histogram,
    MetricsRegistry,
    NullEventLog,
    chrome_trace,
    read_jsonl,
    write_chrome_trace,
    write_metrics,
)

# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------


def test_histogram_bucket_assignment():
    h = Histogram((1.0, 10.0, 100.0))
    for v in (0.5, 1.0):          # <= 1.0 -> bucket 0
        h.observe(v)
    h.observe(10.0)               # upper-inclusive -> bucket 1
    h.observe(50.0)               # bucket 2
    h.observe(1e6)                # overflow bucket
    assert h.counts == [2, 1, 1, 1]
    assert h.count == 5
    assert h.sum == pytest.approx(0.5 + 1.0 + 10.0 + 50.0 + 1e6)
    assert h.min == 0.5 and h.max == 1e6


def test_histogram_quantiles_monotone_and_exact_sidecars():
    h = Histogram((1.0, 2.0, 4.0, 8.0))
    samples = [0.3, 0.9, 1.5, 3.0, 3.5, 7.0, 20.0]
    for s in samples:
        h.observe(s)
    assert h.min == min(samples)
    assert h.max == max(samples)
    assert h.mean() == pytest.approx(sum(samples) / len(samples))
    assert h.min <= h.mean() <= h.max
    qs = [h.quantile(q) for q in (0.0, 0.25, 0.5, 0.75, 0.99, 1.0)]
    assert qs == sorted(qs)               # non-decreasing in q
    assert h.quantile(1.0) == h.max       # overflow resolves to exact max
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_empty_and_bad_boundaries():
    h = Histogram()
    assert math.isnan(h.quantile(0.5)) and math.isnan(h.mean())
    assert h.to_dict()["min"] is None
    assert h.boundaries == DEFAULT_BUCKETS
    with pytest.raises(ValueError):
        Histogram((1.0, 1.0, 2.0))        # duplicate edge
    with pytest.raises(ValueError):
        Histogram((2.0, 1.0))             # not increasing


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_registry_counters_gauges():
    r = MetricsRegistry()
    assert r.inc("a/b") == 1.0
    assert r.inc("a/b", 2.5) == 3.5
    assert r.counter("a/b") == 3.5
    assert r.counter("never") == 0.0
    with pytest.raises(ValueError):
        r.inc("a/b", -1.0)                # counters are monotone
    r.set("g", 1.0)
    r.set("g", -2.0)                      # last write wins
    assert r.gauge("g") == -2.0
    assert r.gauge("never") is None


def test_registry_snapshot_shape_and_order():
    r = MetricsRegistry()
    r.inc("z")
    r.inc("a")
    r.set("gauge/x", 7.0)
    r.observe("lat_ms", 3.0)
    snap = r.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert list(snap["counters"]) == ["a", "z"]        # sorted
    assert snap["histograms"]["lat_ms"]["count"] == 1
    json.dumps(snap)                                   # plain JSON


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("c", 2)
    b.inc("c", 3)
    b.set("g", 9.0)
    for v in (1.0, 50.0):
        a.observe("h", v, buckets=(10.0, 100.0))
        b.observe("h", v * 2, buckets=(10.0, 100.0))
    a.merge(b)
    assert a.counter("c") == 5.0
    assert a.gauge("g") == 9.0
    h = a.histogram("h")
    assert h.count == 4
    assert h.min == 1.0 and h.max == 100.0
    bad = MetricsRegistry()
    bad.observe("h", 1.0, buckets=(5.0,))
    with pytest.raises(ValueError):
        a.merge(bad)                      # boundary mismatch


def test_registry_threaded_counters():
    r = MetricsRegistry()

    def work():
        for _ in range(1000):
            r.inc("n")
            r.observe("h", 1.0)

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert r.counter("n") == 4000.0
    assert r.histogram("h").count == 4000


# ---------------------------------------------------------------------------
# EventLog
# ---------------------------------------------------------------------------


def test_eventlog_ring_file_parity(tmp_path, capsys):
    p = tmp_path / "events.jsonl"
    log = EventLog(tag="t", path=p, registry=MetricsRegistry())
    log.event("hello", "hi there", n=3)
    with log.span("phase", rows=2) as s:
        s["tokens"] = 7
    log.close()
    out = capsys.readouterr().out
    assert "[t] hi there" in out          # stdout echo preserved
    ring = log.records()
    disk = read_jsonl(p)
    assert len(ring) == len(disk) == 2
    assert disk[0]["name"] == "hello" and disk[0]["n"] == 3
    span = disk[1]
    assert span["kind"] == "span" and span["dur_ms"] >= 0.0
    assert span["rows"] == 2 and span["tokens"] == 7   # annotation folded


def test_eventlog_span_feeds_histogram():
    r = MetricsRegistry()
    log = EventLog(tag="t", registry=r)
    with log.span("train/step"):
        pass
    h = r.histogram("train/step_ms")
    assert h is not None and h.count == 1
    assert r.counter("obs/events") == 1.0


def test_null_eventlog_silent(capsys):
    log = NullEventLog()
    log.event("x", "should not print")
    with log.span("y"):
        pass
    assert capsys.readouterr().out == ""
    assert len(log.records()) == 2        # ring kept for debuggability


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_chrome_trace_mapping():
    records = [
        {"t": 2.0, "kind": "span", "name": "s", "dur_ms": 5.0, "loss": 1.5},
        {"t": 3.0, "kind": "event", "name": "e", "track": 4, "rid": 9},
    ]
    doc = chrome_trace(records)
    assert doc["displayTimeUnit"] == "ms"
    sl, ev = doc["traceEvents"]
    assert sl["ph"] == "X" and sl["ts"] == 2.0e6 and sl["dur"] == 5.0e3
    assert sl["args"] == {"loss": 1.5}    # meta keys stripped from args
    assert ev["ph"] == "i" and ev["tid"] == 4 and ev["args"] == {"rid": 9}


def test_exporter_files_roundtrip(tmp_path):
    r = MetricsRegistry()
    log = EventLog(tag="t", path=tmp_path / "ev.jsonl", echo=False,
                   registry=r)
    with log.span("p"):
        pass
    log.event("done")
    log.close()
    tp = write_chrome_trace(log.records(), tmp_path / "trace.json")
    mp = write_metrics(r.snapshot(), tmp_path / "metrics.json")
    trace = json.loads((tmp_path / "trace.json").read_text())
    assert len(trace["traceEvents"]) == 2
    snap = json.loads((tmp_path / "metrics.json").read_text())
    assert "p_ms" in snap["histograms"]
    assert tp.endswith("trace.json") and mp.endswith("metrics.json")
