"""Baseline optimizers: convergence, memory ordering, regret sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.smmf import smmf
from repro.distributed.compress import int8_compress
from repro.optim import adafactor, adam, adamw, came, sgd, sm3
from repro.optim.base import apply_updates, chain, clip_by_global_norm, warmup_cosine
from repro.utils.tree import tree_bytes

# These tests deliberately exercise the deprecated legacy-constructor
# surface (shim parity / reference trajectories); tier-1 errors on shim
# DeprecationWarnings everywhere else (pytest.ini).
pytestmark = pytest.mark.filterwarnings(
    "ignore:.*is deprecated. build via repro.optim.spec.OptimizerSpec.*:DeprecationWarning")

OPTS = {
    "adam": lambda: adam(5e-2),
    "adamw": lambda: adamw(5e-2),
    "adafactor": lambda: adafactor(5e-2),
    "sm3": lambda: sm3(5e-2),
    "came": lambda: came(5e-2),
    "sgd": lambda: sgd(5e-2, momentum=0.9),
    "smmf": lambda: smmf(5e-2),
}


def _quadratic():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((32, 32)).astype(np.float32)
    target = rng.standard_normal((32, 16)).astype(np.float32)

    def loss(p):
        return jnp.mean((a @ p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)

    p0 = {
        "w": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((16,)), jnp.float32),
    }
    return loss, p0


@pytest.mark.parametrize("name", sorted(OPTS))
def test_converges_on_quadratic(name):
    loss, p = _quadratic()
    opt = OPTS[name]()
    s = opt.init(p)
    l0 = float(loss(p))

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s

    for _ in range(300):
        p, s = step(p, s)
    assert float(loss(p)) < 0.15 * l0, f"{name} failed to converge"


def test_state_memory_ordering():
    rng = np.random.default_rng(0)
    params = {
        "w1": jnp.asarray(rng.standard_normal((512, 2048)), jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((2048, 512)), jnp.float32),
    }
    sizes = {n: tree_bytes(jax.eval_shape(OPTS[n]().init, params)) for n in OPTS}
    assert sizes["smmf"] < sizes["adafactor"] < sizes["adam"]
    assert sizes["smmf"] < sizes["sm3"]
    assert sizes["adafactor"] <= sizes["came"]


def test_chain_and_clip():
    loss, p = _quadratic()
    opt = chain(clip_by_global_norm(1.0), adam(5e-2))
    s = opt.init(p)
    for _ in range(200):
        g = jax.grad(loss)(p)
        u, s = opt.update(g, s, p)
        p = apply_updates(p, u)
    assert float(loss(p)) < 1.0


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, 10, 100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 1e-6
    assert float(sched(100)) < 0.2
    assert float(sched(5)) == pytest.approx(0.5)


def test_int8_compress_shim_is_ef_free_and_converges():
    """The retired compressor: warns, carries NO error-feedback buffers
    (transport SR is unbiased per step), and still trains to convergence."""
    loss, p = _quadratic()
    with pytest.warns(DeprecationWarning, match="repro.distributed.transport"):
        opt = int8_compress(adam(5e-2))
    s = opt.init(p)
    # zero full-size f32 EF buffers: state is (count, inner) — the only
    # leaves are the scalar counter and adam's own moments
    assert not hasattr(s, "ef")
    n_inner = len(jax.tree.leaves(adam(5e-2).init(p)))
    assert len(jax.tree.leaves(s)) == n_inner + 1
    for _ in range(300):
        g = jax.grad(loss)(p)
        u, s = opt.update(g, s, p)
        p = apply_updates(p, u)
    assert float(loss(p)) < 0.5  # SR keeps quantized training convergent


def test_regret_sublinear_smmf_vs_adam():
    """Convex online problem: cumulative regret / T must decay (Thm 4.1)."""
    rng = np.random.default_rng(0)
    dim = 20
    w_star = rng.standard_normal(dim).astype(np.float32) * 0.5

    def make_run(opt):
        w = {"w": jnp.zeros((dim,), jnp.float32)}
        s = opt.init(w)
        regret = []
        total = 0.0
        for t in range(400):
            x = rng.standard_normal(dim).astype(np.float32)
            y = float(x @ w_star)

            def f(p):
                return 0.5 * (jnp.dot(p["w"], x) - y) ** 2

            ft = float(f(w))
            fstar = 0.0
            total += ft - fstar
            g = jax.grad(f)(w)
            u, s = opt.update(g, s, w)
            w = apply_updates(w, u)
            regret.append(total / (t + 1))
        return regret

    rng = np.random.default_rng(0)
    r_smmf = make_run(smmf(5e-2, decay_rate=-0.5))
    rng = np.random.default_rng(0)
    r_adam = make_run(adam(5e-2))
    # average regret decays for both and SMMF tracks Adam within 3x
    assert r_smmf[-1] < 0.25 * r_smmf[10]
    assert r_smmf[-1] < 3.0 * r_adam[-1] + 1e-3
