"""Overlapped optimizer step + host-offload tier (the PR 6 tentpole).

* :func:`repro.core.plan.bucket_schedule`: the "grad" order keys on
  reverse-mode gradient availability (descending min flat-leaf index),
  "plan"/None are identity, unknown orders raise;
* **bitwise parity**: a scheduled (interleaved, optimization-barrier
  chained) update — with and without the offload round-trip — produces
  bit-identical updates and state vs the barrier-order baseline, for
  factored f32, quantized, and momentum-free quantized specs, and for the
  full transformer_base train step (the acceptance criterion);
* **donation** still aliases params + optimizer state under ``--overlap``;
* offload structural behavior on CPU (no host memory kind): identity
  placement, exact analytic device/host accounting, transport pricing;
* CPU checkpoint roundtrip with offload enabled; the elastic mesh-change
  roundtrip runs on the 8-device harness (``_offload_child.py``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.plan import LeafPlan, bucket_schedule, grad_ready_rank
from repro.data import SyntheticLMStream
from repro.launch.steps import assert_donation, make_train_step
from repro.models import init_encdec, init_lm
from repro.optim import offload
from repro.optim.spec import OptimizerSpec, Partition, build_optimizer
from repro.utils.tree import tree_bytes

SHAPES = {
    "wq": (32, 64), "wk": (32, 64),
    "deep/w": (16, 48),
    "b1": (64,), "b2": (64,),
}


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in SHAPES.items()}


def _spec(**hp):
    return OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-2, "decay_rate": -0.8, **hp},
        partitions=(Partition(name="norms", match=r"^b\d$", family="adam",
                              hyperparams={"lr": 1e-2, "quant": None}),))


# ---------------------------------------------------------------------------
# schedule policy
# ---------------------------------------------------------------------------

def test_bucket_schedule_orders():
    from repro.core.plan import build_buckets

    plans = [LeafPlan(i, (8, 8), True, (1, 8, 8)) for i in range(2)] \
        + [LeafPlan(2, (4, 4), True, (1, 4, 4))] \
        + [LeafPlan(3, (16,), False, (16,))]
    buckets = build_buckets(plans)
    assert bucket_schedule(buckets, "plan") == tuple(range(len(buckets)))
    assert bucket_schedule(buckets, None) == tuple(range(len(buckets)))
    # "grad": descending min-leaf-index — later-forward leaves' grads are
    # emitted first by reverse mode
    ranks = [grad_ready_rank(b) for b in buckets]
    got = bucket_schedule(buckets, "grad")
    assert [ranks[i] for i in got] == sorted(ranks, reverse=True)
    assert sorted(got) == list(range(len(buckets)))  # a permutation
    with pytest.raises(ValueError):
        bucket_schedule(buckets, "alphabetical")


def test_engine_schedule_covers_all_buckets():
    opt = build_optimizer(_spec(quant="int8"))
    eng = opt.plan(_params())
    sched = eng.schedule("grad")
    assert sorted(sched) == list(range(len(eng.buckets)))
    assert eng.schedule() == tuple(range(len(eng.buckets)))


# ---------------------------------------------------------------------------
# bitwise parity: scheduled / offloaded update == barrier update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hp", [
    {},                              # factored f32 + adam partition
    {"quant": "int8"},               # qstate codec in the loop
    {"beta1": None, "quant": "int8"},  # momentum-free quantized
], ids=["f32", "int8", "int8-nomom"])
def test_scheduled_update_bitwise_parity(hp):
    """The optimization-barrier chain and the grad-order reordering are
    value-exact: bit-identical updates AND state, with and without the
    offload round-trip (identity transfers on CPU, same program shape)."""
    opt = build_optimizer(_spec(**hp))
    params = _params()
    grads = _params(7)
    state = opt.init(params)

    base = jax.jit(opt.update)(grads, state, params)
    for extras in ({"schedule": "grad"}, {"schedule": "grad", "offload": "cold"}):
        got = jax.jit(lambda g, s, p: opt.update(g, s, p, **extras))(
            grads, state, params)
        for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _smoke_setup():
    cfg = smoke_config("transformer_base")
    spec = OptimizerSpec(family="smmf",
                         hyperparams={"lr": 1e-3, "decay_rate": -0.8,
                                      "quant": "int8"})
    init = init_encdec if cfg.family == "encdec" else init_lm
    params = init(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(spec, params)
    batch = SyntheticLMStream(cfg, 2, 16, seed=0).batch(0)
    return cfg, opt, params, opt.init(params), batch


def test_train_step_overlap_bitwise_parity():
    """Acceptance criterion: the interleaved train step is bit-identical
    to the barrier step on transformer_base (smoke, quantized state)."""
    cfg, opt, params, state, batch = _smoke_setup()

    outs = {}
    for tag, kw in [("barrier", {}),
                    ("overlap", {"overlap": True}),
                    ("overlap+offload", {"overlap": True, "offload": "cold"})]:
        step = jax.jit(make_train_step(cfg, opt, **kw))
        outs[tag] = step(params, state, batch)
    for tag in ("overlap", "overlap+offload"):
        for a, b in zip(jax.tree.leaves(outs["barrier"]),
                        jax.tree.leaves(outs[tag])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=tag)


def test_donation_under_overlap():
    """`--overlap --offload cold` keeps the donation contract: params and
    optimizer state still alias in place (fetch/park consume each cold
    array exactly once — no second use blocks the aliasing)."""
    cfg, opt, params, state, batch = _smoke_setup()
    step = jax.jit(make_train_step(cfg, opt, overlap=True, offload="cold"),
                   donate_argnums=(0, 1))
    lowered = step.lower(params, state, batch)
    rep = assert_donation(lowered, lowered.compile())
    assert rep["donated_args"] > 0


# ---------------------------------------------------------------------------
# offload: structural behavior + analytic accounting (CPU)
# ---------------------------------------------------------------------------

def test_check_mode_and_cold_policy():
    assert offload.check_mode(None) is None
    assert offload.check_mode("none") is None
    assert offload.check_mode("cold") == "cold"
    with pytest.raises(ValueError):
        offload.check_mode("hot")
    opt = build_optimizer(_spec(quant="int8"))
    eng = opt.plan(_params())
    assert offload.cold_keys(eng, None) == frozenset()
    cold = offload.cold_keys(eng, "cold")
    # quantized buckets are cold, the adam (quant=None) bucket stays hot
    assert cold and all(bk.quant for bk in eng.buckets if bk.key in cold)
    assert any(bk.key not in cold for bk in eng.buckets)


def test_offload_structural_on_cpu():
    """The CPU backend has no pinned-host kind: supported() is False and
    placement helpers are identity — the tier runs structurally."""
    assert not offload.supported()  # container is CPU-only
    opt = build_optimizer(_spec(quant="int8"))
    params = _params()
    eng = opt.plan(params)
    state = opt.init(params)
    assert offload.place_host(state, eng, "cold") is state
    assert offload.place_host(state, eng, None) is state
    sh = {"x": None}
    assert offload.offload_shardings(sh, None, eng, "cold") is sh


def test_offload_accounting_exact():
    """device + host == total state bytes; host covers exactly the cold
    (quantized) buckets; transport prices the round-trip at 2x host."""
    opt = build_optimizer(_spec(quant="int8"))
    params = _params()
    eng = opt.plan(params)
    state_sds = jax.eval_shape(opt.init, params)
    total = tree_bytes(state_sds)

    off = offload.state_bytes_split(eng, state_sds, None)
    assert off == {"device": total, "host": 0}
    on = offload.state_bytes_split(eng, state_sds, "cold")
    assert on["device"] + on["host"] == total
    assert on["host"] > 0 and on["device"] > 0  # mixed hot/cold spec
    assert offload.transport_bytes(eng, state_sds, "cold") == 2 * on["host"]
    assert offload.transport_bytes(eng, state_sds, None) == 0
    # the acceptance claim: offload-on device-resident bytes strictly below
    # the device-resident quantized baseline
    assert on["device"] < off["device"]


def test_offload_ckpt_roundtrip_cpu(tmp_path):
    """Offload-enabled save → restore → place_state on one CPU device:
    the state pytree is checkpoint-transparent (one logical state) and the
    post-restore trajectory matches the never-checkpointed one bitwise."""
    from repro.checkpoint import restore, save

    opt = build_optimizer(_spec(quant="int8"))
    params = _params()
    eng = opt.plan(params)
    grads = _params(3)
    state = offload.place_host(opt.init(params), eng, "cold")
    upd = jax.jit(lambda g, s, p: opt.update(g, s, p, schedule="grad",
                                             offload="cold"))
    _, state = upd(grads, state, params)
    save(tmp_path, 1, {"opt": state}, spec_hash=None)
    like = {"opt": jax.eval_shape(opt.init, params)}
    got, _ = restore(tmp_path, like, step=1)
    restored = offload.place_host(got["opt"], eng, "cold")
    # continue one more step from both and compare bitwise
    _, a = upd(_params(4), state, params)
    _, b = upd(_params(4), restored, params)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.multidevice
def test_offload_elastic_ckpt_roundtrip_across_mesh_change(emulated_mesh):
    """2-device offloaded train step → checkpoint → restore on a 4-device
    mesh with offload-aware shardings → second step matches the replicated
    no-offload reference (tests/_offload_child.py)."""
    out = emulated_mesh.run("_offload_child.py")
    assert out.returncode == 0, f"child failed:\n{out.stdout}\n{out.stderr}"
    assert "OFFLOAD ELASTIC ROUNDTRIP OK" in out.stdout
