"""Perf-variant flags must preserve semantics (hillclimb safety net)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_cache, init_lm, lm_decode_step, lm_logits, lm_loss
from repro.models.perf import PerfFlags, parse_flags, perf_flags

KEY = jax.random.PRNGKey(0)


def test_parse_flags():
    kw = parse_flags("bf16_accum_attention,ssd_chunk_override=128,moe_capacity_override=1.0")
    assert kw == {"bf16_accum_attention": True, "ssd_chunk_override": 128,
                  "moe_capacity_override": 1.0}
    assert parse_flags("") == {}


def test_scatter_cache_update_matches_onehot():
    cfg = ModelConfig("t", "dense", 2, 64, 4, 128, 100, n_kv_heads=2, dtype="float32")
    p = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 24), 0, 100)

    def decode_all(flags_kw):
        with perf_flags(**flags_kw):
            c = init_cache(cfg, 2, 24)
            step = jax.jit(lambda p, t, c: lm_decode_step(p, cfg, t, c))
            for i in range(24):
                lg, c = step(p, toks[:, i : i + 1], c)
        return np.asarray(lg)

    a = decode_all({})
    b = decode_all({"scatter_cache_update": True})
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_bf16_accum_attention_close():
    cfg = ModelConfig("t", "dense", 2, 64, 4, 128, 100, n_kv_heads=2, dtype="bfloat16")
    p = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 24), 0, 100)
    base, _ = jax.jit(lambda p, t: lm_logits(p, cfg, t))(p, toks)
    with perf_flags(bf16_accum_attention=True):
        opt, _ = jax.jit(lambda p, t: lm_logits(p, cfg, t))(p, toks)
    # bf16 operands + f32 accumulation: small numeric drift only
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt), rtol=0.05, atol=0.05)


def test_ssd_chunk_override_matches():
    cfg = ModelConfig("t", "ssm", 2, 64, 0, 0, 100, ssm_state=16, ssm_headdim=16,
                      ssm_expand=2, ssm_chunk=16, dtype="float32")
    p = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, 100)
    base, _ = jax.jit(lambda p, t: lm_logits(p, cfg, t))(p, toks)
    with perf_flags(ssd_chunk_override=8):
        alt, _ = jax.jit(lambda p, t: lm_logits(p, cfg, t))(p, toks)
    np.testing.assert_allclose(np.asarray(base), np.asarray(alt), rtol=2e-4, atol=2e-4)


def test_flash_bf16_close():
    from repro.models.flash import flash_attention

    q = jax.random.normal(KEY, (2, 256, 8, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 256, 2, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 256, 2, 32), jnp.bfloat16)
    base = flash_attention(q, k, v, block_q=64, block_kv=64)
    with perf_flags(bf16_accum_attention=True):
        opt = flash_attention(q, k, v, block_q=64, block_kv=64)
    np.testing.assert_allclose(np.asarray(base, np.float32), np.asarray(opt, np.float32),
                               rtol=0.06, atol=0.06)


def test_moe_capacity_override_traces():
    cfg = ModelConfig("t", "moe", 2, 64, 4, 48, 100, n_kv_heads=4, n_experts=4,
                      top_k=2, moe_d_ff=48, dtype="float32")
    p = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 32), 0, 100)
    with perf_flags(moe_capacity_override=1.0):
        loss, _ = jax.jit(lambda p, b: lm_loss(p, cfg, b))(p, {"tokens": toks, "labels": toks})
    assert np.isfinite(float(loss))
