"""Quantized optimizer-state (qstate) subsystem tests.

Covers the codec end to end: state dtypes/bytes, spec hashing and CLI rule
plumbing, the in-kernel dequant path (no silent fallback), fused-dense
segment scales, checkpoint round-trips (incl. the fp8 bit-preserving path
and the spec-hash refusal), convergence parity against f32 on the
transformer_base smoke config, and the memory acceptance ratio.
Multi-device placement/parity lives in ``_qstate_child.py``
(test_qstate_sharded below); hypothesis error-bound fuzzing in
``test_qstate_properties.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import OptimizerSpec, Partition, build_optimizer, get_family
from repro.optim.base import apply_updates
from repro.optim.qstate import QTensor
from repro.utils.tree import tree_bytes


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w1": jnp.asarray(rng.standard_normal((48, 96)), jnp.float32),
        "w2": jnp.asarray(rng.standard_normal((48, 96)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((96,)) * 1e-3, jnp.float32),
        "s": jnp.asarray(rng.standard_normal(()), jnp.float32),
    }


def _spec(family="smmf", **hp):
    base = {"lr": 1e-2}
    if family == "smmf":
        base["decay_rate"] = -0.8
    base.update(hp)
    return OptimizerSpec(family=family, hyperparams=base)


def _run_steps(opt, params, steps=3, seed=7):
    state = opt.init(params)
    step = jax.jit(lambda g, s, p: opt.update(g, s, p))
    for t in range(steps):
        rng = np.random.default_rng(seed + t)
        grads = jax.tree.map(
            lambda p: jnp.asarray(rng.standard_normal(p.shape) * 0.1,
                                  jnp.float32), params)
        u, state = step(grads, state, params)
        params = apply_updates(params, u)
    return params, state


# ---------------------------------------------------------------------------
# state layout: dtypes, bytes, capability gating
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,pdtype", [("int8", "int8"),
                                         ("fp8", "float8_e4m3fn")])
def test_smmf_quant_state_layout(mode, pdtype):
    params = _params()
    opt = build_optimizer(_spec(quant=mode))
    state = opt.init(params)
    fac = state.factors["fac:1x72x64"]
    assert len(fac) == 5
    for slot in (0, 1, 3, 4):  # r_m, c_m, r_v, c_v
        qt = fac[slot]
        assert isinstance(qt, QTensor)
        assert str(qt.q.dtype) == pdtype
        assert qt.scale.dtype == jnp.float32
        assert qt.scale.shape == (qt.q.shape[0], 1)
    assert fac[2].dtype == jnp.uint8  # sign matrix untouched


def test_quant_state_bytes_shrink():
    params = _params()
    for family in ("smmf", "adafactor", "came", "adam"):
        f32 = tree_bytes(build_optimizer(_spec(family)).init(params))
        q8 = tree_bytes(build_optimizer(_spec(family, quant="int8")).init(params))
        assert q8 < f32, (family, q8, f32)


def test_momentum_free_smmf_layout_and_bytes():
    """beta1=None holds ONLY (r_v, c_v) — no momentum factors, no sign —
    and int8 then cuts the whole state ~4x (scales included)."""
    params = _params()
    f32 = build_optimizer(_spec(beta1=None)).init(params)
    fac = f32.factors["fac:1x72x64"]
    assert len(fac) == 2 and all(x.dtype == jnp.float32 for x in fac)
    q8 = build_optimizer(_spec(beta1=None, quant="int8")).init(params)
    assert tree_bytes(q8) <= 0.30 * tree_bytes(f32)


def test_sm3_rejects_quant():
    with pytest.raises(ValueError, match="unknown hyperparams|quant"):
        build_optimizer(OptimizerSpec(family="sm3",
                                      hyperparams={"lr": 1e-3,
                                                   "quant": "int8"}))


def test_bad_quant_mode_rejected():
    with pytest.raises(ValueError, match="unknown quantization mode"):
        build_optimizer(_spec(quant="int4"))


def test_engine_stats_report_quantized_buckets():
    params = _params()
    stats = build_optimizer(_spec(quant="int8")).plan(params).stats()
    assert stats["quantized_buckets"] == stats["buckets"] > 0
    stats32 = build_optimizer(_spec()).plan(params).stats()
    assert stats32["quantized_buckets"] == 0


# ---------------------------------------------------------------------------
# spec hashing / serialization / CLI rules (acceptance criteria)
# ---------------------------------------------------------------------------

def test_spec_hash_changes_with_quant_not_with_kernel():
    base = _spec()
    q8 = _spec(quant="int8")
    fp8 = _spec(quant="fp8")
    kern = _spec(quant="int8", use_kernel=True)
    assert base.spec_hash() != q8.spec_hash()
    assert q8.spec_hash() != fp8.spec_hash()
    # execution-only knob: kernel toggle never invalidates the checkpoint
    assert kern.spec_hash() == q8.spec_hash()


def test_quant_spec_json_roundtrip_and_rule():
    spec = _spec(quant="fp8")
    back = OptimizerSpec.from_json(spec.to_json())
    assert back == spec and back.spec_hash() == spec.spec_hash()
    # the ISSUE's CLI form: a per-group quant override via --optim-rule
    ruled = _spec().with_rule("ffn/=smmf,quant=int8")
    (part,) = ruled.partitions
    assert part.hyperparams["quant"] == "int8"
    back = OptimizerSpec.from_json(ruled.to_json())
    assert back == ruled
    build_optimizer(ruled)  # validates against the registry


def test_per_group_quant_override():
    """Only the matching group stores quantized; state keys are unchanged."""
    params = _params()
    spec = OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-2, "decay_rate": -0.8},
        partitions=(Partition(name="mats", match=r"^w", family="smmf",
                              hyperparams={"quant": "int8"}),),
    )
    state = build_optimizer(spec).init(params)
    assert isinstance(state.factors["mats/fac:1x72x64"][0], QTensor)
    for key, sub in state.factors.items():
        if not key.startswith("mats/"):
            assert not any(isinstance(x, QTensor) for x in sub), key


# ---------------------------------------------------------------------------
# numerics: kernel path, fused segment scales, updates stay sane
# ---------------------------------------------------------------------------

def test_kernel_dequant_parity_and_no_fallback():
    """use_kernel + quant=int8: the fused kernel consumes the int8 payloads
    directly (launch counter moves), matches the unfused quantized path,
    and the returned state is still quantized."""
    from repro.kernels.smmf_update import ops as kops

    params = _params()
    opt_k = build_optimizer(_spec(quant="int8", use_kernel=True))
    opt_u = build_optimizer(_spec(quant="int8"))
    n0 = kops.KERNEL_LAUNCHES
    pk, sk = _run_steps(opt_k, params)
    assert kops.KERNEL_LAUNCHES > n0, "silent fallback: no kernel launch traced"
    pu, su = _run_steps(opt_u, params)
    for a, b in zip(jax.tree.leaves(pk), jax.tree.leaves(pu)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert isinstance(sk.factors["fac:1x72x64"][0], QTensor)


def test_fused_dense_segment_scales():
    """Fused flat rows quantize per contained leaf: a tiny leaf next to a
    huge one keeps its own absmax range instead of dying in the shared one."""
    params = {"big": jnp.full((64,), 1e3), "small": jnp.full((48,), 1e-3)}
    opt = build_optimizer(_spec(family="adam", quant="int8"))
    state = opt.init(params)
    grads = {"big": jnp.full((64,), 1e2), "small": jnp.full((48,), 1e-4)}
    u, state = jax.jit(lambda g, s, p: opt.update(g, s, p))(
        grads, state, params)
    qt = state.factors["dense:flat:float32"][1]  # v
    assert qt.scale.shape == (2,)  # one scale per contained leaf
    from repro.optim.qstate import dequantize_slot, fused_segments
    bk = [b for b in opt.plan(params).buckets][0]
    slots = get_family("adam").quant_slots(bk, {"quant": "int8"})
    deq = np.asarray(dequantize_slot(qt, bk, slots[1], "int8")).reshape(-1)
    seg = fused_segments(bk)
    # per-segment reconstruction error bounded by one (sqrt-companded)
    # int8 code: |x̂ - x| <= (√x_seg_max/127)² + 2√(x x_seg_max)/127
    v_ref = np.concatenate([np.full(64, (1e2) ** 2 * 1e-3),
                            np.full(48, (1e-4) ** 2 * 1e-3)])
    for s in (0, 1):
        m = seg == s
        xmax = v_ref[m].max()
        bound = (np.sqrt(xmax) / 127.0) ** 2 \
            + 2 * np.sqrt(v_ref[m].max() * xmax) / 127.0
        err = np.abs(deq[m] - v_ref[m]).max()
        assert err <= 1.01 * bound, (s, err, bound)


@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_quantized_update_tracks_f32(mode):
    """A few steps of quantized SMMF stay close to the f32 trajectory."""
    params = _params()
    p32, _ = _run_steps(build_optimizer(_spec()), params, steps=5)
    pq, _ = _run_steps(build_optimizer(_spec(quant=mode)), params, steps=5)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(pq)):
        a, b = np.asarray(a), np.asarray(b)
        # lr 1e-2 x 5 steps moves params by ~5e-2; the 8-bit preconditioner
        # drift must stay a modest fraction of that motion
        assert np.max(np.abs(a - b)) < 1e-2, np.max(np.abs(a - b))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["int8", "fp8"])
def test_quant_checkpoint_roundtrip_bitwise(mode, tmp_path):
    from repro.checkpoint import ckpt

    spec = _spec(quant=mode)
    opt = build_optimizer(spec)
    params = _params()
    _, state = _run_steps(opt, params)
    ckpt.save(tmp_path, 3, state, spec_hash=spec.spec_hash())
    restored, manifest = ckpt.restore(tmp_path, jax.eval_shape(lambda: state),
                                      spec_hash=spec.spec_hash())
    assert manifest["spec_hash"] == spec.spec_hash()
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype  # fp8 payloads restore bit-preserved
        np.testing.assert_array_equal(
            a.view(np.uint8) if a.dtype.itemsize == 1 else a,
            b.view(np.uint8) if b.dtype.itemsize == 1 else b)


def test_quant_layout_change_refuses_restore(tmp_path):
    from repro.checkpoint import ckpt

    spec8 = _spec(quant="int8")
    opt = build_optimizer(spec8)
    params = _params()
    state = opt.init(params)
    ckpt.save(tmp_path, 1, state, spec_hash=spec8.spec_hash())
    with pytest.raises(ValueError, match="spec hash mismatch"):
        ckpt.restore(tmp_path, jax.eval_shape(lambda: state),
                     spec_hash=_spec().spec_hash())


# ---------------------------------------------------------------------------
# convergence parity (acceptance) + memory acceptance ratio
# ---------------------------------------------------------------------------

def test_transformer_base_convergence_parity():
    """Quantized-vs-f32 final-loss parity on the transformer_base smoke
    config (the convergence-smoke acceptance criterion)."""
    from repro.configs import smoke_config
    from repro.data import SyntheticLMStream
    from repro.launch.steps import make_train_step
    from repro.models import init_encdec

    cfg = smoke_config("transformer_base")  # the paper's encoder-decoder
    stream = SyntheticLMStream(cfg, 4, 32, seed=0)
    finals = {}
    for tag, hp in (("f32", {}), ("int8", {"quant": "int8"})):
        opt = build_optimizer(_spec(lr=1e-3, **hp))
        params = init_encdec(jax.random.PRNGKey(0), cfg)
        state = opt.init(params)
        step = jax.jit(make_train_step(cfg, opt))
        hist = []
        for t in range(25):
            params, state, m = step(params, state,
                                    jax.tree.map(jnp.asarray, stream.batch(t)))
            hist.append(float(m["loss"]))
        finals[tag] = float(np.mean(hist[-5:]))
        assert np.isfinite(finals[tag])
    assert abs(finals["int8"] - finals["f32"]) <= 0.05 * abs(finals["f32"]), finals


def test_memory_acceptance_int8_le_30pct():
    """Acceptance: per-device optimizer-state bytes of smmf(beta1=None),
    quant=int8 <= 30% of the f32 twin on transformer_base, scales included
    (the table itself lives in benchmarks/memory_table.py)."""
    from jax.sharding import AbstractMesh

    from repro.configs import get_config
    from repro.distributed import rules
    from repro.launch import specs as S

    cfg = get_config("transformer_base")
    psds = S.params_specs(cfg)
    mesh = AbstractMesh((("data", 4),))

    def per_dev(**hp):
        opt = build_optimizer(_spec(**hp))
        sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
        return rules.sharded_state_bytes(sh, jax.eval_shape(opt.init, psds))

    assert per_dev(beta1=None, quant="int8") <= 0.30 * per_dev(beta1=None)


# ---------------------------------------------------------------------------
# multi-device placement + elastic restore (emulated-mesh child)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_qstate_sharded_parity_and_elastic(emulated_mesh):
    out = emulated_mesh.run("_qstate_child.py", devices=4)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "QSTATE PARITY OK" in out.stdout
    assert "QSTATE ELASTIC OK" in out.stdout
