"""Hypothesis fuzz properties for the qstate quantization numerics.

Round-trip error bounds and stochastic-rounding unbiasedness of
``repro.core.quant`` (own module: a module-level importorskip must not
skip the deterministic ``test_qstate.py``). Runs where hypothesis is
installed — CI installs requirements-dev.txt.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quant as Q  # noqa: E402

_rows = st.integers(min_value=1, max_value=5)
_cols = st.integers(min_value=1, max_value=64)
_seeds = st.integers(min_value=0, max_value=2**31 - 1)
_scale_pow = st.integers(min_value=-8, max_value=8)


def _mk(rows, cols, seed, scale_pow):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((rows, cols)) * 10.0 ** scale_pow
    return jnp.asarray(x, jnp.float32)


@settings(deadline=None, max_examples=60)
@given(_rows, _cols, _seeds, _scale_pow)
def test_int8_roundtrip_bounded_by_one_code(rows, cols, seed, scale_pow):
    """|deq(quant(x)) - x| <= scale per element: half a code for round-to-
    nearest, a full code for stochastic rounding."""
    x = _mk(rows, cols, seed, scale_pow)
    scale = Q.row_scale(x, "int8")
    for key, codes in ((None, 0.5), (jax.random.PRNGKey(seed), 1.0)):
        q = Q.quantize(x, scale, "int8", key=key)
        err = np.abs(np.asarray(Q.dequantize(q, scale) - x))
        bound = codes * np.asarray(scale) * (1 + 1e-6)
        assert (err <= bound).all(), (err.max(), float(bound.max()))


@settings(deadline=None, max_examples=60)
@given(_rows, _cols, _seeds, _scale_pow)
def test_fp8_roundtrip_relative_bound(rows, cols, seed, scale_pow):
    """e4m3 emulation: elementwise error <= one e4m3 ulp of the scaled
    value — 2^-3 relative for normals, plus the subnormal absolute floor
    (2^-9 of the row scale); doubled under stochastic rounding."""
    x = _mk(rows, cols, seed, scale_pow)
    scale = Q.row_scale(x, "fp8")
    for key, ulps in ((None, 0.5), (jax.random.PRNGKey(seed), 1.0)):
        q = Q.quantize(x, scale, "fp8", key=key)
        err = np.abs(np.asarray(Q.dequantize(q, scale) - x))
        rel = 2.0 * ulps * np.abs(np.asarray(x)) / 8.0
        floor = 2.0 * ulps * np.asarray(scale) * 2.0 ** -9
        assert (err <= rel + floor + 1e-30).all(), float(err.max())


@settings(deadline=None, max_examples=30)
@given(_cols, _seeds, _scale_pow)
def test_int8_stochastic_rounding_unbiased(cols, seed, scale_pow):
    """Averaged over many SR draws, deq(quant(x)) converges to x (this is
    what lets the optimizer re-quantize its state every step without an
    error-feedback buffer)."""
    x = _mk(1, cols, seed, scale_pow)
    scale = Q.row_scale(x, "int8")
    draws = 256

    def one(key):
        return Q.dequantize(Q.quantize(x, scale, "int8", key=key), scale)

    keys = jax.random.split(jax.random.PRNGKey(seed), draws)
    mean = np.asarray(jnp.mean(jax.vmap(one)(keys), axis=0))
    # SE of the mean of a one-code-wide distribution ~ scale/sqrt(draws);
    # allow 5 SEs
    tol = 5.0 * np.asarray(scale) / np.sqrt(draws)
    assert (np.abs(mean - np.asarray(x)) <= tol).all()


@settings(deadline=None, max_examples=40)
@given(_rows, _cols, _seeds)
def test_nonneg_stays_nonneg_and_zero_exact(rows, cols, seed):
    """Non-negative inputs never quantize negative (second-moment slots
    must stay valid under sqrt), and exact zeros round-trip exactly."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(np.abs(rng.standard_normal((rows, cols))), jnp.float32)
    x = x.at[:, 0].set(0.0)
    for mode in ("int8", "fp8"):
        scale = Q.row_scale(x, mode)
        for key in (None, jax.random.PRNGKey(seed)):
            deq = np.asarray(Q.dequantize(
                Q.quantize(x, scale, mode, key=key), scale))
            assert (deq >= 0).all()
            assert (deq[:, 0] == 0).all()


@settings(deadline=None, max_examples=40)
@given(_cols, _seeds, st.integers(min_value=2, max_value=5))
def test_segment_scale_isolates_leaves(cols, seed, nseg):
    """Per-segment scales: each segment's round-trip error is bounded by
    its OWN absmax, not the row's (the fused-dense property)."""
    rng = np.random.default_rng(seed)
    parts = [rng.standard_normal(cols) * 10.0 ** (3 * i) for i in range(nseg)]
    x = jnp.asarray(np.concatenate(parts), jnp.float32)[None, :]
    seg = np.repeat(np.arange(nseg, dtype=np.int32), cols)
    scale = Q.segment_scale(x, seg, nseg, "int8")
    row = scale[seg].reshape(x.shape)
    deq = np.asarray(Q.dequantize(Q.quantize(x, row, "int8"), row))
    err = np.abs(deq - np.asarray(x))[0]
    for s in range(nseg):
        m = seg == s
        own_bound = 0.5 * float(scale[s]) * (1 + 1e-6)
        assert err[m].max() <= own_bound, (s, err[m].max(), own_bound)
