"""Rank-k factored state: the generalized (R, C) layout.

``core/nnmf.nnmf_compress_k`` factorizes a batched stack at rank k — the
positive rank-1 Algorithm-4 baseline plus a randomized range-finder sketch
of the residual. The contract under test:

* ``rank=1`` is bitwise-identical to the batched rank-1 path (the paper
  layout is a strict special case, acceptance criterion);
* higher rank strictly improves reconstruction on matrices with off-rank-1
  structure, and a row with mass never reconstructs to (clamped) zero —
  the property that keeps ``m/(sqrt(v)+eps)`` bounded for low-traffic
  embedding rows;
* plan/bucket plumbing: ``LeafPlan.rank`` reaches the bucket key as an
  ``xrK`` suffix for ``rank > 1`` ONLY — rank-1 keys (and so every
  existing checkpoint's state-dict keys) are byte-identical to the
  pre-rank layout;
* ``rank`` is spec-hash-relevant (state shapes change with it), so a
  mismatched-rank restore is refused.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.nnmf import (
    nnmf_compress,
    nnmf_compress_k,
    nnmf_decompress_k,
)
from repro.core.plan import build_buckets, smmf_planner
from repro.optim import OptimizerSpec, build_optimizer


def _stack(b=3, n=24, m=40, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(np.abs(rng.standard_normal((b, n, m))), jnp.float32)


# ---------------------------------------------------------------------------
# factorizer
# ---------------------------------------------------------------------------

def test_rank1_bitwise_identical_to_algorithm4():
    mat = _stack()
    r_k, c_k = nnmf_compress_k(mat, 1)
    r_1, c_1 = jax.vmap(nnmf_compress)(mat)
    np.testing.assert_array_equal(np.asarray(r_k[:, :, 0]), np.asarray(r_1))
    np.testing.assert_array_equal(np.asarray(c_k[:, :, 0]), np.asarray(c_1))


@pytest.mark.parametrize("rank", [2, 4])
def test_higher_rank_reconstructs_better(rank):
    mat = _stack()
    err = {}
    for k in (1, rank):
        rec = nnmf_decompress_k(*nnmf_compress_k(mat, k))
        err[k] = float(jnp.linalg.norm(mat - rec) / jnp.linalg.norm(mat))
    assert err[rank] < err[1], err


def test_rows_with_mass_keep_positive_baseline():
    """A low-traffic row (tiny but nonzero mass) must not reconstruct to
    clamped zero: the rank-1 NNMF baseline guarantees it, a pure signed
    sketch does not (the adapprox 1/eps blow-up this layout prevents)."""
    mat = np.abs(np.random.default_rng(1).standard_normal((1, 32, 48))
                 ).astype(np.float32)
    mat[0, 5, :] *= 1e-4  # low-traffic row, mass > 0
    rec = np.asarray(nnmf_decompress_k(*nnmf_compress_k(jnp.asarray(mat), 2)))
    rec = np.maximum(rec, 0.0)  # the consumers' clamp
    assert rec[0, 5, :].max() > 0.0


def test_compress_k_rejects_unbatched():
    with pytest.raises(ValueError, match="stack"):
        nnmf_compress_k(jnp.zeros((4, 4)), 2)


# ---------------------------------------------------------------------------
# plan / bucket-key plumbing
# ---------------------------------------------------------------------------

def test_bucket_key_rank_suffix():
    shape = (48, 96)
    p1 = smmf_planner(rank=1)(0, shape)
    p2 = smmf_planner(rank=2)(0, shape)
    assert p1.rank == 1 and p2.rank == 2
    assert "xr" not in p1.bucket_key
    assert p2.bucket_key == p1.bucket_key + "xr2"
    # rank-k never takes the (rank-1-only) fused kernel
    assert not smmf_planner(rank=2, use_kernel=True)(0, shape).kernel_ok
    # different ranks never share a bucket
    buckets = build_buckets([p1, p2], bucket=True)
    assert len(buckets) == 2


def test_rank1_plan_keys_unchanged_on_transformer_base():
    """Acceptance: rank=1 plans produce byte-identical bucket keys for the
    existing families (no ``xr`` suffix anywhere) on the real model."""
    from repro.configs import smoke_config
    from repro.launch import specs as S

    psds = S.params_specs(smoke_config("transformer_base"))
    for family, hp in (("smmf", {"decay_rate": -0.8}), ("adafactor", {})):
        opt = build_optimizer(OptimizerSpec(family=family,
                                            hyperparams={"lr": 1e-3, **hp}))
        eng = opt.plan(psds)
        for bk in eng.buckets:
            assert "xr" not in bk.key, (family, bk.key)


# ---------------------------------------------------------------------------
# spec-hash relevance
# ---------------------------------------------------------------------------

def _adapprox_spec(rank):
    return OptimizerSpec(family="adapprox",
                         hyperparams={"lr": 1e-3, "rank": rank})


def test_rank_is_spec_hash_relevant():
    hashes = {r: _adapprox_spec(r).spec_hash() for r in (1, 2, 3)}
    assert len(set(hashes.values())) == 3, hashes


def test_mismatched_rank_restore_refused(tmp_path):
    from repro.checkpoint import ckpt

    spec2 = _adapprox_spec(2)
    opt = build_optimizer(spec2)
    params = {"w": jnp.asarray(
        np.random.default_rng(0).standard_normal((48, 96)), jnp.float32)}
    state = opt.init(params)
    ckpt.save(tmp_path, 1, state, spec_hash=spec2.spec_hash())
    with pytest.raises(ValueError, match="spec hash mismatch"):
        ckpt.restore(tmp_path, jax.eval_shape(lambda: state),
                     spec_hash=_adapprox_spec(3).spec_hash())
