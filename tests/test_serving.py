"""Serving test harness: the paged continuous-batching engine end to end.

Locks down the PR's claims layer by layer:

* **allocator** — hypothesis property suite over random alloc/free
  traffic: no double-ownership, no partial grants, the reserved scratch
  page never leaves the house, double-free raises;
* **paged cache** — scatter_prefill round-trips bitwise against the dense
  prefill cache; padded positions only ever touch the scratch page;
* **kernel** — flash_decode_paged (Pallas, scalar-prefetched block table)
  vs the gathered XLA reference across page/block shapes and the
  float / int8 / fp8 payload paths;
* **continuous batching oracle** — a request admitted into a busy batch
  produces token-for-token what it produces running alone (dense and
  enc-dec, greedy and sampled), i.e. batching is invisible;
* **lifecycle** — EOS, first-token EOS, max_new, slot reuse, page-grant
  deferral, and pages always returning to the pool;
* **sampling** — per-(seed, token-index) determinism across jit/no-jit
  and batch company, top-k/top-p support restriction, vocab-padding mask;
* **run() regression** — the seed engine returned a pre-loop snapshot of
  the queue; the rebuilt ``run()`` must return exactly what finished
  during the call, including requests admitted before it and submitted
  mid-flight;
* **bench gate** — ``tools/bench_compare.py`` enforces the >= 2x
  tokens/s floor and the legacy-normalized trajectory on
  ``BENCH_serve.json``.

Multi-device coverage (sharded decode parity, mesh page-table
consistency) lives in ``_serving_child.py`` under the MeshHarness.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_lm, lm_prefill
from repro.serving import (
    RESERVED_PAGES,
    GenerationEngine,
    LegacyRequest,
    LegacySlotEngine,
    PageAllocator,
    Request,
    SampleParams,
    gather_pages,
    init_paged_kv,
    pages_needed,
    sample_tokens,
)
from repro.serving.decode import scatter_prefill

try:  # optional dev dep (requirements-dev.txt); the allocator property
    # suite runs under hypothesis when present and falls back to a seeded
    # random sweep otherwise — the invariants are checked either way.
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

CFG = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2,
                  dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _prompts(n, seed=0, lo=3, hi=12):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, CFG.vocab, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _drive(eng, reqs):
    for r in reqs:
        eng.submit(r)
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 500, "engine failed to drain"
    assert all(r.done for r in reqs)
    return [r.out for r in reqs]


# ---------------------------------------------------------------------------
# page allocator: hypothesis property suite
# ---------------------------------------------------------------------------

def _check_allocator_traffic(npages, sizes, seed):
    """Random alloc/free interleaving: every page is exactly one of
    {reserved, free, allocated}; grants are all-or-nothing and distinct."""
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(npages)
    held = []
    for n in sizes:
        if held and rng.random() < 0.4:
            alloc.free(held.pop(rng.integers(len(held))))
        before = alloc.available
        got = alloc.alloc(n)
        if got is None:
            assert n > before, "refused a grant that fit"
            assert alloc.available == before, "failed alloc leaked pages"
        else:
            assert len(got) == n == len(set(got))
            assert all(p >= RESERVED_PAGES for p in got)
            held.append(got)
        alloc.check_invariants()
    for pages in held:
        alloc.free(pages)
    alloc.check_invariants()
    assert alloc.available == alloc.capacity


def _check_reserved_never_granted(npages):
    alloc = PageAllocator(npages)
    got = alloc.alloc(alloc.capacity)
    assert got is not None and 0 not in got
    assert alloc.alloc(1) is None


def _check_pages_needed(tokens, page):
    n = pages_needed(tokens, page)
    assert (n - 1) * page < tokens <= n * page


if HAVE_HYPOTHESIS:
    @given(st.integers(2, 64), st.lists(st.integers(0, 20), max_size=30),
           st.integers(0, 2**32 - 1))
    @settings(max_examples=150, deadline=None)
    def test_allocator_random_traffic_invariants(npages, sizes, seed):
        _check_allocator_traffic(npages, sizes, seed)

    @given(st.integers(2, 40))
    @settings(max_examples=50, deadline=None)
    def test_allocator_reserved_page_never_granted(npages):
        _check_reserved_never_granted(npages)

    @given(st.integers(1, 1000), st.integers(1, 64))
    @settings(max_examples=100, deadline=None)
    def test_pages_needed_is_ceil(tokens, page):
        _check_pages_needed(tokens, page)
else:
    @pytest.mark.parametrize("seed", range(40))
    def test_allocator_random_traffic_invariants(seed):
        rng = np.random.default_rng(1000 + seed)
        _check_allocator_traffic(int(rng.integers(2, 64)),
                                 rng.integers(0, 20, size=30).tolist(), seed)

    @pytest.mark.parametrize("npages", [2, 3, 5, 17, 40])
    def test_allocator_reserved_page_never_granted(npages):
        _check_reserved_never_granted(npages)

    @pytest.mark.parametrize("tokens,page", [
        (1, 1), (1, 16), (16, 16), (17, 16), (1000, 64), (63, 64), (65, 64)])
    def test_pages_needed_is_ceil(tokens, page):
        _check_pages_needed(tokens, page)


def test_allocator_partial_grant_never():
    alloc = PageAllocator(5)          # capacity 4
    assert alloc.alloc(5) is None
    assert alloc.available == 4       # nothing leaked
    assert alloc.alloc(4) is not None
    assert alloc.alloc(1) is None


def test_allocator_double_free_raises():
    alloc = PageAllocator(4)
    pages = alloc.alloc(2)
    alloc.free(pages)
    with pytest.raises(ValueError):
        alloc.free(pages)


def test_allocator_foreign_free_raises():
    alloc = PageAllocator(4)
    with pytest.raises(ValueError):
        alloc.free([0])               # the reserved page was never granted
    with pytest.raises(ValueError):
        alloc.free([99])


def test_allocator_duplicate_free_raises():
    alloc = PageAllocator(6)
    pages = alloc.alloc(1)
    with pytest.raises(ValueError):
        alloc.free(pages + pages)


def test_allocator_negative_alloc_raises():
    with pytest.raises(ValueError):
        PageAllocator(4).alloc(-1)


def test_allocator_too_small_pool_raises():
    with pytest.raises(ValueError):
        PageAllocator(RESERVED_PAGES)


# ---------------------------------------------------------------------------
# paged cache vs dense cache: bitwise scatter parity
# ---------------------------------------------------------------------------

def test_scatter_prefill_bitwise_roundtrip(params):
    """Dense prefill K/V scattered into pages then gathered back is
    bit-identical to the dense cache, for every valid position."""
    page, bsz, s = 8, 2, 16
    tokens = jnp.asarray(np.arange(bsz * s).reshape(bsz, s) % CFG.vocab)
    _, cache = lm_prefill(params, CFG, tokens)
    kv = {"k": cache["attn"]["k"], "v": cache["attn"]["v"]}
    pools = init_paged_kv(CFG, 1 + bsz * (s // page), page).tree()
    tbl = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    valid = jnp.asarray([s, s - 3], jnp.int32)
    out = scatter_prefill(pools, kv, tbl, valid, page, None)
    for name in ("k", "v"):
        for l in range(CFG.n_layers):
            dense = np.asarray(kv[name][l])
            got = np.asarray(gather_pages(out[name][l], tbl))
            for b in range(bsz):
                np.testing.assert_array_equal(
                    got[b, : int(valid[b])], dense[b, : int(valid[b])],
                    err_msg=f"{name} layer {l} row {b}")


def test_scatter_prefill_padding_only_touches_scratch(params):
    """Positions past ``valid`` land on the reserved page: pages the table
    doesn't map keep their sentinel contents untouched."""
    page, bsz, s = 8, 1, 16
    tokens = jnp.asarray(np.arange(s)[None] % CFG.vocab)
    _, cache = lm_prefill(params, CFG, tokens)
    kv = {"k": cache["attn"]["k"], "v": cache["attn"]["v"]}
    pv = init_paged_kv(CFG, 6, page)
    sentinel = {"k": pv.k + 7.0, "v": pv.v + 7.0}
    tbl = jnp.asarray([[2, 4]], jnp.int32)
    out = scatter_prefill(sentinel, kv, tbl, jnp.asarray([page]), page, None)
    for name in ("k", "v"):
        arr = np.asarray(out[name])
        for untouched in (1, 3, 5):
            np.testing.assert_array_equal(arr[:, untouched], 7.0)
        assert not (arr[:, 2] == 7.0).all(), "valid page not written"
        np.testing.assert_array_equal(arr[:, 4], 7.0)  # past valid -> scratch


def test_scatter_prefill_quantized_writes_scales(params):
    page, s = 8, 8
    tokens = jnp.asarray(np.arange(s)[None] % CFG.vocab)
    _, cache = lm_prefill(params, CFG, tokens)
    kv = {"k": cache["attn"]["k"], "v": cache["attn"]["v"]}
    pools = init_paged_kv(CFG, 3, page, kv_quant="int8").tree()
    tbl = jnp.asarray([[1]], jnp.int32)
    out = scatter_prefill(pools, kv, tbl, jnp.asarray([s]), page, "int8")
    assert out["k"].dtype == jnp.int8
    deq = np.asarray(gather_pages(out["k"][0], tbl, scale=out["k_scale"][0]))
    dense = np.asarray(kv["k"][0])
    np.testing.assert_allclose(deq[0, :s], dense[0, :s], atol=0.02, rtol=0.02)


# ---------------------------------------------------------------------------
# flash_decode_paged kernel vs the gathered reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [None, "int8", "fp8"])
@pytest.mark.parametrize("shape", [
    # (bsz, hq, hkv, d, page, npages)
    (2, 4, 2, 16, 8, 4),
    (3, 4, 4, 32, 16, 2),
    (1, 8, 2, 16, 4, 8),
])
def test_flash_decode_paged_matches_ref(shape, quant):
    """The Pallas paged-decode kernel (scalar-prefetched block table,
    in-register dequant) against the gathered XLA reference, including
    rows whose pos leaves trailing pages fully masked."""
    from repro.core.quant import qmax, quantize
    from repro.kernels.flash_decode import (
        flash_decode_paged,
        flash_decode_paged_ref,
    )

    bsz, hq, hkv, d, page, npages = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    pool_pages = 1 + bsz * npages
    q = jnp.asarray(rng.standard_normal((bsz, hq, d)), jnp.float32)
    pos = jnp.asarray(rng.integers(1, npages * page, size=bsz), jnp.int32)
    tbl = jnp.asarray(
        rng.permutation(np.arange(1, pool_pages))[: bsz * npages]
        .reshape(bsz, npages), jnp.int32)
    kf = rng.standard_normal((pool_pages, page, hkv, d)).astype(np.float32)
    vf = rng.standard_normal((pool_pages, page, hkv, d)).astype(np.float32)
    if quant:
        sc_k = np.abs(kf).max(-1) / float(qmax(quant)) + 1e-6
        sc_v = np.abs(vf).max(-1) / float(qmax(quant)) + 1e-6
        args = dict(
            k_scale=jnp.asarray(sc_k), v_scale=jnp.asarray(sc_v))
        kq = quantize(jnp.asarray(kf), jnp.asarray(sc_k)[..., None], quant)
        vq = quantize(jnp.asarray(vf), jnp.asarray(sc_v)[..., None], quant)
        kp, vp = kq, vq
    else:
        args = {}
        kp, vp = jnp.asarray(kf), jnp.asarray(vf)
    got = flash_decode_paged(q, kp, vp, pos, tbl, **args)
    ref = flash_decode_paged_ref(q, kp, vp, pos, tbl, **args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_decode_paged_ref_matches_dense_gather():
    """The paged reference itself is just dense flash-decode over the
    gathered pages — pin that equivalence so both oracles agree."""
    from repro.kernels.flash_decode import flash_decode_paged_ref
    from repro.kernels.flash_decode.ref import flash_decode_ref

    rng = np.random.default_rng(0)
    bsz, hq, hkv, d, page, npages = 2, 4, 2, 16, 8, 3
    q = jnp.asarray(rng.standard_normal((bsz, hq, d)), jnp.float32)
    pool = 1 + bsz * npages
    kp = jnp.asarray(rng.standard_normal((pool, page, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((pool, page, hkv, d)), jnp.float32)
    pos = jnp.asarray([5, 17], jnp.int32)
    tbl = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    paged = flash_decode_paged_ref(q, kp, vp, pos, tbl)
    dense_k = np.asarray(gather_pages(kp, tbl))
    dense_v = np.asarray(gather_pages(vp, tbl))
    dense = flash_decode_ref(q, jnp.asarray(dense_k), jnp.asarray(dense_v),
                             pos)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# continuous-batching oracle: batching is invisible
# ---------------------------------------------------------------------------

def test_batched_request_matches_solo_run(params):
    """Every request admitted into a busy 2-slot engine emits exactly the
    tokens it emits running alone in a 1-slot engine."""
    prompts = _prompts(5, seed=3)
    eng = GenerationEngine(params, CFG, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new=6) for i, p in enumerate(prompts)]
    packed = _drive(eng, reqs)
    for i, p in enumerate(prompts):
        solo_eng = GenerationEngine(params, CFG, slots=1, max_len=64)
        [solo] = _drive(solo_eng, [Request(rid=0, prompt=p, max_new=6)])
        assert packed[i] == solo, f"request {i} diverged under batching"


def test_batched_sampled_request_matches_solo_run(params):
    """The oracle holds for sampled requests too — per-request RNG state
    makes batch company invisible to the stream."""
    prompts = _prompts(4, seed=4)
    mk = lambda i, p: Request(rid=i, prompt=p, max_new=6, temperature=0.9,
                              top_k=20, seed=100 + i)
    eng = GenerationEngine(params, CFG, slots=2, max_len=64)
    packed = _drive(eng, [mk(i, p) for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        solo_eng = GenerationEngine(params, CFG, slots=1, max_len=64)
        [solo] = _drive(solo_eng, [mk(i, p)])
        assert packed[i] == solo, f"sampled request {i} diverged"


def test_greedy_matches_legacy_engine(params):
    """Token-for-token parity with the seed slot-batcher (the dense f32
    reference implementation) on the same request set."""
    prompts = _prompts(5, seed=5)
    eng = GenerationEngine(params, CFG, slots=2, max_len=64)
    new = _drive(eng, [Request(rid=i, prompt=p, max_new=6)
                       for i, p in enumerate(prompts)])
    leg = LegacySlotEngine(params, CFG, slots=2, max_len=64)
    lreqs = [LegacyRequest(rid=i, prompt=p, max_new=6)
             for i, p in enumerate(prompts)]
    for r in lreqs:
        leg.submit(r)
    while leg.step():
        pass
    assert new == [r.out for r in lreqs]


@pytest.mark.parametrize("kw", [
    {"use_kernel": True},
    {"kv_quant": "int8"},
    {"kv_quant": "int8", "use_kernel": True},
    {"page": 8, "use_kernel": True},
    {"page": 32},
])
def test_variant_matches_f32_reference(params, kw):
    """Kernel / int8 / page-size variants reproduce the plain f32 gathered
    reference greedy stream exactly."""
    prompts = _prompts(4, seed=6)
    reqs = lambda: [Request(rid=i, prompt=p, max_new=6)
                    for i, p in enumerate(prompts)]
    base = _drive(GenerationEngine(params, CFG, slots=2, max_len=64), reqs())
    got = _drive(GenerationEngine(params, CFG, slots=2, max_len=64, **kw),
                 reqs())
    assert got == base, f"variant {kw} diverged from f32 reference"


def test_fp8_variant_generates_and_is_deterministic(params):
    """fp8 payloads are coarser than int8 (no bitwise-parity claim at this
    width) but the stream must be reproducible run to run."""
    prompts = _prompts(3, seed=7)
    reqs = lambda: [Request(rid=i, prompt=p, max_new=5)
                    for i, p in enumerate(prompts)]
    kw = dict(slots=2, max_len=64, kv_quant="fp8", use_kernel=True)
    a = _drive(GenerationEngine(params, CFG, **kw), reqs())
    b = _drive(GenerationEngine(params, CFG, **kw), reqs())
    assert a == b
    assert all(len(t) == 5 for t in a)


def test_moe_batching_is_invisible():
    """MoE (capacity routing) continuous-batching oracle: a request packed
    into a busy batch matches its solo paged run token-for-token — the
    token_mask keeps padding out of the capacity cumsum, and capacity is
    per batch row, so batch company cannot perturb routing. (Parity with
    the *legacy* engine is not claimed for MoE: its unpadded prefill
    groups tokens by gcd(16, plen), a different capacity geometry than the
    padded pow2 bucket — see docs/serving.md.)"""
    cfg = ModelConfig("m", "moe", 2, 32, 4, 64, 64, n_kv_heads=2,
                      n_experts=4, top_k=2, dtype="float32")
    params = init_lm(jax.random.PRNGKey(1), cfg)
    prompts = _prompts(4, seed=8)       # all within the one-page bucket
    packed = _drive(GenerationEngine(params, cfg, slots=2, max_len=64,
                                     use_kernel=True),
                    [Request(rid=i, prompt=p, max_new=5)
                     for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        solo_eng = GenerationEngine(params, cfg, slots=1, max_len=64,
                                    use_kernel=True)
        [solo] = _drive(solo_eng, [Request(rid=0, prompt=p, max_new=5)])
        assert packed[i] == solo, f"moe request {i} diverged under batching"


def test_unsupported_family_points_at_legacy(params):
    cfg = ModelConfig("s", "ssm", 2, 32, 4, 64, 64, dtype="float32")
    with pytest.raises(ValueError, match="LegacySlotEngine"):
        GenerationEngine(params, cfg)


# ---------------------------------------------------------------------------
# enc-dec: transformer_base (the smoke config) vs the dense solo reference
# ---------------------------------------------------------------------------

def _encdec_solo_reference(params, cfg, prompt, frames, max_new):
    from repro.models import encdec_decode_step, encode, init_encdec_cache

    enc = encode(params, cfg, jnp.asarray(frames)[None])
    cache = init_encdec_cache(cfg, 1, 64)
    for t in prompt:
        logits, cache = encdec_decode_step(
            params, cfg, jnp.asarray([[int(t)]]), cache, enc)
    out = [int(jnp.argmax(logits[0, 0, : cfg.vocab]))]
    while len(out) < max_new:
        logits, cache = encdec_decode_step(
            params, cfg, jnp.asarray([[out[-1]]]), cache, enc)
        out.append(int(jnp.argmax(logits[0, 0, : cfg.vocab])))
    return out


@pytest.mark.parametrize("kw", [{}, {"kv_quant": "int8", "use_kernel": True}])
def test_encdec_smoke_matches_dense_reference(kw):
    """The acceptance criterion in miniature: transformer_base served
    paged (+ quantized + kernel) emits the dense f32 reference's greedy
    stream exactly, per request, under batching."""
    from repro.configs.transformer_base import SMOKE as cfg
    from repro.models import init_encdec

    params = init_encdec(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=4 + i).astype(np.int32)
               for i in range(3)]
    frames = [rng.standard_normal((cfg.encoder_seq, cfg.d_model))
              .astype(np.float32) for _ in range(3)]
    eng = GenerationEngine(params, cfg, slots=2, max_len=64, **kw)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=5, frames=frames[i])
            for i in range(3)]
    _drive(eng, reqs)
    for i, r in enumerate(reqs):
        ref = _encdec_solo_reference(params, cfg, prompts[i], frames[i], 5)
        assert r.out == ref, f"encdec request {i} diverged under {kw}"


# ---------------------------------------------------------------------------
# lifecycle: EOS / max_new / slot reuse / page accounting
# ---------------------------------------------------------------------------

def _first_greedy_token(params, prompt):
    eng = GenerationEngine(params, CFG, slots=1, max_len=64)
    [out] = _drive(eng, [Request(rid=0, prompt=prompt, max_new=1)])
    return out[0]


def test_eos_stops_generation_early(params):
    prompt = _prompts(1, seed=9)[0]
    free_run = _drive(GenerationEngine(params, CFG, slots=1, max_len=64),
                      [Request(rid=0, prompt=prompt, max_new=8)])[0]
    eos = free_run[3]                       # force a stop at position 3
    eng = GenerationEngine(params, CFG, slots=1, max_len=64, eos_id=eos)
    [out] = _drive(eng, [Request(rid=0, prompt=prompt, max_new=8)])
    assert out == free_run[: free_run.index(eos) + 1]
    assert out[-1] == eos and len(out) <= 8


def test_eos_on_first_token_retires_at_admission(params):
    prompt = _prompts(1, seed=10)[0]
    eos = _first_greedy_token(params, prompt)
    eng = GenerationEngine(params, CFG, slots=1, max_len=64, eos_id=eos)
    eng.submit(Request(rid=0, prompt=prompt, max_new=8))
    while eng.step():
        pass
    assert eng.stats["decode_steps"] == 0      # never entered decode
    assert eng.allocator.available == eng.allocator.capacity


def test_max_new_is_exact(params):
    for max_new in (1, 2, 7):
        eng = GenerationEngine(params, CFG, slots=1, max_len=64)
        [out] = _drive(eng, [Request(rid=0, prompt=_prompts(1)[0],
                                     max_new=max_new)])
        assert len(out) == max_new


def test_slot_reuse_and_page_return(params):
    """More requests than slots: everything completes, pages cycle back,
    and the allocator's books balance at every step."""
    eng = GenerationEngine(params, CFG, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(_prompts(7, seed=11))]
    for r in reqs:
        eng.submit(r)
    while eng.step():
        eng.allocator.check_invariants()
        held = sum(len(p) for p in eng.slot_pages if p is not None)
        assert eng.allocator.available == eng.allocator.capacity - held
    assert all(r.done for r in reqs)
    assert eng.allocator.available == eng.allocator.capacity
    assert all(not eng.tbl[s].any() for s in range(eng.slots))


def test_admission_defers_until_pages_free(params):
    """A pool sized for one request at a time: the second queue entry waits
    (FIFO, no partial grant) and still completes once pages return."""
    # maxp = 4 pages of 16 = 64 tokens; pool of 5 pages fits ONE request
    # needing 3 pages, not two.
    eng = GenerationEngine(params, CFG, slots=2, max_len=64, npages=5)
    prompts = _prompts(2, seed=12, lo=20, hi=21)      # 20 + 12 -> 2 pages... use 3
    reqs = [Request(rid=i, prompt=p, max_new=28) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)                                  # each needs 3 pages
    eng.step()
    assert eng.slot_req.count(None) == 1, "both admitted despite page shortage"
    _drive(eng, [])
    assert all(r.done for r in reqs)
    assert eng.stats["deferred_admissions"] > 0
    assert eng.allocator.available == eng.allocator.capacity


def test_prefill_budget_caps_batch(params):
    """Admission stops adding rows once the token budget is hit, but a
    single over-budget head request is never starved."""
    prompts = _prompts(6, seed=13, lo=10, hi=11)      # 10 tokens each
    eng = GenerationEngine(params, CFG, slots=6, max_len=64,
                           prefill_budget=25)
    _drive(eng, [Request(rid=i, prompt=p, max_new=3)
                 for i, p in enumerate(prompts)])
    assert eng.stats["max_admit_tokens"] <= 25
    assert eng.stats["prefill_batches"] >= 3
    big = GenerationEngine(params, CFG, slots=2, max_len=64, prefill_budget=4)
    [out] = _drive(big, [Request(rid=0, prompt=prompts[0], max_new=3)])
    assert len(out) == 3                               # admitted despite budget < plen


def test_submit_validation(params):
    eng = GenerationEngine(params, CFG, slots=1, max_len=64)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=0, prompt=np.zeros((0,), np.int32)))
    with pytest.raises(ValueError, match="exceeds"):
        eng.submit(Request(rid=1, prompt=np.zeros((60,), np.int32),
                           max_new=32))


# ---------------------------------------------------------------------------
# run(): the seed bug (returned a pre-loop snapshot of the queue)
# ---------------------------------------------------------------------------

def test_run_returns_all_finished_requests(params):
    """Seed bug: ``run()`` snapshotted ``self.queue`` before looping, so
    anything already admitted (queue empty) came back as [] and anything
    finishing mid-run was dropped. The fix returns exactly the finished
    requests."""
    eng = GenerationEngine(params, CFG, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(_prompts(3, seed=14))]
    for r in reqs:
        eng.submit(r)
    eng.step()                      # admit into slots -> queue drains
    done = eng.run()
    assert {r.rid for r in done} == {r.rid for r in reqs}
    assert all(r.done for r in done)


def test_run_includes_mid_flight_submissions(params):
    eng = GenerationEngine(params, CFG, slots=1, max_len=64)
    first = Request(rid=0, prompt=_prompts(1, seed=15)[0], max_new=3)
    eng.submit(first)
    eng.step()
    late = Request(rid=1, prompt=_prompts(1, seed=16)[0], max_new=3)
    eng.submit(late)                # arrives while rid=0 is decoding
    done = eng.run()
    assert {r.rid for r in done} == {0, 1}
    assert eng.run() == []          # drained: nothing finishes twice


def test_run_on_empty_engine_is_empty(params):
    assert GenerationEngine(params, CFG, slots=1, max_len=64).run() == []


# ---------------------------------------------------------------------------
# sampling: determinism, support restriction, no cross-slot bleed
# ---------------------------------------------------------------------------

def _logits(seed, b, v):
    return jnp.asarray(np.random.default_rng(seed)
                       .standard_normal((b, v)).astype(np.float32) * 3)


def _samp_arrays(**kw):
    sp = SampleParams.zeros(1)
    sp.set_slot(0, **kw)
    return sp.arrays()


def test_temperature_zero_is_exact_argmax():
    logits = _logits(0, 4, 64)
    sp = SampleParams.zeros(4)
    for s in range(4):
        sp.set_slot(s, seed=s, count=s)        # RNG state must not matter
    toks = sample_tokens(logits, *sp.arrays(), vocab=64)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_sampling_jit_no_jit_identical():
    logits = _logits(1, 3, 64)
    sp = SampleParams.zeros(3)
    for s in range(3):
        sp.set_slot(s, temperature=0.8, top_k=10, top_p=0.9, seed=7 + s,
                    count=s)
    eager = sample_tokens(logits, *sp.arrays(), vocab=64)
    jitted = jax.jit(lambda l, *a: sample_tokens(l, *a, vocab=64))(
        logits, *sp.arrays())
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(jitted))


def test_no_cross_slot_rng_bleed():
    """A row's draw depends only on its own (logits, params, seed, count) —
    never on batch position or who else is in the batch."""
    row = _logits(2, 1, 64)
    kw = dict(temperature=1.0, seed=42, count=3)
    [alone] = np.asarray(sample_tokens(row, *_samp_arrays(**kw), vocab=64))
    for pos, b in ((0, 4), (2, 4), (7, 8)):
        sp = SampleParams.zeros(b)
        for s in range(b):
            sp.set_slot(s, temperature=1.0, seed=1000 + s, count=s)
        sp.set_slot(pos, **kw)
        batch = jnp.tile(_logits(99, 1, 64), (b, 1)).at[pos].set(row[0])
        got = np.asarray(sample_tokens(batch, *sp.arrays(), vocab=64))
        assert got[pos] == alone, f"row at position {pos}/{b} diverged"


def test_same_seed_same_count_reproduces():
    logits = _logits(3, 1, 64)
    kw = dict(temperature=1.2, top_k=30, seed=5, count=9)
    a = sample_tokens(logits, *_samp_arrays(**kw), vocab=64)
    b = sample_tokens(logits, *_samp_arrays(**kw), vocab=64)
    assert int(a[0]) == int(b[0])


def test_count_advances_the_stream():
    """Different token indices draw from different keys: across many
    counts the stream is not constant (a frozen key would be)."""
    logits = jnp.zeros((1, 64))                # uniform -> pure RNG
    draws = {int(sample_tokens(
        logits, *_samp_arrays(temperature=1.0, seed=1, count=c),
        vocab=64)[0]) for c in range(30)}
    assert len(draws) > 5


def test_top_k_restricts_support():
    logits = _logits(4, 1, 64)
    topk = set(np.asarray(jnp.argsort(logits[0])[::-1][:5]).tolist())
    for c in range(50):
        t = int(sample_tokens(logits, *_samp_arrays(
            temperature=1.5, top_k=5, seed=11, count=c), vocab=64)[0])
        assert t in topk, f"draw {t} outside top-5 {topk}"


def test_top_p_restricts_support():
    probs = np.full(64, 1e-4)
    probs[:3] = [0.5, 0.3, 0.15]               # nucleus at p=0.9 = {0,1,2}
    logits = jnp.log(jnp.asarray(probs / probs.sum(), jnp.float32))[None]
    for c in range(50):
        t = int(sample_tokens(logits, *_samp_arrays(
            temperature=1.0, top_p=0.9, seed=13, count=c), vocab=64)[0])
        assert t in (0, 1, 2), f"draw {t} outside the nucleus"


def test_vocab_padding_never_sampled():
    """Columns past the true vocab (padded logits) are masked before any
    filter and can never be drawn."""
    logits = jnp.full((1, 64), 10.0)           # padding columns look great
    for c in range(40):
        t = int(sample_tokens(logits, *_samp_arrays(
            temperature=2.0, seed=17, count=c), vocab=48)[0])
        assert t < 48
    assert int(sample_tokens(logits, *_samp_arrays(), vocab=48)[0]) < 48


def test_engine_sampled_runs_reproduce(params):
    """Two engine runs with identical seeds give identical streams; a
    different seed moves them."""
    prompts = _prompts(3, seed=17)
    mk = lambda seed_base: [Request(rid=i, prompt=p, max_new=6,
                                    temperature=1.0, seed=seed_base + i)
                            for i, p in enumerate(prompts)]
    a = _drive(GenerationEngine(params, CFG, slots=2, max_len=64), mk(0))
    b = _drive(GenerationEngine(params, CFG, slots=2, max_len=64), mk(0))
    c = _drive(GenerationEngine(params, CFG, slots=2, max_len=64), mk(1000))
    assert a == b
    assert a != c


# ---------------------------------------------------------------------------
# multi-device: sharded decode parity + mesh page-table consistency
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_mesh_sharded_decode_parity(emulated_mesh):
    res = emulated_mesh.run("_serving_child.py")
    assert res.returncode == 0, res.stderr[-4000:]
    assert "SERVING MESH PARITY OK" in res.stdout


@pytest.mark.multidevice
def test_mesh_page_table_consistency(emulated_mesh):
    res = emulated_mesh.run("_serving_child.py")
    assert res.returncode == 0, res.stderr[-4000:]
    assert "SERVING MESH TABLE OK" in res.stdout


# ---------------------------------------------------------------------------
# bench gate: BENCH_serve.json enforcement in tools/bench_compare.py
# ---------------------------------------------------------------------------

def _serve_record(leg_tps, paged_tps, leg_p99=2.0, paged_p99=1.0):
    return {"legacy": {"tokens_per_s": leg_tps, "p99_ms": leg_p99},
            "paged": {"tokens_per_s": paged_tps, "p99_ms": paged_p99}}


def _bench_compare_mod():
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
    try:
        import bench_compare as bc
    finally:
        sys.path.pop(0)
    return bc


def test_bench_gate_enforces_serve_speedup(tmp_path):
    import json
    bc = _bench_compare_mod()
    fails: list = []
    bc._check_serve_invariants(_serve_record(100.0, 250.0), fails)
    assert not fails
    bc._check_serve_invariants(_serve_record(100.0, 150.0), fails)
    assert any("speedup" in f for f in fails), "sub-2x speedup not caught"
    # regression vs committed baseline (legacy-normalized ratios)
    fails = []
    bc._check_serve_baseline(_serve_record(100.0, 300.0),
                             _serve_record(50.0, 150.0), fails)
    assert not fails                          # uniformly slower machine: fine
    bc._check_serve_baseline(_serve_record(100.0, 300.0),
                             _serve_record(100.0, 120.0), fails)
    assert any("regression" in f for f in fails)
    # the full compare() treats a missing candidate record as a failure
    (tmp_path / "BENCH_serve.json").write_text(
        json.dumps(_serve_record(100.0, 250.0)))
    fails = bc.compare(tmp_path, tmp_path)
    assert not [f for f in fails if "BENCH_serve" in f]


def test_committed_serve_baseline_passes_gate():
    """The BENCH_serve.json committed at the repo root must itself satisfy
    the hard >= 2x invariant the CI gate enforces."""
    import json
    from pathlib import Path
    root = Path(__file__).resolve().parent.parent
    rec = json.loads((root / "BENCH_serve.json").read_text())
    bc = _bench_compare_mod()
    fails: list = []
    bc._check_serve_invariants(rec, fails)
    assert not fails, fails
