"""Serving metrics surface: gauges vs allocator truth, latency histograms.

The engine's ``metrics()`` snapshot must be *derived from* — never drift
from — the structures it describes:

* **pool gauge** — ``serve/page_pool_used_frac`` equals
  ``1 - allocator.available / allocator.capacity`` at every admit/retire
  boundary, and returns to the empty-pool value once the engine drains;
* **TTFT / TPOT** — the histograms are monotone-consistent with the
  per-request ``t_submit``/``t_first``/``t_done`` timestamps the engine
  stamps: histogram count matches the admitted/multi-token request
  population, and min/mean/max bracket the values recomputed from the
  raw timestamps;
* **counters** — submitted/admitted/finished/tokens_out reconcile with
  the request set, and deferred admissions surface both in ``stats`` and
  the counter;
* **oracle stability** — running with the per-engine registry attached
  changes no output token: solo-vs-packed greedy parity holds bitwise
  and ``metrics()`` reports a coherent snapshot afterwards.
"""

from __future__ import annotations

import math

import jax
import numpy as np
import pytest

from repro.models import ModelConfig, init_lm
from repro.obs import MetricsRegistry
from repro.serving import GenerationEngine, Request

CFG = ModelConfig("t", "dense", 2, 32, 4, 64, 64, n_kv_heads=2,
                  dtype="float32")


@pytest.fixture(scope="module")
def params():
    return init_lm(jax.random.PRNGKey(0), CFG)


def _reqs(n, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, CFG.vocab,
                                        size=int(rng.integers(3, 12)))
                    .astype(np.int32),
                    max_new=max_new, seed=seed + i)
            for i in range(n)]


def _pool_frac(eng) -> float:
    return 1.0 - eng.allocator.available / eng.allocator.capacity


def test_pool_gauge_tracks_allocator(params):
    """serve/page_pool_used_frac equals the free-list accounting at every
    engine step, and the pool drains back to empty."""
    eng = GenerationEngine(params, CFG, slots=2, max_len=64, page=4)
    empty_frac = _pool_frac(eng)
    assert eng.registry.gauge("serve/page_pool_used_frac") == empty_frac
    reqs = _reqs(5)
    for r in reqs:
        eng.submit(r)
    saw_used = False
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 500
        gauge = eng.registry.gauge("serve/page_pool_used_frac")
        assert gauge == pytest.approx(_pool_frac(eng))
        saw_used = saw_used or gauge > 0
    assert saw_used
    assert _pool_frac(eng) == empty_frac          # every page came home
    assert eng.metrics()["gauges"]["serve/page_pool_used_frac"] \
        == pytest.approx(empty_frac)


def test_ttft_tpot_consistent_with_request_timestamps(params):
    """The latency histograms are recomputable from the timestamps the
    engine stamps on each request: equal counts, bracketing min/mean/max."""
    eng = GenerationEngine(params, CFG, slots=2, max_len=64, page=4)
    reqs = _reqs(6, max_new=5)
    for r in reqs:
        eng.submit(r)
    while eng.step():
        pass

    for r in reqs:
        assert r.t_submit is not None and r.t_first is not None \
            and r.t_done is not None
        assert r.t_submit <= r.t_first <= r.t_done   # monotone lifecycle

    ttft = [(r.t_first - r.t_submit) * 1e3 for r in reqs]
    tpot = [(r.t_done - r.t_first) * 1e3 / (len(r.out) - 1)
            for r in reqs if len(r.out) > 1]
    snap = eng.metrics()
    h_ttft = snap["histograms"]["serve/ttft_ms"]
    h_tpot = snap["histograms"]["serve/tpot_ms"]
    assert h_ttft["count"] == len(ttft)
    assert h_tpot["count"] == len(tpot)
    for h, vals in ((h_ttft, ttft), (h_tpot, tpot)):
        assert h["min"] == pytest.approx(min(vals))
        assert h["max"] == pytest.approx(max(vals))
        assert h["sum"] == pytest.approx(sum(vals))
        assert h["min"] <= h["sum"] / h["count"] <= h["max"]


def test_counters_reconcile_with_request_set(params):
    # slots=1 and a tight pool force queueing + deferred admissions
    eng = GenerationEngine(params, CFG, slots=1, max_len=32, page=4)
    reqs = _reqs(4, max_new=4)
    for r in reqs:
        eng.submit(r)
    while eng.step():
        pass
    snap = eng.metrics()
    c = snap["counters"]
    assert c["serve/submitted"] == len(reqs)
    assert c["serve/admitted"] == len(reqs)
    assert c["serve/finished"] == len(reqs)
    assert c["serve/tokens_out"] == sum(len(r.out) for r in reqs)
    assert c.get("serve/deferred_admissions", 0.0) \
        == snap["stats"]["deferred_admissions"]
    assert snap["gauges"]["serve/queue_depth"] == 0
    assert snap["gauges"]["serve/active_slots"] == 0
    tps = snap["tokens_per_sec"]
    assert math.isfinite(tps) and tps > 0


def test_metrics_do_not_perturb_oracle(params):
    """Solo-vs-packed greedy parity holds with an explicit registry
    attached — the metrics layer is observation-only."""
    packed = GenerationEngine(params, CFG, slots=3, max_len=64, page=4,
                              registry=MetricsRegistry())
    reqs = _reqs(5, max_new=6)
    for r in reqs:
        packed.submit(r)
    while packed.step():
        pass

    for i, r in enumerate(reqs):
        solo_eng = GenerationEngine(params, CFG, slots=1, max_len=64, page=4)
        solo = Request(rid=0, prompt=r.prompt, max_new=r.max_new, seed=r.seed)
        solo_eng.submit(solo)
        while solo_eng.step():
            pass
        assert r.out == solo.out, f"request {i} diverged under batching"

    snap = packed.metrics()
    assert snap["counters"]["serve/finished"] == len(reqs)
    assert snap["histograms"]["serve/ttft_ms"]["count"] == len(reqs)
