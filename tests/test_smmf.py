"""SMMF faithfulness + memory-claim tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.smmf import smmf
from repro.optim import adafactor, adam, came, sm3
from repro.optim.base import apply_updates
from repro.utils.tree import tree_bytes

from reference_smmf import RefSMMF

# These tests deliberately exercise the deprecated legacy-constructor
# surface (shim parity / reference trajectories); tier-1 errors on shim
# DeprecationWarnings everywhere else (pytest.ini).
pytestmark = pytest.mark.filterwarnings(
    "ignore:.*is deprecated. build via repro.optim.spec.OptimizerSpec.*:DeprecationWarning")

SHAPES = {
    "linear": (48, 96),
    "bias": (96,),
    "conv": (3, 3, 8, 16),     # rank-4 (CNN regime)
    "embed": (128, 24),
    "scalar": (),
}


def _random_params(seed=0):
    rng = np.random.default_rng(seed)
    return {k: rng.standard_normal(s).astype(np.float32) for k, s in SHAPES.items()}


def _random_grads(seed):
    rng = np.random.default_rng(seed)
    return {k: rng.standard_normal(s).astype(np.float32) for k, s in SHAPES.items()}


@pytest.mark.parametrize("wd_mode,wd", [("adamw", 0.0), ("adamw", 0.01), ("adam", 0.01)])
def test_matches_paper_reference(wd_mode, wd):
    """The JAX SMMF must reproduce the paper's reference trajectories."""
    params_np = _random_params()
    ref = RefSMMF({k: v.shape for k, v in params_np.items()},
                  lr=1e-2, decay_rate=-0.5, weight_decay=wd, weight_decay_mode=wd_mode)
    opt = smmf(lr=1e-2, decay_rate=-0.5, weight_decay=wd, weight_decay_mode=wd_mode)
    params = jax.tree.map(jnp.asarray, params_np)
    state = opt.init(params)
    for step in range(8):
        grads_np = _random_grads(step + 100)
        grads = jax.tree.map(jnp.asarray, grads_np)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
        params_np = ref.step(params_np, grads_np)
        for k in params_np:
            np.testing.assert_allclose(
                np.asarray(params[k]), params_np[k], rtol=3e-5, atol=3e-6,
                err_msg=f"step {step} leaf {k}",
            )


def test_scalar_factorization_equals_adam_no_bias_correction():
    """A (1,1)-factorized scalar is exact: NNMF of 1x1 is lossless."""
    opt = smmf(lr=1e-2, decay_rate=-0.5)
    p = {"s": jnp.asarray(2.0)}
    state = opt.init(p)
    ref = RefSMMF({"s": ()}, lr=1e-2, decay_rate=-0.5)
    pn = {"s": np.float32(2.0)}
    for step in range(5):
        g = {"s": jnp.asarray(0.1 * (step + 1))}
        u, state = opt.update(g, state, p)
        p = apply_updates(p, u)
        pn = ref.step(pn, {"s": np.float32(0.1 * (step + 1))})
    np.testing.assert_allclose(float(p["s"]), pn["s"], rtol=1e-5)


def _transformer_like_params(d=512, v=2048, layers=4):
    rng = np.random.default_rng(0)
    p = {"embed": rng.standard_normal((v, d)).astype(np.float32)}
    for i in range(layers):
        p[f"w{i}"] = rng.standard_normal((d, 4 * d)).astype(np.float32)
        p[f"o{i}"] = rng.standard_normal((4 * d, d)).astype(np.float32)
    return jax.tree.map(jnp.asarray, p)


def test_memory_claim_96pct_vs_adam():
    """Optimizer state: SMMF must be tiny vs Adam/Adafactor/CAME/SM3.

    The paper's headline: up to 96% less than the memory-efficient family
    and ~59-78x less than Adam.
    """
    params = _transformer_like_params()
    pbytes = tree_bytes(params)
    sizes = {}
    for name, opt in [
        ("smmf", smmf(1e-3)),
        ("adam", adam(1e-3)),
        ("adafactor", adafactor(1e-3)),
        ("came", came(1e-3)),
        ("sm3", sm3(1e-3)),
    ]:
        sizes[name] = tree_bytes(jax.eval_shape(opt.init, params))
    assert sizes["adam"] >= 2 * pbytes * 0.99
    # SMMF = bitpacked sign (~1/32 of params) + O(sqrt) vectors
    assert sizes["smmf"] < sizes["adam"] / 25
    assert sizes["smmf"] < sizes["adafactor"] / 10
    assert sizes["smmf"] < sizes["came"] / 10
    assert sizes["smmf"] < sizes["sm3"] / 10
    # >= 96% reduction vs the cheapest factored baseline on this model
    cheapest = min(sizes["adafactor"], sizes["sm3"], sizes["came"])
    assert sizes["smmf"] <= 0.08 * cheapest


def test_cnn_rank4_memory_advantage():
    """Rank-4 conv momenta: Adafactor slices, SMMF square-matricizes."""
    rng = np.random.default_rng(0)
    params = {
        f"conv{i}": jnp.asarray(rng.standard_normal((512, 256, 3, 3)), jnp.float32)
        for i in range(3)
    }
    sm = tree_bytes(jax.eval_shape(smmf(1e-3).init, params))
    af = tree_bytes(jax.eval_shape(adafactor(1e-3).init, params))
    # adafactor keeps full first moment + sliced second -> ~N floats;
    # smmf keeps ~N/8 bits + vectors
    assert sm < af / 20


def test_beta_schedules():
    from repro.core.schedules import beta1_schedule, beta2_schedule

    b1 = beta1_schedule(0.9, 0.999)
    b2 = beta2_schedule(-0.5)
    assert np.isclose(float(b1(jnp.asarray(1))), 0.9)
    assert np.isclose(float(b1(jnp.asarray(3))), 0.9 * 0.999 ** 2)
    assert np.isclose(float(b2(jnp.asarray(1))), 0.0)
    assert np.isclose(float(b2(jnp.asarray(4))), 0.5)


def test_blockwise_local_variant_converges():
    opt = smmf(lr=5e-2, blocks=4)
    p = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)), jnp.float32)}
    s = opt.init(p)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = loss(p)
    for _ in range(100):
        g = jax.grad(loss)(p)
        u, s = opt.update(g, s, p)
        p = apply_updates(p, u)
    assert loss(p) < 0.05 * l0


def test_blockwise_reconstruction_not_worse():
    """Blockwise rank-1 reconstruction error <= global rank-1 (Frobenius),
    for the NNMF row/col-sum factorization on non-negative matrices."""
    rng = np.random.default_rng(1)
    worse = 0
    for trial in range(10):
        m = np.abs(rng.standard_normal((64, 32))).astype(np.float32)

        def recon(mat):
            r = mat.sum(1)
            c = mat.sum(0)
            tot = mat.sum()
            return np.outer(r, c) / tot

        glob = np.linalg.norm(m - recon(m))
        blocks = np.split(m, 4, axis=0)
        loc = np.sqrt(sum(np.linalg.norm(b - recon(b)) ** 2 for b in blocks))
        if loc > glob + 1e-5:
            worse += 1
    assert worse <= 1  # allow rare numerical tie-breaks


def test_vector_reshape_off_uses_dense_adam_path():
    opt = smmf(lr=1e-2, vector_reshape=False)
    p = {"b": jnp.zeros((64,))}
    s = opt.init(p)
    # fused fallback bucket: full-size m and v as one flat (1, total) row
    assert set(s.factors) == {"dense:flat:float32"}
    m, v = s.factors["dense:flat:float32"]
    assert m.shape == v.shape == (1, 64)
    # fuse_dense=False restores the per-geometry dense:NUM layout
    s1 = smmf(lr=1e-2, vector_reshape=False, fuse_dense=False).init(p)
    assert set(s1.factors) == {"dense:64"}
    # factorized when vector_reshape=True: O(sqrt) factors instead
    s2 = smmf(lr=1e-2).init(p)
    assert set(s2.factors) == {"fac:1x8x8"}


def test_validation_errors():
    with pytest.raises(ValueError):
        smmf(lr=-1.0)
    with pytest.raises(ValueError):
        smmf(decay_rate=0.5)
    with pytest.raises(ValueError):
        smmf(growth_rate=1.5)
    with pytest.raises(ValueError):
        smmf(weight_decay_mode="bogus")
