"""Hypothesis property tests for the SMMF optimizer as a whole."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.smmf import smmf
from repro.optim.base import apply_updates

from reference_smmf import RefSMMF

# These tests deliberately exercise the deprecated legacy-constructor
# surface (shim parity / reference trajectories); tier-1 errors on shim
# DeprecationWarnings everywhere else (pytest.ini).
pytestmark = pytest.mark.filterwarnings(
    "ignore:.*is deprecated. build via repro.optim.spec.OptimizerSpec.*:DeprecationWarning")


@given(
    st.lists(st.integers(min_value=1, max_value=9), min_size=1, max_size=3),
    st.integers(0, 10_000),
    st.sampled_from([-0.5, -0.8]),
)
@settings(max_examples=25, deadline=None)
def test_trajectory_matches_reference_any_shape(dims, seed, gamma):
    """For arbitrary small tensor shapes the JAX SMMF tracks the paper's
    reference trajectory."""
    rng = np.random.default_rng(seed)
    shape = tuple(dims)
    p_np = {"w": rng.standard_normal(shape).astype(np.float32)}
    ref = RefSMMF({"w": shape}, lr=1e-2, decay_rate=gamma)
    opt = smmf(1e-2, decay_rate=gamma)
    p = {"w": jnp.asarray(p_np["w"])}
    state = opt.init(p)
    for step in range(4):
        g_np = {"w": rng.standard_normal(shape).astype(np.float32)}
        u, state = opt.update({"w": jnp.asarray(g_np["w"])}, state, p)
        p = apply_updates(p, u)
        p_np = ref.step(p_np, g_np)
        np.testing.assert_allclose(np.asarray(p["w"]), p_np["w"], rtol=5e-5, atol=5e-6)


@given(st.integers(2, 64), st.integers(2, 64), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_state_is_sublinear_in_param_size(n, m, seed):
    """Persistent SMMF state ~ O(n+m) floats + nm/8 sign bytes << 8nm
    (Adam's two f32 moments)."""
    from repro.utils.tree import tree_bytes

    p = {"w": jnp.zeros((n, m), jnp.float32)}
    state_bytes = tree_bytes(jax.eval_shape(smmf(1e-3).init, p))
    nm = n * m
    # vectors (<= 2*(n+m+8) f32 each for M and V) + packed signs + step
    bound = 4 * 4 * (n + m + 16) + (nm // 8 + n + 8) + 16
    assert state_bytes <= bound
    assert state_bytes < 8 * nm or nm < 64  # << Adam except degenerate tiny


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_update_is_bounded_by_lr_over_sqrt_eps(seed):
    """|update| <= lr * |m|/(sqrt(v)+eps): first step gives |u| <= lr*(1-b1)
    * |g| / (sqrt((1-b2_1)*g^2)) = lr*(1-b1) since b2_1 = 0 -- a stability
    sanity used when reasoning about the paper's loss spikes."""
    rng = np.random.default_rng(seed)
    g = rng.standard_normal((16, 16)).astype(np.float32) * 10
    p = {"w": jnp.zeros((16, 16), jnp.float32)}
    opt = smmf(lr=1.0, decay_rate=-0.5, eps=1e-8)
    state = opt.init(p)
    u, _ = opt.update({"w": jnp.asarray(g)}, state, p)
    # first step: M1 = 0.1*G, V1 = G^2 -> |u| = lr*0.1*|G|/(|G|+eps) <= 0.1
    assert float(jnp.max(jnp.abs(u["w"]))) <= 0.1 + 1e-5
