"""OptimizerSpec API: round-trips, mixed-family trees, freeze, shims.

Covers the api_redesign acceptance criteria:

* ``to_json``/``from_json`` identity and ``spec_hash`` stability;
* bitwise parity of ``build_optimizer(smmf_spec)`` vs the legacy
  ``smmf(...)`` constructor on transformer_base;
* mixed-family specs (SMMF + Adam + frozen groups): group-prefixed state
  keys, zero frozen state bytes, frozen leaves bitwise untouched, and the
  Adam group matching a standalone Adam run leaf-for-leaf;
* checkpoint save->restore under a mixed spec (stable keys, spec-hash
  mismatch raises);
* the widened ``update(grads, state, params, *, step=...)`` protocol;
* deprecation shims delegating to specs;
* the registry ``fuse_dense_ok`` capability (segment-aware RMS clip) for
  adafactor/came.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import smoke_config
from repro.models import init_lm
from repro.optim import (
    OptimizerSpec,
    Partition,
    adam,
    adamw,
    adafactor,
    build_optimizer,
    came,
    chain,
    clip_by_global_norm,
    parse_rule,
    sgd,
    sm3,
    state_bytes_by_group,
)
from repro.optim.base import apply_updates
from repro.core.smmf import smmf

SHAPES = {
    "wq": (48, 96),
    "wk": (48, 96),
    "bias_q": (96,),
    "bias_k": (96,),
    "conv": (3, 3, 8, 16),
    "scale": (64,),
    "scalar": (),
}


def _tree(seed):
    rng = np.random.default_rng(seed)
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in SHAPES.items()}


def _run(opt, steps=4, seed0=70, params=None):
    params = _tree(0) if params is None else params
    state = opt.init(params)
    for s in range(steps):
        u, state = opt.update(_tree(seed0 + s), state, params)
        params = apply_updates(params, u)
    return params, state


MIXED = OptimizerSpec(
    family="smmf",
    hyperparams={"lr": 1e-2, "decay_rate": -0.8},
    partitions=(
        Partition(name="norms", match=r"bias|scale|scalar", family="adam",
                  hyperparams={"lr": 3e-3}),
        Partition(name="frozen", match=r"conv", freeze=True),
    ),
)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def test_roundtrip_identity_and_hash():
    spec = OptimizerSpec(
        family="smmf",
        hyperparams={"lr": 1e-3, "decay_rate": -0.8, "blocks": 4,
                     "kernel_block": (256, 512)},
        schedule={"kind": "warmup_cosine", "peak_lr": 1e-3,
                  "warmup_steps": 10, "total_steps": 100},
        partitions=MIXED.partitions,
    )
    back = OptimizerSpec.from_json(spec.to_json())
    assert back == spec
    assert back.spec_hash() == spec.spec_hash()
    # the hash is sensitive to hyperparams (it guards checkpoint layouts)
    other = OptimizerSpec.from_json(spec.to_json().replace("-0.8", "-0.5"))
    assert other.spec_hash() != spec.spec_hash()
    # and the JSON is plain data
    assert json.loads(spec.to_json())["family"] == "smmf"


def test_predicates_are_programmatic_only():
    spec = OptimizerSpec(partitions=(
        Partition(name="big", predicate=lambda path, leaf: leaf.ndim >= 2),))
    with pytest.raises(ValueError, match="not.*serializable|predicate"):
        spec.to_json()
    # but they do drive grouping
    opt = build_optimizer(spec)
    stats = opt.plan(_tree(0)).stats()
    assert stats["groups"] == 2


def test_parse_rule():
    p = parse_rule("norm|bias=adam,lr=3e-4,weight_decay=0.0", index=1)
    assert p.name == "adam1" and p.family == "adam" and p.match == "norm|bias"
    assert p.hyperparams == {"lr": 3e-4, "weight_decay": 0.0}
    f = parse_rule("^base=freeze")
    assert f.freeze and f.match == "^base"
    with pytest.raises(ValueError):
        parse_rule("no-family-given")
    with pytest.raises(ValueError, match="unknown optimizer family"):
        parse_rule("x=bogus")


def test_spec_validation():
    with pytest.raises(ValueError, match="unknown hyperparams"):
        build_optimizer(OptimizerSpec(family="adam", hyperparams={"decay_rate": -0.5}))
    with pytest.raises(ValueError, match="decay_rate"):
        build_optimizer(OptimizerSpec(family="smmf", hyperparams={"decay_rate": 0.5}))
    with pytest.raises(ValueError, match="duplicate"):
        OptimizerSpec(partitions=(Partition(name="a", match="x"),
                                  Partition(name="a", match="y")))
    with pytest.raises(ValueError, match="partition name"):
        Partition(name="default", match="x")


# ---------------------------------------------------------------------------
# parity: spec-built == legacy constructor (acceptance: bitwise)
# ---------------------------------------------------------------------------

def test_spec_smmf_bitwise_parity_transformer_base():
    cfg = smoke_config("transformer_base")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    with pytest.warns(DeprecationWarning):
        legacy = smmf(1e-3, decay_rate=-0.8)
    spec_built = build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3, "decay_rate": -0.8}))

    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 0.01, params)
    p1, s1 = params, legacy.init(params)
    p2, s2 = params, spec_built.init(params)
    for _ in range(2):
        u1, s1 = legacy.update(grads, s1, p1)
        u2, s2 = spec_built.update(grads, s2, p2)
        p1, p2 = apply_updates(p1, u1), apply_updates(p2, u2)
    # bitwise: params AND every state leaf (incl. packed signs)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert sorted(s1.factors) == sorted(s2.factors)
    for k in s1.factors:
        for a, b in zip(jax.tree.leaves(s1.factors[k]), jax.tree.leaves(s2.factors[k])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# mixed-family trees
# ---------------------------------------------------------------------------

def test_mixed_spec_groups_and_freeze():
    opt = build_optimizer(MIXED)
    params = _tree(0)
    state = opt.init(params)
    # buckets never span groups; keys carry the group prefix
    assert sorted(state.factors) == ["fac:1x72x64", "norms/dense:flat:float32"]
    stats = opt.plan(params).stats()
    assert stats["groups"] == 3 and stats["frozen_leaves"] == 1
    by_group = state_bytes_by_group(opt, params)
    assert by_group["frozen"] == 0
    assert by_group["default"] > 0 and by_group["norms"] > 0

    p_end, state = _run(opt, params=params)
    # frozen leaves bitwise untouched
    np.testing.assert_array_equal(np.asarray(p_end["conv"]), np.asarray(params["conv"]))
    # ONE shared step counter
    assert int(state.step) == 4


def test_mixed_adam_group_matches_standalone_adam():
    """The adam partition's leaves evolve exactly like a standalone
    spec-built adam run over just those leaves (shared step counter)."""
    opt = build_optimizer(MIXED)
    p_end, _ = _run(opt)
    sub = {k: v for k, v in _tree(0).items() if k in ("bias_q", "bias_k", "scale", "scalar")}
    adam_opt = build_optimizer(OptimizerSpec(family="adam", hyperparams={"lr": 3e-3}))
    params, state = sub, adam_opt.init(sub)
    for s in range(4):
        g = {k: v for k, v in _tree(70 + s).items() if k in sub}
        u, state = adam_opt.update(g, state, params)
        params = apply_updates(params, u)
    for k in sub:
        np.testing.assert_array_equal(np.asarray(p_end[k]), np.asarray(params[k]), err_msg=k)


def test_explicit_labels_override_rules():
    labels = {k: "default" for k in SHAPES}
    labels["wq"] = "frozen"
    opt = build_optimizer(MIXED, labels=labels)
    params = _tree(0)
    p_end, state = _run(opt, params=params)
    np.testing.assert_array_equal(np.asarray(p_end["wq"]), np.asarray(params["wq"]))
    assert (np.abs(np.asarray(p_end["conv"]) - np.asarray(params["conv"])) > 0).any()
    with pytest.raises(ValueError, match="names no group"):
        build_optimizer(MIXED, params=params, labels={k: "bogus" for k in SHAPES})


def test_weight_decay_mask_via_partition():
    """A partition with weight_decay=0 exempts its leaves (the wd mask)."""
    spec = OptimizerSpec(
        family="smmf",
        hyperparams={"lr": 1e-2, "decay_rate": -0.8, "weight_decay": 0.1},
        partitions=(Partition(name="nodecay", match=r"bias",
                              hyperparams={"weight_decay": 0.0}),),
    )
    masked, _ = _run(build_optimizer(spec))
    nowd, _ = _run(build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-2, "decay_rate": -0.8})))
    wd, _ = _run(build_optimizer(OptimizerSpec(
        family="smmf",
        hyperparams={"lr": 1e-2, "decay_rate": -0.8, "weight_decay": 0.1})))
    # masked == no-decay on bias leaves, == decayed elsewhere
    np.testing.assert_array_equal(np.asarray(masked["bias_q"]), np.asarray(nowd["bias_q"]))
    np.testing.assert_array_equal(np.asarray(masked["wq"]), np.asarray(wd["wq"]))
    assert (np.asarray(masked["wq"]) != np.asarray(nowd["wq"])).any()


# ---------------------------------------------------------------------------
# the widened update protocol (explicit step)
# ---------------------------------------------------------------------------

def test_update_step_override_and_chain_forwarding():
    opt = build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-2, "decay_rate": -0.8}))
    params = _tree(0)
    state = opt.init(params)
    _, s1 = opt.update(_tree(1), state, params, step=7)
    assert int(s1.step) == 7
    # chain forwards step= through every stage
    chained = chain(clip_by_global_norm(1.0), opt)
    cs = chained.init(params)
    _, cs = chained.update(_tree(1), cs, params, step=5)
    assert int(cs.inner[1].step) == 5
    # schedules read the shared counter: a warmup schedule at step=1 vs
    # step=100 produces different lr -> different update magnitude
    sched_opt = build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-2, "decay_rate": -0.8},
        schedule={"kind": "warmup_cosine", "peak_lr": 1e-2,
                  "warmup_steps": 50, "total_steps": 200}))
    st = sched_opt.init(params)
    u_early, _ = sched_opt.update(_tree(1), st, params, step=1)
    u_peak, _ = sched_opt.update(_tree(1), st, params, step=50)
    n_early = float(jnp.linalg.norm(u_early["wq"]))
    n_peak = float(jnp.linalg.norm(u_peak["wq"]))
    assert n_early < 0.1 * n_peak


def test_partition_lr_override_beats_spec_schedule():
    """A partition overriding lr (no schedule of its own) gets that lr —
    the spec-level schedule must not shadow it."""
    spec = OptimizerSpec(
        family="smmf", hyperparams={"lr": 1.0, "decay_rate": -0.8},
        schedule={"kind": "constant", "value": 0.0},  # default group: lr 0
        partitions=(Partition(name="norms", match=r"bias", family="adam",
                              hyperparams={"lr": 3e-3}),),
    )
    params = _tree(0)
    p_end, _ = _run(build_optimizer(spec), params=params)
    # default group saw the zero schedule -> untouched
    np.testing.assert_array_equal(np.asarray(p_end["wq"]), np.asarray(params["wq"]))
    # the adam partition's explicit lr took effect
    assert (np.asarray(p_end["bias_q"]) != np.asarray(params["bias_q"])).any()


def test_spec_hash_ignores_execution_only_knobs():
    """use_kernel/kernel_block/interpret/lr/schedule never change the state
    layout, so toggling them must not invalidate checkpoints."""
    base = OptimizerSpec(family="smmf", hyperparams={"lr": 1e-3, "decay_rate": -0.8})
    kernel = OptimizerSpec(family="smmf", hyperparams={
        "lr": 3e-4, "decay_rate": -0.8, "use_kernel": True,
        "kernel_block": (512, 512), "interpret": True})
    sched = OptimizerSpec(family="smmf", hyperparams={"decay_rate": -0.8},
                          schedule={"kind": "constant", "value": 1e-4})
    assert base.spec_hash() == kernel.spec_hash() == sched.spec_hash()
    # but layout-relevant knobs DO change it
    assert base.spec_hash() != OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3, "decay_rate": -0.8,
                                    "blocks": 4}).spec_hash()
    assert base.spec_hash() != OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3, "decay_rate": -0.8,
                                    "fuse_dense": False}).spec_hash()


def test_parse_rule_with_tuple_literal():
    p = parse_rule("attn=smmf,kernel_block=(512,512),blocks=4")
    assert p.hyperparams == {"kernel_block": (512, 512), "blocks": 4}


def test_labels_only_partition():
    """A partition with neither match nor predicate is reachable only via
    explicit labels — legal, and matches nothing by rule."""
    spec = OptimizerSpec(family="smmf",
                         hyperparams={"lr": 1e-2, "decay_rate": -0.8},
                         partitions=(Partition(name="icebox", freeze=True),))
    params = _tree(0)
    # no labels: the rule matches nothing, everything trains
    p_end, _ = _run(build_optimizer(spec), params=params)
    assert (np.asarray(p_end["conv"]) != np.asarray(params["conv"])).any()
    # labels route leaves into the labels-only group
    labels = {k: ("icebox" if k == "conv" else "default") for k in SHAPES}
    p_end, _ = _run(build_optimizer(spec, labels=labels), params=params)
    np.testing.assert_array_equal(np.asarray(p_end["conv"]), np.asarray(params["conv"]))


def test_constant_zero_schedule_freezes_updates():
    opt = build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-2, "decay_rate": -0.8},
        schedule={"kind": "constant", "value": 0.0}))
    params = _tree(0)
    p_end, _ = _run(opt, params=params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(p_end[k]), np.asarray(params[k]))


# ---------------------------------------------------------------------------
# checkpoint: mixed-family spec, stable keys, hash verification
# ---------------------------------------------------------------------------

def test_checkpoint_mixed_spec_roundtrip_and_hash(tmp_path):
    opt = build_optimizer(MIXED)
    params = _tree(0)
    _, state = _run(opt, steps=2, params=params)
    h = MIXED.spec_hash()
    save(tmp_path, 2, {"opt": state}, spec_hash=h)

    # state keys are stable: the manifest records the group-prefixed keys
    manifest = json.loads((tmp_path / "step_0000000002" / "manifest.json").read_text())
    assert manifest["spec_hash"] == h
    assert any("norms/dense:flat:float32" in k for k in manifest["leaves"])

    got, _ = restore(tmp_path, {"opt": state}, spec_hash=h)
    for a, b in zip(jax.tree.leaves(got["opt"]), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # resuming under a different spec fails loudly
    other = OptimizerSpec(family="smmf", hyperparams={"lr": 1e-2})
    with pytest.raises(ValueError, match="spec hash mismatch"):
        restore(tmp_path, {"opt": state}, spec_hash=other.spec_hash())
    # pre-spec checkpoints (no recorded hash) restore freely
    save(tmp_path, 3, {"opt": state})
    restore(tmp_path, {"opt": state}, step=3, spec_hash=h)


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ctor,family", [
    (lambda: smmf(1e-3), "smmf"),
    (lambda: adam(1e-3), "adam"),
    (lambda: adamw(1e-3), "adam"),
    (lambda: adafactor(1e-3), "adafactor"),
    (lambda: came(1e-3), "came"),
    (lambda: sm3(1e-3), "sm3"),
    (lambda: sgd(1e-2, momentum=0.9), "sgd"),
])
def test_legacy_constructors_warn_and_delegate(ctor, family):
    with pytest.warns(DeprecationWarning, match="deprecated.*OptimizerSpec"):
        opt = ctor()
    # delegation: the shim returns a spec-built transformation
    assert opt.spec is not None and opt.spec.family == family
    assert opt.plan is not None


# ---------------------------------------------------------------------------
# registry capability: fused dense fallback for adafactor/came
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["adafactor", "came"])
def test_fused_dense_capability_adafactor_came(family):
    """fuse_dense=True (segment-aware RMS clip) matches the unfused layout
    and collapses the dense rank<=1 leaves into one launch."""
    hp = {"lr": 1e-2}
    fused_opt = build_optimizer(OptimizerSpec(
        family=family, hyperparams=dict(hp, fuse_dense=True)))
    plain_opt = build_optimizer(OptimizerSpec(family=family, hyperparams=hp))
    fused_stats = fused_opt.plan(_tree(0)).stats()
    assert fused_stats["fused_dense_leaves"] == 4   # bias_q, bias_k, scale, scalar
    assert fused_stats["dense_buckets"] == 1
    a, _ = _run(fused_opt)
    b, _ = _run(plain_opt)
    for k in a:
        np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                   rtol=1e-6, atol=1e-7, err_msg=f"{family} {k}")


def test_mixed_spec_opt_state_shardings_group_aware():
    """opt_state_shardings handles group-prefixed bucket keys: every leaf
    gets a divisibility-legal spec and the adam group's fused dense row is
    sharded over "data" exactly like the default group's."""
    from jax.sharding import AbstractMesh, PartitionSpec as P

    from repro.configs import get_config
    from repro.distributed import rules
    from repro.launch import specs as S
    from repro.utils.tree import tree_map_with_path

    cfg = get_config("transformer_base")
    psds = S.params_specs(cfg)
    spec = OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3, "decay_rate": -0.8},
        partitions=(Partition(name="norms", match=r"norm|scale$|bias$",
                              family="adam"),
                    Partition(name="icebox", match=r"pos_embed", freeze=True)),
    )
    opt = build_optimizer(spec)
    mesh = AbstractMesh((("data", 4),))
    sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
    state_sds = jax.eval_shape(opt.init, psds)

    flat_paths: dict[str, tuple] = {}
    tree_map_with_path(lambda p, leaf: flat_paths.setdefault(p, tuple(leaf.shape)),
                       state_sds)
    for (path, shape), s in zip(flat_paths.items(), jax.tree.leaves(sh)):
        for dim, want in zip(shape, tuple(s.spec) + (None,) * 8):
            if want is not None:
                assert dim % rules._axsize(mesh, want) == 0, (path, shape, s.spec)
    # the prefixed adam fused row got the dense (None, "data") treatment
    dense_rows = {p: s for (p, _), s in zip(flat_paths.items(), jax.tree.leaves(sh))
                  if "norms/dense:flat" in p}
    assert dense_rows and all(s.spec == P(None, "data") for s in dense_rows.values())


def test_fuse_dense_ignored_without_capability():
    """sm3 has no dense fallback (fuse_dense_ok=False): asking for fusion is
    a no-op instead of an illegal layout."""
    opt = build_optimizer(OptimizerSpec(family="sm3",
                                        hyperparams={"lr": 1e-2, "fuse_dense": True}))
    stats = opt.plan(_tree(0)).stats()
    assert stats["fused_dense_leaves"] == 0
