"""End-to-end spec coverage that must run in its own process.

* the acceptance-criterion mixed spec (SMMF on >=2-D leaves, Adam on
  norms/biases, a frozen group) training through ``repro.launch.train``
  with buffer donation asserted;
* the known XLA SPMD partitioner CHECK crash on
  ``dryrun --arch transformer_base --shape train_4k`` (xfail-gated: starts
  xpassing when an XLA bump fixes it) and its ``--no-scatter-constraints``
  escape hatch.

Subprocesses are required: the dry-run forces 512 host devices at first
jax import, and the XLA CHECK failure aborts the whole process.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

HERE = Path(__file__).resolve().parent
SRC = HERE.parent / "src"


def _run(args, timeout):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{SRC}{os.pathsep}" + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, env=env, timeout=timeout)


def test_mixed_spec_trains_e2e_with_donation(tmp_path):
    """Mixed-family + frozen partitions through the real train launcher:
    the step compiles, donates params+opt state, checkpoints with the spec
    hash, and finishes."""
    out = _run([
        "-m", "repro.launch.train", "--arch", "transformer_base", "--smoke",
        "--steps", "3", "--batch", "4", "--seq", "32", "--ckpt-every", "3",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--optim-rule", "norm|scale$|bias$=adam,lr=3e-4",
        "--optim-rule", "pos_embed=freeze",
    ], timeout=900)
    assert out.returncode == 0, f"train failed:\n{out.stdout}\n{out.stderr}"
    assert "donation verified" in out.stdout
    assert "3 groups" in out.stdout and "frozen" in out.stdout
    assert "state bytes by group" in out.stdout
    assert "[train] done" in out.stdout
    # the checkpoint carries the spec hash (verified on any future resume)
    import json

    manifests = list((tmp_path / "ckpt").glob("step_*/manifest.json"))
    assert manifests and json.loads(manifests[0].read_text()).get("spec_hash")


@pytest.mark.xfail(
    strict=False,
    reason="known XLA SPMD partitioner CHECK crash (spmd_partitioner_util.cc "
           "device_groups mismatch) while partitioning the engine's scatter "
           "reshapes for stacked-scan leaves; tracked in ROADMAP.md, needs an "
           "XLA bump or param-spec-aware scatter constraints",
)
def test_transformer_base_train4k_dryrun_compiles():
    """Regression guard for the known crash: flips to XPASS once fixed."""
    out = _run(["-m", "repro.launch.dryrun", "--arch", "transformer_base",
                "--shape", "train_4k"], timeout=900)
    assert out.returncode == 0, (
        f"dryrun crashed (rc={out.returncode}):\n{out.stdout[-2000:]}\n"
        f"{out.stderr[-2000:]}")


def test_no_scatter_constraints_escape_hatch():
    """--no-scatter-constraints makes the crashing cell compile today."""
    out = _run(["-m", "repro.launch.dryrun", "--arch", "transformer_base",
                "--shape", "train_4k", "--no-scatter-constraints",
                "--variant", "noconstraint_test"], timeout=900)
    assert out.returncode == 0, f"escape hatch failed:\n{out.stdout}\n{out.stderr}"
    assert "ALL CELLS OK" in out.stdout
