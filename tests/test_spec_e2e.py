"""End-to-end spec coverage that must run in its own process.

* the acceptance-criterion mixed spec (SMMF on >=2-D leaves, Adam on
  norms/biases, a frozen group) training through ``repro.launch.train``
  with buffer donation asserted;
* the ``transformer_base/train_4k`` dry-run cell as a **hard regression
  test**: the XLA SPMD partitioner CHECK crash on the engine's scatter
  reshapes is fixed at the root (param-spec-aware scatter constraints +
  the "opt_update_row" boundary rule, PR 4), so the cell must compile
  WITHOUT ``--no-scatter-constraints``;
* the ``--no-scatter-constraints`` A/B hatch still compiles (it now drops
  the fix along with the other optimizer constraints);
* a compile-smoke matrix over every arch × train_4k behind the ``slow``
  marker (``--runslow``; the scheduled CI job runs it).

Subprocesses are required: the dry-run forces 512 host devices at first
jax import.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

HERE = Path(__file__).resolve().parent
SRC = HERE.parent / "src"


def _run(args, timeout):
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{SRC}{os.pathsep}" + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, env=env, timeout=timeout)


def test_mixed_spec_trains_e2e_with_donation(tmp_path):
    """Mixed-family + frozen partitions through the real train launcher:
    the step compiles, donates params+opt state, checkpoints with the spec
    hash, and finishes."""
    out = _run([
        "-m", "repro.launch.train", "--arch", "transformer_base", "--smoke",
        "--steps", "3", "--batch", "4", "--seq", "32", "--ckpt-every", "3",
        "--ckpt-dir", str(tmp_path / "ckpt"),
        "--optim-rule", "norm|scale$|bias$=adam,lr=3e-4",
        "--optim-rule", "pos_embed=freeze",
    ], timeout=900)
    assert out.returncode == 0, f"train failed:\n{out.stdout}\n{out.stderr}"
    assert "donation verified" in out.stdout
    assert "3 groups" in out.stdout and "frozen" in out.stdout
    assert "state bytes by group" in out.stdout
    assert "[train] done" in out.stdout
    # the checkpoint carries the spec hash (verified on any future resume)
    import json

    manifests = list((tmp_path / "ckpt").glob("step_*/manifest.json"))
    assert manifests and json.loads(manifests[0].read_text()).get("spec_hash")


def test_transformer_base_train4k_dryrun_compiles():
    """HARD regression test (was xfail until PR 4): the engine's
    param-spec-aware scatter constraints and the "opt_update_row" boundary
    rule fixed the XLA SPMD partitioner CHECK crash
    (spmd_partitioner_util.cc device_groups mismatch) at the root — this
    cell must compile with constraints ON, no escape hatch."""
    out = _run(["-m", "repro.launch.dryrun", "--arch", "transformer_base",
                "--shape", "train_4k", "--variant", "regression"], timeout=900)
    assert out.returncode == 0, (
        f"dryrun crashed (rc={out.returncode}) — the scatter-constraint fix "
        f"regressed:\n{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
    assert "ALL CELLS OK" in out.stdout


def test_transformer_base_train4k_quantized_compiles():
    """Quantized-state (qstate int8 + fused kernel, in-kernel dequant)
    twin of the hard-regression cell: the sharded train step must compile
    with all constraints ON — payloads, scale rows ("qscale") and the
    boundary pins all agree with ``rules.opt_state_shardings``."""
    out = _run(["-m", "repro.launch.dryrun", "--arch", "transformer_base",
                "--shape", "train_4k", "--quant", "int8", "--use-kernel",
                "--variant", "qstate_regression"], timeout=900)
    assert out.returncode == 0, (
        f"quantized dryrun crashed (rc={out.returncode}):\n"
        f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
    assert "ALL CELLS OK" in out.stdout


def test_no_scatter_constraints_escape_hatch():
    """--no-scatter-constraints (now a pure A/B hatch: it drops the scatter
    fix together with the other optimizer constraints) still compiles."""
    out = _run(["-m", "repro.launch.dryrun", "--arch", "transformer_base",
                "--shape", "train_4k", "--no-scatter-constraints",
                "--variant", "noconstraint_test"], timeout=900)
    assert out.returncode == 0, f"escape hatch failed:\n{out.stdout}\n{out.stderr}"
    assert "ALL CELLS OK" in out.stdout


def _arch_ids():
    sys.path.insert(0, str(SRC))
    try:
        from repro.configs import ARCH_IDS

        return list(ARCH_IDS)
    finally:
        sys.path.pop(0)


@pytest.mark.slow
@pytest.mark.parametrize("arch", _arch_ids())
def test_dryrun_compile_smoke_matrix(arch):
    """Every arch × train_4k lowers + compiles on the production mesh with
    the full constraint set (slow: one multi-minute compile per arch)."""
    out = _run(["-m", "repro.launch.dryrun", "--arch", arch,
                "--shape", "train_4k", "--variant", "matrix"], timeout=1800)
    assert out.returncode == 0, (
        f"{arch}/train_4k dryrun failed:\n{out.stdout[-2000:]}\n"
        f"{out.stderr[-2000:]}")
    assert "ALL CELLS OK" in out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("quant", ["int8", "fp8"])
def test_dryrun_compile_smoke_quantized_cells(quant):
    """Quantized-spec cells of the compile matrix: both qstate modes
    lower + compile on the production mesh (scheduled CI job)."""
    out = _run(["-m", "repro.launch.dryrun", "--arch", "transformer_base",
                "--shape", "train_4k", "--quant", quant,
                "--variant", "matrix"], timeout=1800)
    assert out.returncode == 0, (
        f"transformer_base/train_4k quant={quant} dryrun failed:\n"
        f"{out.stdout[-2000:]}\n{out.stderr[-2000:]}")
    assert "ALL CELLS OK" in out.stdout