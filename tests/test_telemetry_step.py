"""In-jit telemetry: bitwise neutrality, counter coverage, divergence flag.

* **bitwise identity** (the acceptance criterion): ``telemetry=True``
  must not change a single bit of the train step's params/opt-state/loss
  outputs — checked for factored f32, quantized int8 + rank-1 transport,
  and the overlapped (``schedule="grad"``) step on the transformer_base
  smoke;
* ``telemetry`` is an execution-only knob: flipping it leaves the
  ``spec_hash`` (checkpoint key) unchanged;
* **coverage**: the maximally instrumented spec emits every counter
  family — per-bucket update RMS, per-slot clip saturation and requant
  error, per-bucket transport round-trip error, the rank-1 flush
  indicator, and the NaN-guard trip;
* the NaN-guard trip rides out as 1.0 exactly when the in-jit guard
  rejects a non-finite loss (params held bitwise);
* **divergence signature regression** (the PR 5 failure mode): int8
  companding stripped from the second-moment denominators (monkeypatched
  ``repro.optim.qstate._companded``) blows up the transformer_base smoke
  within a few steps — and the ``qstate/requant_err`` telemetry flags it
  at step 0, strictly before the loss moves, while the companded
  baseline's counters stay at their noise floor.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.optim.qstate as qstate
from repro.configs import smoke_config
from repro.data import SyntheticLMStream
from repro.launch.steps import make_train_step
from repro.models import init_encdec, init_lm
from repro.optim import OptimizerSpec, build_optimizer


def _setup(hp=None, batch=2, seq=16):
    cfg = smoke_config("transformer_base")
    spec = OptimizerSpec(
        family="smmf",
        hyperparams={"lr": 1e-3, "decay_rate": -0.8, **(hp or {})})
    init = init_encdec if cfg.family == "encdec" else init_lm
    params = init(jax.random.PRNGKey(0), cfg)
    opt = build_optimizer(spec, params)
    stream = SyntheticLMStream(cfg, batch, seq, seed=0)
    return cfg, opt, params, opt.init(params), stream


# ---------------------------------------------------------------------------
# bitwise neutrality + hash neutrality
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hp,kw", [
    ({}, {}),                                            # factored f32
    ({"quant": "int8", "transport": "rank1"}, {}),       # full numerics stack
    ({"quant": "int8"}, {"overlap": True}),              # scheduled step
], ids=["f32", "int8+rank1", "int8+overlap"])
def test_telemetry_bitwise_identity(hp, kw):
    """telemetry=True adds outputs but changes none: params, opt state and
    the base metrics are bit-identical to the telemetry-off step."""
    cfg, opt, params, state, stream = _setup(hp)
    batch = stream.batch(0)
    off = jax.jit(make_train_step(cfg, opt, telemetry=False, **kw))(
        params, state, batch)
    on = jax.jit(make_train_step(cfg, opt, telemetry=True, **kw))(
        params, state, batch)
    assert "telemetry" not in off[2]
    tel = on[2].pop("telemetry")
    assert len(tel) > 0
    for a, b in zip(jax.tree.leaves(off), jax.tree.leaves(on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_telemetry_knob_is_spec_hash_neutral():
    base = OptimizerSpec(family="smmf",
                         hyperparams={"lr": 1e-3, "decay_rate": -0.8})
    for flag in (True, False):
        spec = OptimizerSpec(
            family="smmf",
            hyperparams={"lr": 1e-3, "decay_rate": -0.8, "telemetry": flag})
        assert spec.spec_hash() == base.spec_hash()


# ---------------------------------------------------------------------------
# counter coverage
# ---------------------------------------------------------------------------


def test_telemetry_counter_families_present():
    cfg, opt, params, state, stream = _setup(
        {"quant": "int8", "transport": "rank1"})
    step = jax.jit(make_train_step(cfg, opt, telemetry=True))
    _, _, metrics = step(params, state, stream.batch(0))
    tel = jax.device_get(metrics["telemetry"])
    prefixes = ("optim/update_rms/", "qstate/clip_sat/",
                "qstate/requant_err/", "transport/rt_err/")
    for p in prefixes:
        assert any(k.startswith(p) for k in tel), f"no {p} counter emitted"
    assert "transport/flush" in tel
    assert tel["train/nan_guard_trip"] == 0.0
    assert all(np.isfinite(v) for v in tel.values())


def test_nan_guard_trip_counter():
    """A non-finite loss trips the in-jit guard: params/state held bitwise
    and the telemetry trip indicator reads exactly 1.0."""
    cfg, opt, params, state, stream = _setup({"quant": "int8"})
    leaves, treedef = jax.tree.flatten(params)
    leaves[0] = jnp.full_like(leaves[0], jnp.nan)   # poison the first leaf
    bad_params = jax.tree.unflatten(treedef, leaves)
    step = jax.jit(make_train_step(cfg, opt, telemetry=True))
    state = opt.init(bad_params)
    p2, s2, metrics = step(bad_params, state, stream.batch(0))
    tel = jax.device_get(metrics["telemetry"])
    assert not np.isfinite(jax.device_get(metrics["loss"]))
    assert tel["train/nan_guard_trip"] == 1.0
    for a, b in zip(jax.tree.leaves(bad_params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# divergence signature (PR 5 regression)
# ---------------------------------------------------------------------------


def _run_traj(companded: bool, n: int = 8):
    """(loss, max requant_err) per step, optionally with int8 companding
    stripped from the quantized denominator slots (the PR 5 bug)."""
    orig = qstate._companded
    if not companded:
        qstate._companded = lambda slot, mode: False
    try:
        cfg, opt, params, state, stream = _setup({"quant": "int8"},
                                                 batch=2, seq=16)
        step = jax.jit(make_train_step(cfg, opt, telemetry=True))
        traj = []
        for i in range(n):
            params, state, m = step(params, state, stream.batch(i))
            tel = jax.device_get(m["telemetry"])
            rq = max(v for k, v in tel.items()
                     if k.startswith("qstate/requant_err/"))
            traj.append((float(jax.device_get(m["loss"])), float(rq)))
        return traj
    finally:
        qstate._companded = orig


def test_linear_int8_divergence_flagged_by_requant_counter():
    good = _run_traj(companded=True)
    bad = _run_traj(companded=False)

    # the companded baseline is healthy: finite, no blow-up
    assert all(np.isfinite(l) for l, _ in good)
    assert max(l for l, _ in good) < 2 * good[0][0]

    # linear int8 on the denominators diverges within the window ...
    l0 = bad[0][0]
    diverged = [i for i, (l, _) in enumerate(bad)
                if not np.isfinite(l) or l > 10 * l0]
    assert diverged, "linear-int8 run did not diverge — signature gone"
    first_bad_loss = diverged[0]
    assert first_bad_loss >= 1, "loss diverged at step 0 — counter can't lead"

    # ... and the requant-error counter flags it at step 0, strictly
    # before the loss moves: same step-0 loss, elevated reconstruction
    # error on the linearly-quantized denominator slots
    assert bad[0][0] == pytest.approx(good[0][0], rel=1e-3)
    assert bad[0][1] > 1.3 * good[0][1], (
        f"step-0 requant_err {bad[0][1]:.4f} not elevated over companded "
        f"baseline {good[0][1]:.4f} — the telemetry no longer leads the "
        f"divergence")
