"""Gradient-transport subsystem (repro.distributed.transport).

Property suite runs twice: deterministically over a fixed case grid
(always — the CI container may not have hypothesis), and fuzzed under
hypothesis when it is importable. Covers: per-bucket-row SR unbiasedness,
rank1 dense-residual-flush exactness at step k, sign-plane roundtrip,
blockwise sub-row scales, spec-level wiring (hash neutrality, zero added
state, per-group overrides, validation), pricing, and the deprecated
``compress.py`` shim's delegation. The 4-device sharded-vs-replicated
convergence parity lives in ``_transport_child.py`` (MeshHarness).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as Q
from repro.core.matricize import effective_shape
from repro.core.signpack import pack_signs, unpack_signs
from repro.distributed import rules
from repro.distributed import transport as T
from repro.optim.spec import OptimizerSpec, build_optimizer

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback only: the fuzz twins are
    HAVE_HYPOTHESIS = False  # skipped, but their decorators must import

    def given(**kw):
        return lambda fn: fn

    def settings(**kw):
        return lambda fn: fn

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")


# ---------------------------------------------------------------------------
# fixtures: a tiny engine with one factored bucket + one fused dense bucket
# ---------------------------------------------------------------------------

PARAMS = {
    "wq": jnp.ones((24, 48)), "wk": jnp.ones((24, 48)),
    "b1": jnp.zeros((48,)), "b2": jnp.zeros((48,)), "s": jnp.ones(()),
}


def _spec(**hp):
    # vector_reshape=False keeps the biases dense, so the engine has a
    # genuine multi-leaf fused flat bucket (b1+b2+s -> one 97-wide row:
    # segment int8 scales, a prime-width rank1 matricization)
    return OptimizerSpec(family="smmf", hyperparams={
        "lr": 1e-2, "decay_rate": -0.8, "vector_reshape": False, **hp})


def _engine(**hp):
    return build_optimizer(_spec(**hp)).plan(PARAMS)


def _rand_gm(bucket, seed):
    rng = np.random.default_rng(seed)
    shape = (bucket.stack, *bucket.geometry) if not bucket.fused \
        else (1, sum(p.numel for p in bucket.plans))
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# mode validation
# ---------------------------------------------------------------------------

def test_check_mode_normalizes_and_rejects():
    assert T.check_mode(None) is None
    assert T.check_mode("none") is None
    assert T.check_mode("int8") == "int8"
    assert T.check_mode("rank1") == "rank1"
    with pytest.raises(ValueError, match="unknown transport mode"):
        T.check_mode("fp8")


def test_check_flush_every_rejects_nonpositive_and_nonint():
    assert T.check_flush_every(1) == 1
    for bad in (0, -3, 2.5, "8", True):
        with pytest.raises(ValueError, match="transport_flush_every"):
            T.check_flush_every(bad)


def test_spec_validation_rejects_bad_transport():
    with pytest.raises(ValueError, match="unknown transport mode"):
        build_optimizer(_spec(transport="bogus"))
    with pytest.raises(ValueError, match="transport_flush_every"):
        build_optimizer(_spec(transport="rank1", transport_flush_every=0))
    with pytest.raises(ValueError, match="unknown hyperparams"):
        build_optimizer(OptimizerSpec(family="smmf",
                                      hyperparams={"transprot": "int8"}))


# ---------------------------------------------------------------------------
# property: int8 SR unbiasedness per bucket-row
# ---------------------------------------------------------------------------

def _check_sr_unbiased(bucket, seed, draws=192):
    gm = _rand_gm(bucket, seed)
    outs = jnp.stack([T.compress_bucket("int8", bucket, gm, jnp.int32(s))
                      for s in range(draws)])
    # per-row absmax scale bounds a single draw's error by one code and
    # the mean's deviation by ~ scale / sqrt(draws)
    scale = float(jnp.max(jnp.abs(gm))) / 127.0
    bias = float(jnp.max(jnp.abs(outs.mean(0) - gm)))
    assert bias <= 5.0 * scale / np.sqrt(draws), (bias, scale)
    # and any single draw never strays more than one code
    worst = float(jnp.max(jnp.abs(outs[0] - gm)))
    assert worst <= scale * 1.0001, (worst, scale)


@pytest.mark.parametrize("which,seed", [(0, 0), (0, 3), (1, 1)])
def test_int8_sr_unbiased_per_bucket_row(which, seed):
    eng = _engine(transport="int8")
    bucket = [b for b in eng.buckets if b.factorized][0] if which == 0 \
        else [b for b in eng.buckets if b.fused][0]
    _check_sr_unbiased(bucket, seed)


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), which=st.integers(0, 1))
def test_int8_sr_unbiased_fuzz(seed, which):
    eng = _engine(transport="int8")
    bucket = [b for b in eng.buckets if b.factorized][0] if which == 0 \
        else [b for b in eng.buckets if b.fused][0]
    _check_sr_unbiased(bucket, seed, draws=96)


# ---------------------------------------------------------------------------
# property: rank1 residual flush is exact at step k, approximate elsewhere
# ---------------------------------------------------------------------------

def _check_flush_exact(bucket, seed, k):
    gm = _rand_gm(bucket, seed)
    for mult in (1, 2, 5):
        out = T.compress_bucket("rank1", bucket, gm, jnp.int32(mult * k), k)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(gm),
                                      err_msg=f"flush step {mult * k}")
    if k == 1:
        return  # every step flushes: the wire is always dense-exact
    # a non-flush step of iid noise is genuinely rank-1-approximated
    out = T.compress_bucket("rank1", bucket, gm, jnp.int32(k + 1), k)
    assert float(jnp.max(jnp.abs(out - gm))) > 0.0
    # but the sign plane is carried losslessly (zero counts as +)
    assert bool(jnp.all(jnp.sign(out) * jnp.sign(gm) >= 0.0))


@pytest.mark.parametrize("which,seed,k", [(0, 0, 4), (0, 2, 1), (1, 1, 8)])
def test_rank1_flush_exact_at_step_k(which, seed, k):
    eng = _engine(transport="rank1")
    bucket = [b for b in eng.buckets if b.factorized][0] if which == 0 \
        else [b for b in eng.buckets if b.fused][0]
    _check_flush_exact(bucket, seed, k)


@needs_hypothesis
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 16),
       which=st.integers(0, 1))
def test_rank1_flush_exact_fuzz(seed, k, which):
    eng = _engine(transport="rank1")
    bucket = [b for b in eng.buckets if b.factorized][0] if which == 0 \
        else [b for b in eng.buckets if b.fused][0]
    _check_flush_exact(bucket, seed, k)


def test_rank1_reconstructs_exact_rank1_between_flushes():
    """A gradient that IS sign*rank-1 survives the wire almost exactly
    (only sketch int8 SR noise — bounded by the blockwise scales)."""
    eng = _engine(transport="rank1")
    bucket = [b for b in eng.buckets if b.factorized][0]
    n, m = effective_shape(bucket.plans[0].numel)
    rng = np.random.default_rng(0)
    r = jnp.asarray(np.abs(rng.standard_normal((bucket.stack, n, 1))) + 0.1)
    c = jnp.asarray(np.abs(rng.standard_normal((bucket.stack, 1, m))) + 0.1)
    sgn = jnp.asarray(np.where(rng.random((bucket.stack, n, m)) < 0.5, -1, 1))
    gm = (r * c * sgn).astype(jnp.float32).reshape(
        bucket.stack, *bucket.geometry)
    out = T.compress_bucket("rank1", bucket, gm, jnp.int32(3), 8)
    # int8 sketches: ~1/127 relative error per factor
    np.testing.assert_allclose(np.asarray(out), np.asarray(gm),
                               rtol=0.12, atol=0.06)


# ---------------------------------------------------------------------------
# property: sign-plane roundtrip
# ---------------------------------------------------------------------------

def _check_sign_roundtrip(arr):
    nonneg = arr >= 0
    signs = unpack_signs(pack_signs(nonneg), arr.shape[1])
    expect = np.where(np.asarray(nonneg), 1.0, -1.0)
    np.testing.assert_array_equal(np.asarray(signs), expect)


@pytest.mark.parametrize("shape,seed", [((3, 8), 0), ((5, 7), 1),
                                        ((1, 1), 2), ((4, 17), 3)])
def test_sign_plane_roundtrip(shape, seed):
    rng = np.random.default_rng(seed)
    _check_sign_roundtrip(jnp.asarray(rng.standard_normal(shape)))


@needs_hypothesis
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 9), m=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1))
def test_sign_plane_roundtrip_fuzz(n, m, seed):
    rng = np.random.default_rng(seed)
    _check_sign_roundtrip(jnp.asarray(rng.standard_normal((n, m))))


# ---------------------------------------------------------------------------
# blockwise sub-row scales (core/quant.py)
# ---------------------------------------------------------------------------

def _check_block_scale(x, block):
    scale = Q.block_scale(x, block, "int8")
    assert scale.shape == (*x.shape[:-1], Q.block_count(x.shape[-1], block))
    row = Q.block_expand(scale, block, x.shape[-1])
    assert row.shape == x.shape
    deq = Q.dequantize(Q.quantize(x, row, "int8"), row)
    # round-to-nearest error bounded by half a code of the LOCAL block
    err = np.abs(np.asarray(deq) - np.asarray(x))
    bound = 0.5 * np.asarray(row) * 1.0001 + 1e-12
    assert (err <= bound).all(), float((err - bound).max())


@pytest.mark.parametrize("length,block,seed", [
    (10, 4, 0), (256, 256, 1), (300, 256, 2), (1, 8, 3), (512, 16, 4)])
def test_block_scale_quantize_roundtrip(length, block, seed):
    rng = np.random.default_rng(seed)
    _check_block_scale(jnp.asarray(rng.standard_normal((3, length)),
                                   jnp.float32), block)


def test_block_scale_localizes_outliers():
    """One huge element must not wreck quantization of far-away blocks."""
    x = jnp.ones((1, 512)) * 0.01
    x = x.at[0, 0].set(1000.0)
    row = Q.block_expand(Q.block_scale(x, 64, "int8"), 64, 512)
    deq = Q.dequantize(Q.quantize(x, row, "int8"), row)
    # blocks beyond the first see only the 0.01s: relative error < 1%
    np.testing.assert_allclose(np.asarray(deq[0, 64:]), 0.01, rtol=0.01)
    # one row-wide scale would have flattened them to zero
    flat = Q.row_scale(x, "int8")
    deq_flat = Q.dequantize(Q.quantize(x, flat, "int8"), flat)
    assert float(jnp.max(jnp.abs(deq_flat[0, 64:]))) == 0.0


def test_block_scale_validation():
    with pytest.raises(ValueError, match="block must be >= 1"):
        Q.block_count(16, 0)
    with pytest.raises(ValueError, match="scale last axis"):
        Q.block_expand(jnp.ones((2, 3)), 4, 100)


@needs_hypothesis
@settings(max_examples=20, deadline=None)
@given(length=st.integers(1, 600), block=st.integers(1, 300),
       seed=st.integers(0, 2**31 - 1))
def test_block_scale_roundtrip_fuzz(length, block, seed):
    rng = np.random.default_rng(seed)
    _check_block_scale(jnp.asarray(rng.standard_normal((2, length)),
                                   jnp.float32), block)


# ---------------------------------------------------------------------------
# determinism / seeding
# ---------------------------------------------------------------------------

def test_transport_bit_reproducible_and_step_dependent():
    eng = _engine(transport="int8")
    bucket = [b for b in eng.buckets if b.factorized][0]
    gm = _rand_gm(bucket, 0)
    for mode in ("int8", "rank1"):
        a = T.compress_bucket(mode, bucket, gm, jnp.int32(3), 4)
        b = T.compress_bucket(mode, bucket, gm, jnp.int32(3), 4)
        c = T.compress_bucket(mode, bucket, gm, jnp.int32(5), 4)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{mode} not reproducible")
        assert bool(jnp.any(a != c)), f"{mode} ignores the step seed"


def test_transport_key_distinct_from_qstate():
    from repro.optim import qstate
    eng = _engine()
    bucket = eng.buckets[0]
    tk = T.transport_key(jnp.int32(3), bucket)
    qk = qstate.update_key(jnp.int32(3), bucket)
    assert not bool(jnp.all(tk == qk))


# ---------------------------------------------------------------------------
# spec wiring: hash neutrality, zero added state, per-group overrides
# ---------------------------------------------------------------------------

def test_spec_hash_untouched_by_transport():
    base = _spec().spec_hash()
    assert _spec(transport="int8").spec_hash() == base
    assert _spec(transport="rank1", transport_flush_every=3).spec_hash() == base


def test_transport_adds_zero_state():
    """Structural EF-free acceptance: the optimizer state under transport
    is shape-identical to the dense-transport state — no residual, no EF
    buffer, nothing full-size beyond what the family itself stores."""
    for mode in ("int8", "rank1"):
        a = jax.eval_shape(build_optimizer(_spec()).init, PARAMS)
        b = jax.eval_shape(build_optimizer(_spec(transport=mode)).init, PARAMS)
        assert jax.tree.structure(a) == jax.tree.structure(b)
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert (x.shape, x.dtype) == (y.shape, y.dtype)


def test_transport_buckets_stat_and_plan_fields():
    eng = _engine(transport="rank1", transport_flush_every=5)
    st_ = eng.stats()
    assert st_["transport_buckets"] == st_["buckets"] > 0
    for bk in eng.buckets:
        assert bk.transport == "rank1"
        assert bk.transport_flush_every == 5
    assert _engine().stats()["transport_buckets"] == 0


def test_per_group_transport_override_via_rule():
    spec = _spec().with_rule("b=adam,transport=int8")
    opt = build_optimizer(spec)
    eng = opt.plan(PARAMS)
    by_group = {bk.plans[0].group: bk.transport for bk in eng.buckets}
    assert by_group["adam0"] == "int8"  # auto-named first rule group
    assert by_group[""] is None
    # and the override group actually trains
    g = jax.tree.map(jnp.ones_like, PARAMS)
    st_ = opt.init(PARAMS)
    u, _ = opt.update(g, st_, PARAMS)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(u))


def test_transport_composes_with_quant_and_overlap():
    spec = _spec(transport="rank1", quant="int8")
    opt = build_optimizer(spec)
    st_ = opt.init(PARAMS)
    g = jax.tree.map(jnp.ones_like, PARAMS)
    u1, s1 = opt.update(g, st_, PARAMS)
    u2, s2 = opt.update(g, st_, PARAMS, schedule="grad")
    for a, b in zip(jax.tree.leaves(u1), jax.tree.leaves(u2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_update_differs_from_dense_transport_on_generic_grads():
    """Transport must actually round-trip the gradient (a no-op wire would
    pass every parity test vacuously)."""
    rng = np.random.default_rng(0)
    g = {k: jnp.asarray(rng.standard_normal(v.shape), jnp.float32)
         for k, v in PARAMS.items()}
    u0, _ = build_optimizer(_spec()).update(
        g, build_optimizer(_spec()).init(PARAMS), PARAMS)
    for mode in ("int8", "rank1"):
        opt = build_optimizer(_spec(transport=mode))
        u, _ = opt.update(g, opt.init(PARAMS), PARAMS)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(u0), jax.tree.leaves(u)))
        assert diff > 0.0, f"{mode} transport was a no-op"


# ---------------------------------------------------------------------------
# pricing
# ---------------------------------------------------------------------------

def test_bucket_grad_bytes_formulas():
    eng = _engine()
    for bk in eng.buckets:
        numel = sum(p.numel for p in bk.plans)
        assert T.bucket_grad_bytes(bk, None) == 4 * numel
        nscales = bk.size if (bk.fused and bk.size > 1) else bk.stack
        assert T.bucket_grad_bytes(bk, "int8") == numel + 4 * nscales
        n, m = effective_shape(numel if bk.fused else bk.plans[0].numel)
        from repro.core.signpack import packed_width
        sketch = bk.stack * (n + m) + 4 * bk.stack * (
            Q.block_count(n, T.SKETCH_BLOCK) + Q.block_count(m, T.SKETCH_BLOCK))
        sign = bk.stack * n * packed_width(m)
        k = 8
        expect = (4 * numel + (k - 1) * (sketch + sign)) // k
        assert T.bucket_grad_bytes(bk, "rank1", k) == expect


def test_boundary_transport_bytes_prices_all_three_modes():
    eng = _engine(transport="rank1")
    out = rules.boundary_transport_bytes(eng, {"data": 4})
    grad = out["grad"]
    assert set(grad["by_mode"]) == {"none", "int8", "rank1"}
    dense = grad["by_mode"]["none"]
    assert grad["by_mode"]["rank1"] < grad["by_mode"]["int8"] < dense
    # planned mode = rank1 everywhere -> actual equals the rank1 column
    assert grad["total"] == grad["by_mode"]["rank1"]
    assert sum(grad["by_group"].values()) == grad["total"]
    # the acceptance ratio, on the test engine too
    assert grad["by_mode"]["rank1"] <= 0.35 * dense
    assert grad["by_mode"]["int8"] <= 0.30 * dense


def test_grad_bytes_decrease_with_flush_period():
    eng = _engine()
    bk = [b for b in eng.buckets if b.factorized][0]
    b1 = T.bucket_grad_bytes(bk, "rank1", 1)
    b4 = T.bucket_grad_bytes(bk, "rank1", 4)
    b16 = T.bucket_grad_bytes(bk, "rank1", 16)
    assert b1 == 4 * sum(p.numel for p in bk.plans)  # k=1: always dense
    assert b16 < b4 < b1


# ---------------------------------------------------------------------------
# multi-device: sharded-vs-replicated convergence parity (emulated mesh)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_transport_sharded_parity(emulated_mesh):
    out = emulated_mesh.run("_transport_child.py", devices=4)
    assert out.returncode == 0, f"{out.stdout}\n{out.stderr}"
    assert "TRANSPORT PARITY OK int8" in out.stdout
    assert "TRANSPORT PARITY OK rank1" in out.stdout


# ---------------------------------------------------------------------------
# deprecated compress.py shim
# ---------------------------------------------------------------------------

def test_compress_shim_warns_and_delegates():
    from repro.distributed.compress import int8_compress
    from repro.optim import adam

    with pytest.warns(DeprecationWarning,
                      match="is deprecated. build via repro.optim.spec"):
        with pytest.warns(DeprecationWarning,
                          match="repro.distributed.transport"):
            opt = int8_compress(adam(1e-2))
    p = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                          jnp.float32)}
    s = opt.init(p)
    # state = (count, inner): no EF tree, nothing param-shaped outside adam
    assert not hasattr(s, "ef")
    g = jax.tree.map(jnp.ones_like, p)
    u, s2 = opt.update(g, s, p)
    assert int(s2.count) == 1
    assert np.isfinite(np.asarray(u["w"])).all()
