"""The optimizer zoo: adapprox (rank-k), hfac, and the AdaPM recipe.

Three additions that ride the existing leaf-plan engine unchanged:
``adapprox`` (rank-k second-moment factors + full-size momentum on the
square-matricized plan), ``hfac`` (factor-level EMAs, additive momentum
fit, no sign matrix), and AdaPM-style partial momentum — which is not a
family at all but one ``beta1=None`` partition rule on ``smmf``
(``examples/adapm_recipe.py``). Covered: registry + validation, state
layout, descent on a toy objective, quantized state, checkpointing, and
mesh sharding of the new rank-k slot shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh

from conftest import spec_opt
from repro.optim import OptimizerSpec, Partition, build_optimizer
from repro.optim.base import apply_updates
from repro.optim.families import get_family
from repro.optim.qstate import QTensor


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w1": jnp.asarray(rng.standard_normal((48, 96)), jnp.float32),
            "w2": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((96,)) * 1e-3, jnp.float32)}


def _quadratic_descent(opt, steps=50, seed=0):
    rng = np.random.default_rng(seed)
    tgt = jax.tree.map(
        lambda x: jnp.asarray(rng.standard_normal(x.shape), jnp.float32),
        _params())

    def loss_fn(p):
        return sum(jnp.mean((p[k] - tgt[k]) ** 2) for k in p)

    params = jax.tree.map(jnp.zeros_like, tgt)
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        l, g = jax.value_and_grad(loss_fn)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    first = None
    for _ in range(steps):
        params, state, l = step(params, state)
        first = float(l) if first is None else first
    return first, float(l), state


# ---------------------------------------------------------------------------
# registry + validation
# ---------------------------------------------------------------------------

def test_zoo_families_registered():
    for name in ("adapprox", "hfac"):
        fam = get_family(name)
        assert fam.name == name and fam.quant_slots is not None


@pytest.mark.parametrize("bad", [0, -1, 1.5, True, "2"])
def test_adapprox_rank_validation(bad):
    with pytest.raises(ValueError, match="rank"):
        build_optimizer(OptimizerSpec(
            family="adapprox", hyperparams={"lr": 1e-3, "rank": bad}))


def test_hfac_validation():
    with pytest.raises(ValueError, match="beta1"):
        build_optimizer(OptimizerSpec(
            family="hfac", hyperparams={"lr": 1e-3, "beta1": 1.5}))


# ---------------------------------------------------------------------------
# state layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rank", [1, 2, 4])
def test_adapprox_state_shapes(rank):
    opt = spec_opt("adapprox", 1e-3, rank=rank)
    state = jax.eval_shape(opt.init, _params())
    fac_slots = [s for bkstate in state.factors.values() for s in bkstate
                 if len(s.shape) == 3 and s.shape[-1] == rank]
    # both matrices factorize; R_v and C_v carry the trailing rank axis
    assert len(fac_slots) >= 2
    full_m = [s for bkstate in state.factors.values() for s in bkstate
              if len(s.shape) == 3 and s.shape[-1] != rank]
    assert full_m, "full-size momentum slot missing"


def test_adapprox_momentum_free_drops_full_slot():
    opt = spec_opt("adapprox", 1e-3, rank=2, beta1=None)
    state = jax.eval_shape(opt.init, _params())
    for bkstate in state.factors.values():
        for s in bkstate:
            if len(s.shape) == 3:
                assert s.shape[-1] == 2, s.shape  # factors only


def test_hfac_state_is_four_factor_vectors():
    opt = spec_opt("hfac", 1e-3)
    state = jax.eval_shape(opt.init, _params())
    for key, bkstate in state.factors.items():
        if key.startswith("fac:"):
            assert len(bkstate) == 4
            assert all(len(s.shape) == 2 for s in bkstate), key


# ---------------------------------------------------------------------------
# descent + quantized state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fam,hp", [
    ("adapprox", {"rank": 1}),
    ("adapprox", {"rank": 2}),
    ("hfac", {}),
], ids=["adapprox_r1", "adapprox_r2", "hfac"])
def test_zoo_descends_on_quadratic(fam, hp):
    first, last, _ = _quadratic_descent(spec_opt(fam, 1e-2, **hp))
    assert np.isfinite(last) and last < 0.5 * first, (first, last)


@pytest.mark.parametrize("fam,hp", [
    ("adapprox", {"rank": 2}),
    ("hfac", {}),
], ids=["adapprox", "hfac"])
def test_zoo_quantized_state_runs_and_stores_qtensors(fam, hp):
    first, last, state = _quadratic_descent(
        spec_opt(fam, 1e-2, quant="int8", **hp))
    assert np.isfinite(last) and last < first
    qts = [s for bkstate in state.factors.values() for s in bkstate
           if isinstance(s, QTensor)]
    assert qts, "no quantized slots in state"
    assert all(q.q.dtype.itemsize == 1 for q in qts)


def test_adapm_recipe_partition_drops_momentum_slots():
    """The shipped AdaPM recipe layout: the matched group holds the
    momentum-free 2-slot state, the rest the full 5-slot state."""
    opt = build_optimizer(OptimizerSpec(
        family="smmf", hyperparams={"lr": 1e-3},
        partitions=(Partition(name="nomom", match=r"^w",
                              hyperparams={"beta1": None}),)))
    state = jax.eval_shape(opt.init, _params())
    by_group = {k: len(v) for k, v in state.factors.items()
                if "fac:" in k}
    nomom = {k: n for k, n in by_group.items() if k.startswith("nomom")}
    assert nomom and all(n == 2 for n in nomom.values()), by_group


# ---------------------------------------------------------------------------
# checkpointing + sharding
# ---------------------------------------------------------------------------

def test_zoo_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import ckpt

    spec = OptimizerSpec(family="adapprox",
                         hyperparams={"lr": 1e-3, "rank": 2, "quant": "int8"})
    opt = build_optimizer(spec)
    _, _, state = _quadratic_descent(opt, steps=3)
    ckpt.save(tmp_path, 3, state, spec_hash=spec.spec_hash())
    restored, manifest = ckpt.restore(tmp_path, jax.eval_shape(lambda: state),
                                      spec_hash=spec.spec_hash())
    assert manifest["spec_hash"] == spec.spec_hash()
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_array_equal(
            a.view(np.uint8) if a.dtype.itemsize == 1 else a,
            b.view(np.uint8) if b.dtype.itemsize == 1 else b)


@pytest.mark.parametrize("fam,hp", [
    ("adapprox", {"rank": 2}),
    ("adapprox", {"rank": 2, "quant": "int8"}),
    ("hfac", {}),
    ("hfac", {"quant": "int8"}),
], ids=["adapprox", "adapprox_int8", "hfac", "hfac_int8"])
def test_zoo_state_shardings_legal(fam, hp):
    """Every zoo state leaf — including the 3-D rank-k factor slots and
    their per-column scale rows — gets a legal mesh placement."""
    from repro.configs import get_config
    from repro.distributed import rules
    from repro.launch import specs as S

    mesh = AbstractMesh((("data", 16), ("model", 16)))
    cfg = get_config("transformer_base")
    psds = S.params_specs(cfg)
    opt = spec_opt(fam, 1e-3, **hp)
    sh = rules.opt_state_shardings(mesh, cfg, psds, opt)
    state_sds = jax.eval_shape(opt.init, psds)
    n_sharded = 0
    for leaf, s in zip(jax.tree.leaves(state_sds), jax.tree.leaves(sh)):
        for dim, want in zip(leaf.shape, tuple(s.spec) + (None,) * 8):
            if want is None:
                continue
            n_sharded += 1
            assert dim % rules._axsize(mesh, want) == 0, (leaf.shape, s.spec)
    assert n_sharded > 0  # the factored slots actually shard
