"""Bench regression gate: candidate BENCH_*.json vs committed baselines.

    PYTHONPATH=src python tools/bench_compare.py \
        --baseline-dir . --candidate-dir results/bench

The repo root carries the committed perf-trajectory snapshots
(``BENCH_step_time.json``, ``BENCH_opt_memory.json``,
``BENCH_transport.json``, ``BENCH_serve.json``,
``BENCH_telemetry.json``); ``benchmarks/run.py``
writes fresh ones under ``results/bench/``. This tool fails (exit 1, one
line per violation) when the candidate regresses:

* **bytes** (deterministic spec math — tight tolerance
  :data:`BYTES_TOL`): per-arch state bytes per optimizer family, the
  qstate per-device grid, the offload device/host split, and the
  boundary-transport pricing must not grow;
* **step time** (noisy CPU wall-clock — generous ratio tolerance
  :data:`TIME_TOL`): each optimizer's ms/step must stay within the
  multiplier of its committed baseline;
* **hard invariants on the candidate alone** (no baseline needed):
  overlap-on step time <= overlap-off within :data:`OVERLAP_TOL` at equal
  memory (the interleaved schedule must never cost wall-clock), offload-on
  per-device device-resident bytes strictly below the device-resident
  qstate baseline (the tier's acceptance criterion), the paged serving
  engine (``BENCH_serve.json``) at least :data:`SERVE_SPEEDUP_MIN` x the
  legacy slot-batcher's tokens/s on the same trace — both engines run in
  the same process, so the ratio needs no baseline — and the gradient
  transport record (``BENCH_transport.json``): rank1/int8 boundary bytes
  within :data:`TRANSPORT_RANK1_MAX` / :data:`TRANSPORT_INT8_MAX` of
  dense f32 and compressed-vs-dense convergence parity within
  :data:`TRANSPORT_PARITY_TOL` (seeded smoke, machine-independent), and
  the telemetry record (``BENCH_telemetry.json``): the ``--telemetry``
  in-jit collector must hold the full train step within
  :data:`TELEMETRY_OVERHEAD_MAX` of the telemetry-off step (off/on
  measured interleaved in one process, so no baseline is needed);
* **serving trajectory** vs baseline: legacy-normalized tokens/s and p99
  per-token latency ratios within :data:`TIME_TOL`.

Timing rows compare as ratios so a uniformly slower CI machine passes;
only a *relative* regression of one variant trips the gate. Bytes rows
are analytic and must be reproducible to the tolerance on any machine.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

# analytic byte numbers must reproduce; 2% headroom for benign layout
# drift (e.g. a new tiny leaf in a measured tree)
BYTES_TOL = 0.02
# wall-clock per-optimizer multiplier vs baseline (CPU CI noise is real;
# the ratio normalization below absorbs uniform machine-speed shifts)
TIME_TOL = 1.75
# overlap-on vs overlap-off, same run, same machine: near-equal is the
# claim (on CPU the schedule is a pure reordering), so the tolerance only
# absorbs timer noise
OVERLAP_TOL = 0.25
# paged serving vs the seed slot-batcher on the same trace, same machine:
# the continuous-batching engine must clear this throughput multiple (the
# PR's acceptance criterion — a hard invariant on the candidate alone)
SERVE_SPEEDUP_MIN = 2.0
# gradient transport (BENCH_transport.json) — hard invariants on the
# candidate alone: rank1/int8 gradient-boundary bytes as a fraction of
# dense f32, and compressed-vs-dense final-loss parity on the
# transformer_base smoke (the run is seeded + synthetic, so the losses
# are reproducible on a pinned jax version)
TRANSPORT_RANK1_MAX = 0.35
TRANSPORT_INT8_MAX = 0.30
TRANSPORT_PARITY_TOL = 0.005
# fully-quantized Adafactor/CAME (momentum slot on blockwise sub-row
# scales): int8 per-device bytes as a fraction of the family's f32 row —
# mirrors MOMENTUM_QUANT_ACCEPT_FRACTION in benchmarks/memory_table.py
MOMENTUM_QUANT_MAX = 0.30
# --telemetry in-jit counters: full-train-step time with the collector on
# vs off (BENCH_telemetry.json, same process, interleaved rounds) — the
# observability subsystem's acceptance budget, a hard invariant on the
# candidate alone
TELEMETRY_OVERHEAD_MAX = 1.10


def _load(d: Path, name: str) -> dict | None:
    p = d / name
    if not p.exists():
        return None
    return json.loads(p.read_text())


def _walk_bytes(base, cand, path, fails):
    """Recursively compare every *_bytes / 'total'-ish int under matching
    keys; candidate must not exceed baseline * (1 + BYTES_TOL)."""
    if isinstance(base, dict) and isinstance(cand, dict):
        for k in base:
            if k in cand:
                _walk_bytes(base[k], cand[k], f"{path}/{k}", fails)
        return
    if isinstance(base, list) and isinstance(cand, list):
        for i, (b, c) in enumerate(zip(base, cand)):
            _walk_bytes(b, c, f"{path}[{i}]", fails)
        return
    key = path.rsplit("/", 1)[-1].split("[")[0]
    # bytes leaves are *_bytes / total / per_device, plus two records whose
    # leaves are keyed by family/group NAME: the per-arch state-bytes table
    # (archs/<arch>/<family>) and the boundary pricing by group
    named_bytes = "/boundary_by_group/" in path or "/archs/" in path \
        or "/groups/" in path
    if not (key.endswith("bytes") or key in ("total", "per_device")
            or named_bytes):
        return
    if not isinstance(base, (int, float)) or not isinstance(cand, (int, float)):
        return
    if cand > base * (1 + BYTES_TOL) + 1:
        fails.append(f"bytes regression at {path}: {base} -> {cand} "
                     f"(+{(cand / base - 1):.1%} > {BYTES_TOL:.0%})")


def _check_times(base: dict, cand: dict, fails: list[str]) -> None:
    """Per-optimizer ms vs baseline, normalized by the adam row (absorbs a
    uniformly faster/slower machine), generous TIME_TOL on top."""
    b_opt, c_opt = base.get("optimizers", {}), cand.get("optimizers", {})
    b_ref = b_opt.get("adam", {}).get("ms")
    c_ref = c_opt.get("adam", {}).get("ms")
    if not b_ref or not c_ref:
        return
    for name, b in b_opt.items():
        c = c_opt.get(name)
        if c is None or name == "adam":
            continue
        b_ratio, c_ratio = b["ms"] / b_ref, c["ms"] / c_ref
        if c_ratio > b_ratio * TIME_TOL:
            fails.append(
                f"step-time regression for {name}: {c_ratio:.2f}x adam vs "
                f"baseline {b_ratio:.2f}x (tol {TIME_TOL}x)")


def _check_overlap_invariants(cand: dict, fails: list[str]) -> None:
    grid = cand.get("overlap_offload", {})
    base, over = grid.get("base"), grid.get("overlap")
    if base and over:
        # equal memory: the schedule knob moves no state
        if over["device_bytes"] != base["device_bytes"] or \
                over["host_bytes"] != base["host_bytes"]:
            fails.append("overlap row changed the state-byte split "
                         f"({base} vs {over}) — not an equal-memory compare")
        if over["ms"] > base["ms"] * (1 + OVERLAP_TOL):
            fails.append(
                f"overlap-on step time {over['ms']:.2f}ms exceeds "
                f"overlap-off {base['ms']:.2f}ms by more than "
                f"{OVERLAP_TOL:.0%}")
    off = grid.get("offload")
    if base and off:
        if not off["device_bytes"] < base["device_bytes"]:
            fails.append(
                f"offload-on device bytes {off['device_bytes']} not strictly "
                f"below device-resident baseline {base['device_bytes']}")
        if off["offload_transport_bytes"] != 2 * off["host_bytes"]:
            fails.append("offload transport pricing inconsistent with the "
                         "host split (expect 2x host bytes per step)")


def _check_offload_memory(cand: dict, fails: list[str]) -> None:
    dev_base: dict = {}
    for row in cand.get("offload", []):
        key = row["variant"]
        if row["offload"] == "none":
            dev_base[key] = row["per_device_device_bytes"]
        elif key in dev_base and \
                not row["per_device_device_bytes"] < dev_base[key]:
            fails.append(
                f"offload memory row {key}: device bytes "
                f"{row['per_device_device_bytes']} not strictly below "
                f"device-resident baseline {dev_base[key]}")


def _check_zoo_invariants(cand: dict, fails: list[str]) -> None:
    """Hard invariants on the candidate BENCH_opt_memory.json alone (the
    byte math is analytic, so no baseline is needed):

    * per arch, ``adapprox`` state bytes < ``adam`` (full momentum plus
      rank-k second-moment factors must beat two full moments) and
      ``hfac`` < ``adafactor`` (four factor vectors beat factored-v plus a
      full-size momentum slot);
    * in the qstate grid, the fully-quantized Adafactor/CAME rows (momentum
      slot on blockwise sub-row scales) hold <= MOMENTUM_QUANT_MAX of
      their f32 twins per device.
    """
    for arch, row in cand.get("archs", {}).items():
        for small, big in (("adapprox", "adam"), ("hfac", "adafactor")):
            if small in row and big in row and not row[small] < row[big]:
                fails.append(
                    f"zoo memory invariant at archs/{arch}: {small} "
                    f"{row[small]} not below {big} {row[big]}")
    f32 = {}
    for row in cand.get("qstate", []):
        if row["variant"] in ("adafactor", "came"):
            if row["quant"] == "f32":
                f32[row["variant"]] = row["per_device"]
            elif row["quant"] == "int8" and row["variant"] in f32:
                frac = row["per_device"] / f32[row["variant"]]
                if frac > MOMENTUM_QUANT_MAX:
                    fails.append(
                        f"momentum-quant invariant: {row['variant']} int8 "
                        f"per-device bytes are {frac:.1%} of f32 "
                        f"(max {MOMENTUM_QUANT_MAX:.0%})")


def _check_telemetry_invariants(cand: dict, fails: list[str]) -> None:
    """Hard budget on the candidate alone: the in-jit telemetry collector
    must hold the full train step within TELEMETRY_OVERHEAD_MAX of the
    telemetry-off step. Off/on run interleaved in one process, so the
    ratio is machine-independent; the record must also actually carry
    counters (events_per_step > 0), else the 'overhead' measured nothing."""
    ratio = cand.get("overhead_ratio")
    if ratio is not None and ratio > TELEMETRY_OVERHEAD_MAX:
        fails.append(
            f"telemetry overhead {ratio:.3f}x exceeds the "
            f"{TELEMETRY_OVERHEAD_MAX}x full-step budget")
    if not cand.get("events_per_step"):
        fails.append("telemetry record has events_per_step == 0 — the "
                     "instrumented spec emitted no in-jit counters")


def _check_serve_invariants(cand: dict, fails: list[str]) -> None:
    """Hard floor on the candidate alone: paged engine tokens/s must be at
    least SERVE_SPEEDUP_MIN x the legacy slot-batcher on the same trace.
    Both engines run in the same process on the same machine, so the ratio
    is machine-independent — no baseline needed."""
    leg = cand.get("legacy", {}).get("tokens_per_s")
    for variant in ("paged", "paged_kernel", "paged_kernel_int8"):
        row = cand.get(variant)
        if not leg or not row:
            continue
        speedup = row["tokens_per_s"] / leg
        if speedup < SERVE_SPEEDUP_MIN:
            fails.append(
                f"serving speedup for {variant}: {speedup:.2f}x legacy "
                f"tokens/s, below the {SERVE_SPEEDUP_MIN}x floor")


def _check_transport_invariants(cand: dict, fails: list[str]) -> None:
    """Hard floors on the candidate alone (analytic pricing + a seeded
    deterministic convergence smoke — no baseline or machine normalization
    needed)."""
    modes = cand.get("pricing", {}).get("modes", {})
    for mode, cap in (("rank1", TRANSPORT_RANK1_MAX),
                      ("int8", TRANSPORT_INT8_MAX)):
        row = modes.get(mode)
        if row and row["ratio_vs_dense"] > cap:
            fails.append(
                f"transport pricing for {mode}: "
                f"{row['ratio_vs_dense']:.1%} of dense gradient bytes, "
                f"above the {cap:.0%} ceiling")
    conv = cand.get("convergence")
    if conv is None:
        return  # --fast run: pricing-only record
    for mode in ("int8", "rank1"):
        row = conv.get(mode)
        if row and row["rel_vs_dense"] > TRANSPORT_PARITY_TOL:
            fails.append(
                f"transport convergence parity for {mode}: final loss "
                f"{row['rel_vs_dense']:.2%} off dense transport "
                f"(tol {TRANSPORT_PARITY_TOL:.1%})")


def _check_transport_baseline(base: dict, cand: dict, fails: list[str]) -> None:
    """Per-mode optimizer step time vs baseline, normalized by the dense
    row (same ratio scheme as _check_times)."""
    b_ms, c_ms = base.get("opt_ms", {}), cand.get("opt_ms", {})
    b_ref = b_ms.get("none", {}).get("ms")
    c_ref = c_ms.get("none", {}).get("ms")
    if not b_ref or not c_ref:
        return
    for mode, b in b_ms.items():
        c = c_ms.get(mode)
        if c is None or mode == "none":
            continue
        b_ratio, c_ratio = b["ms"] / b_ref, c["ms"] / c_ref
        if c_ratio > b_ratio * TIME_TOL:
            fails.append(
                f"transport step-time regression for {mode}: "
                f"{c_ratio:.2f}x dense vs baseline {b_ratio:.2f}x "
                f"(tol {TIME_TOL}x)")


def _check_serve_baseline(base: dict, cand: dict, fails: list[str]) -> None:
    """Candidate speedup ratios vs the committed baseline's, with the same
    generous multiplier as step times (both are legacy-normalized, so a
    uniformly slower machine cancels out)."""
    b_leg = base.get("legacy", {}).get("tokens_per_s")
    c_leg = cand.get("legacy", {}).get("tokens_per_s")
    if not b_leg or not c_leg:
        return
    for variant in ("paged", "paged_kernel", "paged_kernel_int8"):
        b, c = base.get(variant), cand.get(variant)
        if not b or not c:
            continue
        b_ratio = b["tokens_per_s"] / b_leg
        c_ratio = c["tokens_per_s"] / c_leg
        if c_ratio < b_ratio / TIME_TOL:
            fails.append(
                f"serving throughput regression for {variant}: "
                f"{c_ratio:.2f}x legacy vs baseline {b_ratio:.2f}x "
                f"(tol {TIME_TOL}x)")
        b_p99, c_p99 = b.get("p99_ms"), c.get("p99_ms")
        b_lp99, c_lp99 = base["legacy"].get("p99_ms"), cand["legacy"].get("p99_ms")
        if b_p99 and c_p99 and b_lp99 and c_lp99:
            if c_p99 / c_lp99 > (b_p99 / b_lp99) * TIME_TOL:
                fails.append(
                    f"serving p99 latency regression for {variant}: "
                    f"{c_p99 / c_lp99:.2f}x legacy vs baseline "
                    f"{b_p99 / b_lp99:.2f}x (tol {TIME_TOL}x)")


def compare(baseline_dir: Path, candidate_dir: Path) -> list[str]:
    fails: list[str] = []
    checked = 0
    for name in ("BENCH_step_time.json", "BENCH_opt_memory.json",
                 "BENCH_transport.json", "BENCH_serve.json",
                 "BENCH_telemetry.json"):
        base, cand = _load(baseline_dir, name), _load(candidate_dir, name)
        if cand is None:
            fails.append(f"candidate {candidate_dir / name} missing — did "
                         "benchmarks/run.py run?")
            continue
        if name == "BENCH_step_time.json":
            _check_overlap_invariants(cand, fails)
        elif name == "BENCH_opt_memory.json":
            _check_offload_memory(cand, fails)
            _check_zoo_invariants(cand, fails)
        elif name == "BENCH_transport.json":
            _check_transport_invariants(cand, fails)
        elif name == "BENCH_telemetry.json":
            # ratio-only record: the budget is absolute, so a baseline adds
            # nothing — invariant check regardless of one being present
            _check_telemetry_invariants(cand, fails)
            continue
        else:
            _check_serve_invariants(cand, fails)
        if base is None:
            print(f"[bench_compare] no baseline {baseline_dir / name}; "
                  "invariant checks only")
            continue
        checked += 1
        if name == "BENCH_serve.json":
            _check_serve_baseline(base, cand, fails)
            continue
        _walk_bytes(base, cand, name, fails)
        if name == "BENCH_step_time.json":
            _check_times(base, cand, fails)
        elif name == "BENCH_transport.json":
            _check_transport_baseline(base, cand, fails)
    if checked:
        print(f"[bench_compare] compared {checked} baseline record(s)")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--candidate-dir", default="results/bench",
                    help="directory holding the freshly measured BENCH_*.json")
    args = ap.parse_args(argv)
    fails = compare(Path(args.baseline_dir), Path(args.candidate_dir))
    for f in fails:
        print(f"[bench_compare] FAIL: {f}")
    if fails:
        return 1
    print("[bench_compare] OK: no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
